"""Layer-2 correctness: model block functions, top-k fusion, variant registry."""

import jax
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed):
    return np.random.default_rng(seed).standard_normal(shape).astype(np.float32)


def test_distance_block_l2_tuple_wrapped():
    x, y = rand((256, 64), 0), rand((256, 64), 1)
    (d,) = model.distance_block_l2(x, y)
    np.testing.assert_allclose(d, ref.pairwise_sq_l2(x, y), rtol=1e-5, atol=1e-4)


def test_distance_block_cosine_tuple_wrapped():
    x, y = rand((256, 64), 2), rand((256, 64), 3)
    (d,) = model.distance_block_cosine(x, y)
    np.testing.assert_allclose(d, ref.pairwise_cosine(x, y), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("metric", ["l2", "cosine"])
def test_knn_block_matches_argsort(metric):
    x, y = rand((256, 64), 4), rand((1024, 64), 5)
    k = 32
    if metric == "l2":
        vals, idx = model.knn_block_l2(x, y, k=k)
        full = np.asarray(ref.pairwise_sq_l2(x, y))
    else:
        vals, idx = model.knn_block_cosine(x, y, k=k)
        full = np.asarray(ref.pairwise_cosine(x, y))
    vals, idx = np.asarray(vals), np.asarray(idx)
    assert vals.shape == (256, k) and idx.shape == (256, k)
    assert idx.dtype == np.int32
    # Values must be the k smallest per row, ascending.
    want_vals = np.sort(full, axis=1)[:, :k]
    np.testing.assert_allclose(vals, want_vals, rtol=1e-4, atol=1e-4)
    assert (np.diff(vals, axis=1) >= -1e-6).all()
    # Indices must point at the values they claim.
    np.testing.assert_allclose(
        np.take_along_axis(full, idx, axis=1), vals, rtol=1e-5, atol=1e-5
    )


def test_knn_values_consistent_with_indices_under_ties():
    # All-equal rows: any index permutation is fine, values must all match.
    x = np.ones((256, 64), np.float32)
    y = np.ones((1024, 64), np.float32)
    vals, idx = model.knn_block_l2(x, y, k=8)
    np.testing.assert_allclose(np.asarray(vals), 0.0, atol=1e-4)
    assert ((np.asarray(idx) >= 0) & (np.asarray(idx) < 1024)).all()


def test_variants_registry_shapes():
    vs = model.variants()
    assert len(vs) >= 8
    for name, (fn, specs, meta) in vs.items():
        assert meta["kind"] in ("distance", "knn")
        assert [list(s.shape) for s in specs] == [
            [meta["m"], meta["d"]],
            [meta["n"], meta["d"]],
        ]
        if meta["kind"] == "knn":
            assert meta["k"] <= meta["n"]


@pytest.mark.parametrize(
    "name", ["dist_l2_m256_n256_d64", "knn_cos_m256_n1024_d128_k32"]
)
def test_variant_executes(name):
    fn, specs, meta = model.variants()[name]
    args = [rand(tuple(s.shape), i) for i, s in enumerate(specs)]
    out = fn(*args)
    if meta["kind"] == "distance":
        assert out[0].shape == (meta["m"], meta["n"])
    else:
        vals, idx = out
        assert vals.shape == (meta["m"], meta["k"])
        assert idx.shape == (meta["m"], meta["k"])
