"""AOT path: lowering produces loadable HLO text with the expected interface.

These tests exercise exactly what the Rust runtime consumes: HLO text with a
tuple-rooted ENTRY whose parameter shapes match the manifest.
"""

import json
import os
import subprocess
import sys

import pytest

from compile import aot, model


def test_lower_distance_variant_to_hlo_text():
    fn, specs, meta = model.variants()["dist_l2_m256_n256_d64"]
    text = aot.lower_variant(fn, specs)
    assert "ENTRY" in text
    assert "f32[256,64]" in text  # parameters
    assert "f32[256,256]" in text  # output tile
    # return_tuple=True: root must be a tuple for Rust's to_tuple().
    assert "tuple" in text


def test_lower_knn_variant_has_two_outputs():
    fn, specs, meta = model.variants()["knn_l2_m256_n1024_d64_k32"]
    text = aot.lower_variant(fn, specs)
    assert "ENTRY" in text
    assert "f32[256,32]" in text
    assert "s32[256,32]" in text


def test_hlo_text_has_no_mosaic_custom_call():
    # interpret=True must lower Pallas to plain HLO; a tpu_custom_call would
    # be unloadable on the CPU PJRT plugin.
    fn, specs, _ = model.variants()["dist_cos_m256_n256_d64"]
    text = aot.lower_variant(fn, specs)
    assert "tpu_custom_call" not in text
    assert "mosaic" not in text.lower()


def test_aot_main_writes_artifacts_and_manifest(tmp_path):
    out = tmp_path / "artifacts"
    env = dict(os.environ, PYTHONPATH=os.path.dirname(os.path.dirname(__file__)))
    subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(out),
            "--only",
            "dist_l2_m256_n256_d64",
        ],
        check=True,
        cwd=os.path.dirname(os.path.dirname(__file__)),
        env=env,
    )
    man = json.loads((out / "manifest.json").read_text())
    assert man["dist_l2_m256_n256_d64"]["metric"] == "l2"
    hlo = (out / "dist_l2_m256_n256_d64.hlo.txt").read_text()
    assert "ENTRY" in hlo


def test_manifest_merge_on_partial_rebuild(tmp_path):
    out = tmp_path / "artifacts"
    env = dict(os.environ, PYTHONPATH=os.path.dirname(os.path.dirname(__file__)))
    cwd = os.path.dirname(os.path.dirname(__file__))
    for only in ("dist_l2_m256_n256_d64", "dist_cos_m256_n256_d64"):
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--only", only],
            check=True,
            cwd=cwd,
            env=env,
        )
    man = json.loads((out / "manifest.json").read_text())
    assert set(man) >= {"dist_l2_m256_n256_d64", "dist_cos_m256_n256_d64"}
