"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes/tilings; every case asserts allclose against
``kernels.ref``. This is the core numeric signal for the whole stack — the
AOT artifacts are these exact kernels baked to HLO.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pairwise, ref

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed, dtype=np.float32, scale=1.0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * scale).astype(dtype)


# --- fixed-shape smoke tests -------------------------------------------------

@pytest.mark.parametrize("m,n,d,tm,tn", [
    (128, 128, 64, 128, 128),
    (256, 256, 128, 128, 128),
    (256, 1024, 64, 128, 256),
    (128, 384, 32, 64, 128),
])
def test_sq_l2_matches_ref(m, n, d, tm, tn):
    x, y = rand((m, d), 1), rand((n, d), 2)
    got = pairwise.pairwise_sq_l2(x, y, tm=tm, tn=tn)
    want = ref.pairwise_sq_l2(x, y)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("m,n,d,tm,tn", [
    (128, 128, 64, 128, 128),
    (256, 256, 128, 128, 128),
    (256, 1024, 64, 128, 256),
])
def test_cosine_matches_ref(m, n, d, tm, tn):
    x, y = rand((m, d), 3), rand((n, d), 4)
    got = pairwise.pairwise_cosine(x, y, tm=tm, tn=tn)
    want = ref.pairwise_cosine(x, y)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_sq_l2_self_distance_zero():
    x = rand((128, 64), 5)
    d = np.asarray(pairwise.pairwise_sq_l2(x, x))
    np.testing.assert_allclose(np.diag(d), np.zeros(128), atol=1e-3)


def test_sq_l2_nonnegative_with_duplicates():
    # Duplicated rows stress the max(., 0) clamp: the analytic form goes
    # slightly negative in f32 for identical vectors.
    x = rand((128, 64), 6)
    x[64:] = x[:64]
    d = np.asarray(pairwise.pairwise_sq_l2(x, x))
    assert (d >= 0).all()


def test_cosine_zero_vector_guard():
    x = rand((128, 64), 7)
    x[0, :] = 0.0
    d = np.asarray(pairwise.pairwise_cosine(x, x))
    assert np.isfinite(d).all()


def test_cosine_range():
    x = rand((128, 32), 8)
    d = np.asarray(pairwise.pairwise_cosine(x, x))
    assert (d >= -1e-5).all() and (d <= 2.0 + 1e-5).all()


def test_sq_l2_symmetry():
    x = rand((128, 64), 9)
    y = rand((128, 64), 10)
    dxy = np.asarray(pairwise.pairwise_sq_l2(x, y))
    dyx = np.asarray(pairwise.pairwise_sq_l2(y, x))
    np.testing.assert_allclose(dxy, dyx.T, rtol=1e-5, atol=1e-4)


def test_bf16_inputs_accumulate_in_f32():
    x = rand((128, 64), 11).astype(jnp.bfloat16)
    y = rand((128, 64), 12).astype(jnp.bfloat16)
    got = pairwise.pairwise_sq_l2(x, y)
    assert got.dtype == jnp.float32
    want = ref.pairwise_sq_l2(x.astype(jnp.float32), y.astype(jnp.float32))
    # bf16 inputs lose mantissa; tolerance reflects input rounding only.
    np.testing.assert_allclose(got, want, rtol=3e-2, atol=3e-1)


def test_tile_must_divide_shape():
    x, y = rand((100, 64), 13), rand((128, 64), 14)
    with pytest.raises(ValueError):
        pairwise.pairwise_sq_l2(x, y, tm=64, tn=64)


# --- hypothesis sweeps -------------------------------------------------------

TILES = st.sampled_from([32, 64, 128])


@settings(max_examples=20, deadline=None)
@given(
    mi=st.integers(1, 3),
    ni=st.integers(1, 3),
    d=st.sampled_from([8, 32, 64, 128]),
    tm=TILES,
    tn=TILES,
    seed=st.integers(0, 2**31 - 1),
    metric=st.sampled_from(["l2", "cosine"]),
)
def test_hypothesis_kernel_vs_ref(mi, ni, d, tm, tn, seed, metric):
    m, n = mi * tm, ni * tn
    x, y = rand((m, d), seed, scale=2.0), rand((n, d), seed + 1, scale=0.5)
    if metric == "l2":
        got = pairwise.pairwise_sq_l2(x, y, tm=tm, tn=tn)
        want = ref.pairwise_sq_l2(x, y)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)
    else:
        got = pairwise.pairwise_cosine(x, y, tm=tm, tn=tn)
        want = ref.pairwise_cosine(x, y)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    d=st.sampled_from([16, 64]),
    dtype=st.sampled_from([np.float32, np.float64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_dtypes(d, dtype, seed):
    x = rand((64, d), seed, dtype=dtype)
    y = rand((64, d), seed + 7, dtype=dtype)
    got = pairwise.pairwise_sq_l2(x, y, tm=64, tn=64)
    want = ref.pairwise_sq_l2(jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.float32))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_vmem_footprint_reported():
    fp = pairwise.vmem_footprint_bytes(128, 128, 128)
    # 2 input tiles + upcasts + out tile; must sit far below 16 MiB VMEM.
    assert 0 < fp < 8 * 2**20
