"""Layer-2 JAX compute graph: blocked dissimilarity-graph construction.

The Rust coordinator builds kNN / epsilon-ball graphs by streaming tile
pairs of the dataset through these functions (AOT-compiled to HLO once by
``aot.py``). Each function is a pure block computation:

* ``distance_block_*``  — full (m, n) dissimilarity tile.
* ``knn_block_*``       — dissimilarity tile fused with per-row top-k, so
  only (m, k) values + indices cross the PJRT boundary instead of (m, n).
  The k-way merge across column blocks happens in Rust.

All heavy lifting is delegated to the Layer-1 Pallas kernels in
``kernels/pairwise.py``; this layer adds the top-k selection and fixes the
AOT-visible signatures. Python never runs at clustering time.
"""

import jax
import jax.numpy as jnp

from .kernels import pairwise


def distance_block_l2(x, y):
    """Squared-l2 dissimilarity tile D[m, n] (tuple-wrapped for AOT)."""
    return (pairwise.pairwise_sq_l2(x, y),)


def distance_block_cosine(x, y):
    """Cosine dissimilarity tile D[m, n] (tuple-wrapped for AOT)."""
    return (pairwise.pairwise_cosine(x, y),)


def _knn_block(dist_fn, x, y, k):
    # NOTE: deliberately NOT lax.top_k — jax lowers it to the `topk(...,
    # largest=true)` HLO instruction, which the xla crate's bundled XLA
    # 0.5.1 text parser predates. k unrolled argmin+mask steps lower to
    # reduce/select ops every XLA version parses, and k <= 32 keeps the
    # unroll small. Ties resolve to the lowest index (argmin), matching the
    # Rust coordinator's (weight, id) tie-break.
    d = dist_fn(x, y)
    n = d.shape[1]
    cols = jnp.arange(n, dtype=jnp.int32)[None, :]
    vals, idxs = [], []
    for _ in range(k):
        i = jnp.argmin(d, axis=1).astype(jnp.int32)
        v = jnp.min(d, axis=1)
        vals.append(v)
        idxs.append(i)
        d = jnp.where(cols == i[:, None], jnp.inf, d)
    return jnp.stack(vals, axis=1), jnp.stack(idxs, axis=1)


def knn_block_l2(x, y, *, k):
    """Per-row k nearest of the l2 tile: (vals[m, k], idx[m, k])."""
    return _knn_block(pairwise.pairwise_sq_l2, x, y, k)


def knn_block_cosine(x, y, *, k):
    """Per-row k nearest of the cosine tile: (vals[m, k], idx[m, k])."""
    return _knn_block(pairwise.pairwise_cosine, x, y, k)


# ---------------------------------------------------------------------------
# AOT variant registry.
#
# Each entry fixes the static shapes one compiled PJRT executable serves.
# Rust pads the tail tiles up to these shapes (distances to padded rows are
# discarded on the Rust side via the index output / row counts).
# ---------------------------------------------------------------------------

def _spec(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def variants():
    """name -> (jittable fn taking concrete specs, example args, meta).

    meta is serialised into artifacts/manifest.json for the Rust runtime.
    """
    out = {}

    def add(name, fn, shapes, meta):
        out[name] = (fn, [_spec(s) for s in shapes], meta)

    for d in (64, 128):
        add(
            f"dist_l2_m256_n256_d{d}",
            distance_block_l2,
            [(256, d), (256, d)],
            {"kind": "distance", "metric": "l2", "m": 256, "n": 256, "d": d},
        )
        add(
            f"dist_cos_m256_n256_d{d}",
            distance_block_cosine,
            [(256, d), (256, d)],
            {"kind": "distance", "metric": "cosine", "m": 256, "n": 256, "d": d},
        )
        for k in (32,):
            add(
                f"knn_l2_m256_n1024_d{d}_k{k}",
                lambda x, y, k=k: knn_block_l2(x, y, k=k),
                [(256, d), (1024, d)],
                {"kind": "knn", "metric": "l2", "m": 256, "n": 1024, "d": d, "k": k},
            )
            add(
                f"knn_cos_m256_n1024_d{d}_k{k}",
                lambda x, y, k=k: knn_block_cosine(x, y, k=k),
                [(256, d), (1024, d)],
                {"kind": "knn", "metric": "cosine", "m": 256, "n": 1024, "d": d, "k": k},
            )
    return out
