"""AOT lowering: JAX (L2 + L1) -> HLO text artifacts for the Rust runtime.

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``). The text
parser reassigns ids and round-trips cleanly — see
/opt/xla-example/load_hlo/ and its README.

Run as ``python -m compile.aot --out-dir ../artifacts`` (the Makefile does
this). Emits one ``<name>.hlo.txt`` per variant plus ``manifest.json``
describing shapes so the Rust runtime can size its buffers.
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(fn, specs) -> str:
    return to_hlo_text(jax.jit(fn).lower(*specs))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated variant names to (re)build; default all",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    manifest = {}
    for name, (fn, specs, meta) in model.variants().items():
        if only is not None and name not in only:
            continue
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        text = lower_variant(fn, specs)
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            **meta,
            "file": os.path.basename(path),
            "inputs": [list(s.shape) for s in specs],
        }
        print(f"wrote {path} ({len(text)} chars)")

    man_path = os.path.join(args.out_dir, "manifest.json")
    # Merge with an existing manifest so --only rebuilds do not drop entries.
    if only is not None and os.path.exists(man_path):
        with open(man_path) as f:
            old = json.load(f)
        old.update(manifest)
        manifest = old
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {man_path} ({len(manifest)} variants)")


if __name__ == "__main__":
    main()
