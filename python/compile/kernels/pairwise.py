"""Layer-1 Pallas kernels: tiled pairwise dissimilarity blocks.

The paper's numeric hot-spot is dissimilarity-graph construction: squared-l2
over SIFT-style dense vectors and cosine over bag-of-words vectors. On the
authors' CPU fleet this was a blocked BLAS job; here it is re-thought for the
TPU memory hierarchy (see DESIGN.md §Hardware-Adaptation):

* the cross-term ``x @ y.T`` is an MXU contraction; tiles are kept at
  multiples of 128 in both output dimensions so the systolic array is fully
  occupied;
* each grid step holds one ``(tm, d)`` X-tile, one ``(tn, d)`` Y-tile and one
  ``(tm, tn)`` output tile in VMEM — the full distance matrix never exists in
  HBM at once when the caller streams blocks;
* the row-norm corrections for l2 are fused into the same tile so distances
  leave the kernel finished.

The kernels MUST be lowered with ``interpret=True`` on this image: real TPU
lowering emits a Mosaic custom-call that the CPU PJRT plugin cannot execute.
The AOT path (aot.py) bakes the interpreted lowering into plain HLO, which is
what the Rust runtime loads.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sq_l2_kernel(x_ref, y_ref, o_ref):
    """One (tm, tn) tile of the squared-l2 distance matrix.

    o[i, j] = ||x_i||^2 + ||y_j||^2 - 2 x_i . y_j, clamped at 0.
    The matmul accumulates in f32 (``preferred_element_type``) so bf16 inputs
    keep MXU-native precision behaviour.
    """
    x = x_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    cross = jax.lax.dot_general(
        x,
        y,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    xx = jnp.sum(x * x, axis=1, keepdims=True)
    yy = jnp.sum(y * y, axis=1, keepdims=True)
    o_ref[...] = jnp.maximum(xx + yy.T - 2.0 * cross, 0.0)


def _cosine_kernel(x_ref, y_ref, o_ref):
    """One (tm, tn) tile of the cosine dissimilarity matrix.

    Rows are normalised in-tile (epsilon-guarded), then 1 - x_n @ y_n.T.
    Normalising inside the tile costs O((tm+tn)d) FLOPs against the
    O(tm*tn*d) contraction — negligible — and saves a separate HBM pass.
    """
    x = x_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    xn = x * jax.lax.rsqrt(jnp.maximum(jnp.sum(x * x, axis=1, keepdims=True), 1e-24))
    yn = y * jax.lax.rsqrt(jnp.maximum(jnp.sum(y * y, axis=1, keepdims=True), 1e-24))
    cross = jax.lax.dot_general(
        xn,
        yn,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = 1.0 - cross


def _tiled_pairwise(kernel, x, y, *, tm, tn):
    """Run ``kernel`` over an (m/tm, n/tn) grid of output tiles.

    Both X and Y keep their full feature dimension ``d`` resident per tile
    (d <= 512 in all our variants, comfortably inside VMEM); the grid walks
    output tiles so each X-tile is re-read n/tn times — the standard
    matmul-style schedule the paper performed with blocked BLAS.
    """
    m, d = x.shape
    n, _ = y.shape
    if m % tm or n % tn:
        raise ValueError(f"shape ({m},{n}) not divisible by tile ({tm},{tn})")
    grid = (m // tm, n // tn)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls.
    )(x, y)


def pairwise_sq_l2(x, y, *, tm=128, tn=128):
    """Pallas tiled squared-l2 distance block. See ``ref.pairwise_sq_l2``."""
    return _tiled_pairwise(_sq_l2_kernel, x, y, tm=tm, tn=tn)


def pairwise_cosine(x, y, *, tm=128, tn=128):
    """Pallas tiled cosine dissimilarity block. See ``ref.pairwise_cosine``."""
    return _tiled_pairwise(_cosine_kernel, x, y, tm=tm, tn=tn)


@functools.lru_cache(maxsize=None)
def vmem_footprint_bytes(tm: int, tn: int, d: int, in_dtype_bytes: int = 4) -> int:
    """Estimated VMEM residency of one grid step, used by the perf report.

    One X tile + one Y tile (input dtype) + one f32 output tile + the two
    f32 upcast copies the interpreter materialises (worst case).
    """
    tiles_in = (tm * d + tn * d) * in_dtype_bytes
    upcast = (tm * d + tn * d) * 4
    out = tm * tn * 4
    return tiles_in + upcast + out
