"""Pure-jnp reference oracles for the Pallas pairwise-dissimilarity kernels.

These are the ground truth the pytest suite checks the Layer-1 kernels
against. They intentionally avoid any Pallas machinery: plain jnp only.
"""

import jax
import jax.numpy as jnp


def pairwise_sq_l2(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Squared euclidean distance matrix D[i, j] = ||x_i - y_j||^2.

    Args:
        x: [m, d] float array.
        y: [n, d] float array.
    Returns:
        [m, n] float32 array of squared distances.
    """
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    xx = jnp.sum(x * x, axis=1, keepdims=True)  # [m, 1]
    yy = jnp.sum(y * y, axis=1, keepdims=True).T  # [1, n]
    cross = x @ y.T  # [m, n]
    d = xx + yy - 2.0 * cross
    # Numerical floor: exact distances are non-negative.
    return jnp.maximum(d, 0.0)


def pairwise_cosine(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Cosine dissimilarity matrix D[i, j] = 1 - cos(x_i, y_j).

    Zero vectors are guarded with an epsilon on the norm (matching the
    kernel's normalisation).
    """
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    xn = x / jnp.maximum(jnp.linalg.norm(x, axis=1, keepdims=True), 1e-12)
    yn = y / jnp.maximum(jnp.linalg.norm(y, axis=1, keepdims=True), 1e-12)
    return 1.0 - xn @ yn.T


def knn_from_block(d: jnp.ndarray, k: int):
    """Reference top-k nearest (smallest distance) per row of a block.

    Returns (values [m, k], indices [m, k]) sorted ascending by distance.
    """
    neg_vals, idx = jax.lax.top_k(-d, k)
    return -neg_vals, idx
