//! Minimal offline substitute for the `anyhow` crate.
//!
//! Implements the subset the coordinator uses: an opaque [`Error`] with a
//! context chain, the [`Context`] extension trait for `Result`/`Option`,
//! the [`anyhow!`]/[`bail!`]/[`ensure!`] macros, and `?`-conversion from
//! any `std::error::Error`. Display follows anyhow's convention: `{}`
//! prints the outermost context, `{:#}` prints the whole chain joined
//! with `": "`.

use std::fmt;

/// `Result` specialised to [`Error`] (overridable like anyhow's).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An opaque error: a chain of human-readable messages, outermost context
/// first, root cause last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg(message: impl fmt::Display) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, context: impl fmt::Display) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }

    /// Iterate the context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// `?`-conversion from any concrete std error. Coherent with the reflexive
// `From<Error> for Error` because `Error` itself deliberately does NOT
// implement `std::error::Error` (the same trick anyhow uses).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

mod private {
    pub trait Sealed {}
    impl<T, E> Sealed for std::result::Result<T, E> {}
    impl<T> Sealed for Option<T> {}
}

/// Anything that can become an [`Error`] when attaching context: either an
/// `Error` already, or any concrete `std::error::Error`.
pub trait IntoError {
    fn into_error(self) -> Error;
}

impl IntoError for Error {
    fn into_error(self) -> Error {
        self
    }
}

impl<E> IntoError for E
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn into_error(self) -> Error {
        Error::from(self)
    }
}

/// Context-attaching extension for `Result` and `Option`.
pub trait Context<T>: private::Sealed {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Wrap the error value with lazily-evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`] built by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// `bail!` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !$cond {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("root {}", 42)
    }

    #[test]
    fn display_modes() {
        let e = fails().unwrap_err().context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root 42");
        assert_eq!(e.root_cause(), "root 42");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<usize> {
            Ok(s.parse::<usize>()?)
        }
        assert_eq!(parse("17").unwrap(), 17);
        assert!(parse("x").is_err());
    }

    #[test]
    fn context_on_results_and_options() {
        let r: std::result::Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "missing",
        ));
        let e = r.context("reading file").unwrap_err();
        assert!(format!("{e:#}").contains("reading file"));
        assert!(format!("{e:#}").contains("missing"));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("no value {}", 3)).unwrap_err();
        assert_eq!(format!("{e}"), "no value 3");
    }

    #[test]
    fn ensure_and_single_expr_anyhow() {
        fn check(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            Ok(x)
        }
        assert!(check(3).is_ok());
        assert!(check(30).is_err());
        let msg = String::from("owned message");
        let e = anyhow!(msg);
        assert_eq!(format!("{e}"), "owned message");
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e = Error::msg("cause").context("mid").context("top");
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("top"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("cause"));
    }
}
