//! Minimal offline substitute for the `rustc-hash` crate.
//!
//! Provides [`FxHasher`] — the fast, non-cryptographic multiply-rotate
//! hash used by rustc — and the [`FxHashMap`] / [`FxHashSet`] aliases the
//! coordinator uses for its hot-path neighbor maps. Unlike the std
//! `RandomState`, the hasher is fully deterministic, which keeps map
//! iteration order reproducible across runs for identical insertion
//! sequences (the engines never rely on iteration order for correctness,
//! only determinism of accounting).

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

/// A `HashMap` keyed by [`FxHasher`].
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;

/// A `HashSet` keyed by [`FxHasher`].
pub type FxHashSet<T> = HashSet<T, FxBuildHasher>;

/// `BuildHasherDefault` specialisation for [`FxHasher`].
pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// The rustc multiply-rotate hasher (word-at-a-time, deterministic).
#[derive(Debug, Clone, Copy, Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, mut bytes: &[u8]) {
        while bytes.len() >= 8 {
            let (chunk, rest) = bytes.split_at(8);
            self.add_to_hash(u64::from_le_bytes(chunk.try_into().unwrap()));
            bytes = rest;
        }
        if !bytes.is_empty() {
            let mut word = 0u64;
            for (i, &b) in bytes.iter().enumerate() {
                word |= (b as u64) << (8 * i);
            }
            self.add_to_hash(word);
        }
    }

    #[inline]
    fn write_u8(&mut self, n: u8) {
        self.add_to_hash(n as u64);
    }

    #[inline]
    fn write_u16(&mut self, n: u16) {
        self.add_to_hash(n as u64);
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add_to_hash(n as u64);
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add_to_hash(n);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add_to_hash(n as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_basics() {
        let mut m: FxHashMap<u32, &str> = FxHashMap::default();
        m.insert(1, "one");
        m.insert(2, "two");
        assert_eq!(m.get(&1), Some(&"one"));
        assert_eq!(m.len(), 2);
        m.remove(&1);
        assert!(!m.contains_key(&1));
    }

    #[test]
    fn set_basics() {
        let mut s: FxHashSet<(usize, u32)> = FxHashSet::default();
        assert!(s.insert((0, 7)));
        assert!(!s.insert((0, 7)));
        assert!(s.contains(&(0, 7)));
    }

    #[test]
    fn deterministic_across_instances() {
        let hash = |x: u64| {
            let mut h = FxHasher::default();
            h.write_u64(x);
            h.finish()
        };
        assert_eq!(hash(42), hash(42));
        assert_ne!(hash(42), hash(43));
    }

    #[test]
    fn byte_stream_matches_itself_regardless_of_chunking() {
        // write() must be deterministic for a given byte sequence.
        let mut a = FxHasher::default();
        a.write(&[1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11]);
        let mut b = FxHasher::default();
        b.write(&[1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11]);
        assert_eq!(a.finish(), b.finish());
    }
}
