//! Shared workload builders for the bench harness.
//!
//! Graphs are cached under `target/bench_cache/` (the binary graph format
//! from `graph::io`), so re-running a bench skips the brute-force kNN
//! builds. Delete the directory to force a rebuild.

#![allow(dead_code)]

use std::path::PathBuf;

use rac_hac::data::{gaussian_mixture, topic_docs};
use rac_hac::graph::{read_graph, write_graph, Graph};
use rac_hac::knn::{knn_graph, Backend};

fn cache_dir() -> PathBuf {
    let dir = PathBuf::from("target/bench_cache");
    std::fs::create_dir_all(&dir).expect("create bench cache dir");
    dir
}

/// Build-or-load a cached graph.
pub fn cached(name: &str, build: impl FnOnce() -> Graph) -> Graph {
    let path = cache_dir().join(format!("{name}.bin"));
    if let Ok(g) = read_graph(&path) {
        return g;
    }
    eprintln!("[bench] building workload {name} (cached for future runs)...");
    let g = build();
    write_graph(&g, &path).expect("write graph cache");
    g
}

/// SIFT-like kNN workload (DESIGN.md substitute for the SIFT rows).
pub fn sift_knn(n: usize, d: usize, k: usize, seed: u64) -> Graph {
    cached(&format!("sift_n{n}_d{d}_k{k}_s{seed}"), || {
        let ds = gaussian_mixture(n, d, (n / 128).max(8), 0.8, 0.02, seed);
        knn_graph(&ds, k, Backend::Native, None).expect("knn")
    })
}

/// Web/doc-like cosine kNN workload (substitute for WEB88M). The paper's
/// WEB88M graph has mean degree ~4500, so the kNN substitute is dense-ish
/// (k in the tens-to-hundreds).
pub fn docs_knn(n: usize, d: usize, topics: usize, k: usize, seed: u64) -> Graph {
    cached(&format!("docs_n{n}_d{d}_t{topics}_k{k}_s{seed}"), || {
        let ds = topic_docs(n, d, topics, seed);
        knn_graph(&ds, k, Backend::Native, None).expect("knn")
    })
}

/// Complete cosine graph over doc-like data. News20 (355M edges = n²) and
/// RCV1 (0.5B ≈ n²) are COMPLETE graphs in paper Table 3 — the kNN
/// versions behave very differently under average linkage (cosine hubs),
/// so Fig-2 fidelity requires the complete graph.
pub fn docs_complete(n: usize, d: usize, topics: usize, seed: u64) -> Graph {
    cached(&format!("docsc_n{n}_d{d}_t{topics}_s{seed}"), || {
        let ds = topic_docs(n, d, topics, seed);
        rac_hac::knn::complete_graph(&ds)
    })
}

/// Dense complete-graph workload over a small SIFT-like dataset (the
/// paper's SIFT1M row is a complete graph; scaled down per DESIGN.md).
pub fn sift_complete(n: usize, d: usize, seed: u64) -> Graph {
    cached(&format!("siftc_n{n}_d{d}_s{seed}"), || {
        let ds = gaussian_mixture(n, d, (n / 64).max(4), 0.8, 0.02, seed);
        rac_hac::knn::complete_graph(&ds)
    })
}

/// Least-squares slope of log(y) vs log(x) — Fig 3d's "roughly linear".
pub fn loglog_slope(points: &[(f64, f64)]) -> f64 {
    let pts: Vec<(f64, f64)> = points
        .iter()
        .filter(|&&(x, y)| x > 0.0 && y > 0.0)
        .map(|&(x, y)| (x.ln(), y.ln()))
        .collect();
    let n = pts.len() as f64;
    let sx: f64 = pts.iter().map(|p| p.0).sum();
    let sy: f64 = pts.iter().map(|p| p.1).sum();
    let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
    (n * sxy - sx * sy) / (n * sxx - sx * sx)
}
