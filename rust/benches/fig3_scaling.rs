//! Fig 3 bench (DESIGN.md E-F3ab/c/d): scaling with machines and CPUs,
//! and merge-time linearity in merges.
//!
//! Paper Fig 3: (a) runtime vs machines for SIFT200K, (b) for SIFT1B,
//! (c) speedup vs CPUs/machine on SIFT1B at 200 machines, (d) log-log
//! merge time vs merges per round (slope ~1).
//!
//! Here "machines" are simulated shards in one process (DESIGN.md §1), so
//! two curves are reported per sweep: wall-clock (real threads, includes
//! the simulator's messaging overhead) and **critical-path compute** —
//! per-round max-across-shards compute time, the quantity a real fleet's
//! wall clock would track once the network is pipelined (the paper
//! overlaps communication with computation via batching).
//!
//! ```bash
//! cargo bench --bench fig3_scaling
//! ```

#[path = "common.rs"]
mod common;

use std::time::Instant;

use rac_hac::dist::{DistConfig, DistRacEngine};
use rac_hac::graph::Graph;
use rac_hac::linkage::Linkage;
use rac_hac::rac::RacEngine;
use rac_hac::util::bench::Table;

fn run(g: &Graph, machines: usize, cpus: usize) -> (f64, rac_hac::rac::RacResult) {
    let t = Instant::now();
    let r = DistRacEngine::new(
        g,
        Linkage::Complete,
        DistConfig::new(machines, cpus),
    )
    .run();
    (t.elapsed().as_secs_f64(), r)
}

fn machine_sweep(label: &str, g: &Graph, sweeps: &[usize]) {
    println!("\n-- {label}: runtime vs # machines (1 cpu each) --");
    let t = Table::new(
        &["machines", "sim(s)", "speedup", "net msgs", "net MiB", "wall(s)"],
        &[9, 9, 8, 10, 9, 9],
    );
    let mut base = None;
    let mut speedups = Vec::new();
    for &m in sweeps {
        let (wall, r) = run(g, m, 1);
        let sim = r.metrics.total_sim_time().as_secs_f64();
        let base_s = *base.get_or_insert(sim);
        speedups.push(base_s / sim);
        t.row(&[
            &m.to_string(),
            &format!("{sim:.3}"),
            &format!("{:.2}x", base_s / sim),
            &r.metrics.total_net_messages().to_string(),
            &format!("{:.1}", r.metrics.total_net_bytes() as f64 / (1 << 20) as f64),
            &format!("{wall:.3}"),
        ]);
    }
    // Paper Fig 3a/3b shape: speedup grows with machines (sub-linearly).
    // `sim` is the critical-path model (DESIGN.md §1: this testbed has one
    // CPU, so in-process wall clock cannot scale).
    assert!(
        *speedups.last().unwrap() > 1.2,
        "{label}: no simulated speedup at max machines ({speedups:?})"
    );
}

fn main() {
    eprintln!("[fig3] building workloads (cached across runs)...");
    let small = common::sift_knn(8_000, 64, 16, 9); // SIFT200K-like (Fig 3a)
    let big = common::sift_knn(30_000, 64, 20, 7); // SIFT1B-like (Fig 3b)

    // ---- Fig 3a/3b: machines sweeps ------------------------------------
    machine_sweep("Fig 3a (SIFT200K-like)", &small, &[1, 2, 4, 8]);
    machine_sweep("Fig 3b (SIFT1B-like)", &big, &[1, 2, 4, 8, 16]);

    // ---- Fig 3c: CPUs per machine at fixed machines --------------------
    println!("\n-- Fig 3c (SIFT1B-like): speedup vs CPUs/machine (4 machines) --");
    let t = Table::new(&["cpus/machine", "sim(s)", "speedup"], &[12, 9, 8]);
    let mut base = None;
    let mut last = 0.0;
    for cpus in [1usize, 2, 4, 8] {
        let (_, r) = run(&big, 4, cpus);
        let sim = r.metrics.total_sim_time().as_secs_f64();
        let base_s = *base.get_or_insert(sim);
        last = base_s / sim;
        t.row(&[
            &cpus.to_string(),
            &format!("{sim:.3}"),
            &format!("{:.2}x", base_s / sim),
        ]);
    }
    // Paper Fig 3c: diminishing but positive returns from more CPUs.
    assert!(last > 1.2, "no CPU-scaling benefit (last speedup {last:.2})");

    // ---- Fig 3d: merge time vs merges per round (log-log slope) --------
    // Use the shared-memory engine so per-round merge-phase timings are
    // clean of messaging noise; the paper's claim is near-linearity.
    println!("\n-- Fig 3d: per-round merge time vs merges (log-log) --");
    let mut points: Vec<(f64, f64)> = Vec::new();
    for g in [&small, &big] {
        let r = RacEngine::new(g, Linkage::Complete).with_threads(1).run();
        points.extend(
            r.metrics
                .merge_time_series()
                .into_iter()
                .filter(|&(m, t)| m >= 4 && t > 0.0)
                .map(|(m, t)| (m as f64, t)),
        );
    }
    let slope = common::loglog_slope(&points);
    // Print a decimated scatter for eyeballing.
    let t = Table::new(&["merges", "merge time (us)"], &[9, 16]);
    let mut sorted = points.clone();
    sorted.sort_by(|a, b| a.0.total_cmp(&b.0));
    for p in sorted.iter().step_by((sorted.len() / 15).max(1)) {
        t.row(&[&format!("{:.0}", p.0), &format!("{:.0}", p.1 * 1e6)]);
    }
    println!(
        "log-log slope: {slope:.2} over {} rounds (paper Fig 3d: ~1 — merge time is\n\
         nearly linear in merges per round)",
        points.len()
    );
    assert!(
        (0.5..1.6).contains(&slope),
        "merge time should scale near-linearly in merges (slope {slope:.2})"
    );

    println!("\nfig3 bench OK");
}
