//! Hot-path benches and the repo's perf-trajectory harness.
//!
//! ```bash
//! cargo bench --bench hot_paths                  # human-readable tables
//! cargo bench --bench hot_paths -- --json        # + write BENCH_hot_paths.json
//! cargo bench --bench hot_paths -- --json --smoke  # CI short-budget mode
//! cargo bench --bench hot_paths -- --json --out target/perf.json
//! ```
//!
//! The JSON report is the unit of the perf trajectory: one
//! `engine × linkage × threads` matrix of medians over the SIFT-like kNN
//! workload, each cell carrying the per-phase split
//! (`t_find`/`t_merge`/`t_update_nn`) summed from [`RunMetrics`], plus a
//! headline comparing the flat-store engine against the retained PR-1
//! hashmap baseline ([`HashRacEngine`]) at default threads, and a
//! `rac_flat_scalar` / `rac_flat_simd` counterpart pair pinning the
//! forced-scalar fallback against the detected row-scan kernel (the run
//! asserts their dendrograms bitwise equal; the report's `simd_dispatch`
//! field records which kernel was active). CI runs the
//! smoke mode on every push and uploads `BENCH_hot_paths.json` as an
//! artifact, so regressions and wins are visible PR over PR.
//!
//! Every entry is tagged with the engine-core revision
//! ([`rac_hac::engine::DRIVER_REV`]) so the trajectory can show that the
//! shared-round-driver refactor is overhead-free: the driver's store and
//! selector parameters are generics (monomorphized per engine — no `dyn`
//! in the inner loop), so post-refactor medians must track the
//! pre-refactor datapoints.

#[path = "common.rs"]
mod common;

use std::time::Duration;

use rac_hac::dist::{DistConfig, DistRacEngine};
use rac_hac::graph::Graph;
use rac_hac::hac::{naive_hac, nn_chain};
use rac_hac::linkage::Linkage;
use rac_hac::metrics::RunMetrics;
use rac_hac::rac::baseline::HashRacEngine;
use rac_hac::rac::{RacEngine, RacResult};
use rac_hac::trace::TraceSink;
use rac_hac::util::bench::{time_budget, Table, Timing};
use rac_hac::util::json::{obj, Json};
use rac_hac::util::parallel::default_threads;
use rac_hac::util::pool::Pool;

/// One measured configuration of the engine matrix.
struct Cell {
    engine: &'static str,
    linkage: Linkage,
    threads: usize,
    timing: Timing,
    metrics: RunMetrics,
}

impl Cell {
    fn to_json(&self) -> Json {
        let mut find = Duration::ZERO;
        let mut merge = Duration::ZERO;
        let mut update = Duration::ZERO;
        for r in &self.metrics.rounds {
            find += r.t_find;
            merge += r.t_merge;
            update += r.t_update_nn;
        }
        obj([
            ("engine", self.engine.into()),
            ("driver", rac_hac::engine::DRIVER_REV.into()),
            ("linkage", self.linkage.name().into()),
            ("threads", self.threads.into()),
            ("median_us", us(self.timing.median).into()),
            ("mean_us", us(self.timing.mean).into()),
            ("min_us", us(self.timing.min).into()),
            ("samples", self.timing.samples.into()),
            ("t_find_us", us(find).into()),
            ("t_merge_us", us(merge).into()),
            ("t_update_nn_us", us(update).into()),
            ("rounds", self.metrics.merge_rounds().into()),
        ])
    }
}

fn us(d: Duration) -> usize {
    d.as_micros() as usize
}

/// Time `build().run()` under `budget`, keeping the metrics of the last
/// sample for the phase split.
fn measure(
    budget: Duration,
    min_samples: usize,
    mut run: impl FnMut() -> RacResult,
) -> (Timing, RunMetrics) {
    let mut last: Option<RunMetrics> = None;
    let timing = time_budget(budget, min_samples, || {
        let r = run();
        last = Some(r.metrics);
    });
    (timing, last.expect("at least one sample ran"))
}

fn engine_matrix(g: &Graph, budget: Duration, min_samples: usize) -> Vec<Cell> {
    let dt = default_threads();
    let thread_counts: Vec<usize> = if dt == 1 { vec![1] } else { vec![1, dt] };
    let mut cells = Vec::new();
    for linkage in Linkage::SPARSE_REDUCIBLE {
        for &threads in &thread_counts {
            let (timing, metrics) = measure(budget, min_samples, || {
                RacEngine::new(g, linkage).with_threads(threads).run()
            });
            cells.push(Cell {
                engine: "rac_flat",
                linkage,
                threads,
                timing,
                metrics,
            });
            let (timing, metrics) = measure(budget, min_samples, || {
                HashRacEngine::new(g, linkage).with_threads(threads).run()
            });
            cells.push(Cell {
                engine: "rac_hash",
                linkage,
                threads,
                timing,
                metrics,
            });
        }
        let (timing, metrics) = measure(budget, min_samples, || {
            DistRacEngine::new(g, linkage, DistConfig::new(4, 2)).run()
        });
        cells.push(Cell {
            engine: "dist_rac_4x2",
            linkage,
            threads: 1,
            timing,
            metrics,
        });
    }
    cells
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let write_json = args.iter().any(|a| a == "--json");
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| "BENCH_hot_paths.json".to_string());

    let (g, workload_name, budget, min_samples) = if smoke {
        (common::sift_knn(2_000, 32, 12, 9), "sift_knn_smoke", Duration::from_millis(150), 2)
    } else {
        (common::sift_knn(8_000, 64, 16, 9), "sift_knn", Duration::from_secs(1), 3)
    };
    println!(
        "workload: SIFT-like kNN graph n={} ({} edges, max degree {}){}\n",
        g.n(),
        g.m(),
        g.max_degree(),
        if smoke { " [smoke]" } else { "" }
    );

    // ---- engine × linkage × threads matrix ------------------------------
    println!("-- engines (flat store vs hashmap baseline vs dist) --");
    let mut cells = engine_matrix(&g, budget, min_samples);
    let t = Table::new(
        &["engine", "linkage", "threads", "median", "mean", "samples"],
        &[14, 10, 8, 12, 12, 8],
    );
    for c in &cells {
        t.row(&[
            c.engine,
            c.linkage.name(),
            &c.threads.to_string(),
            &format!("{:.3?}", c.timing.median),
            &format!("{:.3?}", c.timing.mean),
            &c.timing.samples.to_string(),
        ]);
    }

    // ---- tracing overhead guard (complete linkage, default threads) -----
    // Two trajectory cells pinning the observability layer's cost: a run
    // with a *disabled* sink attached must track `rac_flat` (the sink
    // check is one branch per span site — if these drift apart, the
    // instrumentation leaked into the hot path), and a run with an
    // *enabled* sink shows the price of actually recording.
    let headline_threads = default_threads();
    {
        let (timing, metrics) = measure(budget, min_samples, || {
            RacEngine::new(&g, Linkage::Complete)
                .with_threads(headline_threads)
                .with_trace(&TraceSink::disabled())
                .run()
        });
        cells.push(Cell {
            engine: "rac_flat_sink_off",
            linkage: Linkage::Complete,
            threads: headline_threads,
            timing,
            metrics,
        });
        let (timing, metrics) = measure(budget, min_samples, || {
            let sink = TraceSink::enabled();
            let r = RacEngine::new(&g, Linkage::Complete)
                .with_threads(headline_threads)
                .with_trace(&sink)
                .run();
            sink.take();
            r
        });
        cells.push(Cell {
            engine: "rac_flat_sink_on",
            linkage: Linkage::Complete,
            threads: headline_threads,
            timing,
            metrics,
        });
        let base = cells
            .iter()
            .find(|c| {
                c.engine == "rac_flat"
                    && c.linkage == Linkage::Complete
                    && c.threads == headline_threads
            })
            .expect("baseline cell measured")
            .timing
            .median;
        let off = cells[cells.len() - 2].timing.median;
        let on = cells[cells.len() - 1].timing.median;
        println!(
            "\n-- tracing overhead (complete linkage, {headline_threads} threads) --\n\
             untraced {:.3?}  sink-off {:.3?} ({:+.1}%)  sink-on {:.3?} ({:+.1}%)",
            base,
            off,
            (off.as_secs_f64() / base.as_secs_f64().max(1e-12) - 1.0) * 100.0,
            on,
            (on.as_secs_f64() / base.as_secs_f64().max(1e-12) - 1.0) * 100.0,
        );
    }

    // ---- simd dispatch guard (complete linkage, default threads) --------
    // Counterpart cells for the row-scan kernels (`store::scan`): the same
    // run pinned to the scalar fallback vs the detected SIMD kernel. The
    // dendrograms must agree bitwise — that is the kernels' core contract —
    // and the medians record what vectorization buys on this machine.
    {
        use rac_hac::store::scan;
        // Scoped pins: each cell runs under its kernel and the guard
        // restores the entry dispatch, so an RAC_FORCE_SCALAR pin on the
        // bench process still governs every cell outside this block.
        let scalar_d = {
            let _pin = scan::KernelPin::scalar();
            let d = RacEngine::new(&g, Linkage::Complete)
                .with_threads(headline_threads)
                .run()
                .dendrogram;
            let (timing, metrics) = measure(budget, min_samples, || {
                RacEngine::new(&g, Linkage::Complete).with_threads(headline_threads).run()
            });
            cells.push(Cell {
                engine: "rac_flat_scalar",
                linkage: Linkage::Complete,
                threads: headline_threads,
                timing,
                metrics,
            });
            d
        };
        let simd_d = {
            let _pin = scan::KernelPin::pin(scan::detect());
            let d = RacEngine::new(&g, Linkage::Complete)
                .with_threads(headline_threads)
                .run()
                .dendrogram;
            let (timing, metrics) = measure(budget, min_samples, || {
                RacEngine::new(&g, Linkage::Complete).with_threads(headline_threads).run()
            });
            cells.push(Cell {
                engine: "rac_flat_simd",
                linkage: Linkage::Complete,
                threads: headline_threads,
                timing,
                metrics,
            });
            d
        };
        assert_eq!(
            scalar_d.bitwise_merges(),
            simd_d.bitwise_merges(),
            "forced-scalar and {} dendrograms must be bitwise identical",
            scan::detect().name()
        );
        let sc = cells[cells.len() - 2].timing.median;
        let sv = cells[cells.len() - 1].timing.median;
        println!(
            "\n-- simd dispatch ({}; complete linkage, {headline_threads} threads) --\n\
             scalar {:.3?}  simd {:.3?} → {:.2}x (dendrograms bitwise equal)",
            scan::detect().name(),
            sc,
            sv,
            sc.as_secs_f64() / sv.as_secs_f64().max(1e-12)
        );
    }

    // ---- headline: flat vs hashmap at default threads -------------------
    let pick = |engine: &str| {
        cells
            .iter()
            .find(|c| {
                c.engine == engine
                    && c.linkage == Linkage::Complete
                    && c.threads == headline_threads
            })
            .expect("headline cell measured")
    };
    let flat = pick("rac_flat");
    let hash = pick("rac_hash");
    let speedup = hash.timing.median.as_secs_f64() / flat.timing.median.as_secs_f64().max(1e-12);
    println!(
        "\nheadline (complete linkage, {headline_threads} threads): \
         flat {:.3?} vs hashmap {:.3?} → {speedup:.2}x",
        flat.timing.median, hash.timing.median
    );

    // ---- slower context rows + dispatch overhead (full mode only) -------
    if !smoke {
        println!("\n-- sequential baselines (complete linkage) --");
        let t = Table::new(&["engine", "median", "samples"], &[18, 12, 8]);
        let naive = time_budget(budget, min_samples, || naive_hac(&g, Linkage::Complete));
        t.row(&["naive_hac (heap)", &format!("{:.3?}", naive.median), &naive.samples.to_string()]);
        let chain = time_budget(budget, min_samples, || nn_chain(&g, Linkage::Complete));
        t.row(&["nn_chain", &format!("{:.3?}", chain.median), &chain.samples.to_string()]);

        println!("\n-- pool dispatch overhead (per par_map_indexed call) --");
        let t = Table::new(&["threads", "n=64", "n=4096"], &[8, 12, 12]);
        for threads in [2usize, 4, 8] {
            let pool = Pool::new(threads);
            let t64 = time_budget(Duration::from_millis(300), 50, || {
                pool.par_map_indexed(64, |i| i * 2)
            });
            let t4k = time_budget(Duration::from_millis(300), 50, || {
                pool.par_map_indexed(4096, |i| i * 2)
            });
            t.row(&[
                &threads.to_string(),
                &format!("{:.1?}", t64.median),
                &format!("{:.1?}", t4k.median),
            ]);
        }
    }

    // ---- JSON trajectory datapoint --------------------------------------
    if write_json {
        let report = obj([
            ("schema", "bench_hot_paths/v1".into()),
            ("driver", rac_hac::engine::DRIVER_REV.into()),
            ("simd_dispatch", rac_hac::store::scan::detect().name().into()),
            ("mode", (if smoke { "smoke" } else { "full" }).into()),
            (
                "workload",
                obj([
                    ("name", workload_name.into()),
                    ("n", g.n().into()),
                    ("edges", g.m().into()),
                    ("max_degree", g.max_degree().into()),
                ]),
            ),
            (
                "headline",
                obj([
                    ("linkage", Linkage::Complete.name().into()),
                    ("threads", headline_threads.into()),
                    ("flat_median_us", us(flat.timing.median).into()),
                    ("hashmap_median_us", us(hash.timing.median).into()),
                    ("speedup", speedup.into()),
                ]),
            ),
            (
                "cells",
                Json::Arr(cells.iter().map(Cell::to_json).collect()),
            ),
        ]);
        std::fs::write(&out_path, format!("{report}\n")).expect("write bench report");
        println!("\nwrote {out_path}");
    }

    println!("\nhot_paths bench OK");
}
