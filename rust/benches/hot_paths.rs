//! Hot-path microbenches (DESIGN.md E-Perf): the quantities tracked by the
//! performance pass in EXPERIMENTS.md §Perf.
//!
//! ```bash
//! cargo bench --bench hot_paths
//! ```

#[path = "common.rs"]
mod common;

use std::time::Duration;

use rac_hac::dist::{DistConfig, DistRacEngine};
use rac_hac::hac::{naive_hac, nn_chain};
use rac_hac::linkage::Linkage;
use rac_hac::rac::RacEngine;
use rac_hac::util::bench::{time_budget, Table};
use rac_hac::util::parallel::default_threads;
use rac_hac::util::pool::Pool;

fn main() {
    let budget = Duration::from_secs(2);
    let g = common::sift_knn(8_000, 64, 16, 9);
    println!(
        "workload: SIFT-like n=8000 kNN graph ({} edges, max degree {})\n",
        g.m(),
        g.max_degree()
    );

    // ---- end-to-end engines on the same graph ---------------------------
    println!("-- engines, end-to-end (complete linkage) --");
    let t = Table::new(&["engine", "median", "mean", "samples"], &[26, 12, 12, 8]);
    let mut line = |name: &str, timing: rac_hac::util::bench::Timing| {
        t.row(&[
            name,
            &format!("{:.3?}", timing.median),
            &format!("{:.3?}", timing.mean),
            &timing.samples.to_string(),
        ]);
    };
    line(
        "naive_hac (heap)",
        time_budget(budget, 3, || naive_hac(&g, Linkage::Complete)),
    );
    line(
        "nn_chain",
        time_budget(budget, 3, || nn_chain(&g, Linkage::Complete)),
    );
    line(
        "rac (1 thread)",
        time_budget(budget, 3, || {
            RacEngine::new(&g, Linkage::Complete).with_threads(1).run()
        }),
    );
    line(
        &format!("rac ({} threads)", default_threads()),
        time_budget(budget, 3, || {
            RacEngine::new(&g, Linkage::Complete)
                .with_threads(default_threads())
                .run()
        }),
    );
    line(
        "dist_rac (4x2)",
        time_budget(budget, 3, || {
            DistRacEngine::new(
                &g,
                Linkage::Complete,
                DistConfig::new(4, 2),
            )
            .run()
        }),
    );

    // ---- pool dispatch overhead ----------------------------------------
    println!("\n-- pool dispatch overhead (per par_map_indexed call) --");
    let t = Table::new(&["threads", "n=64", "n=4096"], &[8, 12, 12]);
    for threads in [2usize, 4, 8] {
        let pool = Pool::new(threads);
        let t64 = time_budget(Duration::from_millis(300), 50, || {
            pool.par_map_indexed(64, |i| i * 2)
        });
        let t4k = time_budget(Duration::from_millis(300), 50, || {
            pool.par_map_indexed(4096, |i| i * 2)
        });
        t.row(&[
            &threads.to_string(),
            &format!("{:.1?}", t64.median),
            &format!("{:.1?}", t4k.median),
        ]);
    }

    // ---- phase split for the RAC engine ---------------------------------
    println!("\n-- rac phase split (1 thread, complete linkage) --");
    let r = RacEngine::new(&g, Linkage::Complete).with_threads(1).run();
    let (mut tf, mut tm, mut tu) = (Duration::ZERO, Duration::ZERO, Duration::ZERO);
    let mut scans = 0usize;
    for rm in &r.metrics.rounds {
        tf += rm.t_find;
        tm += rm.t_merge;
        tu += rm.t_update_nn;
        scans += rm.nn_scan_entries;
    }
    println!(
        "find {:?} | merge {:?} | update_nn {:?} | {} nn-scan entries | {} rounds",
        tf,
        tm,
        tu,
        scans,
        r.metrics.merge_rounds()
    );

    println!("\nhot_paths bench OK");
}
