//! Theory-section benches (DESIGN.md E-T4, E-T5, E-G1, E-G2): regenerate
//! the quantitative claims of paper §4.
//!
//! ```bash
//! cargo bench --bench theory
//! ```

#[path = "common.rs"]
mod common;

use rac_hac::data::{adversarial_thm4, grid1d_graph, random_regular_graph, stable_hierarchy};
use rac_hac::linkage::Linkage;
use rac_hac::rac::RacEngine;
use rac_hac::util::bench::Table;

fn main() {
    println!("\n=== E-T4: Theorem 4 — Ω(n) rounds at height log n (average linkage) ===");
    let t = Table::new(&["n", "height", "rounds", "rounds/n"], &[8, 8, 8, 10]);
    for levels in [4u32, 6, 8, 10] {
        let g = adversarial_thm4(levels);
        let r = RacEngine::new(&g, Linkage::Average).run();
        let n = g.n();
        let rounds = r.metrics.merge_rounds();
        assert_eq!(r.dendrogram.height(), levels as usize);
        assert!(rounds + 1 >= n / 2, "expected Ω(n) rounds, got {rounds}");
        t.row(&[
            &n.to_string(),
            &r.dendrogram.height().to_string(),
            &rounds.to_string(),
            &format!("{:.3}", rounds as f64 / n as f64),
        ]);
    }
    println!("paper: rounds grow linearly in n while the tree height is log n.");

    println!("\n=== E-T5: Theorem 5 — stable trees finish in height rounds ===");
    let t = Table::new(&["n", "height", "rounds", "status"], &[8, 8, 8, 8]);
    for depth in [4u32, 6, 8, 10, 12] {
        let g = stable_hierarchy(depth, 4.0, depth as u64);
        let r = RacEngine::new(&g, Linkage::Average).run();
        let rounds = r.metrics.merge_rounds();
        assert_eq!(rounds, depth as usize);
        t.row(&[
            &g.n().to_string(),
            &depth.to_string(),
            &rounds.to_string(),
            "OK",
        ]);
    }
    println!("paper: on stable cluster trees RAC needs exactly height rounds.");

    println!("\n=== E-G1: §4.2.2 1-d grid — round-1 alpha = 1/3, O(log n) rounds ===");
    let t = Table::new(
        &["n", "rounds", "3*log2(n)", "alpha_r1", "alpha_mean"],
        &[8, 8, 10, 9, 10],
    );
    for n in [1_000usize, 10_000, 100_000] {
        let g = grid1d_graph(n, 3);
        let r = RacEngine::new(&g, Linkage::Single).run();
        let a1 = r.metrics.rounds[0].alpha();
        let alphas: Vec<f64> = r
            .metrics
            .rounds
            .iter()
            .filter(|rm| rm.clusters > 50 && rm.merges > 0)
            .map(|rm| rm.alpha())
            .collect();
        let mean = alphas.iter().sum::<f64>() / alphas.len() as f64;
        let bound = 3 * (n as f64).log2() as usize;
        assert!((a1 - 1.0 / 3.0).abs() < 0.03, "round-1 alpha {a1}");
        assert!(r.metrics.merge_rounds() <= bound);
        t.row(&[
            &n.to_string(),
            &r.metrics.merge_rounds().to_string(),
            &bound.to_string(),
            &format!("{a1:.3}"),
            &format!("{mean:.3}"),
        ]);
    }
    println!("paper: fresh ranks give alpha = 1/3 (round 1); conditioning settles ~1/4 — still a constant, so rounds = O(log n).");

    println!("\n=== E-G2: §4.2.2 bounded-degree graph — round-1 alpha >= 1/(4d) ===");
    let t = Table::new(
        &["n", "d", "alpha_r1", "1/(4d)", "rounds"],
        &[8, 4, 9, 8, 8],
    );
    for (n, d) in [(10_000usize, 4usize), (10_000, 8), (10_000, 16)] {
        let g = random_regular_graph(n, d, 5);
        let r = RacEngine::new(&g, Linkage::Single).run();
        let a1 = r.metrics.rounds[0].alpha();
        let bound = 1.0 / (4.0 * d as f64);
        assert!(a1 >= bound, "alpha {a1} below theory bound {bound}");
        t.row(&[
            &n.to_string(),
            &d.to_string(),
            &format!("{a1:.3}"),
            &format!("{bound:.4}"),
            &r.metrics.merge_rounds().to_string(),
        ]);
    }
    println!(
        "paper: Theorem 6 with alpha = 1/(4d). NOTE the large total round counts: as\n\
         clusters grow their degree is no longer bounded by d, so the per-round bound\n\
         decays — the paper's bounded-CLUSTER-degree assumption (\"supported by\n\
         experiments\") holds on metric kNN graphs (cf. Table-4 bench) but not here.\n\
         This is the negative diagnostic, kept deliberately."
    );

    println!("\ntheory bench OK");
}
