//! Sync-point trajectory harness for the distributed engines.
//!
//! ```bash
//! cargo bench --bench dist_sync                    # human tables
//! cargo bench --bench dist_sync -- --json          # + BENCH_dist_sync.json
//! cargo bench --bench dist_sync -- --json --smoke  # CI short-budget mode
//! cargo bench --bench dist_sync -- --json --out target/dist_sync.json
//! ```
//!
//! For each workload × engine × ε × topology, runs the simulated fleet
//! and reports merges, rounds, **sync_points** (global barriers), the
//! critical-path time model `t_sim`, and wire traffic. The headline is
//! TeraHAC's subgraph-batching claim, pinned in-bench: on the Theorem-4
//! adversarial chain and the Theorem-5 stable hierarchy the batched
//! `dist_approx` engine needs strictly fewer sync points than rounds
//! (per-round engines pay one barrier per round by construction), while
//! merges stay O(n) and the dendrogram remains topology-invariant.
//!
//! Executed-mode counterpart cells (`*_exec` engines) run the default
//! fleet for real — thread-per-machine shards over channels — and report
//! the measured `t_exec` next to the model's `t_sim`, pinned bitwise
//! against the simulation in-bench.
//!
//! CI uploads the JSON as the third perf-trajectory artifact next to
//! `BENCH_hot_paths.json` and `BENCH_approx_tradeoff.json`.

use rac_hac::approx::ApproxResult;
use rac_hac::data;
use rac_hac::dist::{DistApproxEngine, DistConfig, DistRacEngine, ExecOptions, SyncMode};
use rac_hac::graph::Graph;
use rac_hac::linkage::Linkage;
use rac_hac::metrics::RunMetrics;
use rac_hac::util::bench::Table;
use rac_hac::util::json::{obj, Json};

const EPSILONS: [f64; 3] = [0.0, 0.1, 1.0];
const TOPOLOGIES: [(usize, usize); 3] = [(1, 1), (4, 2), (8, 4)];
const VSHARDS: u32 = 8;

struct Workload {
    name: &'static str,
    graph: Graph,
}

fn workloads(smoke: bool) -> Vec<Workload> {
    let (adv, stable, grid) = if smoke { (6, 6, 256) } else { (8, 8, 1024) };
    vec![
        Workload {
            name: "adversarial",
            graph: data::adversarial_thm4(adv),
        },
        Workload {
            name: "stable_hierarchy",
            graph: data::stable_hierarchy(stable, 4.0, 23),
        },
        Workload {
            name: "grid1d",
            graph: data::grid1d_graph(grid, 11),
        },
    ]
}

struct Cell {
    workload: &'static str,
    engine: &'static str,
    epsilon: f64,
    machines: usize,
    cpus: usize,
    merges: usize,
    rounds: usize,
    sync_points: usize,
    t_sim_us: usize,
    /// Measured executed-mode wall time; zero for simulated cells.
    t_exec_us: usize,
    net_messages: usize,
    net_bytes: usize,
}

impl Cell {
    fn from_metrics(
        workload: &'static str,
        engine: &'static str,
        epsilon: f64,
        (machines, cpus): (usize, usize),
        merges: usize,
        m: &RunMetrics,
    ) -> Cell {
        Cell {
            workload,
            engine,
            epsilon,
            machines,
            cpus,
            merges,
            rounds: m.rounds.len(),
            sync_points: m.total_sync_points(),
            t_sim_us: m.total_sim_time().as_micros() as usize,
            t_exec_us: m.total_exec_time().as_micros() as usize,
            net_messages: m.total_net_messages(),
            net_bytes: m.total_net_bytes(),
        }
    }

    fn to_json(&self) -> Json {
        obj([
            ("workload", self.workload.into()),
            ("engine", self.engine.into()),
            ("epsilon", self.epsilon.into()),
            ("machines", self.machines.into()),
            ("cpus", self.cpus.into()),
            ("merges", self.merges.into()),
            ("rounds", self.rounds.into()),
            ("sync_points", self.sync_points.into()),
            ("t_sim_us", self.t_sim_us.into()),
            ("t_exec_us", self.t_exec_us.into()),
            ("net_messages", self.net_messages.into()),
            ("net_bytes", self.net_bytes.into()),
        ])
    }
}

fn run_batched(g: &Graph, topo: (usize, usize), eps: f64) -> ApproxResult {
    DistApproxEngine::new(g, Linkage::Average, DistConfig::new(topo.0, topo.1), eps)
        .with_sync_mode(SyncMode::Batched { vshards: VSHARDS })
        .run()
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let write_json = args.iter().any(|a| a == "--json");
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| "BENCH_dist_sync.json".to_string());

    let mut cells: Vec<Cell> = Vec::new();
    let mut workload_meta: Vec<Json> = Vec::new();
    for w in workloads(smoke) {
        println!("== workload {}: n={} edges={} ==", w.name, w.graph.n(), w.graph.m());
        workload_meta.push(obj([
            ("name", w.name.into()),
            ("n", w.graph.n().into()),
            ("edges", w.graph.m().into()),
        ]));
        let t = Table::new(
            &[
                "engine", "epsilon", "machines", "cpus", "rounds", "syncs", "t_sim", "t_exec",
                "net_kB",
            ],
            &[24, 8, 9, 5, 7, 6, 12, 12, 9],
        );
        for &topo in &TOPOLOGIES {
            // Exact baseline: one barrier per round, rounds = merge
            // schedule of exact RAC.
            let exact =
                DistRacEngine::new(&w.graph, Linkage::Average, DistConfig::new(topo.0, topo.1))
                    .run();
            cells.push(Cell::from_metrics(
                w.name,
                "dist_rac",
                0.0,
                topo,
                exact.dendrogram.merges().len(),
                &exact.metrics,
            ));
            for eps in EPSILONS {
                let unbatched = DistApproxEngine::new(
                    &w.graph,
                    Linkage::Average,
                    DistConfig::new(topo.0, topo.1),
                    eps,
                )
                .run();
                assert_eq!(
                    unbatched.metrics.total_sync_points(),
                    unbatched.metrics.rounds.len(),
                    "per-round engine: every round is a sync point"
                );
                cells.push(Cell::from_metrics(
                    w.name,
                    "dist_approx",
                    eps,
                    topo,
                    unbatched.dendrogram.merges().len(),
                    &unbatched.metrics,
                ));

                let batched = run_batched(&w.graph, topo, eps);
                assert_eq!(
                    batched.dendrogram.merges().len(),
                    unbatched.dendrogram.merges().len(),
                    "batching must not lose merges"
                );
                let (rounds, syncs) = (
                    batched.metrics.rounds.len(),
                    batched.metrics.total_sync_points(),
                );
                assert!(syncs <= rounds, "{}: sync_points > rounds", w.name);
                if w.name != "grid1d" {
                    // The collapse workloads: strictly fewer barriers
                    // than rounds (the acceptance-bar claim).
                    assert!(
                        syncs < rounds,
                        "{} eps={eps}: batching produced no local rounds",
                        w.name
                    );
                }
                cells.push(Cell::from_metrics(
                    w.name,
                    "dist_approx_batched",
                    eps,
                    topo,
                    batched.dendrogram.merges().len(),
                    &batched.metrics,
                ));
            }
        }
        // Topology invariance of the batched schedule (quick in-bench
        // anchor; the full property lives in rust/tests/dist_batching.rs).
        let a = run_batched(&w.graph, TOPOLOGIES[0], 0.1);
        let b = run_batched(&w.graph, TOPOLOGIES[2], 0.1);
        assert_eq!(
            a.dendrogram.bitwise_merges(),
            b.dendrogram.bitwise_merges(),
            "{}: batched dendrogram depends on topology",
            w.name
        );
        // Executed-mode counterpart cells on the default fleet (4×2):
        // real threads + channels, measured t_exec, pinned bitwise
        // against the simulation (the full differential matrix lives in
        // rust/tests/dist_executed.rs).
        let topo = (4, 2);
        let sim_rac =
            DistRacEngine::new(&w.graph, Linkage::Average, DistConfig::new(topo.0, topo.1)).run();
        let exec_rac =
            DistRacEngine::new(&w.graph, Linkage::Average, DistConfig::new(topo.0, topo.1))
                .with_exec(ExecOptions::default())
                .run();
        assert_eq!(
            sim_rac.dendrogram.bitwise_merges(),
            exec_rac.dendrogram.bitwise_merges(),
            "{}: executed dist_rac diverged from simulation",
            w.name
        );
        cells.push(Cell::from_metrics(
            w.name,
            "dist_rac_exec",
            0.0,
            topo,
            exec_rac.dendrogram.merges().len(),
            &exec_rac.metrics,
        ));
        let sim_batched = run_batched(&w.graph, topo, 0.1);
        let exec_batched =
            DistApproxEngine::new(&w.graph, Linkage::Average, DistConfig::new(topo.0, topo.1), 0.1)
                .with_sync_mode(SyncMode::Batched { vshards: VSHARDS })
                .with_exec(ExecOptions::default())
                .run();
        assert_eq!(
            sim_batched.dendrogram.bitwise_merges(),
            exec_batched.dendrogram.bitwise_merges(),
            "{}: executed batched dist_approx diverged from simulation",
            w.name
        );
        cells.push(Cell::from_metrics(
            w.name,
            "dist_approx_batched_exec",
            0.1,
            topo,
            exec_batched.dendrogram.merges().len(),
            &exec_batched.metrics,
        ));
        for c in cells.iter().filter(|c| c.workload == w.name) {
            t.row(&[
                c.engine,
                &c.epsilon.to_string(),
                &c.machines.to_string(),
                &c.cpus.to_string(),
                &c.rounds.to_string(),
                &c.sync_points.to_string(),
                &format!("{}us", c.t_sim_us),
                &format!("{}us", c.t_exec_us),
                &format!("{:.1}", c.net_bytes as f64 / 1024.0),
            ]);
        }
        println!();
    }

    // Headline: barrier collapse on the adversarial chain at ε = 1,
    // default fleet (4 machines × 2 cpus).
    let pick = |engine: &str| {
        cells
            .iter()
            .find(|c| {
                c.workload == "adversarial"
                    && c.engine == engine
                    && c.machines == 4
                    && (c.engine == "dist_rac" || c.epsilon == 1.0)
            })
            .expect("headline cell measured")
    };
    let (exact, unbatched, batched) =
        (pick("dist_rac"), pick("dist_approx"), pick("dist_approx_batched"));
    println!(
        "headline (adversarial, average, 4x2): dist_rac {} rounds/syncs vs \
         dist_approx(eps=1) {} vs batched {} rounds / {} sync points \
         ({} merges, t_sim {}us vs {}us)",
        exact.rounds,
        unbatched.rounds,
        batched.rounds,
        batched.sync_points,
        batched.merges,
        batched.t_sim_us,
        unbatched.t_sim_us,
    );

    if write_json {
        let report = obj([
            ("schema", "bench_dist_sync/v2".into()),
            ("mode", (if smoke { "smoke" } else { "full" }).into()),
            ("vshards", (VSHARDS as usize).into()),
            ("workloads", Json::Arr(workload_meta)),
            (
                "headline",
                obj([
                    ("workload", "adversarial".into()),
                    ("rounds_dist_rac", exact.rounds.into()),
                    ("rounds_dist_approx_eps1", unbatched.rounds.into()),
                    ("rounds_batched_eps1", batched.rounds.into()),
                    ("sync_points_batched_eps1", batched.sync_points.into()),
                    ("merges", batched.merges.into()),
                ]),
            ),
            ("cells", Json::Arr(cells.iter().map(Cell::to_json).collect())),
        ]);
        std::fs::write(&out_path, format!("{report}\n")).expect("write bench report");
        println!("\nwrote {out_path}");
    }

    println!("\ndist_sync bench OK");
}
