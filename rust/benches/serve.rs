//! Serve-layer query harness: the read path of clustering-as-a-service.
//!
//! ```bash
//! cargo bench --bench serve                    # human tables
//! cargo bench --bench serve -- --json          # + BENCH_serve.json
//! cargo bench --bench serve -- --json --smoke  # CI short-budget mode
//! cargo bench --bench serve -- --json --out target/serve.json
//! ```
//!
//! Three sections, the first two asserted in-bench:
//!
//! * **Bitwise pinning.** Before any timing, every query class the index
//!   answers is checked bitwise against the naive [`Dendrogram::cut_*`]
//!   path, over all five engines' output on the same graph and over a
//!   structurally disconnected kNN graph (where `cut_k` must return the
//!   same named error from both paths). A serving layer that is fast but
//!   wrong is worthless; the bench refuses to report numbers for one.
//! * **Indexed vs naive threshold cuts.** The naive path rebuilds a
//!   UnionFind and re-scans the whole merge list per query; the index
//!   answers from a binary search plus precomputed intervals. The indexed
//!   total over the same threshold sweep must be *strictly* faster.
//! * **Zipfian hammering from all cores.** `default_threads()` reader
//!   threads share one [`ServeHandle`], each drawing a skewed query mix
//!   (hot points, hot thresholds — `Rng::zipf`) across all five query
//!   classes through `load()` snapshots, the way a service front-end
//!   would. Reported: per-class mean latency and aggregate queries/sec.
//!
//! CI uploads the JSON as a perf-trajectory artifact next to
//! `BENCH_recovery.json`.

use std::time::Instant;

use rac_hac::approx::ApproxEngine;
use rac_hac::data::{gaussian_mixture, Dataset, Metric};
use rac_hac::dendrogram::Dendrogram;
use rac_hac::dist::{DistApproxEngine, DistConfig, DistRacEngine};
use rac_hac::knn::{knn_graph, Backend};
use rac_hac::linkage::{Linkage, Weight};
use rac_hac::rac::baseline::HashRacEngine;
use rac_hac::rac::RacEngine;
use rac_hac::serve::{ServeHandle, ServeIndex};
use rac_hac::util::bench::{black_box, time_fn, Table};
use rac_hac::util::json::{obj, Json};
use rac_hac::util::parallel::default_threads;
use rac_hac::util::rng::Rng;

/// Zipf exponent for the hot-key query mix (`Rng::zipf` needs s > 1).
const ZIPF_S: f64 = 1.2;

/// Candidate thresholds, ascending: extremes, every distinct merge
/// weight (the exclusive-boundary case), and midpoints between them.
/// Ascending order matters for the Zipfian draw below: hot (low) indices
/// mean low thresholds, i.e. small clusters, the realistic hot case.
fn thresholds(d: &Dendrogram) -> Vec<Weight> {
    let mut ws: Vec<Weight> = d.merges().iter().map(|m| m.weight).collect();
    ws.sort_by(Weight::total_cmp);
    let mut ts = vec![0.0];
    for i in 0..ws.len() {
        ts.push(ws[i]);
        if i + 1 < ws.len() && ws[i] < ws[i + 1] {
            ts.push((ws[i] + ws[i + 1]) / 2.0);
        }
    }
    if let Some(last) = ws.last() {
        ts.push(last + 1.0);
    }
    ts
}

/// A kNN graph over two far-apart blobs: structurally disconnected, so
/// the `cut_k` error contract is exercised, not just the happy path.
fn disconnected_dendrogram() -> Dendrogram {
    let (n, dim) = (120usize, 8usize);
    let mut rng = Rng::seed_from(0x5EB1);
    let mut rows = vec![0.0f32; n * dim];
    for (i, row) in rows.chunks_mut(dim).enumerate() {
        let offset = if i < n / 2 { 0.0 } else { 1000.0 };
        for x in row {
            *x = (offset + rng.range_f64(0.0, 1.0)) as f32;
        }
    }
    let ds = Dataset {
        n,
        d: dim,
        metric: Metric::L2,
        rows,
    };
    let g = knn_graph(&ds, 4, Backend::Native, None).unwrap();
    RacEngine::new(&g, Linkage::Average).run().dendrogram
}

/// Bitwise gate: index answers == naive answers on this dendrogram, for
/// a spread of thresholds and every answerable (and unanswerable) k.
fn pin(name: &str, d: &Dendrogram) {
    let idx = ServeIndex::build(d).unwrap_or_else(|e| panic!("{name}: {e}"));
    let n = d.n();
    let ts = thresholds(d);
    for t in ts.iter().step_by(1 + ts.len() / 40) {
        let naive = d.cut_threshold(*t);
        assert_eq!(idx.cut_threshold(*t), naive, "{name}: cut_threshold({t})");
        for p in (0..n).step_by(1 + n / 13) {
            let rep = naive
                .iter()
                .position(|&l| l == naive[p])
                .expect("p matches itself") as u32;
            assert_eq!(
                idx.point_membership(p as u32, *t).unwrap(),
                rep,
                "{name}: point_membership({p}, {t})"
            );
        }
    }
    for k in (0..=n + 1).step_by(1 + n / 29) {
        assert_eq!(idx.cut_k(k), d.cut_k(k), "{name}: cut_k({k})");
    }
    // k around the component boundary, where Disconnected fires.
    let comps = d.remaining_clusters();
    for k in comps.saturating_sub(1)..=comps + 1 {
        assert_eq!(idx.cut_k(k), d.cut_k(k), "{name}: boundary cut_k({k})");
    }
}

struct ClassStat {
    ops: usize,
    nanos: u128,
}

const CLASSES: [&str; 5] = ["point_membership", "cut_threshold", "cut_k", "members", "diff"];

/// One reader thread's Zipfian mix, through `handle.load()` per query.
fn hammer(handle: &ServeHandle, seed: u64, ops: usize, ts: &[Weight]) -> Vec<ClassStat> {
    let mut rng = Rng::seed_from(seed);
    let mut stats: Vec<ClassStat> = (0..CLASSES.len())
        .map(|_| ClassStat { ops: 0, nanos: 0 })
        .collect();
    let draw_t = |rng: &mut Rng| ts[(rng.zipf(ts.len() as u64, ZIPF_S) - 1) as usize];
    for _ in 0..ops {
        let idx = handle.load();
        let n = idx.n();
        let comps = idx.components();
        let p = (rng.zipf(n as u64, ZIPF_S) - 1) as u32;
        // 40% membership, 20% members, 15% threshold cuts, 15% k-cuts,
        // 10% diffs — reads of single points dominate a serving mix.
        let class = match rng.below(20) {
            0..=7 => 0,
            8..=11 => 3,
            12..=14 => 1,
            15..=17 => 2,
            _ => 4,
        };
        let t0 = Instant::now();
        match class {
            0 => {
                black_box(idx.point_membership(p, draw_t(&mut rng)).unwrap());
            }
            1 => {
                black_box(idx.cut_threshold(draw_t(&mut rng)));
            }
            2 => {
                let k = comps + (rng.zipf((n - comps + 1) as u64, ZIPF_S) - 1) as usize;
                black_box(idx.cut_k(k).unwrap());
            }
            3 => {
                black_box(idx.cluster_members(p, draw_t(&mut rng)).unwrap());
            }
            _ => {
                let (a, b) = (draw_t(&mut rng), draw_t(&mut rng));
                let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
                black_box(idx.diff(lo, hi).unwrap());
            }
        }
        stats[class].ops += 1;
        stats[class].nanos += t0.elapsed().as_nanos();
    }
    stats
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let write_json = args.iter().any(|a| a == "--json");
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| "BENCH_serve.json".to_string());

    // -- Section 1: bitwise pinning gates ---------------------------------
    let gate_ds = gaussian_mixture(240, 8, 5, 0.5, 0.05, 41);
    let gate_g = knn_graph(&gate_ds, 6, Backend::Native, None).unwrap();
    let cfg = || DistConfig::new(3, 2);
    let engines: Vec<(&str, Dendrogram)> = vec![
        ("rac", RacEngine::new(&gate_g, Linkage::Average).run().dendrogram),
        (
            "hash_rac",
            HashRacEngine::new(&gate_g, Linkage::Average).run().dendrogram,
        ),
        (
            "approx",
            ApproxEngine::new(&gate_g, Linkage::Average, 0.1).run().dendrogram,
        ),
        (
            "dist_rac",
            DistRacEngine::new(&gate_g, Linkage::Average, cfg()).run().dendrogram,
        ),
        (
            "dist_approx",
            DistApproxEngine::new(&gate_g, Linkage::Average, cfg(), 0.1)
                .run()
                .dendrogram,
        ),
    ];
    for (name, d) in &engines {
        pin(name, d);
    }
    let disc = disconnected_dendrogram();
    assert!(
        disc.remaining_clusters() >= 2,
        "disconnected fixture merged into one component"
    );
    pin("disconnected", &disc);
    println!(
        "pinning OK: {} engines + disconnected ({} components), every query bitwise-equal \
         to naive",
        engines.len(),
        disc.remaining_clusters()
    );

    // -- Main workload ----------------------------------------------------
    let n = if smoke { 2_000 } else { 20_000 };
    let ds = gaussian_mixture(n, 8, 20, 0.6, 0.05, 42);
    let g = knn_graph(&ds, 8, Backend::Native, None).unwrap();
    let d = RacEngine::new(&g, Linkage::Average).run().dendrogram;
    let idx = ServeIndex::build(&d).expect("engine output must index");
    println!(
        "workload: n={n} merges={} components={} index={}B",
        idx.num_merges(),
        idx.components(),
        idx.memory_bytes()
    );
    let ts = thresholds(&d);

    // -- Section 2: indexed vs naive threshold sweep ----------------------
    let sweep: Vec<Weight> = ts.iter().step_by(1 + ts.len() / 32).copied().collect();
    for t in &sweep {
        assert_eq!(idx.cut_threshold(*t), d.cut_threshold(*t), "sweep at {t}");
    }
    let samples = if smoke { 3 } else { 5 };
    let t_naive = time_fn(1, samples, || {
        for t in &sweep {
            black_box(d.cut_threshold(*t));
        }
    });
    let t_indexed = time_fn(1, samples, || {
        for t in &sweep {
            black_box(idx.cut_threshold(*t));
        }
    });
    assert!(
        t_indexed.median < t_naive.median,
        "indexed threshold cuts ({:?} median) must strictly beat the naive per-query \
         UnionFind rebuild ({:?} median) over {} thresholds",
        t_indexed.median,
        t_naive.median,
        sweep.len()
    );
    let speedup = t_naive.median.as_nanos() as f64 / t_indexed.median.as_nanos().max(1) as f64;
    println!(
        "threshold sweep ({} cuts): naive {}  indexed {}  speedup {speedup:.1}x",
        sweep.len(),
        t_naive,
        t_indexed
    );

    // The k-cut gap is larger still (naive re-sorts the merge list per
    // query); reported but not gated — the acceptance claim is thresholds.
    let ks: Vec<usize> = (0..8)
        .map(|i| idx.components() + i * (n - idx.components()) / 8)
        .collect();
    let k_naive = time_fn(1, samples, || {
        for k in &ks {
            black_box(d.cut_k(*k).unwrap());
        }
    });
    let k_indexed = time_fn(1, samples, || {
        for k in &ks {
            black_box(idx.cut_k(*k).unwrap());
        }
    });
    let k_speedup = k_naive.median.as_nanos() as f64 / k_indexed.median.as_nanos().max(1) as f64;
    println!(
        "k-cut sweep ({} cuts): naive {}  indexed {}  speedup {k_speedup:.1}x",
        ks.len(),
        k_naive,
        k_indexed
    );

    // -- Section 3: Zipfian hammering from all cores ----------------------
    let threads = default_threads();
    let per_thread_ops = if smoke { 4_000 } else { 40_000 };
    let handle = ServeHandle::new(ServeIndex::build(&d).unwrap());
    let wall = Instant::now();
    let per_thread: Vec<Vec<ClassStat>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let handle = &handle;
                let ts = &ts;
                s.spawn(move || hammer(handle, 0x5EED ^ t as u64, per_thread_ops, ts))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let wall_s = wall.elapsed().as_secs_f64();

    let mut agg: Vec<ClassStat> = (0..CLASSES.len())
        .map(|_| ClassStat { ops: 0, nanos: 0 })
        .collect();
    for stats in &per_thread {
        for (a, s) in agg.iter_mut().zip(stats) {
            a.ops += s.ops;
            a.nanos += s.nanos;
        }
    }
    let total_ops: usize = agg.iter().map(|a| a.ops).sum();
    let qps = total_ops as f64 / wall_s;
    println!(
        "\nhammer: {threads} threads x {per_thread_ops} ops in {wall_s:.2}s = {qps:.0} \
         queries/sec aggregate"
    );
    let table = Table::new(&["class", "ops", "mean_us"], &[18, 10, 10]);
    for (name, a) in CLASSES.iter().zip(&agg) {
        let mean_us = a.nanos as f64 / 1000.0 / a.ops.max(1) as f64;
        table.row(&[name, &a.ops.to_string(), &format!("{mean_us:.2}")]);
    }

    println!(
        "\nheadline: n={n}, {} threads: {qps:.0} q/s mixed; indexed threshold cuts \
         {speedup:.1}x naive, k-cuts {k_speedup:.1}x naive",
        threads
    );

    if write_json {
        let classes: Vec<Json> = CLASSES
            .iter()
            .zip(&agg)
            .map(|(name, a)| {
                obj([
                    ("class", (*name).into()),
                    ("ops", a.ops.into()),
                    (
                        "mean_us",
                        (a.nanos as f64 / 1000.0 / a.ops.max(1) as f64).into(),
                    ),
                ])
            })
            .collect();
        let report = obj([
            ("schema", "bench_serve/v1".into()),
            ("mode", (if smoke { "smoke" } else { "full" }).into()),
            ("n", n.into()),
            ("merges", idx.num_merges().into()),
            ("components", idx.components().into()),
            ("index_bytes", idx.memory_bytes().into()),
            ("threads", threads.into()),
            ("zipf_s", ZIPF_S.into()),
            ("engines_pinned", engines.len().into()),
            ("sweep_thresholds", sweep.len().into()),
            ("naive_threshold_sweep_us", (t_naive.median.as_micros() as usize).into()),
            (
                "indexed_threshold_sweep_us",
                (t_indexed.median.as_micros() as usize).into(),
            ),
            ("threshold_speedup", speedup.into()),
            ("naive_k_sweep_us", (k_naive.median.as_micros() as usize).into()),
            ("indexed_k_sweep_us", (k_indexed.median.as_micros() as usize).into()),
            ("k_speedup", k_speedup.into()),
            ("hammer_ops", total_ops.into()),
            ("hammer_wall_s", wall_s.into()),
            ("queries_per_sec", qps.into()),
            ("classes", Json::Arr(classes)),
        ]);
        std::fs::write(&out_path, format!("{report}\n")).expect("write bench report");
        println!("\nwrote {out_path}");
    }

    println!("\nserve bench OK");
}
