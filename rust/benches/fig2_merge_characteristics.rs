//! Fig 2 bench (DESIGN.md E-F2a/b/cd): merge characteristics — merges per
//! round and nearest-neighbor updates per merge (β).
//!
//! Paper Fig 2: (a) NN updates per merge for News20/RCV1; (b) merges per
//! round for News20/RCV1; (c)/(d) merges per round for SIFT1B/SIFT1M.
//! Datasets are the DESIGN.md §1 substitutes at laptop scale; the claims
//! being reproduced are *shape* claims: an initial parallelism burst, a
//! hump/bottleneck for the SIFT-like data, and β bounded by a small
//! constant.
//!
//! ```bash
//! cargo bench --bench fig2_merge_characteristics
//! ```

#[path = "common.rs"]
mod common;

use rac_hac::linkage::Linkage;
use rac_hac::metrics::RunMetrics;
use rac_hac::rac::RacEngine;

/// Print a per-round series downsampled to at most `max_rows` rows.
fn print_series(label: &str, m: &RunMetrics, max_rows: usize) {
    let rounds: Vec<_> = m.rounds.iter().filter(|r| r.merges > 0).collect();
    let step = rounds.len().div_ceil(max_rows).max(1);
    println!("\n-- {label}: merges per round (downsampled x{step}) --");
    println!("{:>6} {:>9} {:>9} {:>7} {:>7}", "round", "clusters", "merges", "alpha", "beta");
    for r in rounds.iter().step_by(step) {
        println!(
            "{:>6} {:>9} {:>9} {:>7.3} {:>7.2}",
            r.round,
            r.clusters,
            r.merges,
            r.alpha(),
            r.beta()
        );
    }
}

fn check_burst_shape(label: &str, m: &RunMetrics) {
    // Shape claims: round 1 merges a sizeable fraction; rounds << merges.
    let r1 = &m.rounds[0];
    assert!(
        r1.alpha() > 0.05,
        "{label}: round-1 alpha {:.3} too small for a parallelism burst",
        r1.alpha()
    );
    assert!(
        m.merge_rounds() * 10 < m.total_merges(),
        "{label}: rounds {} not << merges {}",
        m.merge_rounds(),
        m.total_merges()
    );
}

fn main() {
    // ---- Fig 2a/2b: News20- and RCV1-shaped runs -----------------------
    // News20: 18846 docs, 20 classes, 355M edges (= n² — a COMPLETE
    // graph); RCV1: 23149 docs, 103 topics, 0.5B edges (also complete).
    // Substituted with complete cosine graphs at 3000/4000 docs with
    // matching class counts (DESIGN.md §1; complete graphs at the paper's
    // n would need ~6 GiB per graph here), average linkage as in classic
    // document clustering.
    for (label, n, topics) in [("News20-like", 3_000usize, 20usize), ("RCV1-like", 4_000, 103)] {
        let g = common::docs_complete(n, 64, topics, 17);
        let r = RacEngine::new(&g, Linkage::Average).run();
        print_series(label, &r.metrics, 18);
        let beta_max = r.metrics.max_beta();
        println!(
            "{label}: {} rounds, {} merges; beta mean {:.2} / max {:.2}  (Fig 2a: bounded)",
            r.metrics.merge_rounds(),
            r.metrics.total_merges(),
            r.metrics.mean_beta(),
            beta_max,
        );
        check_burst_shape(label, &r.metrics);
        // Fig 2a's claim: NN updates per merge stay bounded by a small
        // constant (paper curves sit in the single digits / low tens).
        assert!(
            r.metrics.mean_beta() < 40.0,
            "beta must stay bounded (mean {:.1}, max {beta_max:.1})",
            r.metrics.mean_beta()
        );
    }

    // ---- Fig 2c/2d: SIFT-shaped runs (l2 kNN / complete) ---------------
    // SIFT1B (sparse kNN graph) and SIFT1M (complete graph), scaled.
    {
        let g = common::sift_knn(30_000, 64, 20, 7);
        let r = RacEngine::new(&g, Linkage::Complete).run();
        print_series("SIFT1B-like (sparse kNN, complete linkage)", &r.metrics, 18);
        println!(
            "SIFT1B-like: {} rounds, {} merges",
            r.metrics.merge_rounds(),
            r.metrics.total_merges()
        );
        check_burst_shape("SIFT1B-like", &r.metrics);
        // The paper's non-intuitive SIFT "hump": merges/round is not
        // monotone — after the initial burst decays there is a later local
        // maximum before the final tail.
        let series: Vec<usize> = r
            .metrics
            .rounds
            .iter()
            .filter(|x| x.merges > 0)
            .map(|x| x.merges)
            .collect();
        let third = series.len() / 3;
        let early_min = *series[third / 2..third].iter().min().unwrap_or(&0);
        let later_max = *series[third..2 * third].iter().max().unwrap_or(&0);
        println!(
            "hump check: min around round {third}/3 = {early_min}, later max = {later_max}"
        );
    }
    {
        let g = common::sift_complete(3_000, 64, 7);
        let r = RacEngine::new(&g, Linkage::Complete).run();
        print_series("SIFT1M-like (complete graph, complete linkage)", &r.metrics, 18);
        println!(
            "SIFT1M-like: {} rounds, {} merges",
            r.metrics.merge_rounds(),
            r.metrics.total_merges()
        );
    }

    println!("\nfig2 bench OK");
}
