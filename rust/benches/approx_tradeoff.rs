//! Quality-vs-speed harness for the (1+ε)-approximate engine.
//!
//! ```bash
//! cargo bench --bench approx_tradeoff                    # human tables
//! cargo bench --bench approx_tradeoff -- --json          # + BENCH_approx_tradeoff.json
//! cargo bench --bench approx_tradeoff -- --json --smoke  # CI short-budget mode
//! cargo bench --bench approx_tradeoff -- --json --out target/approx.json
//! ```
//!
//! For each workload × linkage × threads, sweeps ε ∈ {0, 0.01, 0.1, 1.0}
//! and reports merge rounds, wall time, total edge scans, the worst
//! per-merge goodness ratio (must stay ≤ 1+ε), and the adjusted Rand
//! index of a k-cluster flat cut against the exact engine's dendrogram.
//! The ε = 0 row doubles as a live check of the exactness anchor: its
//! dendrogram is asserted bitwise-equal to the exact engine's.
//!
//! Workloads cover the regimes that motivate the knob: the Theorem-4
//! adversarial instance (exact RAC degenerates to one merge per round —
//! rounds collapse dramatically with any ε > 0), a SIFT-like kNN graph
//! (the paper's main workload shape), and the Theorem-5 stable hierarchy
//! (already optimal at ε = 0 — rounds stay flat, showing the knob costs
//! nothing when exactness is already parallel).
//!
//! CI uploads the JSON as the second perf-trajectory artifact next to
//! `BENCH_hot_paths.json`.

#[path = "common.rs"]
mod common;

use std::time::Duration;

use rac_hac::approx::{quality, ApproxEngine, ApproxResult};
use rac_hac::data;
use rac_hac::dendrogram::Dendrogram;
use rac_hac::graph::Graph;
use rac_hac::linkage::Linkage;
use rac_hac::rac::RacEngine;
use rac_hac::util::bench::{time_budget, Table, Timing};
use rac_hac::util::json::{obj, Json};
use rac_hac::util::parallel::default_threads;

const EPSILONS: [f64; 4] = [0.0, 0.01, 0.1, 1.0];

struct Workload {
    name: &'static str,
    graph: Graph,
    /// Flat-cut size for the ARI comparison.
    cut_k: usize,
}

struct Cell {
    workload: &'static str,
    linkage: Linkage,
    threads: usize,
    epsilon: f64,
    timing: Timing,
    rounds: usize,
    edge_scans: usize,
    quality_ratio: f64,
    ari_vs_exact: f64,
}

impl Cell {
    fn to_json(&self) -> Json {
        obj([
            ("workload", self.workload.into()),
            ("linkage", self.linkage.name().into()),
            ("threads", self.threads.into()),
            ("epsilon", self.epsilon.into()),
            ("median_us", (self.timing.median.as_micros() as usize).into()),
            ("min_us", (self.timing.min.as_micros() as usize).into()),
            ("samples", self.timing.samples.into()),
            ("rounds", self.rounds.into()),
            ("edge_scans", self.edge_scans.into()),
            ("quality_ratio", self.quality_ratio.into()),
            ("ari_vs_exact", self.ari_vs_exact.into()),
        ])
    }
}

fn workloads(smoke: bool) -> Vec<Workload> {
    if smoke {
        vec![
            Workload {
                name: "adversarial",
                graph: data::adversarial_thm4(7), // n = 128
                cut_k: 8,
            },
            Workload {
                name: "sift_knn",
                graph: common::sift_knn(2_000, 32, 12, 9),
                cut_k: 16,
            },
            Workload {
                name: "stable_hierarchy",
                graph: data::stable_hierarchy(7, 4.0, 23), // n = 128
                cut_k: 16,
            },
        ]
    } else {
        vec![
            Workload {
                name: "adversarial",
                graph: data::adversarial_thm4(9), // n = 512
                cut_k: 8,
            },
            Workload {
                name: "sift_knn",
                graph: common::sift_knn(8_000, 64, 16, 9),
                cut_k: 16,
            },
            Workload {
                name: "stable_hierarchy",
                graph: data::stable_hierarchy(10, 4.0, 23), // n = 1024
                cut_k: 16,
            },
        ]
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let write_json = args.iter().any(|a| a == "--json");
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| "BENCH_approx_tradeoff.json".to_string());

    let (budget, min_samples) = if smoke {
        (Duration::from_millis(100), 2)
    } else {
        (Duration::from_millis(600), 3)
    };
    let dt = default_threads();
    let thread_counts: Vec<usize> = if smoke || dt == 1 { vec![dt] } else { vec![1, dt] };

    let mut cells: Vec<Cell> = Vec::new();
    let mut workload_meta: Vec<Json> = Vec::new();
    for w in workloads(smoke) {
        println!(
            "== workload {}: n={} edges={} (cut k={}) ==",
            w.name,
            w.graph.n(),
            w.graph.m(),
            w.cut_k
        );
        workload_meta.push(obj([
            ("name", w.name.into()),
            ("n", w.graph.n().into()),
            ("edges", w.graph.m().into()),
            ("cut_k", w.cut_k.into()),
        ]));
        let t = Table::new(
            &["linkage", "threads", "epsilon", "rounds", "median", "ARI", "ratio"],
            &[10, 8, 8, 8, 12, 8, 8],
        );
        for linkage in Linkage::SPARSE_REDUCIBLE {
            // Exact reference: dendrogram for the ARI column and the ε=0
            // bitwise check. It is bitwise thread-invariant, so one run
            // serves every thread count.
            let exact = RacEngine::new(&w.graph, linkage).run();
            let exact_d: &Dendrogram = &exact.dendrogram;
            // Clamp k into the answerable range — kNN workloads can be
            // disconnected, where cut_k below the component count is a
            // named error by design.
            let k_cut = w
                .cut_k
                .min(w.graph.n())
                .max(exact_d.remaining_clusters());
            let exact_cut = exact_d.cut_k(k_cut).expect("clamped k is answerable");
            for &threads in &thread_counts {
                for epsilon in EPSILONS {
                    let mut last: Option<ApproxResult> = None;
                    let timing = time_budget(budget, min_samples, || {
                        last = Some(
                            ApproxEngine::new(&w.graph, linkage, epsilon)
                                .with_threads(threads)
                                .run(),
                        );
                    });
                    let r = last.expect("at least one sample ran");
                    if epsilon == 0.0 {
                        assert_eq!(
                            exact_d.bitwise_merges(),
                            r.dendrogram.bitwise_merges(),
                            "{}/{linkage:?}: eps=0 must be bitwise-exact",
                            w.name
                        );
                    }
                    let ari = quality::adjusted_rand_index(
                        &exact_cut,
                        // Same graph, same components: the clamped k is
                        // answerable for the approximate dendrogram too.
                        &r.dendrogram.cut_k(k_cut).expect("clamped k is answerable"),
                    );
                    let cell = Cell {
                        workload: w.name,
                        linkage,
                        threads,
                        epsilon,
                        timing,
                        rounds: r.metrics.merge_rounds(),
                        edge_scans: quality::edge_scans(&r.metrics),
                        quality_ratio: quality::merge_quality_ratio(&r.bounds),
                        ari_vs_exact: ari,
                    };
                    t.row(&[
                        linkage.name(),
                        &threads.to_string(),
                        &format!("{epsilon}"),
                        &cell.rounds.to_string(),
                        &format!("{:.3?}", cell.timing.median),
                        &format!("{:.3}", cell.ari_vs_exact),
                        &format!("{:.3}", cell.quality_ratio),
                    ]);
                    cells.push(cell);
                }
            }
        }
        println!();
    }

    // Headline: the round collapse on the adversarial instance at the
    // default thread count, average linkage.
    let pick = |eps: f64| {
        cells
            .iter()
            .find(|c| {
                c.workload == "adversarial"
                    && c.linkage == Linkage::Average
                    && c.threads == dt
                    && c.epsilon == eps
            })
            .expect("headline cell measured")
    };
    let (tight, loose) = (pick(0.0), pick(1.0));
    println!(
        "headline (adversarial, average, {dt} threads): \
         eps=0 {} rounds / {:.3?} vs eps=1 {} rounds / {:.3?} (ARI {:.3})",
        tight.rounds, tight.timing.median, loose.rounds, loose.timing.median, loose.ari_vs_exact
    );

    if write_json {
        let report = obj([
            ("schema", "bench_approx_tradeoff/v1".into()),
            ("mode", (if smoke { "smoke" } else { "full" }).into()),
            ("workloads", Json::Arr(workload_meta)),
            (
                "headline",
                obj([
                    ("workload", "adversarial".into()),
                    ("linkage", Linkage::Average.name().into()),
                    ("threads", dt.into()),
                    ("rounds_eps0", tight.rounds.into()),
                    ("rounds_eps1", loose.rounds.into()),
                    ("ari_eps1", loose.ari_vs_exact.into()),
                ]),
            ),
            ("cells", Json::Arr(cells.iter().map(Cell::to_json).collect())),
        ]);
        std::fs::write(&out_path, format!("{report}\n")).expect("write bench report");
        println!("\nwrote {out_path}");
    }

    println!("\napprox_tradeoff bench OK");
}
