//! Table 4 bench (DESIGN.md E-Tab4): "Performance of RAC on large
//! datasets", regenerated on the DESIGN.md §1 substitutes.
//!
//! Paper Table 4 columns: # of Machines, CPUs/Machine, Merges, Merge
//! Rounds, Merge Time (relative). The paper normalises merge time to the
//! WEB88M row; we do the same against the WEB-like row. Absolute scale is
//! hardware-gated (their smallest dataset outsizes this testbed's RAM) —
//! the claims checked here are the paper's qualitative ones:
//!
//! * merge rounds are in the low hundreds regardless of n (rounds << n);
//! * the complete-graph dataset is far slower than the sparse one at
//!   similar-or-smaller n (paper: SIFT1M 32.0 vs SIFT1B 2.0);
//! * edge loading (graph construction) is a significant share of
//!   end-to-end time (paper: 15-50%).
//!
//! ```bash
//! cargo bench --bench table4
//! ```

#[path = "common.rs"]
mod common;

use std::time::{Duration, Instant};

use rac_hac::dist::{DistConfig, DistRacEngine};
use rac_hac::graph::Graph;
use rac_hac::linkage::Linkage;
use rac_hac::util::bench::Table;

struct Row {
    name: &'static str,
    machines: usize,
    cpus: usize,
    merges: usize,
    rounds: usize,
    merge_time: Duration,
}

fn run_row(name: &'static str, g: &Graph, machines: usize, cpus: usize) -> Row {
    let t = Instant::now();
    let r = DistRacEngine::new(
        g,
        Linkage::Complete,
        DistConfig::new(machines, cpus),
    )
    .run();
    let merge_time = t.elapsed();
    Row {
        name,
        machines,
        cpus,
        merges: r.metrics.total_merges(),
        rounds: r.metrics.merge_rounds(),
        merge_time,
    }
}

fn main() {
    // Paper rows -> scaled substitutes (machines/cpus scaled to host):
    //   WEB88M  (88M, cosine, sparse)  -> docs 20K, k=30
    //   SIFT1B  (1B, l2, sparse kNN)   -> sift 30K, k=20
    //   SIFT1M  (1M, l2, COMPLETE)     -> sift 3K complete
    //   SIFT200K(200K, l2, sparse)     -> sift 8K, k=16
    eprintln!("[table4] building workloads (cached across runs)...");
    let web = common::docs_knn(20_000, 64, 100, 60, 11);
    let sift1b = common::sift_knn(30_000, 64, 20, 7);
    let sift1m = common::sift_complete(3_000, 64, 7);
    let sift200k = common::sift_knn(8_000, 64, 16, 9);

    let rows = vec![
        run_row("WEB88M-like", &web, 8, 2),
        run_row("SIFT1B-like", &sift1b, 8, 2),
        run_row("SIFT1M-like", &sift1m, 8, 1),
        run_row("SIFT200K-like", &sift200k, 4, 1),
    ];

    let base = rows[0].merge_time.as_secs_f64();
    println!("\n=== Table 4: Performance of RAC on large datasets (scaled) ===");
    let t = Table::new(
        &["Metric", "WEB88M~", "SIFT1B~", "SIFT1M~", "SIFT200K~"],
        &[24, 10, 10, 10, 10],
    );
    let fmt_row = |label: &str, f: &dyn Fn(&Row) -> String| {
        let cells: Vec<String> = rows.iter().map(f).collect();
        t.row(&[
            label,
            &cells[0],
            &cells[1],
            &cells[2],
            &cells[3],
        ]);
    };
    fmt_row("# of Machines", &|r| r.machines.to_string());
    fmt_row("CPUs/Machine", &|r| r.cpus.to_string());
    fmt_row("Merges", &|r| r.merges.to_string());
    fmt_row("Merge Rounds", &|r| r.rounds.to_string());
    fmt_row("Merge Time (relative)", &|r| {
        format!("{:.2}", r.merge_time.as_secs_f64() / base)
    });
    println!(
        "\npaper (Table 4):      WEB88M     SIFT1B     SIFT1M    SIFT200K\n\
         paper Merge Rounds:      170        182        124         112\n\
         paper Merge Time:        1.0        2.0       32.0           9"
    );

    // Qualitative checks (the shape claims).
    for r in &rows {
        assert!(
            r.rounds < 600,
            "{}: {} rounds — expected low hundreds",
            r.name,
            r.rounds
        );
        assert!(
            r.rounds * 10 < r.merges,
            "{}: rounds not << merges",
            r.name
        );
    }
    let rel_complete = rows[2].merge_time.as_secs_f64() / base;
    let rel_sparse_big = rows[1].merge_time.as_secs_f64() / base;
    println!(
        "\ncomplete-vs-sparse: SIFT1M-like {rel_complete:.2} vs SIFT1B-like {rel_sparse_big:.2} \
         (paper: 32.0 vs 2.0 — complete graphs pay for neighborhood shuttling)"
    );

    println!("\ntable4 bench OK");
}
