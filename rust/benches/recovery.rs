//! Fault-tolerance cost harness for the executed distributed mode.
//!
//! ```bash
//! cargo bench --bench recovery                    # human tables
//! cargo bench --bench recovery -- --json          # + BENCH_recovery.json
//! cargo bench --bench recovery -- --json --smoke  # CI short-budget mode
//! cargo bench --bench recovery -- --json --out target/recovery.json
//! ```
//!
//! Two claims of the v2 fault-tolerance subsystem, both asserted
//! in-bench on pinned multi-sync workloads (the barrier-collapsing
//! batched `dist_approx` engine, where cuts are sparse and segments are
//! long — exactly where checkpoint and recovery cost matter):
//!
//! * **Delta checkpoints are cheaper than full blobs.** The default
//!   cadence (a full blob every 4th cut, dirty-row deltas between) must
//!   cut *strictly* fewer total bytes than the v1 behaviour of a full
//!   blob at every cut (`checkpoint_full_every = 1`), on the same
//!   schedule, with a bitwise-identical dendrogram.
//! * **Shard replay is cheaper than global rollback.** For the same
//!   mid-segment fault, journaled single-shard replay must replay
//!   *strictly* fewer machine-rounds than restarting the whole fleet
//!   from the last cut — the survivors' work is exactly what the
//!   journal saves. Both land on the unfaulted run's bits.
//!
//! CI uploads the JSON as a perf-trajectory artifact next to
//! `BENCH_dist_sync.json`.

use rac_hac::approx::ApproxResult;
use rac_hac::data;
use rac_hac::dist::{
    DistApproxEngine, DistConfig, ExecOptions, FaultSpec, RecoveryMode, SyncMode,
};
use rac_hac::graph::Graph;
use rac_hac::linkage::Linkage;
use rac_hac::util::bench::Table;
use rac_hac::util::json::{obj, Json};

const TOPO: (usize, usize) = (4, 2);
const EPSILON: f64 = 0.1;
const VSHARDS: u32 = 8;
const FAULT_MACHINE: usize = 1;

struct Workload {
    name: &'static str,
    graph: Graph,
}

fn workloads(smoke: bool) -> Vec<Workload> {
    // Both collapse barriers under batched sync (dist_sync pins that),
    // so their cut schedules leave real multi-round segments to recover.
    let levels = if smoke { 6 } else { 8 };
    vec![
        Workload {
            name: "adversarial",
            graph: data::adversarial_thm4(levels),
        },
        Workload {
            name: "stable_hierarchy",
            graph: data::stable_hierarchy(levels, 4.0, 23),
        },
    ]
}

fn run(g: &Graph, opts: ExecOptions) -> ApproxResult {
    DistApproxEngine::new(g, Linkage::Average, DistConfig::new(TOPO.0, TOPO.1), EPSILON)
        .with_sync_mode(SyncMode::Batched { vshards: VSHARDS })
        .with_exec(opts)
        .run()
}

struct Cell {
    workload: &'static str,
    scenario: &'static str,
    recovery_mode: &'static str,
    checkpoint_full_every: usize,
    fault_round: Option<usize>,
    rounds: usize,
    merges: usize,
    checkpoint_bytes: usize,
    recovery_rounds_replayed: usize,
    recovery_bytes_replayed: usize,
    t_recover_us: usize,
    t_exec_us: usize,
}

impl Cell {
    fn new(
        workload: &'static str,
        scenario: &'static str,
        recovery_mode: &'static str,
        checkpoint_full_every: usize,
        fault_round: Option<usize>,
        res: &ApproxResult,
    ) -> Cell {
        let m = &res.metrics;
        Cell {
            workload,
            scenario,
            recovery_mode,
            checkpoint_full_every,
            fault_round,
            rounds: m.rounds.len(),
            merges: res.dendrogram.merges().len(),
            checkpoint_bytes: m.checkpoint_bytes,
            recovery_rounds_replayed: m.recovery_rounds_replayed,
            recovery_bytes_replayed: m.recovery_bytes_replayed,
            t_recover_us: m.t_recover.as_micros() as usize,
            t_exec_us: m.total_exec_time().as_micros() as usize,
        }
    }

    fn to_json(&self) -> Json {
        obj([
            ("workload", self.workload.into()),
            ("scenario", self.scenario.into()),
            ("recovery_mode", self.recovery_mode.into()),
            ("checkpoint_full_every", self.checkpoint_full_every.into()),
            ("fault_round", self.fault_round.unwrap_or(0).into()),
            ("faulted", self.fault_round.is_some().into()),
            ("rounds", self.rounds.into()),
            ("merges", self.merges.into()),
            ("checkpoint_bytes", self.checkpoint_bytes.into()),
            (
                "recovery_rounds_replayed",
                self.recovery_rounds_replayed.into(),
            ),
            (
                "recovery_bytes_replayed",
                self.recovery_bytes_replayed.into(),
            ),
            ("t_recover_us", self.t_recover_us.into()),
            ("t_exec_us", self.t_exec_us.into()),
        ])
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let write_json = args.iter().any(|a| a == "--json");
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| "BENCH_recovery.json".to_string());

    let mut cells: Vec<Cell> = Vec::new();
    let mut workload_meta: Vec<Json> = Vec::new();
    for w in workloads(smoke) {
        println!("== workload {}: n={} edges={} ==", w.name, w.graph.n(), w.graph.m());

        // Checkpoint cells: same schedule, full-blob cadence vs the
        // default delta cadence.
        let full_cadence = run(
            &w.graph,
            ExecOptions {
                checkpoint_full_every: 1,
                ..ExecOptions::default()
            },
        );
        let delta_cadence = run(&w.graph, ExecOptions::default());
        assert_eq!(
            full_cadence.dendrogram.bitwise_merges(),
            delta_cadence.dendrogram.bitwise_merges(),
            "{}: checkpoint cadence changed the dendrogram",
            w.name
        );
        assert!(
            delta_cadence.metrics.checkpoint_bytes < full_cadence.metrics.checkpoint_bytes,
            "{}: delta cadence cut {} checkpoint bytes, full cadence {} — deltas must be \
             strictly cheaper",
            w.name,
            delta_cadence.metrics.checkpoint_bytes,
            full_cadence.metrics.checkpoint_bytes
        );
        cells.push(Cell::new(
            w.name,
            "clean_full_cadence",
            "none",
            1,
            None,
            &full_cadence,
        ));
        let default_cadence = ExecOptions::default().checkpoint_full_every;
        cells.push(Cell::new(
            w.name,
            "clean_delta_cadence",
            "none",
            default_cadence,
            None,
            &delta_cadence,
        ));

        // Recovery cells: fault the same machine at a mid-segment round —
        // one where the previous round did not sync, so there is real
        // work between the last cut and the fault. The batched engine's
        // barrier collapse (pinned in dist_sync) guarantees one exists.
        let schedule: Vec<usize> = delta_cadence
            .metrics
            .rounds
            .iter()
            .map(|r| r.sync_points)
            .collect();
        let fault_round = (1..schedule.len())
            .find(|&f| schedule[f - 1] == 0)
            .unwrap_or_else(|| {
                panic!(
                    "{}: no mid-segment round in sync schedule {schedule:?} — \
                     the workload no longer batches",
                    w.name
                )
            });
        let faulted = |mode: RecoveryMode| {
            run(
                &w.graph,
                ExecOptions {
                    faults: vec![FaultSpec {
                        machine: FAULT_MACHINE,
                        round: fault_round,
                    }],
                    recovery_mode: mode,
                    ..ExecOptions::default()
                },
            )
        };
        let global = faulted(RecoveryMode::Global);
        let shard = faulted(RecoveryMode::ShardReplay);
        for (name, res) in [("global", &global), ("shard_replay", &shard)] {
            assert_eq!(
                delta_cadence.dendrogram.bitwise_merges(),
                res.dendrogram.bitwise_merges(),
                "{}: {name} recovery diverged from the unfaulted run",
                w.name
            );
        }
        assert!(
            global.metrics.recovery_rounds_replayed > 0,
            "{}: mid-segment fault at round {fault_round} replayed nothing under global \
             rollback",
            w.name
        );
        assert!(
            shard.metrics.recovery_rounds_replayed < global.metrics.recovery_rounds_replayed,
            "{}: shard replay replayed {} machine-rounds, global rollback {} — replaying \
             one shard must be strictly cheaper",
            w.name,
            shard.metrics.recovery_rounds_replayed,
            global.metrics.recovery_rounds_replayed
        );
        cells.push(Cell::new(
            w.name,
            "fault_mid_segment",
            "global",
            default_cadence,
            Some(fault_round),
            &global,
        ));
        cells.push(Cell::new(
            w.name,
            "fault_mid_segment",
            "shard_replay",
            default_cadence,
            Some(fault_round),
            &shard,
        ));

        workload_meta.push(obj([
            ("name", w.name.into()),
            ("n", w.graph.n().into()),
            ("edges", w.graph.m().into()),
            ("fault_round", fault_round.into()),
        ]));

        let t = Table::new(
            &[
                "scenario", "recovery", "full_every", "fault", "rounds", "ckpt_B", "replay_rnds",
                "replay_B", "t_recover", "t_exec",
            ],
            &[20, 13, 11, 6, 7, 10, 12, 10, 11, 11],
        );
        for c in cells.iter().filter(|c| c.workload == w.name) {
            t.row(&[
                c.scenario,
                c.recovery_mode,
                &c.checkpoint_full_every.to_string(),
                &c.fault_round.map_or("-".to_string(), |f| f.to_string()),
                &c.rounds.to_string(),
                &c.checkpoint_bytes.to_string(),
                &c.recovery_rounds_replayed.to_string(),
                &c.recovery_bytes_replayed.to_string(),
                &format!("{}us", c.t_recover_us),
                &format!("{}us", c.t_exec_us),
            ]);
        }
        println!();
    }

    // Headline: both inequalities on the adversarial chain.
    let pick = |scenario: &str, mode: &str| {
        cells
            .iter()
            .find(|c| {
                c.workload == "adversarial" && c.scenario == scenario && c.recovery_mode == mode
            })
            .expect("headline cell measured")
    };
    let (full, delta) = (
        pick("clean_full_cadence", "none"),
        pick("clean_delta_cadence", "none"),
    );
    let (global, shard) = (
        pick("fault_mid_segment", "global"),
        pick("fault_mid_segment", "shard_replay"),
    );
    println!(
        "headline (adversarial, 4x2, eps={EPSILON}, batched): checkpoints {}B delta-chained \
         vs {}B all-full; recovery replayed {} machine-rounds shard vs {} global",
        delta.checkpoint_bytes,
        full.checkpoint_bytes,
        shard.recovery_rounds_replayed,
        global.recovery_rounds_replayed,
    );

    if write_json {
        let report = obj([
            ("schema", "bench_recovery/v1".into()),
            ("mode", (if smoke { "smoke" } else { "full" }).into()),
            ("epsilon", EPSILON.into()),
            ("machines", TOPO.0.into()),
            ("cpus", TOPO.1.into()),
            ("vshards", (VSHARDS as usize).into()),
            ("workloads", Json::Arr(workload_meta)),
            (
                "headline",
                obj([
                    ("workload", "adversarial".into()),
                    ("checkpoint_bytes_full", full.checkpoint_bytes.into()),
                    ("checkpoint_bytes_delta", delta.checkpoint_bytes.into()),
                    (
                        "replayed_machine_rounds_global",
                        global.recovery_rounds_replayed.into(),
                    ),
                    (
                        "replayed_machine_rounds_shard",
                        shard.recovery_rounds_replayed.into(),
                    ),
                    ("t_recover_us_global", global.t_recover_us.into()),
                    ("t_recover_us_shard", shard.t_recover_us.into()),
                ]),
            ),
            ("cells", Json::Arr(cells.iter().map(Cell::to_json).collect())),
        ]);
        std::fs::write(&out_path, format!("{report}\n")).expect("write bench report");
        println!("\nwrote {out_path}");
    }

    println!("\nrecovery bench OK");
}
