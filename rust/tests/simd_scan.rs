//! Differential suite for the SIMD row-scan kernels
//! (`rac_hac::store::scan`): every vector kernel the machine supports
//! must be **bitwise** equal to the scalar reference on both hot scans —
//! per raw row (random / tie-heavy / tombstone-heavy, every length and
//! remainder shape), through the store's padded rows, and end-to-end
//! through full dendrograms of all five engines under forced-scalar vs
//! forced-SIMD dispatch.

use rac_hac::approx::ApproxEngine;
use rac_hac::data::{random_sparse_graph, random_tied_graph};
use rac_hac::dist::{DistApproxEngine, DistConfig, DistRacEngine};
use rac_hac::graph::Graph;
use rac_hac::linkage::{EdgeState, Linkage, Weight};
use rac_hac::rac::baseline::HashRacEngine;
use rac_hac::rac::RacEngine;
use rac_hac::store::scan::{self, Kernel, LANES, NO_NN};
use rac_hac::store::{Entry, NeighborStore, NeighborsRef, TOMBSTONE};
use rac_hac::util::prop::for_all_seeds;
use rac_hac::util::rng::Rng;

fn entry(id: u32, w: Weight) -> Entry {
    Entry {
        id,
        edge: EdgeState { weight: w, count: 1 },
    }
}

#[derive(Clone, Copy)]
enum Style {
    /// Continuous weights, occasional NaN (which must never win).
    Random,
    /// Quantised weights (many exact ties), ±0.0 included.
    TieHeavy,
    /// Mostly dead slots, each keeping a tempting stale finite weight.
    TombstoneHeavy,
}

/// Build a row of `len` slots with unique live ids and style-dependent
/// weights. Dead slots keep a finite stale weight — exactly what the
/// arena leaves behind after `remove` — so a kernel that forgets to mask
/// before comparing weights fails here.
fn make_row(rng: &mut Rng, len: usize, style: Style) -> Vec<Entry> {
    let mut ids: Vec<u32> = (0..(3 * len.max(1)) as u32).collect();
    rng.shuffle(&mut ids);
    (0..len)
        .map(|i| {
            let dead = match style {
                Style::Random => rng.bool_with(0.15),
                Style::TieHeavy => rng.bool_with(0.15),
                Style::TombstoneHeavy => rng.bool_with(0.7),
            };
            let w = match style {
                Style::Random => {
                    if rng.bool_with(0.05) {
                        Weight::NAN
                    } else {
                        rng.range_f64(0.0, 4.0)
                    }
                }
                Style::TieHeavy | Style::TombstoneHeavy => {
                    let w = rng.below(4) as f64 * 0.25;
                    if w == 0.0 && rng.bool_with(0.5) {
                        -0.0
                    } else {
                        w
                    }
                }
            };
            let id = if dead { TOMBSTONE } else { ids[i] };
            entry(id, w)
        })
        .collect()
}

fn styles() -> [Style; 3] {
    [Style::Random, Style::TieHeavy, Style::TombstoneHeavy]
}

/// `(weight, id)`-min scan: every supported kernel bitwise-equals the
/// scalar fold on every row length (all chunk/remainder shapes).
#[test]
fn nn_kernels_match_scalar_bitwise() {
    let kernels = scan::available();
    for_all_seeds(0x51D0_0001, 8, |rng| {
        for style in styles() {
            for len in 0..=4 * LANES + 3 {
                let row = make_row(rng, len, style);
                let (want_id, want_w) = scan::scan_nn_with(Kernel::Scalar, &row);
                for &k in &kernels {
                    let (id, w) = scan::scan_nn_with(k, &row);
                    assert_eq!(
                        (id, w.to_bits()),
                        (want_id, want_w.to_bits()),
                        "{} diverged from scalar on len {len} row {row:?}",
                        k.name()
                    );
                }
            }
        }
    });
}

/// ε-good band sweep: every supported kernel visits the same entries in
/// the same (storage) order with the same weight bits as the scalar
/// filter — including exact-boundary thresholds and `id > a` cuts.
#[test]
fn band_kernels_match_scalar_bitwise() {
    let kernels = scan::available();
    for_all_seeds(0x51D0_0002, 8, |rng| {
        for style in styles() {
            for len in 0..=4 * LANES + 3 {
                let row = make_row(rng, len, style);
                // Threshold: often exactly a weight present in the row
                // (the band boundary), sometimes random, sometimes +inf.
                let live: Vec<&Entry> = row.iter().filter(|e| e.id != TOMBSTONE).collect();
                let thr = match (live.is_empty(), rng.below(4)) {
                    (false, 0 | 1) => live[rng.below(live.len())].edge.weight,
                    (_, 2) => Weight::INFINITY,
                    _ => rng.range_f64(0.0, 4.0),
                };
                // nn pointer: a live id, NO_NN, or arbitrary.
                let nn_a = match (live.is_empty(), rng.below(3)) {
                    (false, 0) => live[rng.below(live.len())].id,
                    (_, 1) => NO_NN,
                    _ => rng.below(64) as u32,
                };
                let a = rng.below(3 * len.max(1)) as u32;
                let mut want = Vec::new();
                scan::scan_band_with(Kernel::Scalar, &row, a, thr, nn_a, &mut |b, w| {
                    want.push((b, w.to_bits()));
                });
                for &k in &kernels {
                    let mut got = Vec::new();
                    scan::scan_band_with(k, &row, a, thr, nn_a, &mut |b, w| {
                        got.push((b, w.to_bits()));
                    });
                    assert_eq!(
                        got,
                        want,
                        "{} diverged from scalar: a={a} thr={thr} nn={nn_a} row {row:?}",
                        k.name()
                    );
                }
            }
        }
    });
}

/// Regression for the vacant-padding trap: an isolated cluster's band is
/// `thr = +inf, nn = u32::MAX`, and a vacant pad slot decodes to exactly
/// that boundary `(+inf, u32::MAX)` — the dead mask must reject it on
/// every kernel.
#[test]
fn vacant_padding_never_enters_an_isolated_band() {
    let row = vec![Entry::VACANT; 2 * LANES];
    for &k in &scan::available() {
        let mut hits = Vec::new();
        scan::scan_band_with(k, &row, 0, Weight::INFINITY, NO_NN, &mut |b, w| {
            hits.push((b, w));
        });
        assert!(hits.is_empty(), "{}: padding leaked {hits:?}", k.name());
        let (id, w) = scan::scan_nn_with(k, &row);
        assert_eq!((id, w), (NO_NN, Weight::INFINITY), "{}", k.name());
    }
}

/// The kernels through the store itself: padded `RowRef` spans (including
/// rows churned by removes) scan identically on every kernel, and the
/// `RowRef` fast paths agree with the scalar `NeighborsRef` defaults
/// through the hashmap backend.
#[test]
fn store_rows_scan_identically_on_every_kernel() {
    for_all_seeds(0x51D0_0003, 6, |rng| {
        let g = random_sparse_graph(rng);
        let mut s = NeighborStore::from_graph(&g);
        // Churn some tombstones into the rows.
        for u in 0..g.n() as u32 {
            for (v, _) in g.neighbors(u) {
                if rng.bool_with(0.2) {
                    s.remove(u, v);
                }
            }
        }
        for c in 0..g.n() as u32 {
            let row = s.row(c);
            let span = row.entries();
            assert_eq!(span.len() % LANES, 0, "row {c} span not lane-padded");
            let want = scan::scan_nn_with(Kernel::Scalar, span);
            for &k in &scan::available() {
                let got = scan::scan_nn_with(k, span);
                assert_eq!(
                    (got.0, got.1.to_bits()),
                    (want.0, want.1.to_bits()),
                    "{}: row {c}",
                    k.name()
                );
            }
            // RowRef override vs the trait's scalar default (hashmap
            // view of the same live edges): nn_min is order-independent
            // so the comparison is bitwise.
            let map: rustc_hash::FxHashMap<u32, EdgeState> = row.iter().collect();
            let (mi, mw) = (&map).nn_min();
            assert_eq!((want.0, want.1.to_bits()), (mi, mw.to_bits()), "row {c}");
        }
    });
}

fn run_all_engines(g: &Graph, l: Linkage) -> Vec<Vec<(u32, u32, u64)>> {
    vec![
        RacEngine::new(g, l).with_threads(2).run().dendrogram.bitwise_merges(),
        HashRacEngine::new(g, l).with_threads(1).run().dendrogram.bitwise_merges(),
        ApproxEngine::new(g, l, 0.1).run().dendrogram.bitwise_merges(),
        DistRacEngine::new(g, l, DistConfig::new(3, 2)).run().dendrogram.bitwise_merges(),
        DistApproxEngine::new(g, l, DistConfig::new(3, 2), 0.1).run().dendrogram.bitwise_merges(),
    ]
}

/// End-to-end: forcing the scalar fallback vs the detected SIMD dispatch
/// must produce bitwise-identical dendrograms for all five engines, on
/// continuous and tie-heavy graphs, for every sparse-reducible linkage.
/// The entry dispatch is restored afterward (via [`scan::KernelPin`]) so
/// an `RAC_FORCE_SCALAR` pin keeps governing the rest of this binary —
/// the forced-scalar CI pass must stay a forced-scalar pass.
#[test]
fn forced_scalar_and_forced_simd_full_runs_agree() {
    let _restore_entry_dispatch = scan::KernelPin::pin(scan::active());
    for_all_seeds(0x51D0_0004, 4, |rng| {
        let g = if rng.bool_with(0.5) {
            random_tied_graph(rng)
        } else {
            random_sparse_graph(rng)
        };
        for l in Linkage::SPARSE_REDUCIBLE {
            let scalar = {
                let _pin = scan::KernelPin::scalar();
                run_all_engines(&g, l)
            };
            let simd = {
                let _pin = scan::KernelPin::pin(scan::detect());
                run_all_engines(&g, l)
            };
            assert_eq!(
                scalar,
                simd,
                "{l:?}: scalar and {} dispatch diverged (n={})",
                scan::detect().name(),
                g.n()
            );
        }
    });
}
