//! Distributed-engine invariants: determinism across topologies, network
//! accounting sanity, metrics consistency, and robustness properties.

use rac_hac::data::{gaussian_mixture, grid1d_graph, topic_docs};
use rac_hac::dist::{DistConfig, DistRacEngine};
use rac_hac::graph::Graph;
use rac_hac::knn::{knn_graph, Backend};
use rac_hac::linkage::Linkage;
use rac_hac::rac::RacEngine;
use rac_hac::util::prop::for_all_seeds;

fn workload(seed: u64) -> Graph {
    let ds = gaussian_mixture(400, 16, 10, 0.6, 0.05, seed);
    knn_graph(&ds, 8, Backend::Native, None).unwrap()
}

#[test]
fn identical_dendrogram_across_topologies() {
    let g = workload(1);
    let base = DistRacEngine::new(&g, Linkage::Average, DistConfig::new(1, 1)).run();
    for (m, c) in [(2, 1), (3, 2), (7, 1), (16, 4)] {
        let r = DistRacEngine::new(
            &g,
            Linkage::Average,
            DistConfig::new(m, c),
        )
        .run();
        assert!(
            base.dendrogram.same_clustering(&r.dendrogram, 1e-12),
            "topology ({m},{c}) changed the clustering"
        );
        // Merge ROUND structure must also be identical (the algorithm is
        // deterministic; only wall-clock may differ).
        let rounds_a: Vec<usize> = base.metrics.rounds.iter().map(|x| x.merges).collect();
        let rounds_b: Vec<usize> = r.metrics.rounds.iter().map(|x| x.merges).collect();
        assert_eq!(rounds_a, rounds_b, "topology ({m},{c}) changed round structure");
    }
}

#[test]
fn repeated_runs_are_bitwise_deterministic() {
    let g = workload(2);
    let cfg = DistConfig::new(4, 2);
    let a = DistRacEngine::new(&g, Linkage::Complete, cfg).run();
    let b = DistRacEngine::new(&g, Linkage::Complete, cfg).run();
    let ma: Vec<_> = a.dendrogram.merges().iter().map(|m| (m.a, m.b, m.weight)).collect();
    let mb: Vec<_> = b.dendrogram.merges().iter().map(|m| (m.a, m.b, m.weight)).collect();
    assert_eq!(ma, mb, "same run must produce identical merge lists");
}

#[test]
fn single_machine_has_zero_network() {
    let g = workload(3);
    let r = DistRacEngine::new(&g, Linkage::Average, DistConfig::new(1, 4)).run();
    assert_eq!(r.metrics.total_net_messages(), 0);
    assert_eq!(r.metrics.total_net_bytes(), 0);
}

#[test]
fn network_grows_with_machines() {
    let g = workload(4);
    let mut prev = 0usize;
    for m in [2usize, 4, 8] {
        let r = DistRacEngine::new(
            &g,
            Linkage::Average,
            DistConfig::new(m, 1),
        )
        .run();
        let bytes = r.metrics.total_net_bytes();
        assert!(bytes > prev, "bytes must grow with shard count");
        prev = bytes;
    }
}

#[test]
fn metrics_account_merges_and_clusters() {
    for_all_seeds(0xACC7, 8, |rng| {
        let g = workload(rng.next_u64());
        let r = DistRacEngine::new(
            &g,
            Linkage::Average,
            DistConfig::new(3, 2),
        )
        .run();
        // Merge conservation.
        assert_eq!(r.metrics.total_merges(), r.dendrogram.merges().len());
        // Cluster-count recurrence: clusters_{t+1} = clusters_t - merges_t.
        for w in r.metrics.rounds.windows(2) {
            assert_eq!(w[1].clusters, w[0].clusters - w[0].merges);
        }
        // Alpha/beta in sane ranges.
        for rm in &r.metrics.rounds {
            assert!(rm.alpha() <= 0.5 + 1e-9, "alpha can never exceed 1/2");
            assert!(rm.nn_updates <= rm.clusters);
        }
    });
}

#[test]
fn beta_stays_bounded_on_metric_graphs() {
    // Theorem 9's beta assumption, on the workload class the paper says it
    // holds for.
    let g = workload(5);
    let r = DistRacEngine::new(&g, Linkage::Complete, DistConfig::new(4, 1)).run();
    assert!(
        r.metrics.max_beta() <= g.max_degree() as f64,
        "beta {} exceeded max degree {}",
        r.metrics.max_beta(),
        g.max_degree()
    );
}

#[test]
fn handles_disconnected_graphs() {
    // Forest of components, one per island; engine must stop cleanly.
    let mut edges = Vec::new();
    for island in 0..10u32 {
        let b = island * 10;
        for i in 0..9 {
            edges.push((b + i, b + i + 1, 1.0 + (i as f64) * 0.1 + island as f64 * 0.01));
        }
    }
    let g = Graph::from_edges(100, edges);
    let r = DistRacEngine::new(&g, Linkage::Average, DistConfig::new(4, 2)).run();
    assert_eq!(r.dendrogram.merges().len(), 90);
    assert_eq!(r.dendrogram.remaining_clusters(), 10);
}

#[test]
fn more_machines_than_clusters() {
    let g = grid1d_graph(5, 1);
    let r = DistRacEngine::new(&g, Linkage::Single, DistConfig::new(16, 4)).run();
    assert_eq!(r.dendrogram.merges().len(), 4);
}

#[test]
fn max_rounds_cap_halts_cleanly() {
    let g = workload(6);
    let r = DistRacEngine::new(&g, Linkage::Average, DistConfig::default())
        .with_max_rounds(3)
        .run();
    assert!(r.metrics.rounds.len() <= 3);
    assert!(r.dendrogram.merges().len() < g.n());
    r.dendrogram.validate().unwrap();
}

#[test]
fn cosine_docs_workload_round_trip() {
    let ds = topic_docs(300, 32, 8, 9);
    let g = knn_graph(&ds, 6, Backend::Native, None).unwrap();
    let shared = RacEngine::new(&g, Linkage::Average).run();
    let dist = DistRacEngine::new(&g, Linkage::Average, DistConfig::new(5, 2)).run();
    assert!(shared.dendrogram.same_clustering(&dist.dendrogram, 1e-12));
}
