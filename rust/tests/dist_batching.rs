//! Differential suite for the batched `dist_approx` engine (TeraHAC-style
//! shard-local subgraph batching, `SyncMode::Batched`) against the
//! per-round engine and the shared-memory oracles.
//!
//! Contracts under test:
//!
//! * **Topology invariance, bitwise** — the batched merge schedule is a
//!   pure function of `(graph, ε, vshards)`: the subgraph partition is
//!   `vshard_of(id, n, vshards)`, never the machine count, so dendrogram
//!   AND quality trace are bitwise identical across `(machines, cpus)`
//!   topologies (the sharding layer stays accounting-only).
//! * **The (1+ε) band** — every recorded merge audits within `1 + ε` of
//!   the minimum linkage visible to either endpoint, via
//!   [`quality::merge_quality_ratio`] over the trace, not the engine's
//!   own selection code. At ε = 0 the ratio is exactly 1 — every merge
//!   happens at its visible minimum — even on tie-heavy graphs.
//! * **ε = 0 dendrogram equality** — with distinct linkage values the
//!   batched schedule merges only reciprocal-NN pairs, so it builds the
//!   same merge *tree* as the unbatched engine (= RAC = HAC); grouping
//!   merges into different rounds associates the Lance–Williams folds
//!   differently, so the comparison is `same_clustering`, not bitwise
//!   (the bitwise ε = 0 anchor belongs to the unbatched engine and is
//!   pinned in `approx_quality.rs` / `store_equivalence.rs`).
//! * **Sync-point accounting** — `sync_points <= rounds` always (each
//!   round is at most one global barrier), every round of the per-round
//!   engines is exactly one sync point, wire traffic flows only in sync
//!   rounds, and on the round-collapse workloads (Theorem-4 adversarial
//!   chain, Theorem-5 stable hierarchy) the inequality is **strict**:
//!   batching provably takes global synchronisation off some rounds.
//! * **Per-shard driver equivalence** — the batched engine's pre-sync
//!   merge prefix is bitwise the run of the shared-memory
//!   [`RoundDriver`] under a [`GoodSelector`] scoped to the same virtual
//!   shards ([`VShardScope`]): the local phase *is* the shared driver
//!   restricted to locally-owned edges.

use rac_hac::approx::quality;
use rac_hac::data;
use rac_hac::data::{random_sparse_graph, random_tied_graph};
use rac_hac::dist::{vshard_of, DistApproxEngine, DistConfig, SyncMode, VShardScope};
use rac_hac::engine::{GoodSelector, RoundDriver};
use rac_hac::graph::Graph;
use rac_hac::linkage::Linkage;
use rac_hac::rac::RacEngine;
use rac_hac::store::NeighborStore;
use rac_hac::util::prop::for_all_seeds;

const TOPOLOGIES: [(usize, usize); 3] = [(1, 1), (3, 2), (7, 4)];
const EPSILONS: [f64; 3] = [0.0, 0.1, 1.0];
const VSHARDS: u32 = 8;

fn batched(
    g: &Graph,
    linkage: Linkage,
    (machines, cpus): (usize, usize),
    eps: f64,
) -> rac_hac::approx::ApproxResult {
    DistApproxEngine::new(g, linkage, DistConfig::new(machines, cpus), eps)
        .with_sync_mode(SyncMode::Batched { vshards: VSHARDS })
        .run()
}

#[test]
fn batched_dendrogram_and_trace_are_topology_invariant_bitwise() {
    for_all_seeds(0xBA7C1, 8, |rng| {
        let g = if rng.bool_with(0.5) {
            random_tied_graph(rng)
        } else {
            random_sparse_graph(rng)
        };
        for eps in EPSILONS {
            let base = batched(&g, Linkage::Average, TOPOLOGIES[0], eps);
            for &topo in &TOPOLOGIES[1..] {
                let r = batched(&g, Linkage::Average, topo, eps);
                assert_eq!(
                    base.dendrogram.bitwise_merges(),
                    r.dendrogram.bitwise_merges(),
                    "eps={eps} topology={topo:?} (n={})",
                    g.n()
                );
                let key = |bs: &[quality::MergeBound]| -> Vec<(u64, u64)> {
                    bs.iter()
                        .map(|b| (b.weight.to_bits(), b.visible_min.to_bits()))
                        .collect()
                };
                assert_eq!(
                    key(&base.bounds),
                    key(&r.bounds),
                    "eps={eps} topology={topo:?}: quality trace diverged"
                );
                // The sync schedule is part of the algorithm, not the
                // deployment: identical per-round sync flags everywhere.
                let syncs = |m: &rac_hac::metrics::RunMetrics| -> Vec<usize> {
                    m.rounds.iter().map(|r| r.sync_points).collect()
                };
                assert_eq!(
                    syncs(&base.metrics),
                    syncs(&r.metrics),
                    "eps={eps} topology={topo:?}: sync schedule diverged"
                );
            }
        }
    });
}

#[test]
fn batched_is_topology_invariant_on_the_adversarial_chain() {
    // The deterministic theory generator counterpart of the random
    // property above: the Theorem-4 instance (n = 32), all ε, all
    // topologies — bitwise.
    let g = data::adversarial_thm4(5);
    for eps in EPSILONS {
        let base = batched(&g, Linkage::Average, TOPOLOGIES[0], eps);
        assert_eq!(base.dendrogram.merges().len(), 31, "eps={eps}");
        for &topo in &TOPOLOGIES[1..] {
            let r = batched(&g, Linkage::Average, topo, eps);
            assert_eq!(
                base.dendrogram.bitwise_merges(),
                r.dendrogram.bitwise_merges(),
                "eps={eps} topology={topo:?}"
            );
        }
    }
}

#[test]
fn every_batched_merge_respects_the_goodness_band() {
    for_all_seeds(0xBA7C2, 10, |rng| {
        let g = if rng.bool_with(0.5) {
            random_tied_graph(rng)
        } else {
            random_sparse_graph(rng)
        };
        let reference = RacEngine::new(&g, Linkage::Average).run();
        for eps in EPSILONS {
            let r = batched(&g, Linkage::Average, (3, 2), eps);
            r.dendrogram.validate().unwrap();
            assert_eq!(r.bounds.len(), r.dendrogram.merges().len(), "one bound per merge");
            let ratio = quality::merge_quality_ratio(&r.bounds);
            assert!(
                ratio <= 1.0 + eps + 1e-12,
                "eps={eps}: worst ratio {ratio} (n={})",
                g.n()
            );
            // Batching reschedules merges, never loses them: every
            // component still fully agglomerates.
            assert_eq!(
                r.dendrogram.merges().len(),
                reference.dendrogram.merges().len(),
                "eps={eps} (n={})",
                g.n()
            );
        }
    });
}

#[test]
fn batched_zero_epsilon_quality_is_exact_even_under_ties() {
    // At ε = 0 acceptance requires the merge weight to equal both
    // endpoints' cached minima, so every audited ratio is exactly 1 —
    // including on quantised-weight graphs where tie scheduling may
    // legitimately pick a different (equally exact) tree.
    for_all_seeds(0xBA7C3, 10, |rng| {
        let g = random_tied_graph(rng);
        let r = batched(&g, Linkage::Average, (3, 2), 0.0);
        assert_eq!(quality::merge_quality_ratio(&r.bounds), 1.0, "n={}", g.n());
    });
}

#[test]
fn batched_zero_epsilon_matches_unbatched_dendrogram_wise() {
    // Continuous weights (no ties): the batched ε = 0 schedule merges
    // only reciprocal-NN pairs, so the merge tree equals the unbatched
    // engine's (= RAC's); only the round grouping — and with it the FP
    // association of the folds — differs.
    for_all_seeds(0xBA7C4, 12, |rng| {
        let g = random_sparse_graph(rng);
        for l in Linkage::SPARSE_REDUCIBLE {
            let unbatched = DistApproxEngine::new(&g, l, DistConfig::new(3, 2), 0.0).run();
            let b = batched(&g, l, (3, 2), 0.0);
            assert!(
                unbatched.dendrogram.same_clustering(&b.dendrogram, 1e-9),
                "{l:?}: batched eps=0 tree diverged (n={})",
                g.n()
            );
        }
    });
}

#[test]
fn sync_points_bounded_by_rounds_and_traffic_only_at_sync() {
    for_all_seeds(0xBA7C5, 10, |rng| {
        let g = random_sparse_graph(rng);
        let machines = rng.range_usize(1, 8);
        let cores = rng.range_usize(1, 4);
        for eps in [0.1, 1.0] {
            // Per-round engine: every round is exactly one sync point.
            let (u, _) = DistApproxEngine::new(
                &g,
                Linkage::Average,
                DistConfig::new(machines, cores),
                eps,
            )
            .run_detailed();
            assert_eq!(u.metrics.total_sync_points(), u.metrics.rounds.len());

            // Batched engine: monotone improvement, silent local rounds.
            let (b, report) = DistApproxEngine::new(
                &g,
                Linkage::Average,
                DistConfig::new(machines, cores),
                eps,
            )
            .with_sync_mode(SyncMode::Batched { vshards: VSHARDS })
            .run_detailed();
            assert!(b.metrics.total_sync_points() <= b.metrics.rounds.len());
            let mut sync_rounds = Vec::new();
            for rm in &b.metrics.rounds {
                assert!(rm.sync_points <= 1, "a round is at most one barrier");
                assert!(rm.net_bytes >= rm.net_messages);
                if rm.sync_points == 0 {
                    assert_eq!(
                        (rm.net_messages, rm.net_bytes),
                        (0, 0),
                        "round {}: local rounds must be silent",
                        rm.round
                    );
                } else {
                    sync_rounds.push(rm.round);
                }
            }
            for batch in &report.batches {
                assert_ne!(batch.src, batch.dst, "local traffic accounted");
                assert!(
                    sync_rounds.contains(&batch.round),
                    "batch sent in non-sync round {}",
                    batch.round
                );
            }
            if machines == 1 {
                assert!(report.batches.is_empty(), "single machine must be silent");
            }
            assert_eq!(b.metrics.total_net_messages(), report.total_batches());
            assert_eq!(b.metrics.total_net_bytes(), report.total_bytes());
        }
    });
}

/// The Theorem-4 adversarial chain: the exact engine exposes one
/// reciprocal pair per round (Ω(n) rounds); ε-good selection collapses
/// rounds to ~log n (PR 3), and batching takes the global barrier off
/// the shard-local ones — `sync_points < rounds`, strictly, while merges
/// stay O(n).
#[test]
fn adversarial_round_and_sync_point_collapse() {
    let g = data::adversarial_thm4(7); // n = 128
    let exact = RacEngine::new(&g, Linkage::Average).run();
    let exact_rounds = exact.metrics.merge_rounds();
    assert!(exact_rounds >= 100, "exact collapse expected: {exact_rounds}");
    for eps in EPSILONS {
        let u = DistApproxEngine::new(&g, Linkage::Average, DistConfig::new(3, 2), eps).run();
        assert_eq!(u.metrics.total_sync_points(), u.metrics.rounds.len());

        let b = batched(&g, Linkage::Average, (3, 2), eps);
        assert_eq!(b.dendrogram.merges().len(), 127, "eps={eps}");
        let rounds = b.metrics.rounds.len();
        let syncs = b.metrics.total_sync_points();
        assert!(
            syncs < rounds,
            "eps={eps}: no local round batched ({syncs} syncs of {rounds} rounds)"
        );
        let ratio = quality::merge_quality_ratio(&b.bounds);
        assert!(ratio <= 1.0 + eps + 1e-12, "eps={eps}: {ratio}");
    }
    // Explicit round-count collapse at a relaxed band: the batched
    // engine's rounds AND sync points sit far below the exact engine's
    // Ω(n) rounds (merges stay at n - 1 = 127 throughout).
    let b = batched(&g, Linkage::Average, (3, 2), 1.0);
    assert!(
        b.metrics.rounds.len() * 4 < exact_rounds,
        "batched rounds {} vs exact {exact_rounds}",
        b.metrics.rounds.len()
    );
    assert!(
        b.metrics.total_sync_points() * 4 < exact_rounds,
        "batched sync points {} vs exact rounds {exact_rounds}",
        b.metrics.total_sync_points()
    );
}

/// Theorem-5 stable hierarchy: subtrees are contiguous id ranges, so
/// whole subtrees drain inside virtual shards and only the top-of-tree
/// merges need sync points — strictly fewer barriers than rounds, with
/// flat cuts still agreeing with exact HAC (even ε = 1 cannot cross the
/// separation bands).
#[test]
fn stable_hierarchy_sync_point_collapse_with_perfect_cuts() {
    let g = data::stable_hierarchy(6, 4.0, 23); // n = 64
    let hac = rac_hac::hac::naive_hac(&g, Linkage::Average);
    for eps in EPSILONS {
        let b = batched(&g, Linkage::Average, (3, 2), eps);
        assert_eq!(b.dendrogram.merges().len(), 63, "eps={eps}");
        let rounds = b.metrics.rounds.len();
        let syncs = b.metrics.total_sync_points();
        assert!(
            syncs < rounds,
            "eps={eps}: subtree merges did not batch ({syncs} of {rounds})"
        );
        for k in [2usize, 4, 8] {
            let ari = quality::adjusted_rand_index(
                &hac.cut_k(k).unwrap(),
                &b.dendrogram.cut_k(k).unwrap(),
            );
            assert_eq!(ari, 1.0, "eps={eps} k={k}");
        }
    }
}

/// The local phase IS the shared round driver under a vshard-scoped
/// selector: running [`RoundDriver`] with `GoodSelector::scoped(eps,
/// VShardScope)` to its fixed point reproduces, bitwise, the batched
/// engine's merge prefix up to its first sync point — and every scoped
/// merge stays inside one virtual shard.
#[test]
fn scoped_driver_reproduces_the_batched_engines_local_prefix() {
    // Ascending path: weights 1..n-1, so the frontier pair is unique and
    // the local fixed point is exactly "absorb block 0" — deterministic
    // and non-trivial for every ε.
    let n = 64usize;
    let g = Graph::from_edges(
        n,
        (0..n - 1).map(|i| (i as u32, (i + 1) as u32, (i + 1) as f64)),
    );
    for eps in [0.0, 0.5] {
        let mut driver = RoundDriver::new(NeighborStore::from_graph(&g), n, Linkage::Average);
        driver.set_threads(2);
        let mut selector = GoodSelector::scoped(eps, VShardScope::new(n, VSHARDS));
        let scoped = driver.run(&mut selector);
        assert!(
            !scoped.dendrogram.merges().is_empty(),
            "eps={eps}: the scoped fixed point must be non-trivial"
        );
        for m in scoped.dendrogram.merges() {
            assert_eq!(
                vshard_of(m.a, n, VSHARDS),
                vshard_of(m.b, n, VSHARDS),
                "eps={eps}: scoped merge ({}, {}) crossed a virtual shard",
                m.a,
                m.b
            );
        }
        let b = batched(&g, Linkage::Average, (3, 2), eps);
        let prefix_len = scoped.dendrogram.merges().len();
        assert!(b.dendrogram.merges().len() > prefix_len, "sync work remains");
        let full = b.dendrogram.bitwise_merges();
        assert_eq!(
            scoped.dendrogram.bitwise_merges()[..],
            full[..prefix_len],
            "eps={eps}: batched local prefix != scoped driver run"
        );
    }
}

/// vshards is an algorithm knob: one block degenerates to the unbatched
/// schedule's merge set (everything is local until the final sync), and
/// a block per cluster degenerates to the per-round engine exactly.
#[test]
fn vshard_extremes_degenerate_sensibly() {
    let mut rng = rac_hac::util::rng::Rng::seed_from(0xBA7C6);
    let g = random_sparse_graph(&mut rng);
    let n = g.n();
    // One block: every edge is local, so at most the terminal (empty)
    // sync fires — zero when a local round finishes the run outright.
    let one = DistApproxEngine::new(&g, Linkage::Average, DistConfig::new(3, 2), 0.5)
        .with_sync_mode(SyncMode::Batched { vshards: 1 })
        .run();
    assert!(one.metrics.total_sync_points() <= 1);
    // A block per cluster: nothing is ever local, so every round is a
    // sync and the schedule (and dendrogram, bitwise) is the per-round
    // engine's.
    let per_cluster = DistApproxEngine::new(&g, Linkage::Average, DistConfig::new(3, 2), 0.5)
        .with_sync_mode(SyncMode::Batched { vshards: n as u32 })
        .run();
    let unbatched =
        DistApproxEngine::new(&g, Linkage::Average, DistConfig::new(3, 2), 0.5).run();
    assert_eq!(
        per_cluster.metrics.total_sync_points(),
        per_cluster.metrics.rounds.len()
    );
    assert_eq!(
        per_cluster.dendrogram.bitwise_merges(),
        unbatched.dendrogram.bitwise_merges()
    );
}
