//! Property suite for the (1+ε)-approximate engine and its quality
//! instruments.
//!
//! The two contracts under test:
//!
//! * **ε = 0 exactness anchor** — `ApproxEngine` at `ε = 0` produces a
//!   dendrogram **bitwise identical** to [`RacEngine`]'s, on random
//!   sparse graphs for every `SPARSE_REDUCIBLE` linkage and across
//!   thread counts, and on complete graphs for every reducible linkage
//!   (Ward/WPGMA included). This pins the relaxed criterion's
//!   degeneration to reciprocal nearest neighbors *and* the shared
//!   phase-2/3 arithmetic and ordering.
//! * **(1+ε) goodness band** — at any ε every merge's recorded
//!   `(weight, visible minimum)` pair satisfies `ratio <= 1 + ε`, audited
//!   through [`quality::merge_quality_ratio`] rather than the engine's
//!   own selection code.
//!
//! Plus the `cut_k` / `cut_threshold` agreement property that underpins
//! the ARI comparisons (`quality::compare_runs` cuts both dendrograms at
//! the same `k`), and the `dist_approx` topology-invariance property: the
//! sharded ε-good engine is bitwise identical to the shared-memory one
//! for every `(machines, cores, ε)` (the sharding layer is
//! accounting-only, exactly as for the exact engines).
//!
//! The random property graphs (`random_sparse_graph`,
//! `random_tied_graph`) are the crate-shared generators in
//! `rac_hac::data` — the same shapes `store_equivalence` throws at the
//! engines.

use rac_hac::approx::{good, quality, ApproxEngine};
use rac_hac::data;
use rac_hac::data::{random_sparse_graph, random_tied_graph};
use rac_hac::dist::{DistApproxEngine, DistConfig};
use rac_hac::hac::naive_hac;
use rac_hac::linkage::{Linkage, Weight};
use rac_hac::rac::RacEngine;
use rac_hac::util::prop::for_all_seeds;

#[test]
fn zero_epsilon_is_bitwise_exact_on_sparse_graphs() {
    for_all_seeds(0xA9902, 30, |rng| {
        let g = random_sparse_graph(rng);
        for l in Linkage::SPARSE_REDUCIBLE {
            let exact = RacEngine::new(&g, l).with_threads(1).run();
            let approx = ApproxEngine::new(&g, l, 0.0).with_threads(1).run();
            assert_eq!(
                exact.dendrogram.bitwise_merges(),
                approx.dendrogram.bitwise_merges(),
                "{l:?}: eps=0 diverged from the exact engine (n={})",
                g.n()
            );
        }
    });
}

#[test]
fn zero_epsilon_is_bitwise_exact_under_heavy_weight_ties() {
    for_all_seeds(0x71ED, 30, |rng| {
        let g = random_tied_graph(rng);
        for l in Linkage::SPARSE_REDUCIBLE {
            let exact = RacEngine::new(&g, l).with_threads(1).run();
            for threads in [1usize, 4] {
                let approx = ApproxEngine::new(&g, l, 0.0).with_threads(threads).run();
                assert_eq!(
                    exact.dendrogram.bitwise_merges(),
                    approx.dendrogram.bitwise_merges(),
                    "{l:?}: eps=0 diverged on a tie-heavy graph (n={}, threads={threads})",
                    g.n()
                );
            }
        }
    });
}

#[test]
fn goodness_band_holds_under_heavy_weight_ties() {
    for_all_seeds(0x71EE, 15, |rng| {
        let g = random_tied_graph(rng);
        for eps in [0.1, 1.0] {
            let r = ApproxEngine::new(&g, Linkage::Average, eps).run();
            r.dendrogram.validate().unwrap();
            let ratio = quality::merge_quality_ratio(&r.bounds);
            assert!(
                ratio <= 1.0 + eps + 1e-12,
                "eps={eps}: ratio {ratio} on tie-heavy graph (n={})",
                g.n()
            );
        }
    });
}

#[test]
fn zero_epsilon_is_bitwise_exact_across_thread_counts() {
    for_all_seeds(0xA9903, 15, |rng| {
        let g = random_sparse_graph(rng);
        for l in Linkage::SPARSE_REDUCIBLE {
            let exact = RacEngine::new(&g, l).with_threads(1).run();
            for threads in [2usize, 8] {
                let approx = ApproxEngine::new(&g, l, 0.0).with_threads(threads).run();
                assert_eq!(
                    exact.dendrogram.bitwise_merges(),
                    approx.dendrogram.bitwise_merges(),
                    "{l:?}: eps=0 at {threads} threads diverged (n={})",
                    g.n()
                );
            }
        }
    });
}

#[test]
fn zero_epsilon_is_bitwise_exact_on_complete_graphs() {
    // Complete graphs admit every reducible linkage, including the
    // complete-graph-only Ward and WPGMA updates.
    for (depth, seed) in [(4u32, 23u64), (5, 7), (6, 91)] {
        let g = data::stable_hierarchy(depth, 4.0, seed);
        for l in [
            Linkage::Single,
            Linkage::Complete,
            Linkage::Average,
            Linkage::WeightedAverage,
            Linkage::Ward,
        ] {
            let exact = RacEngine::new(&g, l).with_threads(4).run();
            let approx = ApproxEngine::new(&g, l, 0.0).with_threads(4).run();
            assert_eq!(
                exact.dendrogram.bitwise_merges(),
                approx.dendrogram.bitwise_merges(),
                "{l:?} depth={depth}"
            );
        }
    }
}

#[test]
fn every_merge_respects_the_goodness_band() {
    for_all_seeds(0xB04D, 20, |rng| {
        let g = random_sparse_graph(rng);
        for eps in [0.01, 0.1, 1.0] {
            for l in Linkage::SPARSE_REDUCIBLE {
                let r = ApproxEngine::new(&g, l, eps).run();
                r.dendrogram.validate().unwrap();
                assert_eq!(
                    r.bounds.len(),
                    r.dendrogram.merges().len(),
                    "one bound per merge"
                );
                let ratio = quality::merge_quality_ratio(&r.bounds);
                assert!(
                    ratio <= 1.0 + eps + 1e-12,
                    "{l:?} eps={eps}: worst ratio {ratio} (n={})",
                    g.n()
                );
            }
        }
    });
}

#[test]
fn relaxation_never_loses_merges() {
    // Approximation changes which merges happen, never how many: every
    // component still fully agglomerates.
    for_all_seeds(0xC0A7, 15, |rng| {
        let g = random_sparse_graph(rng);
        let exact = RacEngine::new(&g, Linkage::Average).run();
        for eps in [0.1, 1.0] {
            let approx = ApproxEngine::new(&g, Linkage::Average, eps).run();
            assert_eq!(
                approx.dendrogram.merges().len(),
                exact.dendrogram.merges().len(),
                "eps={eps} (n={})",
                g.n()
            );
        }
    });
}

#[test]
fn relaxed_selection_is_thread_invariant() {
    for_all_seeds(0x7123D, 10, |rng| {
        let g = random_sparse_graph(rng);
        for eps in [0.1, 1.0] {
            let base = ApproxEngine::new(&g, Linkage::Average, eps)
                .with_threads(1)
                .run();
            for threads in [2usize, 8] {
                let r = ApproxEngine::new(&g, Linkage::Average, eps)
                    .with_threads(threads)
                    .run();
                assert_eq!(
                    base.dendrogram.bitwise_merges(),
                    r.dendrogram.bitwise_merges(),
                    "eps={eps} threads={threads} (n={})",
                    g.n()
                );
            }
        }
    });
}

#[test]
fn adversarial_round_collapse_and_quality() {
    // The Theorem-4 instance is the motivating workload: the exact
    // engine exposes one reciprocal pair per round (Ω(n) rounds); the
    // relaxed band restores per-round parallelism by orders of magnitude
    // while every merge stays (1+ε)-good.
    let g = data::adversarial_thm4(7); // n = 128
    let exact = RacEngine::new(&g, Linkage::Average).run();
    let exact_rounds = exact.metrics.merge_rounds();
    assert!(exact_rounds >= 100, "exact collapse expected: {exact_rounds}");
    for eps in [0.1, 1.0] {
        let r = ApproxEngine::new(&g, Linkage::Average, eps).run();
        assert_eq!(r.dendrogram.merges().len(), 127);
        let rounds = r.metrics.merge_rounds();
        // Any non-trivial band restores near-log round counts here (both
        // ε values can hit that floor, so compare against exact, not
        // against each other).
        assert!(
            rounds * 4 < exact_rounds,
            "eps={eps}: {rounds} rounds vs exact {exact_rounds}"
        );
        let ratio = quality::merge_quality_ratio(&r.bounds);
        assert!(ratio <= 1.0 + eps + 1e-12, "eps={eps}: {ratio}");
    }
}

#[test]
fn flat_cuts_agree_with_exact_hac_on_stable_hierarchies() {
    // Theorem-5 stable hierarchy: separation bands are a factor base
    // apart, so even ε = 1 merges stay inside the correct subtree and
    // every natural cut matches exact HAC with ARI exactly 1.
    let g = data::stable_hierarchy(6, 4.0, 23); // n = 64
    let hac = naive_hac(&g, Linkage::Average);
    for eps in [0.0, 0.1, 1.0] {
        let approx = ApproxEngine::new(&g, Linkage::Average, eps).run();
        for k in [2usize, 4, 8, 16] {
            let ari = quality::adjusted_rand_index(
                &hac.cut_k(k).unwrap(),
                &approx.dendrogram.cut_k(k).unwrap(),
            );
            assert_eq!(ari, 1.0, "eps={eps} k={k}");
        }
    }
}

// ---------------------------------------------------------------------
// dist_approx: the sharded ε-good engine.
// ---------------------------------------------------------------------

/// Topology invariance: for any `(machines, cores)` and any ε, the
/// sharded engine's dendrogram AND quality trace are bitwise the
/// shared-memory engine's. Runs on the tie-heavy quantised-weight graphs
/// — the hardest regime for selection determinism.
#[test]
fn dist_approx_is_topology_invariant_bitwise() {
    for_all_seeds(0xD1AC, 8, |rng| {
        let g = if rng.bool_with(0.5) {
            random_tied_graph(rng)
        } else {
            random_sparse_graph(rng)
        };
        for eps in [0.0, 0.1, 1.0] {
            let base = ApproxEngine::new(&g, Linkage::Average, eps).run();
            for (machines, cores) in [(1usize, 1usize), (2, 4), (5, 2), (9, 1)] {
                let r = DistApproxEngine::new(
                    &g,
                    Linkage::Average,
                    DistConfig::new(machines, cores),
                    eps,
                )
                .run();
                assert_eq!(
                    base.dendrogram.bitwise_merges(),
                    r.dendrogram.bitwise_merges(),
                    "eps={eps} topology=({machines},{cores}) (n={})",
                    g.n()
                );
                let key = |bs: &[quality::MergeBound]| -> Vec<(u64, u64)> {
                    bs.iter()
                        .map(|b| (b.weight.to_bits(), b.visible_min.to_bits()))
                        .collect()
                };
                assert_eq!(
                    key(&base.bounds),
                    key(&r.bounds),
                    "eps={eps} topology=({machines},{cores}): quality trace diverged"
                );
            }
        }
    });
}

/// The ε=0 anchor composes with sharding: `DistApprox(0)` equals the
/// exact engine bitwise for every linkage on tie-heavy graphs.
#[test]
fn dist_approx_zero_epsilon_anchor_under_heavy_weight_ties() {
    for_all_seeds(0xD1AD, 10, |rng| {
        let g = random_tied_graph(rng);
        for l in Linkage::SPARSE_REDUCIBLE {
            let exact = RacEngine::new(&g, l).with_threads(1).run();
            let dist =
                DistApproxEngine::new(&g, l, DistConfig::new(4, 2), 0.0).run();
            assert_eq!(
                exact.dendrogram.bitwise_merges(),
                dist.dendrogram.bitwise_merges(),
                "{l:?} (n={})",
                g.n()
            );
        }
    });
}

/// The goodness band holds for the sharded engine's recorded trace, and
/// its network accounting keeps the dist invariants (bytes >= messages,
/// strictly cross-shard batches).
#[test]
fn dist_approx_band_and_accounting_invariants() {
    for_all_seeds(0xD1AE, 8, |rng| {
        let g = random_sparse_graph(rng);
        let machines = rng.range_usize(1, 7);
        let cores = rng.range_usize(1, 4);
        for eps in [0.1, 1.0] {
            let (r, report) = DistApproxEngine::new(
                &g,
                Linkage::Average,
                DistConfig::new(machines, cores),
                eps,
            )
            .run_detailed();
            r.dendrogram.validate().unwrap();
            let ratio = quality::merge_quality_ratio(&r.bounds);
            assert!(ratio <= 1.0 + eps + 1e-12, "eps={eps}: {ratio}");
            for b in &report.batches {
                assert_ne!(b.src, b.dst, "local traffic accounted");
                assert!(b.bytes >= b.messages);
            }
            if machines == 1 {
                assert!(report.batches.is_empty(), "single machine must be silent");
            }
            assert_eq!(r.metrics.total_net_messages(), report.total_batches());
            assert_eq!(r.metrics.total_net_bytes(), report.total_bytes());
        }
    });
}

#[test]
fn compare_runs_reports_the_tradeoff() {
    let g = data::adversarial_thm4(6);
    let exact = RacEngine::new(&g, Linkage::Average).run();
    let approx = ApproxEngine::new(&g, Linkage::Average, 1.0).run();
    let c = quality::compare_runs(
        (&exact.dendrogram, &exact.metrics),
        (&approx.dendrogram, &approx.metrics),
        4,
    );
    assert!(c.rounds_approx < c.rounds_exact);
    assert!(c.edge_scans_approx > 0 && c.edge_scans_exact > 0);
    assert!((-1.0..=1.0).contains(&c.ari));
}

#[test]
fn selection_is_a_maximal_conflict_free_set() {
    // Engine-independent check of the selection invariants on random
    // candidate sets: pairwise disjoint, and no unmatched candidate edge
    // remains (maximality).
    for_all_seeds(0x5E1EC7, 40, |rng| {
        let n = rng.range_usize(2, 60);
        let mut cands: Vec<(Weight, u32, u32)> = Vec::new();
        for _ in 0..rng.range_usize(0, 3 * n) {
            let a = rng.below(n) as u32;
            let b = rng.below(n) as u32;
            if a != b {
                cands.push((rng.range_f64(0.1, 10.0), a.min(b), a.max(b)));
            }
        }
        let mut matched = vec![false; n];
        let pairs = good::select_matching(cands.clone(), &mut matched);
        let mut seen = vec![false; n];
        for p in &pairs {
            assert!(p.leader < p.partner);
            assert!(!seen[p.leader as usize] && !seen[p.partner as usize], "overlap");
            seen[p.leader as usize] = true;
            seen[p.partner as usize] = true;
        }
        assert_eq!(seen, matched);
        for &(_, a, b) in &cands {
            assert!(
                matched[a as usize] || matched[b as usize],
                "candidate ({a},{b}) left both endpoints unmatched — not maximal"
            );
        }
    });
}

// ---------------------------------------------------------------------
// cut_k / cut_threshold agreement (the instrument the ARI comparisons
// stand on).
// ---------------------------------------------------------------------

#[test]
fn cut_k_agrees_with_cut_threshold_at_strict_boundaries() {
    // On the exact dendrogram of a random sparse graph: applying the j
    // smallest merges via cut_k(n - j) equals cutting at the (j+1)-th
    // merge weight, whenever that boundary is a strict weight increase
    // (a threshold cut cannot split ties; cut_k's documented
    // (weight, id) order handles them deterministically).
    for_all_seeds(0xC07, 25, |rng| {
        let g = random_sparse_graph(rng);
        for l in Linkage::SPARSE_REDUCIBLE {
            let d = naive_hac(&g, l);
            let mut weights: Vec<Weight> = d.merges().iter().map(|m| m.weight).collect();
            weights.sort_by(Weight::total_cmp);
            let n = d.n();
            for j in 0..=weights.len() {
                let strict_below = j == 0 || j == weights.len() || weights[j - 1] < weights[j];
                if !strict_below {
                    continue;
                }
                let threshold = if j == weights.len() {
                    weights.last().copied().unwrap_or(0.0) + 1.0
                } else {
                    weights[j]
                };
                assert_eq!(
                    // n - j >= remaining_clusters always, so the cut is
                    // answerable even on disconnected inputs.
                    d.cut_k(n - j).unwrap(),
                    d.cut_threshold(threshold),
                    "{l:?}: j={j} of {} merges (n={n})",
                    weights.len()
                );
            }
        }
    });
}

#[test]
fn cut_agreement_holds_for_approx_dendrograms_too() {
    // The same agreement on the ε-engine's output — quality comparisons
    // cut approximate dendrograms with the same instruments.
    for_all_seeds(0xC08, 10, |rng| {
        let g = random_sparse_graph(rng);
        let d = ApproxEngine::new(&g, Linkage::Average, 0.5).run().dendrogram;
        let mut weights: Vec<Weight> = d.merges().iter().map(|m| m.weight).collect();
        weights.sort_by(Weight::total_cmp);
        let n = d.n();
        for j in 0..=weights.len() {
            let strict = j == 0 || j == weights.len() || weights[j - 1] < weights[j];
            if !strict {
                continue;
            }
            let threshold = if j == weights.len() {
                weights.last().copied().unwrap_or(0.0) + 1.0
            } else {
                weights[j]
            };
            assert_eq!(
                d.cut_k(n - j).unwrap(),
                d.cut_threshold(threshold),
                "j={j} (n={n})"
            );
        }
    });
}
