//! Adversarial-bytes property suite for the wire and checkpoint codecs.
//!
//! The executed distributed mode feeds `decode_batch` real bytes from
//! other threads and feeds the checkpoint decoders blobs on every boot
//! and every recovery — full v1 blobs, v2 dirty-row deltas, and whole
//! full→delta→delta chains — so the decoders face exactly the inputs
//! this suite synthesises: truncations at arbitrary cuts, flipped tags,
//! corrupted length prefixes, chains with missing links, and plain
//! random garbage. The contract everywhere is *reject with an error* —
//! never panic, never allocate unbounded memory, never mis-decode.

use rac_hac::dendrogram::{Dendrogram, Merge};
use rac_hac::dist::checkpoint::{self, DeltaCheckpoint, MachineCheckpoint};
use rac_hac::dist::{decode_batch, encode_batch, Message};
use rac_hac::serve::{codec as dendrogram_codec, ServeIndex};
use rac_hac::util::prop::for_all_seeds;
use rac_hac::util::rng::Rng;

/// Draw a random but *valid* message.
fn random_message(rng: &mut Rng) -> Message {
    match rng.below(11) {
        0 => Message::NnQuery {
            cluster: rng.next_u64() as u32,
        },
        1 => Message::NnReply {
            cluster: rng.next_u64() as u32,
            nn: rng.next_u64() as u32,
        },
        2 => Message::PartnerFetch {
            partner: rng.next_u64() as u32,
        },
        3 => Message::PartnerState {
            partner: rng.next_u64() as u32,
            size: rng.next_u64(),
            entries: (0..rng.below(6))
                .map(|_| (rng.next_u64() as u32, rng.f64(), rng.next_u64()))
                .collect(),
        },
        4 => Message::PairViewQuery {
            cluster: rng.next_u64() as u32,
        },
        5 => Message::PairViewReply {
            cluster: rng.next_u64() as u32,
            merging: rng.bool_with(0.5),
            partner: rng.next_u64() as u32,
            size: rng.next_u64(),
            pair_weight: rng.f64(),
        },
        6 => Message::EdgePatch {
            target: rng.next_u64() as u32,
            leader: rng.next_u64() as u32,
            retired: rng.next_u64() as u32,
            weight: rng.f64(),
            count: rng.next_u64(),
        },
        7 => Message::NnCacheQuery {
            cluster: rng.next_u64() as u32,
        },
        8 => Message::NnCacheReply {
            cluster: rng.next_u64() as u32,
            nn: rng.next_u64() as u32,
            weight: rng.f64(),
        },
        9 => Message::CandidateBatch {
            edges: (0..rng.below(6))
                .map(|_| (rng.f64(), rng.next_u64() as u32, rng.next_u64() as u32))
                .collect(),
        },
        _ => Message::MatchingBroadcast {
            pairs: (0..rng.below(6))
                .map(|_| (rng.next_u64() as u32, rng.next_u64() as u32, rng.f64()))
                .collect(),
        },
    }
}

fn random_batch(rng: &mut Rng) -> Vec<Message> {
    (0..rng.below(8)).map(|_| random_message(rng)).collect()
}

fn random_checkpoint(rng: &mut Rng) -> MachineCheckpoint {
    let n = rng.range_usize(0, 24);
    MachineCheckpoint {
        machine: rng.below(8) as u32,
        machines: 8,
        round: rng.next_u64() % 1000,
        n,
        rows: (0..rng.below(n + 1))
            .map(|i| {
                (
                    i as u32,
                    rng.next_u64() as u32,
                    rng.f64(),
                    (0..rng.below(5))
                        .map(|_| (rng.next_u64() as u32, rng.f64(), rng.next_u64()))
                        .collect(),
                )
            })
            .collect(),
        size: (0..n).map(|_| rng.next_u64() % 100).collect(),
        active: (0..n).map(|_| rng.bool_with(0.7)).collect(),
    }
}

#[test]
fn valid_batches_round_trip() {
    for_all_seeds(0xC0DEC, 32, |rng| {
        let batch = random_batch(rng);
        let back = decode_batch(&encode_batch(&batch)).unwrap();
        assert_eq!(back, batch);
    });
}

#[test]
fn truncated_batches_are_rejected_at_every_cut() {
    for_all_seeds(0xC0DEC + 1, 16, |rng| {
        let bytes = encode_batch(&random_batch(rng));
        for cut in 0..bytes.len() {
            assert!(
                decode_batch(&bytes[..cut]).is_err(),
                "cut={cut}/{} accepted",
                bytes.len()
            );
        }
        // One byte too many is rejected too (trailing-bytes check).
        let mut extended = bytes.clone();
        extended.push(0);
        assert!(decode_batch(&extended).is_err());
    });
}

#[test]
fn unknown_tags_are_rejected() {
    for_all_seeds(0xC0DEC + 2, 16, |rng| {
        // A batch with one message: its tag byte sits right after the
        // 4-byte count prefix. Every out-of-range tag value must error.
        let bytes = encode_batch(&[random_message(rng)]);
        for bad_tag in [11u8, 12, 60, 0xFF] {
            let mut corrupt = bytes.clone();
            corrupt[4] = bad_tag;
            let err = decode_batch(&corrupt).unwrap_err();
            assert!(err.contains("tag"), "tag={bad_tag}: {err}");
        }
    });
}

#[test]
fn corrupt_length_prefixes_fail_fast_without_huge_allocation() {
    // A maxed-out count prefix claims ~4 billion elements; the decoders
    // must reject it from the remaining-bytes bound *before* reserving
    // element storage. If this regresses to trusting the prefix, the
    // test dies by OOM rather than by assertion — still a failure.
    let empty = encode_batch(&[]);
    let mut corrupt = empty.clone();
    corrupt[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
    assert!(decode_batch(&corrupt).is_err());

    // The same attack on an inner vector prefix: a PartnerState with no
    // entries has its entry count in the last 4 bytes.
    let bytes = encode_batch(&[Message::PartnerState {
        partner: 1,
        size: 2,
        entries: vec![],
    }]);
    let mut corrupt = bytes.clone();
    let at = corrupt.len() - 4;
    corrupt[at..].copy_from_slice(&u32::MAX.to_le_bytes());
    assert!(decode_batch(&corrupt).is_err());
}

#[test]
fn random_garbage_never_panics_the_batch_decoder() {
    for_all_seeds(0xC0DEC + 3, 64, |rng| {
        let len = rng.below(200);
        let bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        // Must return; Ok is fine if the garbage happens to parse.
        let _ = decode_batch(&bytes);
    });
}

#[test]
fn random_single_byte_corruptions_never_panic() {
    for_all_seeds(0xC0DEC + 4, 24, |rng| {
        let mut bytes = encode_batch(&random_batch(rng));
        if bytes.is_empty() {
            return;
        }
        for _ in 0..16 {
            let at = rng.below(bytes.len());
            let old = bytes[at];
            bytes[at] ^= (rng.next_u64() as u8) | 1;
            let _ = decode_batch(&bytes);
            bytes[at] = old;
        }
    });
}

#[test]
fn checkpoints_round_trip_and_reject_corruption() {
    for_all_seeds(0xC0DEC + 5, 24, |rng| {
        let cp = random_checkpoint(rng);
        let blob = checkpoint::encode(&cp);
        assert_eq!(checkpoint::decode(&blob).unwrap(), cp);
        // Every truncation rejected.
        for cut in 0..blob.len() {
            assert!(checkpoint::decode(&blob[..cut]).is_err(), "cut={cut}");
        }
        // Random single-byte corruptions never panic (magic, counts,
        // payload — wherever they land).
        let mut mutated = blob.clone();
        for _ in 0..16 {
            let at = rng.below(mutated.len());
            let old = mutated[at];
            mutated[at] ^= (rng.next_u64() as u8) | 1;
            let _ = checkpoint::decode(&mutated);
            mutated[at] = old;
        }
    });
}

#[test]
fn random_garbage_never_panics_the_checkpoint_decoder() {
    for_all_seeds(0xC0DEC + 6, 64, |rng| {
        let len = rng.below(300);
        let bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let _ = checkpoint::decode(&bytes);
    });
}

/// Draw a random but *valid* delta chaining onto `base`: a subset of its
/// owned rows replaced, a subset of the replicated vectors changed.
fn random_delta_for(rng: &mut Rng, base: &MachineCheckpoint) -> DeltaCheckpoint {
    let rows = base
        .rows
        .iter()
        .filter(|_| rng.bool_with(0.5))
        .map(|r| {
            (
                r.0,
                rng.next_u64() as u32,
                rng.f64(),
                (0..rng.below(4))
                    .map(|_| (rng.next_u64() as u32, rng.f64(), rng.next_u64()))
                    .collect(),
            )
        })
        .collect();
    DeltaCheckpoint {
        machine: base.machine,
        machines: base.machines,
        round: base.round + 1,
        base_round: base.round,
        n: base.n,
        rows,
        size: (0..base.n)
            .filter(|_| rng.bool_with(0.3))
            .map(|i| (i as u32, rng.next_u64() % 100))
            .collect(),
        active: (0..base.n)
            .filter(|_| rng.bool_with(0.3))
            .map(|i| (i as u32, rng.bool_with(0.5)))
            .collect(),
    }
}

#[test]
fn delta_blobs_round_trip() {
    for_all_seeds(0xC0DEC + 7, 24, |rng| {
        let base = random_checkpoint(rng);
        let d = random_delta_for(rng, &base);
        let blob = checkpoint::encode_delta(&d);
        assert_eq!(checkpoint::decode_delta(&blob).unwrap(), d);
        // decode_any tells the versions apart by the version word.
        assert_eq!(
            checkpoint::decode_any(&blob).unwrap(),
            checkpoint::AnyCheckpoint::Delta(d)
        );
        assert_eq!(
            checkpoint::decode_any(&checkpoint::encode(&base)).unwrap(),
            checkpoint::AnyCheckpoint::Full(base)
        );
    });
}

#[test]
fn truncated_delta_blobs_are_rejected_at_every_cut() {
    for_all_seeds(0xC0DEC + 8, 16, |rng| {
        let base = random_checkpoint(rng);
        let blob = checkpoint::encode_delta(&random_delta_for(rng, &base));
        for cut in 0..blob.len() {
            assert!(checkpoint::decode_delta(&blob[..cut]).is_err(), "cut={cut}");
            assert!(checkpoint::decode_any(&blob[..cut]).is_err(), "any cut={cut}");
        }
        let mut extended = blob.clone();
        extended.push(0);
        assert!(checkpoint::decode_delta(&extended).is_err());
    });
}

#[test]
fn corrupt_delta_counts_fail_fast_without_huge_allocation() {
    // The delta header is 40 bytes (magic, version, machine, machines,
    // round, base_round, n); the dirty-row count sits at [40..44], and in
    // an all-empty delta the size-change and active-change counts follow
    // at [44..48] and [48..52]. A maxed count claims ~4 billion records;
    // the remaining-bytes bound must reject it before reserving storage.
    let empty = DeltaCheckpoint {
        machine: 0,
        machines: 1,
        round: 1,
        base_round: 0,
        n: 4,
        rows: vec![],
        size: vec![],
        active: vec![],
    };
    let blob = checkpoint::encode_delta(&empty);
    for at in [40usize, 44, 48] {
        let mut corrupt = blob.clone();
        corrupt[at..at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(
            checkpoint::decode_delta(&corrupt).is_err(),
            "maxed count at {at} accepted"
        );
    }
    // The per-row entry count is equally hostile territory: a one-row
    // delta has it 16 bytes into the row record.
    let one_row = DeltaCheckpoint {
        rows: vec![(0, 1, 0.5, vec![])],
        ..empty
    };
    let blob = checkpoint::encode_delta(&one_row);
    let at = 44 + 16; // count(4) + id(4) + nn(4) + weight(8)
    let mut corrupt = blob.clone();
    corrupt[at..at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
    assert!(checkpoint::decode_delta(&corrupt).is_err());
}

#[test]
fn random_garbage_and_byte_flips_never_panic_the_delta_decoder() {
    for_all_seeds(0xC0DEC + 9, 48, |rng| {
        let len = rng.below(300);
        let bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let _ = checkpoint::decode_delta(&bytes);
        let _ = checkpoint::decode_any(&bytes);
        // And single-byte corruptions of a valid blob.
        let base = random_checkpoint(rng);
        let mut blob = checkpoint::encode_delta(&random_delta_for(rng, &base));
        for _ in 0..16 {
            let at = rng.below(blob.len());
            let old = blob[at];
            blob[at] ^= (rng.next_u64() as u8) | 1;
            let _ = checkpoint::decode_delta(&blob);
            let _ = checkpoint::restore_chain(&[checkpoint::encode(&base), blob.clone()]);
            blob[at] = old;
        }
    });
}

#[test]
fn checkpoint_chains_fold_correctly_and_reject_broken_links() {
    for_all_seeds(0xC0DEC + 10, 24, |rng| {
        let base = random_checkpoint(rng);
        let d1 = random_delta_for(rng, &base);
        let mut after1 = base.clone();
        checkpoint::apply_delta(&mut after1, &d1).unwrap();
        let d2 = random_delta_for(rng, &after1);
        let mut after2 = after1.clone();
        checkpoint::apply_delta(&mut after2, &d2).unwrap();

        let full = checkpoint::encode(&base);
        let b1 = checkpoint::encode_delta(&d1);
        let b2 = checkpoint::encode_delta(&d2);

        // The happy chain folds to the last cut's snapshot.
        assert_eq!(
            checkpoint::restore_chain(&[full.clone(), b1.clone(), b2.clone()]).unwrap(),
            after2
        );
        assert_eq!(checkpoint::restore_chain(&[full.clone()]).unwrap(), base);

        // An empty chain, a chain that starts with a delta (its base is
        // gone), a full blob in the middle, and a skipped link are each
        // rejected with a named error — never a panic, never a silent
        // mis-restore.
        assert!(checkpoint::restore_chain(&[])
            .unwrap_err()
            .contains("empty"));
        assert!(checkpoint::restore_chain(&[b1.clone()])
            .unwrap_err()
            .contains("starts with a delta"));
        assert!(checkpoint::restore_chain(&[full.clone(), full.clone(), b1.clone()])
            .unwrap_err()
            .contains("middle"));
        // Skipping d1 leaves d2 chaining onto a round the base never
        // reached: the missing-link check must catch it.
        assert!(checkpoint::restore_chain(&[full.clone(), b2.clone()])
            .unwrap_err()
            .contains("missing link"));

        // A delta cut for a different machine or id space is rejected by
        // apply_delta before any mutation.
        let mut alien = d1.clone();
        alien.machine = base.machine.wrapping_add(1);
        let mut scratch = base.clone();
        assert!(checkpoint::apply_delta(&mut scratch, &alien).is_err());
        assert_eq!(scratch, base, "failed apply mutated the base");
    });
}

/// Draw a random but *valid* dendrogram: a forest built by merging random
/// live representatives, with a mix of continuous and deliberately tied
/// weights (ties stress the serve-layer sort downstream, but here they
/// just need to survive the codec bit-exactly).
fn random_dendrogram(rng: &mut Rng) -> Dendrogram {
    let n = rng.range_usize(0, 40);
    let mut live: Vec<u32> = (0..n as u32).collect();
    let target = if n == 0 { 0 } else { rng.below(n) };
    let mut merges = Vec::new();
    for _ in 0..target {
        if live.len() < 2 {
            break;
        }
        let i = rng.below(live.len());
        let mut j = rng.below(live.len());
        while j == i {
            j = rng.below(live.len());
        }
        let (a, b) = (live[i].min(live[j]), live[i].max(live[j]));
        live.retain(|&x| x != b);
        let weight = if rng.bool_with(0.3) {
            rng.below(5) as f64 * 0.5
        } else {
            rng.range_f64(-5.0, 5.0)
        };
        merges.push(Merge { a, b, weight });
    }
    Dendrogram::new(n, merges)
}

#[test]
fn dendrogram_blobs_round_trip_bit_exact() {
    for_all_seeds(0xC0DEC + 11, 32, |rng| {
        let d = random_dendrogram(rng);
        let blob = dendrogram_codec::encode(&d);
        let back = dendrogram_codec::decode(&blob).unwrap();
        assert_eq!(back.n(), d.n());
        assert_eq!(back.bitwise_merges(), d.bitwise_merges());
    });
}

#[test]
fn truncated_dendrogram_blobs_are_rejected_at_every_cut() {
    for_all_seeds(0xC0DEC + 12, 16, |rng| {
        let blob = dendrogram_codec::encode(&random_dendrogram(rng));
        for cut in 0..blob.len() {
            assert!(
                dendrogram_codec::decode(&blob[..cut]).is_err(),
                "cut={cut}/{} accepted",
                blob.len()
            );
        }
        let mut extended = blob.clone();
        extended.push(0);
        assert!(dendrogram_codec::decode(&extended).is_err());
    });
}

#[test]
fn corrupt_dendrogram_counts_fail_fast_without_huge_allocation() {
    // Header layout: magic [0..8], version [8..12], n [12..20],
    // count [20..28]. A maxed merge count claims 2^64-1 records; the
    // `count < max(n, 1)` bound must reject it before the element loop.
    let d = Dendrogram::new(4, vec![Merge { a: 0, b: 2, weight: 1.5 }]);
    let blob = dendrogram_codec::encode(&d);
    let mut corrupt = blob.clone();
    corrupt[20..28].copy_from_slice(&u64::MAX.to_le_bytes());
    let err = dendrogram_codec::decode(&corrupt).unwrap_err();
    assert!(err.contains("corrupt merge count"), "got: {err}");

    // A count that passes the n bound but not the byte budget is caught
    // by the remaining-bytes check, again before allocation.
    let mut corrupt = blob.clone();
    corrupt[12..20].copy_from_slice(&1000u64.to_le_bytes());
    corrupt[20..28].copy_from_slice(&999u64.to_le_bytes());
    assert!(dendrogram_codec::decode(&corrupt).is_err());

    // A maxed *point* count with an in-budget merge list decodes without
    // allocating anything proportional to the claim (the decoder's
    // validation is count-bounded by design) — and the serve layer's own
    // size gate then refuses to build an index over it, also without
    // touching memory proportional to n.
    let mut corrupt = blob;
    corrupt[12..20].copy_from_slice(&u64::MAX.to_le_bytes());
    if let Ok(huge) = dendrogram_codec::decode(&corrupt) {
        assert_eq!(huge.merges().len(), 1);
        let err = ServeIndex::build(&huge).unwrap_err();
        assert!(format!("{err}").contains("too large"), "got: {err}");
    }
}

#[test]
fn random_garbage_and_byte_flips_never_panic_the_dendrogram_decoder() {
    for_all_seeds(0xC0DEC + 13, 48, |rng| {
        let len = rng.below(300);
        let bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let _ = dendrogram_codec::decode(&bytes);
        // And single-byte corruptions of a valid blob: reject or decode,
        // never panic, never over-allocate.
        let mut blob = dendrogram_codec::encode(&random_dendrogram(rng));
        if blob.is_empty() {
            return;
        }
        for _ in 0..16 {
            let at = rng.below(blob.len());
            let old = blob[at];
            blob[at] ^= (rng.next_u64() as u8) | 1;
            let _ = dendrogram_codec::decode(&blob);
            blob[at] = old;
        }
    });
}
