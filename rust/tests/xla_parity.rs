//! XLA-path parity: the AOT Pallas kernels executed through PJRT must
//! reproduce the pure-Rust oracle — distances, kNN graphs, and the full
//! clustering pipeline.
//!
//! These tests need `artifacts/` (run `make artifacts` once); they skip
//! with a notice when it is absent so `cargo test` stays runnable from a
//! fresh checkout.

use rac_hac::data::{gaussian_mixture, topic_docs, Metric};
use rac_hac::hac::naive_hac;
use rac_hac::knn::{knn_graph, Backend};
use rac_hac::linkage::Linkage;
use rac_hac::rac::RacEngine;
use rac_hac::runtime::{default_artifacts_dir, KernelRuntime};

fn runtime_or_skip() -> Option<KernelRuntime> {
    match KernelRuntime::open(default_artifacts_dir()) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (no AOT artifacts: {e:#}) — run `make artifacts`");
            None
        }
    }
}

#[test]
fn distance_blocks_match_oracle_l2() {
    let Some(rt) = runtime_or_skip() else { return };
    let meta = rt.manifest().find("distance", Metric::L2, 64).unwrap().clone();
    let ds = gaussian_mixture(meta.m + meta.n, 64, 8, 0.7, 0.0, 3);
    let x = &ds.rows[..meta.m * 64];
    let y = &ds.rows[meta.m * 64..(meta.m + meta.n) * 64];
    let out = rt.distance_block(&meta, x, y).unwrap();
    assert_eq!(out.len(), meta.m * meta.n);
    for i in (0..meta.m).step_by(37) {
        for j in (0..meta.n).step_by(41) {
            let want = ds.dissimilarity(i, meta.m + j);
            let got = out[i * meta.n + j] as f64;
            assert!(
                (got - want).abs() <= 1e-2 + 1e-4 * want.abs(),
                "D[{i},{j}] = {got}, oracle {want}"
            );
        }
    }
}

#[test]
fn distance_blocks_match_oracle_cosine() {
    let Some(rt) = runtime_or_skip() else { return };
    let meta = rt
        .manifest()
        .find("distance", Metric::Cosine, 64)
        .unwrap()
        .clone();
    let ds = topic_docs(meta.m + meta.n, 64, 6, 5);
    let x = &ds.rows[..meta.m * 64];
    let y = &ds.rows[meta.m * 64..(meta.m + meta.n) * 64];
    let out = rt.distance_block(&meta, x, y).unwrap();
    for i in (0..meta.m).step_by(29) {
        for j in (0..meta.n).step_by(31) {
            let want = ds.dissimilarity(i, meta.m + j);
            let got = out[i * meta.n + j] as f64;
            assert!(
                (got - want).abs() <= 1e-4 + 1e-4 * want.abs(),
                "D[{i},{j}] = {got}, oracle {want}"
            );
        }
    }
}

#[test]
fn knn_blocks_sorted_and_consistent() {
    let Some(rt) = runtime_or_skip() else { return };
    let meta = rt.manifest().find("knn", Metric::L2, 128).unwrap().clone();
    let k = meta.k.unwrap();
    let ds = gaussian_mixture(meta.m + meta.n, 128, 10, 0.7, 0.0, 7);
    let x = &ds.rows[..meta.m * 128];
    let y = &ds.rows[meta.m * 128..(meta.m + meta.n) * 128];
    let (vals, idx) = rt.knn_block(&meta, x, y).unwrap();
    assert_eq!(vals.len(), meta.m * k);
    for r in 0..meta.m {
        for c in 0..k {
            let (v, j) = (vals[r * k + c], idx[r * k + c]);
            assert!((0..meta.n as i32).contains(&j));
            // Values ascending per row.
            if c > 0 {
                assert!(vals[r * k + c - 1] <= v + 1e-5);
            }
            // Value matches the claimed index's true distance.
            let want = ds.dissimilarity(r, meta.m + j as usize);
            assert!(
                (v as f64 - want).abs() <= 1e-2 + 1e-4 * want.abs(),
                "row {r} rank {c}: {v} vs oracle {want}"
            );
        }
    }
}

#[test]
fn xla_knn_graph_matches_native() {
    let Some(rt) = runtime_or_skip() else { return };
    // Sizes straddling the 256/1024 tile boundaries, both metrics.
    for (n, d, k, seed) in [(700usize, 64usize, 8usize, 1u64), (1300, 128, 12, 2)] {
        let ds = gaussian_mixture(n, d, 12, 0.7, 0.02, seed);
        let native = knn_graph(&ds, k, Backend::Native, None).unwrap();
        let xla = knn_graph(&ds, k, Backend::Xla, Some(&rt)).unwrap();
        assert_eq!(native.n(), xla.n());
        // Edge sets must agree except for f32-rounding ties at the k-th
        // boundary; demand >= 99.5% Jaccard overlap and identical graphs
        // through the clustering.
        let mut common = 0usize;
        let mut total_native = 0usize;
        for u in 0..n as u32 {
            for (v, _) in native.neighbors(u) {
                total_native += 1;
                if xla.weight(u, v).is_some() {
                    common += 1;
                }
            }
        }
        let overlap = common as f64 / total_native as f64;
        assert!(
            overlap >= 0.995,
            "edge overlap only {overlap:.4} for n={n} d={d}"
        );
    }
}

#[test]
fn xla_pipeline_clusters_correctly() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = topic_docs(600, 64, 10, 11);
    let g = knn_graph(&ds, 8, Backend::Xla, Some(&rt)).unwrap();
    g.validate().unwrap();
    // Complete linkage: the paper's choice on sparse kNN graphs (average
    // linkage over cosine kNN suffers hub-induced serialisation; Fig-2's
    // News20/RCV1 average-linkage runs are complete graphs — see the
    // fig2 bench).
    let hac = naive_hac(&g, Linkage::Complete);
    let rac = RacEngine::new(&g, Linkage::Complete).run();
    assert!(hac.same_clustering(&rac.dendrogram, 1e-9));
    // Clusterable data: far fewer rounds than merges.
    assert!(rac.metrics.merge_rounds() * 3 < rac.metrics.total_merges());
}

#[test]
fn unsupported_dim_reports_helpful_error() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = gaussian_mixture(300, 48, 5, 0.5, 0.0, 1); // d=48: no variant
    let err = knn_graph(&ds, 4, Backend::Xla, Some(&rt)).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("no knn AOT variant"), "{msg}");
    assert!(msg.contains("available dims"), "{msg}");
}
