//! The tracing layer's two contracts, pinned end to end:
//!
//! * **Invariance** — tracing is purely observational. A traced run's
//!   dendrogram, (1+ε) bounds trace, and sync schedule are bitwise
//!   identical to the untraced run's, across engines × topologies ×
//!   both distributed modes (simulated and executed), including faulted
//!   executed runs.
//! * **Accounting equality** — the trace analyzer's totals are folded
//!   from events emitted at the *same code sites* where `RunMetrics`
//!   accumulates its counters, so `trace-report` and the metrics must
//!   agree exactly: `net_messages`, `net_bytes`, `sync_points`,
//!   `checkpoint_bytes`, and the recovery counters — even on a faulted
//!   shard-replay run.
//!
//! Both writers (JSONL and Chrome/Perfetto) are round-tripped on real
//! engine traces, and every recorded event passes schema validation.

use rac_hac::approx::quality::MergeBound;
use rac_hac::approx::ApproxEngine;
use rac_hac::data::{adversarial_thm4, grid1d_graph};
use rac_hac::dist::{
    DistApproxEngine, DistConfig, DistRacEngine, ExecOptions, FaultSpec, RecoveryMode, SyncMode,
};
use rac_hac::graph::Graph;
use rac_hac::linkage::Linkage;
use rac_hac::metrics::RunMetrics;
use rac_hac::rac::RacEngine;
use rac_hac::trace::{
    analyze::{analyze, validate_events, TraceReport},
    parse_chrome, parse_jsonl, write_chrome, write_jsonl, EventKind, TraceEvent, TraceSink,
};

const TOPOLOGIES: [(usize, usize); 3] = [(1, 1), (3, 2), (5, 1)];

fn sync_schedule(m: &RunMetrics) -> Vec<(usize, usize, usize)> {
    m.rounds
        .iter()
        .map(|r| (r.clusters, r.merges, r.sync_points))
        .collect()
}

fn bounds_bits(bs: &[MergeBound]) -> Vec<(u64, u64)> {
    bs.iter()
        .map(|b| (b.weight.to_bits(), b.visible_min.to_bits()))
        .collect()
}

/// Drain a run's trace, schema-validate every event, and fold it.
fn drain_and_analyze(sink: &TraceSink) -> (Vec<TraceEvent>, TraceReport) {
    let events = sink.take();
    validate_events(&events).unwrap_or_else(|e| panic!("trace failed validation: {e}"));
    (events, analyze(&events))
}

/// The analyzer totals that have `RunMetrics` counterparts must match
/// them exactly (equality by construction — same accounting sites).
fn assert_totals_match(report: &TraceReport, m: &RunMetrics, tag: &str) {
    assert_eq!(report.rounds, m.rounds.len(), "{tag}: round count");
    assert_eq!(
        report.net_messages,
        m.total_net_messages(),
        "{tag}: net_messages"
    );
    assert_eq!(report.net_bytes, m.total_net_bytes(), "{tag}: net_bytes");
    assert_eq!(
        report.sync_points,
        m.total_sync_points(),
        "{tag}: sync_points"
    );
    assert_eq!(
        report.checkpoint_bytes, m.checkpoint_bytes,
        "{tag}: checkpoint_bytes"
    );
    assert_eq!(
        report.recovery_rounds_replayed, m.recovery_rounds_replayed,
        "{tag}: recovery_rounds_replayed"
    );
    assert_eq!(
        report.recovery_bytes_replayed, m.recovery_bytes_replayed,
        "{tag}: recovery_bytes_replayed"
    );
}

/// Both writers must round-trip the event stream losslessly.
fn assert_writers_roundtrip(events: &[TraceEvent]) {
    let jsonl = write_jsonl(events);
    assert_eq!(&parse_jsonl(&jsonl).unwrap(), events, "jsonl round trip");
    let chrome = write_chrome(events);
    assert_eq!(&parse_chrome(&chrome).unwrap(), events, "chrome round trip");
}

#[test]
fn traced_rac_is_bitwise_identical_to_untraced() {
    let g = grid1d_graph(300, 7);
    for linkage in [Linkage::Single, Linkage::Average] {
        let plain = RacEngine::new(&g, linkage).run();
        let sink = TraceSink::enabled();
        let traced = RacEngine::new(&g, linkage).with_trace(&sink).run();
        assert_eq!(
            plain.dendrogram.bitwise_merges(),
            traced.dendrogram.bitwise_merges(),
            "{linkage:?}: tracing perturbed the dendrogram"
        );
        let (events, report) = drain_and_analyze(&sink);
        assert_eq!(report.engine, "rac");
        assert_totals_match(&report, &traced.metrics, "rac");
        // Shared-memory engine: one coordinator participant, three phase
        // spans per completed merge round, no wire traffic.
        assert_eq!(report.net_messages, 0);
        let phases = events
            .iter()
            .filter(|e| matches!(e.kind, EventKind::Phase(_)))
            .count();
        assert!(phases >= 3 * traced.metrics.merge_rounds());
        assert_writers_roundtrip(&events);
    }
}

#[test]
fn traced_approx_preserves_bounds_trace() {
    let g = grid1d_graph(250, 11);
    for eps in [0.0, 0.5] {
        let plain = ApproxEngine::new(&g, Linkage::Average, eps).run();
        let sink = TraceSink::enabled();
        let traced = ApproxEngine::new(&g, Linkage::Average, eps)
            .with_trace(&sink)
            .run();
        assert_eq!(
            plain.dendrogram.bitwise_merges(),
            traced.dendrogram.bitwise_merges(),
            "eps={eps}: tracing perturbed the dendrogram"
        );
        assert_eq!(
            bounds_bits(&plain.bounds),
            bounds_bits(&traced.bounds),
            "eps={eps}: tracing perturbed the bounds trace"
        );
        let (_, report) = drain_and_analyze(&sink);
        assert_eq!(report.engine, "approx");
        assert_totals_match(&report, &traced.metrics, "approx");
    }
}

#[test]
fn traced_dist_rac_matches_untraced_across_topologies_and_modes() {
    let g = grid1d_graph(200, 13);
    for topo in TOPOLOGIES {
        for exec in [None, Some(ExecOptions::default())] {
            let mode = if exec.is_some() { "executed" } else { "sim" };
            let mk = |sink: Option<&TraceSink>| {
                let mut eng =
                    DistRacEngine::new(&g, Linkage::Average, DistConfig::new(topo.0, topo.1));
                if let Some(s) = sink {
                    eng = eng.with_trace(s);
                }
                if let Some(opts) = exec.clone() {
                    eng = eng.with_exec(opts);
                }
                eng.run()
            };
            let plain = mk(None);
            let sink = TraceSink::enabled();
            let traced = mk(Some(&sink));
            let tag = format!("dist_rac topo={topo:?} mode={mode}");
            assert_eq!(
                plain.dendrogram.bitwise_merges(),
                traced.dendrogram.bitwise_merges(),
                "{tag}: tracing perturbed the dendrogram"
            );
            assert_eq!(
                sync_schedule(&plain.metrics),
                sync_schedule(&traced.metrics),
                "{tag}: tracing perturbed the sync schedule"
            );
            let (events, report) = drain_and_analyze(&sink);
            assert_eq!(report.engine, "dist_rac", "{tag}");
            assert_totals_match(&report, &traced.metrics, &tag);
            if topo.0 > 1 {
                assert!(report.net_messages > 0, "{tag}: no wire traffic traced");
            }
            if exec.is_some() && topo.0 > 1 {
                // Executed fleets record per-machine barrier waits and a
                // per-(src, dst) wire matrix; the simulation records one
                // coordinator-level aggregate instead.
                assert!(!report.barriers.is_empty(), "{tag}: no barrier spans");
                assert!(report.wire.len() > 1, "{tag}: no wire matrix");
            }
            assert_writers_roundtrip(&events);
        }
    }
}

#[test]
fn traced_dist_approx_matches_untraced_across_sync_modes() {
    let g = grid1d_graph(180, 17);
    let topo = (3, 2);
    for sync in [SyncMode::PerRound, SyncMode::Batched { vshards: 8 }] {
        for exec in [None, Some(ExecOptions::default())] {
            let mode = if exec.is_some() { "executed" } else { "sim" };
            let mk = |sink: Option<&TraceSink>| {
                let mut eng = DistApproxEngine::new(
                    &g,
                    Linkage::Average,
                    DistConfig::new(topo.0, topo.1),
                    0.1,
                )
                .with_sync_mode(sync);
                if let Some(s) = sink {
                    eng = eng.with_trace(s);
                }
                if let Some(opts) = exec.clone() {
                    eng = eng.with_exec(opts);
                }
                eng.run()
            };
            let plain = mk(None);
            let sink = TraceSink::enabled();
            let traced = mk(Some(&sink));
            let tag = format!("dist_approx sync={sync:?} mode={mode}");
            assert_eq!(
                plain.dendrogram.bitwise_merges(),
                traced.dendrogram.bitwise_merges(),
                "{tag}: tracing perturbed the dendrogram"
            );
            assert_eq!(
                bounds_bits(&plain.bounds),
                bounds_bits(&traced.bounds),
                "{tag}: tracing perturbed the bounds trace"
            );
            assert_eq!(
                sync_schedule(&plain.metrics),
                sync_schedule(&traced.metrics),
                "{tag}: tracing perturbed the sync schedule"
            );
            let (_, report) = drain_and_analyze(&sink);
            assert_eq!(report.engine, "dist_approx", "{tag}");
            assert_totals_match(&report, &traced.metrics, &tag);
        }
    }
}

#[test]
fn traced_adversarial_instance_stays_bitwise() {
    // The Theorem-4 chain merges one pair per round under the exact
    // engine — the longest round schedule per node, a worst case for any
    // per-round overhead to leak into behaviour.
    let g = adversarial_thm4(5);
    let plain = RacEngine::new(&g, Linkage::Average).run();
    let sink = TraceSink::enabled();
    let traced = RacEngine::new(&g, Linkage::Average).with_trace(&sink).run();
    assert_eq!(
        plain.dendrogram.bitwise_merges(),
        traced.dendrogram.bitwise_merges()
    );
    let (_, report) = drain_and_analyze(&sink);
    assert_totals_match(&report, &traced.metrics, "adversarial rac");
}

#[test]
fn faulted_shard_replay_run_traces_recovery_and_matches_metrics() {
    // The acceptance-criteria run: an executed fleet with a multi-fault
    // campaign under journaled shard replay. The trace must validate,
    // carry the fault/recovery timeline, fold to the RunMetrics
    // counters exactly, and the run itself must stay bitwise identical
    // to the clean and untraced runs.
    let g = grid1d_graph(160, 23);
    let topo = (3, 2);
    let faulted = ExecOptions {
        faults: vec![
            FaultSpec { machine: 1, round: 2 },
            FaultSpec { machine: 0, round: 4 },
        ],
        recovery_mode: RecoveryMode::ShardReplay,
        checkpoint_full_every: 2,
        ..ExecOptions::default()
    };
    let clean = DistRacEngine::new(&g, Linkage::Average, DistConfig::new(topo.0, topo.1))
        .with_exec(ExecOptions::default())
        .run();
    let plain = DistRacEngine::new(&g, Linkage::Average, DistConfig::new(topo.0, topo.1))
        .with_exec(faulted.clone())
        .run();
    let sink = TraceSink::enabled();
    let traced = DistRacEngine::new(&g, Linkage::Average, DistConfig::new(topo.0, topo.1))
        .with_trace(&sink)
        .with_exec(faulted)
        .run();
    assert_eq!(
        clean.dendrogram.bitwise_merges(),
        traced.dendrogram.bitwise_merges(),
        "faulted traced run diverged from the clean run"
    );
    assert_eq!(
        plain.dendrogram.bitwise_merges(),
        traced.dendrogram.bitwise_merges(),
        "tracing perturbed the faulted run"
    );
    assert_eq!(
        plain.metrics.recovery_rounds_replayed,
        traced.metrics.recovery_rounds_replayed,
        "tracing perturbed recovery accounting"
    );
    let (events, report) = drain_and_analyze(&sink);
    // The core acceptance assertion: analyzer totals == RunMetrics.
    assert_totals_match(&report, &traced.metrics, "faulted shard replay");
    assert!(traced.metrics.recovery_rounds_replayed > 0, "no replay happened");
    assert!(traced.metrics.checkpoint_bytes > 0, "no checkpoints cut");
    // Both scheduled faults fired and were recorded, with their
    // matching replay events in the timeline.
    assert_eq!(report.faults, 2);
    let replays = events
        .iter()
        .filter(|e| matches!(e.kind, EventKind::Recovery { .. }))
        .count();
    assert!(replays >= 2, "expected a recovery event per fault");
    assert!(
        report.timeline.iter().any(|t| t.label.contains("down")),
        "fault missing from the timeline"
    );
    assert!(
        report
            .timeline
            .iter()
            .any(|t| t.label.contains("recovery replay")),
        "replay missing from the timeline"
    );
    assert_writers_roundtrip(&events);
}

#[test]
fn faulted_global_rollback_rewinds_trace_rounds_with_metrics() {
    // Global rollback discards rounds since the last checkpoint and
    // re-executes them; round-scoped trace events must rewind with the
    // metrics (or the analyzer would double-count the replayed rounds).
    let g = grid1d_graph(140, 29);
    let topo = (3, 1);
    let sink = TraceSink::enabled();
    let traced = DistRacEngine::new(&g, Linkage::Average, DistConfig::new(topo.0, topo.1))
        .with_trace(&sink)
        .with_exec(ExecOptions {
            faults: vec![FaultSpec { machine: 2, round: 3 }],
            recovery_mode: RecoveryMode::Global,
            ..ExecOptions::default()
        })
        .run();
    let (_, report) = drain_and_analyze(&sink);
    assert_totals_match(&report, &traced.metrics, "faulted global rollback");
    assert!(traced.metrics.recovery_rounds_replayed > 0);
    assert_eq!(report.faults, 1);
}

#[test]
fn disabled_sink_runs_record_nothing() {
    let g = grid1d_graph(80, 3);
    let sink = TraceSink::disabled();
    let r = RacEngine::new(&g, Linkage::Average).with_trace(&sink).run();
    assert_eq!(r.dendrogram.merges().len(), 79);
    assert!(sink.take().is_empty(), "disabled sink collected events");
}

#[test]
fn one_sink_collects_exactly_one_run_span_per_engine_run() {
    // Reusing a sink across runs would break the one-run-per-trace
    // schema; each run gets its own sink, and each trace validates.
    let g = Graph::from_edges(4, [(0, 1, 1.0), (1, 2, 2.0), (2, 3, 3.0)]);
    for _ in 0..2 {
        let sink = TraceSink::enabled();
        RacEngine::new(&g, Linkage::Single).with_trace(&sink).run();
        let (events, _) = drain_and_analyze(&sink);
        let runs = events
            .iter()
            .filter(|e| matches!(e.kind, EventKind::Run))
            .count();
        assert_eq!(runs, 1);
    }
}
