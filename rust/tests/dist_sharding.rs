//! Sharding-layer properties of the distributed engine, across random
//! graphs, topologies, and linkages (via `util::prop::for_all_seeds`):
//!
//! * cluster→machine placement is a total partition of the live clusters;
//! * every accounted network batch is strictly cross-shard (a single
//!   machine is perfectly silent);
//! * the per-round accounting invariants hold: `net_bytes >=
//!   net_messages`, and the run-level totals equal the batch log.

use rac_hac::dist::{partition, shard_of, DistConfig, DistRacEngine};
use rac_hac::graph::Graph;
use rac_hac::linkage::Linkage;
use rac_hac::util::prop::for_all_seeds;
use rac_hac::util::rng::Rng;

/// Random connected-ish sparse graph with continuous weights.
fn random_graph(rng: &mut Rng) -> Graph {
    let n = rng.range_usize(4, 120);
    let mut edges = Vec::new();
    for i in 1..n {
        edges.push(((i - 1) as u32, i as u32, rng.range_f64(0.1, 10.0)));
    }
    for _ in 0..rng.range_usize(0, 2 * n) {
        let u = rng.below(n);
        let v = rng.below(n);
        if u != v {
            edges.push((u as u32, v as u32, rng.range_f64(0.1, 10.0)));
        }
    }
    Graph::from_edges(n, edges)
}

fn random_linkage(rng: &mut Rng) -> Linkage {
    Linkage::SPARSE_REDUCIBLE[rng.below(Linkage::SPARSE_REDUCIBLE.len())]
}

#[test]
fn placement_is_a_total_partition() {
    for_all_seeds(0x5AAD, 25, |rng| {
        let machines = rng.range_usize(1, 24);
        let n = rng.range_usize(0, 300);
        // A random sparse id set (not necessarily contiguous), like the
        // live-cluster set mid-run.
        let ids: Vec<u32> = (0..n as u32).filter(|_| rng.f64() < 0.6).collect();
        let parts = partition(&ids, machines);
        assert_eq!(parts.len(), machines.max(1), "one list per machine");
        // Total: every id appears exactly once, on its own shard.
        let total: usize = parts.iter().map(Vec::len).sum();
        assert_eq!(total, ids.len(), "partition must be total");
        for (s, part) in parts.iter().enumerate() {
            for &id in part {
                assert_eq!(shard_of(id, machines), s, "id {id} on wrong shard");
            }
        }
    });
}

#[test]
fn batches_are_strictly_cross_shard() {
    for_all_seeds(0xC205, 12, |rng| {
        let g = random_graph(rng);
        let machines = rng.range_usize(1, 9);
        let cores = rng.range_usize(1, 5);
        let linkage = random_linkage(rng);
        let (r, report) =
            DistRacEngine::new(&g, linkage, DistConfig::new(machines, cores)).run_detailed();
        // Every connected component merges completely.
        assert_eq!(r.dendrogram.merges().len(), g.n() - g.components());
        for b in &report.batches {
            assert_ne!(b.src, b.dst, "{linkage:?}: local traffic accounted");
            assert!(b.src < machines.max(1) && b.dst < machines.max(1));
            assert!(b.messages >= 1, "empty batch accounted");
            assert!(b.bytes >= b.messages, "batch smaller than its messages");
        }
        if machines == 1 {
            assert!(report.batches.is_empty(), "single machine must be silent");
        }
    });
}

#[test]
fn round_accounting_invariants() {
    for_all_seeds(0xACC2, 12, |rng| {
        let g = random_graph(rng);
        let machines = rng.range_usize(1, 9);
        let cores = rng.range_usize(1, 5);
        let linkage = random_linkage(rng);
        let (r, report) =
            DistRacEngine::new(&g, linkage, DistConfig::new(machines, cores)).run_detailed();
        for rm in &r.metrics.rounds {
            assert!(
                rm.net_bytes >= rm.net_messages,
                "{linkage:?} round {}: bytes {} < messages {}",
                rm.round,
                rm.net_bytes,
                rm.net_messages
            );
        }
        // The batch log and the per-round counters describe the same run.
        assert_eq!(r.metrics.total_net_messages(), report.total_batches());
        assert_eq!(r.metrics.total_net_bytes(), report.total_bytes());
    });
}

#[test]
fn topology_never_changes_the_clustering() {
    // The sharding layer is accounting-only: sweep machines × cores on one
    // graph and demand bitwise-identical merge lists.
    let mut rng = Rng::seed_from(0xD15C);
    let g = random_graph(&mut rng);
    let base = DistRacEngine::new(&g, Linkage::Average, DistConfig::new(1, 1)).run();
    for machines in [2usize, 3, 5, 8, 13] {
        for cores in [1usize, 4] {
            let r = DistRacEngine::new(&g, Linkage::Average, DistConfig::new(machines, cores))
                .run();
            let a: Vec<_> = base
                .dendrogram
                .merges()
                .iter()
                .map(|m| (m.a, m.b, m.weight.to_bits()))
                .collect();
            let b: Vec<_> = r
                .dendrogram
                .merges()
                .iter()
                .map(|m| (m.a, m.b, m.weight.to_bits()))
                .collect();
            assert_eq!(a, b, "topology ({machines},{cores}) changed the merges");
        }
    }
}
