//! Sharding-layer properties of the distributed engine, across random
//! graphs, topologies, and linkages (via `util::prop::for_all_seeds`):
//!
//! * cluster→machine placement is a total partition of the live clusters;
//! * every accounted network batch is strictly cross-shard (a single
//!   machine is perfectly silent);
//! * the per-round accounting invariants hold: `net_bytes >=
//!   net_messages`, and the run-level totals equal the batch log;
//! * **pinned wire traffic** — on hand-built graphs the *exact* batch
//!   sequence (src, dst, round, messages, encoded bytes) of `dist_rac`,
//!   `dist_approx`, and batched `dist_approx` is asserted message for
//!   message, with byte counts derived through the real codec
//!   ([`encode_batch`]), so any future wire/protocol change shows up as
//!   a reviewable diff instead of silent accounting drift.

use rac_hac::dist::{
    encode_batch, partition, shard_of, BatchRecord, DistApproxEngine, DistConfig, DistRacEngine,
    Message, SyncMode,
};
use rac_hac::graph::Graph;
use rac_hac::linkage::Linkage;
use rac_hac::util::prop::for_all_seeds;
use rac_hac::util::rng::Rng;

/// Random connected-ish sparse graph with continuous weights.
fn random_graph(rng: &mut Rng) -> Graph {
    let n = rng.range_usize(4, 120);
    let mut edges = Vec::new();
    for i in 1..n {
        edges.push(((i - 1) as u32, i as u32, rng.range_f64(0.1, 10.0)));
    }
    for _ in 0..rng.range_usize(0, 2 * n) {
        let u = rng.below(n);
        let v = rng.below(n);
        if u != v {
            edges.push((u as u32, v as u32, rng.range_f64(0.1, 10.0)));
        }
    }
    Graph::from_edges(n, edges)
}

fn random_linkage(rng: &mut Rng) -> Linkage {
    Linkage::SPARSE_REDUCIBLE[rng.below(Linkage::SPARSE_REDUCIBLE.len())]
}

#[test]
fn placement_is_a_total_partition() {
    for_all_seeds(0x5AAD, 25, |rng| {
        let machines = rng.range_usize(1, 24);
        let n = rng.range_usize(0, 300);
        // A random sparse id set (not necessarily contiguous), like the
        // live-cluster set mid-run.
        let ids: Vec<u32> = (0..n as u32).filter(|_| rng.f64() < 0.6).collect();
        let parts = partition(&ids, machines);
        assert_eq!(parts.len(), machines.max(1), "one list per machine");
        // Total: every id appears exactly once, on its own shard.
        let total: usize = parts.iter().map(Vec::len).sum();
        assert_eq!(total, ids.len(), "partition must be total");
        for (s, part) in parts.iter().enumerate() {
            for &id in part {
                assert_eq!(shard_of(id, machines), s, "id {id} on wrong shard");
            }
        }
    });
}

#[test]
fn batches_are_strictly_cross_shard() {
    for_all_seeds(0xC205, 12, |rng| {
        let g = random_graph(rng);
        let machines = rng.range_usize(1, 9);
        let cores = rng.range_usize(1, 5);
        let linkage = random_linkage(rng);
        let (r, report) =
            DistRacEngine::new(&g, linkage, DistConfig::new(machines, cores)).run_detailed();
        // Every connected component merges completely.
        assert_eq!(r.dendrogram.merges().len(), g.n() - g.components());
        for b in &report.batches {
            assert_ne!(b.src, b.dst, "{linkage:?}: local traffic accounted");
            assert!(b.src < machines.max(1) && b.dst < machines.max(1));
            assert!(b.messages >= 1, "empty batch accounted");
            assert!(b.bytes >= b.messages, "batch smaller than its messages");
        }
        if machines == 1 {
            assert!(report.batches.is_empty(), "single machine must be silent");
        }
    });
}

#[test]
fn round_accounting_invariants() {
    for_all_seeds(0xACC2, 12, |rng| {
        let g = random_graph(rng);
        let machines = rng.range_usize(1, 9);
        let cores = rng.range_usize(1, 5);
        let linkage = random_linkage(rng);
        let (r, report) =
            DistRacEngine::new(&g, linkage, DistConfig::new(machines, cores)).run_detailed();
        for rm in &r.metrics.rounds {
            assert!(
                rm.net_bytes >= rm.net_messages,
                "{linkage:?} round {}: bytes {} < messages {}",
                rm.round,
                rm.net_bytes,
                rm.net_messages
            );
        }
        // The batch log and the per-round counters describe the same run.
        assert_eq!(r.metrics.total_net_messages(), report.total_batches());
        assert_eq!(r.metrics.total_net_bytes(), report.total_bytes());
    });
}

// ---------------------------------------------------------------------
// Pinned wire-traffic regressions.
// ---------------------------------------------------------------------

/// Build the expected batch log from `(src, dst, round, messages)`
/// tuples, encoding each batch through the real codec so the pinned byte
/// counts are the wire lengths (the codec round-trip is exercised again
/// by `Network::send`'s debug assertion on every live batch).
fn expected_records(batches: &[(usize, usize, usize, Vec<Message>)]) -> Vec<BatchRecord> {
    batches
        .iter()
        .map(|(src, dst, round, msgs)| BatchRecord {
            src: *src,
            dst: *dst,
            messages: msgs.len(),
            bytes: encode_batch(msgs).len(),
            round: *round,
        })
        .collect()
}

/// The 4-point pinning graph: 0-1 merge first (w=1), 2-3 second (w=2),
/// the unions join last over the 1-2 bridge (w=9). With `machines = 2`
/// and id-mod placement the shards are {0, 2} and {1, 3}, so both round-0
/// merges are cross-shard — every phase's traffic is exercised.
fn pin_graph() -> Graph {
    Graph::from_edges(4, [(0, 1, 1.0), (2, 3, 2.0), (1, 2, 9.0)])
}

#[test]
fn pinned_dist_rac_traffic_on_a_hand_built_graph() {
    let (r, report) =
        DistRacEngine::new(&pin_graph(), Linkage::Average, DistConfig::new(2, 1)).run_detailed();
    assert_eq!(r.dendrogram.merges().len(), 3);
    // Round 0: NN-pointer exchange (every pointer is cross-shard), then
    // the merge phase ships both partner states and the cross-pair
    // views. Round 1 merges (0, 2) entirely on shard 0 — silent — and
    // finishes the run (no empty terminal round is recorded).
    let expected = expected_records(&[
        (
            0,
            1,
            0,
            vec![Message::NnQuery { cluster: 1 }, Message::NnQuery { cluster: 3 }],
        ),
        (
            1,
            0,
            0,
            vec![
                Message::NnReply { cluster: 1, nn: 0 },
                Message::NnReply { cluster: 3, nn: 2 },
            ],
        ),
        (
            1,
            0,
            0,
            vec![Message::NnQuery { cluster: 0 }, Message::NnQuery { cluster: 2 }],
        ),
        (
            0,
            1,
            0,
            vec![
                Message::NnReply { cluster: 0, nn: 1 },
                Message::NnReply { cluster: 2, nn: 3 },
            ],
        ),
        (
            0,
            1,
            0,
            vec![
                Message::PartnerFetch { partner: 1 },
                Message::PairViewQuery { cluster: 3 },
                Message::PartnerFetch { partner: 3 },
                Message::PairViewQuery { cluster: 1 },
            ],
        ),
        (
            1,
            0,
            0,
            vec![
                Message::PartnerState {
                    partner: 1,
                    size: 1,
                    entries: vec![(0, 1.0, 1), (2, 9.0, 1)],
                },
                Message::PairViewReply {
                    cluster: 3,
                    merging: true,
                    partner: 2,
                    size: 1,
                    pair_weight: 2.0,
                },
                Message::PartnerState {
                    partner: 3,
                    size: 1,
                    entries: vec![(2, 2.0, 1)],
                },
                Message::PairViewReply {
                    cluster: 1,
                    merging: true,
                    partner: 0,
                    size: 1,
                    pair_weight: 1.0,
                },
            ],
        ),
    ]);
    assert_eq!(report.batches, expected);
    // Per-round counters mirror the log, and every bulk-synchronous
    // round (terminal one included) is one sync point.
    let per_round: Vec<(usize, usize, usize)> = r
        .metrics
        .rounds
        .iter()
        .map(|rm| (rm.net_messages, rm.net_bytes, rm.sync_points))
        .collect();
    let round0_bytes: usize = expected.iter().map(|b| b.bytes).sum();
    assert_eq!(per_round, vec![(6, round0_bytes, 1), (0, 0, 1)]);
}

#[test]
fn pinned_dist_approx_traffic_on_a_hand_built_graph() {
    let (r, report) =
        DistApproxEngine::new(&pin_graph(), Linkage::Average, DistConfig::new(2, 1), 0.0)
            .run_detailed();
    assert_eq!(r.dendrogram.merges().len(), 3);
    // Round 0: the ε-good find phase queries remote NN *caches* only for
    // edges passing the local half of the test — (0,1) and (2,3); both
    // candidates originate on the coordinator shard, so no gather batch
    // is sent, and the matching broadcast reaches shard 1. The merge
    // phase mirrors dist_rac's. Round 1 (merge (0,2) on shard 0) is
    // silent and finishes the run.
    let expected = expected_records(&[
        (
            0,
            1,
            0,
            vec![
                Message::NnCacheQuery { cluster: 1 },
                Message::NnCacheQuery { cluster: 3 },
            ],
        ),
        (
            1,
            0,
            0,
            vec![
                Message::NnCacheReply {
                    cluster: 1,
                    nn: 0,
                    weight: 1.0,
                },
                Message::NnCacheReply {
                    cluster: 3,
                    nn: 2,
                    weight: 2.0,
                },
            ],
        ),
        (
            0,
            1,
            0,
            vec![Message::MatchingBroadcast {
                pairs: vec![(0, 1, 1.0), (2, 3, 2.0)],
            }],
        ),
        (
            0,
            1,
            0,
            vec![
                Message::PartnerFetch { partner: 1 },
                Message::PairViewQuery { cluster: 3 },
                Message::PartnerFetch { partner: 3 },
                Message::PairViewQuery { cluster: 1 },
            ],
        ),
        (
            1,
            0,
            0,
            vec![
                Message::PartnerState {
                    partner: 1,
                    size: 1,
                    entries: vec![(0, 1.0, 1), (2, 9.0, 1)],
                },
                Message::PairViewReply {
                    cluster: 3,
                    merging: true,
                    partner: 2,
                    size: 1,
                    pair_weight: 2.0,
                },
                Message::PartnerState {
                    partner: 3,
                    size: 1,
                    entries: vec![(2, 2.0, 1)],
                },
                Message::PairViewReply {
                    cluster: 1,
                    merging: true,
                    partner: 0,
                    size: 1,
                    pair_weight: 1.0,
                },
            ],
        ),
    ]);
    assert_eq!(report.batches, expected);
    let per_round: Vec<(usize, usize, usize)> = r
        .metrics
        .rounds
        .iter()
        .map(|rm| (rm.net_messages, rm.net_bytes, rm.sync_points))
        .collect();
    let round0_bytes: usize = expected.iter().map(|b| b.bytes).sum();
    assert_eq!(per_round, vec![(5, round0_bytes, 1), (0, 0, 1)]);
}

#[test]
fn pinned_batched_dist_approx_traffic_with_deferred_patch_flush() {
    // 3 points, vshards = 2 → blocks {0, 1} and {2}; machines = 2 own one
    // block each (Blocked placement). Round 0 merges (0, 1) locally and
    // DEFERS the cross-machine patch of cluster 2's row; round 1 has no
    // local work, so it synchronises: the deferred EdgePatch flushes
    // first, then the global find exchange and the cross-machine merge
    // of (0, 2) — all of it charged to the sync round.
    let g = Graph::from_edges(3, [(0, 1, 1.0), (1, 2, 5.0)]);
    let (r, report) = DistApproxEngine::new(&g, Linkage::Average, DistConfig::new(2, 1), 0.0)
        .with_sync_mode(SyncMode::Batched { vshards: 2 })
        .run_detailed();
    assert_eq!(
        r.dendrogram
            .merges()
            .iter()
            .map(|m| (m.a, m.b, m.weight))
            .collect::<Vec<_>>(),
        vec![(0, 1, 1.0), (0, 2, 5.0)]
    );
    let expected = expected_records(&[
        (
            0,
            1,
            1,
            vec![Message::EdgePatch {
                target: 2,
                leader: 0,
                retired: 1,
                weight: 5.0,
                count: 1,
            }],
        ),
        (0, 1, 1, vec![Message::NnCacheQuery { cluster: 2 }]),
        (
            1,
            0,
            1,
            vec![Message::NnCacheReply {
                cluster: 2,
                nn: 0,
                weight: 5.0,
            }],
        ),
        (
            0,
            1,
            1,
            vec![Message::MatchingBroadcast {
                pairs: vec![(0, 2, 5.0)],
            }],
        ),
        (0, 1, 1, vec![Message::PartnerFetch { partner: 2 }]),
        (
            1,
            0,
            1,
            vec![Message::PartnerState {
                partner: 2,
                size: 1,
                entries: vec![(0, 5.0, 1)],
            }],
        ),
    ]);
    assert_eq!(report.batches, expected);
    // Round 0 is a silent local round; round 1 carries everything and is
    // the run's only sync point.
    let per_round: Vec<(usize, usize, usize)> = r
        .metrics
        .rounds
        .iter()
        .map(|rm| (rm.net_messages, rm.net_bytes, rm.sync_points))
        .collect();
    let sync_bytes: usize = expected.iter().map(|b| b.bytes).sum();
    assert_eq!(per_round, vec![(0, 0, 0), (6, sync_bytes, 1)]);
}

#[test]
fn topology_never_changes_the_clustering() {
    // The sharding layer is accounting-only: sweep machines × cores on one
    // graph and demand bitwise-identical merge lists.
    let mut rng = Rng::seed_from(0xD15C);
    let g = random_graph(&mut rng);
    let base = DistRacEngine::new(&g, Linkage::Average, DistConfig::new(1, 1)).run();
    for machines in [2usize, 3, 5, 8, 13] {
        for cores in [1usize, 4] {
            let r = DistRacEngine::new(&g, Linkage::Average, DistConfig::new(machines, cores))
                .run();
            let a: Vec<_> = base
                .dendrogram
                .merges()
                .iter()
                .map(|m| (m.a, m.b, m.weight.to_bits()))
                .collect();
            let b: Vec<_> = r
                .dendrogram
                .merges()
                .iter()
                .map(|m| (m.a, m.b, m.weight.to_bits()))
                .collect();
            assert_eq!(a, b, "topology ({machines},{cores}) changed the merges");
        }
    }
}
