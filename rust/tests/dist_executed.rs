//! Differential suite for the *executed* distributed mode
//! ([`rac_hac::dist::exec`]): thread-per-machine shards exchanging real
//! channel-backed batches, versus the pure simulation that shares its
//! round logic.
//!
//! Contracts under test:
//!
//! * **Bitwise equality** — for every topology × ε × sync mode, the
//!   executed run's dendrogram, (1+ε) bounds trace, and per-round sync
//!   schedule are bitwise identical to the simulated run's. Execution
//!   changes the clock, never the algorithm.
//! * **Fault recovery** — killing shards mid-run (round-indexed fault
//!   campaigns: multi-machine, repeated, fault-during-recovery, plus
//!   seeded random faults) and recovering — by BSP global rollback or by
//!   journaled single-shard replay — replays to the *same* bitwise
//!   result. Determinism of the round body is what makes checkpoint
//!   replay sound; this suite is the pin, for both recovery modes and
//!   for delta-checkpoint chains at every cadence.
//! * **Link-delay injection** — per-link latency/jitter stretch the
//!   measured `t_exec` without perturbing any result bit (delays reorder
//!   packet arrivals; the barrier discipline absorbs them).
//! * **Clock ownership** — executed runs report `t_exec` and zero
//!   `t_sim`; simulated runs the reverse.

use rac_hac::approx::quality::MergeBound;
use rac_hac::approx::ApproxResult;
use rac_hac::data::{self, grid1d_graph, random_sparse_graph, random_tied_graph};
use rac_hac::dist::{
    DistApproxEngine, DistConfig, DistRacEngine, ExecOptions, FaultSpec, RecoveryMode, SyncMode,
};
use rac_hac::graph::Graph;
use rac_hac::linkage::Linkage;
use rac_hac::metrics::RunMetrics;
use rac_hac::util::prop::for_all_seeds;

const TOPOLOGIES: [(usize, usize); 3] = [(1, 1), (3, 2), (7, 4)];
const EPSILONS: [f64; 2] = [0.0, 0.1];
const VSHARDS: u32 = 8;

fn sync_modes() -> [SyncMode; 2] {
    [SyncMode::PerRound, SyncMode::Batched { vshards: VSHARDS }]
}

fn recovery_modes() -> [RecoveryMode; 2] {
    [RecoveryMode::Global, RecoveryMode::ShardReplay]
}

/// A fault campaign exercising every shape the driver distinguishes,
/// clamped into an m-machine topology: two distinct machines in one
/// round, the same machine again later, and an exact repeat — the second
/// instance fires while the first recovery is freshest, i.e. a fault
/// *during* recovery.
fn campaign(m: usize) -> Vec<FaultSpec> {
    let other = 2.min(m - 1);
    vec![
        FaultSpec { machine: 0, round: 2 },
        FaultSpec {
            machine: other,
            round: 2,
        },
        FaultSpec { machine: 0, round: 4 },
        FaultSpec { machine: 0, round: 4 },
    ]
}

fn rac_run(g: &Graph, topo: (usize, usize), exec: Option<ExecOptions>) -> rac_hac::rac::RacResult {
    let mut eng = DistRacEngine::new(g, Linkage::Average, DistConfig::new(topo.0, topo.1));
    if let Some(opts) = exec {
        eng = eng.with_exec(opts);
    }
    eng.run()
}

fn approx_run(
    g: &Graph,
    topo: (usize, usize),
    eps: f64,
    sync: SyncMode,
    exec: Option<ExecOptions>,
) -> ApproxResult {
    let mut eng = DistApproxEngine::new(g, Linkage::Average, DistConfig::new(topo.0, topo.1), eps)
        .with_sync_mode(sync);
    if let Some(opts) = exec {
        eng = eng.with_exec(opts);
    }
    eng.run()
}

fn bounds_bits(bs: &[MergeBound]) -> Vec<(u64, u64)> {
    bs.iter()
        .map(|b| (b.weight.to_bits(), b.visible_min.to_bits()))
        .collect()
}

fn sync_schedule(m: &RunMetrics) -> Vec<(usize, usize, usize)> {
    m.rounds
        .iter()
        .map(|r| (r.clusters, r.merges, r.sync_points))
        .collect()
}

/// The executed run must report only the measured clock, the simulated
/// run only the modeled one.
fn assert_clock_ownership(sim: &RunMetrics, exec: &RunMetrics) {
    assert!(sim.total_exec_time().is_zero(), "simulated run has t_exec");
    assert!(exec.total_sim_time().is_zero(), "executed run has t_sim");
    assert!(
        sim.total_merges() == 0 || !sim.total_sim_time().is_zero(),
        "simulated run lost its t_sim model"
    );
}

#[test]
fn executed_dist_rac_is_bitwise_equal_to_simulated() {
    for_all_seeds(0xE8EC, 4, |rng| {
        let g = if rng.bool_with(0.5) {
            random_tied_graph(rng)
        } else {
            random_sparse_graph(rng)
        };
        for topo in TOPOLOGIES {
            let sim = rac_run(&g, topo, None);
            let exec = rac_run(&g, topo, Some(ExecOptions::default()));
            assert_eq!(
                sim.dendrogram.bitwise_merges(),
                exec.dendrogram.bitwise_merges(),
                "topology={topo:?} n={}",
                g.n()
            );
            assert_eq!(
                sync_schedule(&sim.metrics),
                sync_schedule(&exec.metrics),
                "topology={topo:?}: round schedule diverged"
            );
            assert_clock_ownership(&sim.metrics, &exec.metrics);
        }
    });
}

#[test]
fn executed_dist_approx_is_bitwise_equal_to_simulated() {
    for_all_seeds(0xE8EC + 1, 3, |rng| {
        let g = if rng.bool_with(0.5) {
            random_tied_graph(rng)
        } else {
            random_sparse_graph(rng)
        };
        for topo in TOPOLOGIES {
            for eps in EPSILONS {
                for sync in sync_modes() {
                    let sim = approx_run(&g, topo, eps, sync, None);
                    let exec = approx_run(&g, topo, eps, sync, Some(ExecOptions::default()));
                    assert_eq!(
                        sim.dendrogram.bitwise_merges(),
                        exec.dendrogram.bitwise_merges(),
                        "topology={topo:?} eps={eps} sync={sync:?} n={}",
                        g.n()
                    );
                    assert_eq!(
                        bounds_bits(&sim.bounds),
                        bounds_bits(&exec.bounds),
                        "topology={topo:?} eps={eps} sync={sync:?}: bounds trace diverged"
                    );
                    assert_eq!(
                        sync_schedule(&sim.metrics),
                        sync_schedule(&exec.metrics),
                        "topology={topo:?} eps={eps} sync={sync:?}: sync schedule diverged"
                    );
                    assert_clock_ownership(&sim.metrics, &exec.metrics);
                }
            }
        }
    });
}

#[test]
fn executed_mode_on_the_adversarial_chain_all_modes() {
    // The deterministic Theorem-4 instance: lots of reciprocal structure
    // per round, exercising multi-pair merge rounds in one shot.
    let g = data::adversarial_thm4(5);
    for topo in TOPOLOGIES {
        let sim = rac_run(&g, topo, None);
        let exec = rac_run(&g, topo, Some(ExecOptions::default()));
        assert_eq!(exec.dendrogram.merges().len(), 31, "topology={topo:?}");
        assert_eq!(
            sim.dendrogram.bitwise_merges(),
            exec.dendrogram.bitwise_merges(),
            "topology={topo:?}"
        );
        for eps in EPSILONS {
            for sync in sync_modes() {
                let sim = approx_run(&g, topo, eps, sync, None);
                let exec = approx_run(&g, topo, eps, sync, Some(ExecOptions::default()));
                assert_eq!(
                    sim.dendrogram.bitwise_merges(),
                    exec.dendrogram.bitwise_merges(),
                    "topology={topo:?} eps={eps} sync={sync:?}"
                );
                assert_eq!(bounds_bits(&sim.bounds), bounds_bits(&exec.bounds));
            }
        }
    }
}

#[test]
fn killed_shard_recovers_to_bitwise_identical_dendrogram() {
    let g = grid1d_graph(180, 7);
    let topo = (3, 2);
    let faulted_opts = ExecOptions {
        faults: vec![FaultSpec {
            machine: 1,
            round: 3,
        }],
        ..ExecOptions::default()
    };

    // Exact engine.
    let clean = rac_run(&g, topo, Some(ExecOptions::default()));
    let recovered = rac_run(&g, topo, Some(faulted_opts.clone()));
    assert_eq!(
        clean.dendrogram.bitwise_merges(),
        recovered.dendrogram.bitwise_merges(),
        "dist_rac: recovery diverged from the unfaulted run"
    );
    // And both equal the simulation — recovery is invisible end to end.
    let sim = rac_run(&g, topo, None);
    assert_eq!(
        sim.dendrogram.bitwise_merges(),
        recovered.dendrogram.bitwise_merges()
    );

    // ε-good engines, per-round and batched.
    for sync in sync_modes() {
        let clean = approx_run(&g, topo, 0.1, sync, Some(ExecOptions::default()));
        let recovered = approx_run(&g, topo, 0.1, sync, Some(faulted_opts.clone()));
        assert_eq!(
            clean.dendrogram.bitwise_merges(),
            recovered.dendrogram.bitwise_merges(),
            "sync={sync:?}: recovery diverged from the unfaulted run"
        );
        assert_eq!(
            bounds_bits(&clean.bounds),
            bounds_bits(&recovered.bounds),
            "sync={sync:?}: recovery perturbed the bounds trace"
        );
    }
}

#[test]
fn faults_at_various_rounds_and_machines_all_recover() {
    let g = grid1d_graph(120, 11);
    let topo = (3, 1);
    let clean = rac_run(&g, topo, Some(ExecOptions::default()));
    for machine in 0..topo.0 {
        for round in [0, 1, 4] {
            for mode in recovery_modes() {
                let recovered = rac_run(
                    &g,
                    topo,
                    Some(ExecOptions {
                        faults: vec![FaultSpec { machine, round }],
                        recovery_mode: mode,
                        ..ExecOptions::default()
                    }),
                );
                assert_eq!(
                    clean.dendrogram.bitwise_merges(),
                    recovered.dendrogram.bitwise_merges(),
                    "fault at machine={machine} round={round} mode={mode:?} diverged"
                );
            }
        }
    }
    // A fault scheduled past the last round never fires; the run is just
    // a clean run.
    let late = rac_run(
        &g,
        topo,
        Some(ExecOptions {
            faults: vec![FaultSpec {
                machine: 0,
                round: 100_000,
            }],
            ..ExecOptions::default()
        }),
    );
    assert_eq!(
        clean.dendrogram.bitwise_merges(),
        late.dendrogram.bitwise_merges()
    );
}

#[test]
fn link_delays_stretch_the_clock_but_not_the_result() {
    use std::time::Duration;
    let g = grid1d_graph(60, 3);
    let topo = (3, 2);
    let fast = rac_run(&g, topo, Some(ExecOptions::default()));
    let slow = rac_run(
        &g,
        topo,
        Some(ExecOptions {
            latency: Duration::from_millis(2),
            jitter: Duration::from_micros(300),
            ..ExecOptions::default()
        }),
    );
    assert_eq!(
        fast.dendrogram.bitwise_merges(),
        slow.dendrogram.bitwise_merges(),
        "latency/jitter must not perturb results"
    );
    // Every merge round exchanges at least one cross-shard batch under
    // mod placement on a grid, so 2ms per hop dominates the fast run's
    // channel overhead by a wide margin.
    assert!(
        slow.metrics.total_exec_time() > fast.metrics.total_exec_time(),
        "slow {:?} <= fast {:?}",
        slow.metrics.total_exec_time(),
        fast.metrics.total_exec_time()
    );
}

#[test]
fn single_machine_executed_has_zero_wire_traffic() {
    let g = grid1d_graph(100, 5);
    let sim = rac_run(&g, (1, 1), None);
    let exec = rac_run(&g, (1, 1), Some(ExecOptions::default()));
    assert_eq!(
        sim.dendrogram.bitwise_merges(),
        exec.dendrogram.bitwise_merges()
    );
    assert_eq!(exec.metrics.total_net_messages(), 0);
    assert_eq!(exec.metrics.total_net_bytes(), 0);
}

#[test]
fn multi_machine_executed_reports_real_traffic() {
    let g = grid1d_graph(100, 5);
    let exec = rac_run(&g, (3, 2), Some(ExecOptions::default()));
    assert!(exec.metrics.total_net_messages() > 0);
    assert!(exec.metrics.total_net_bytes() > 0);
}

#[test]
fn multi_fault_campaigns_recover_bitwise_across_the_matrix() {
    // The satellite matrix: a campaign with two distinct machines in one
    // round, a repeat on the same machine, and a fault-during-recovery
    // duplicate, across every topology × ε × sync mode × recovery mode.
    // Dendrogram, bounds trace, and sync schedule must all be bitwise
    // identical to the unfaulted run.
    let g = grid1d_graph(140, 17);
    for topo in TOPOLOGIES {
        for eps in EPSILONS {
            for sync in sync_modes() {
                let clean = approx_run(&g, topo, eps, sync, Some(ExecOptions::default()));
                for mode in recovery_modes() {
                    let recovered = approx_run(
                        &g,
                        topo,
                        eps,
                        sync,
                        Some(ExecOptions {
                            faults: campaign(topo.0),
                            recovery_mode: mode,
                            ..ExecOptions::default()
                        }),
                    );
                    let tag = format!("topo={topo:?} eps={eps} sync={sync:?} mode={mode:?}");
                    assert_eq!(
                        clean.dendrogram.bitwise_merges(),
                        recovered.dendrogram.bitwise_merges(),
                        "{tag}: dendrogram diverged"
                    );
                    assert_eq!(
                        bounds_bits(&clean.bounds),
                        bounds_bits(&recovered.bounds),
                        "{tag}: bounds trace diverged"
                    );
                    assert_eq!(
                        sync_schedule(&clean.metrics),
                        sync_schedule(&recovered.metrics),
                        "{tag}: sync schedule diverged"
                    );
                }
            }
        }
    }
}

#[test]
fn fault_at_the_final_round_recovers() {
    // The last round is the edge case: the checkpoint chain is at its
    // longest and the remaining work is at its smallest.
    let g = grid1d_graph(120, 19);
    let topo = (3, 2);
    for sync in sync_modes() {
        let clean = approx_run(&g, topo, 0.1, sync, Some(ExecOptions::default()));
        let last = clean.metrics.rounds.len() - 1;
        for mode in recovery_modes() {
            let recovered = approx_run(
                &g,
                topo,
                0.1,
                sync,
                Some(ExecOptions {
                    faults: vec![FaultSpec {
                        machine: 1,
                        round: last,
                    }],
                    recovery_mode: mode,
                    ..ExecOptions::default()
                }),
            );
            assert_eq!(
                clean.dendrogram.bitwise_merges(),
                recovered.dendrogram.bitwise_merges(),
                "sync={sync:?} mode={mode:?}: fault at final round {last} diverged"
            );
            assert_eq!(
                bounds_bits(&clean.bounds),
                bounds_bits(&recovered.bounds),
                "sync={sync:?} mode={mode:?}: bounds trace diverged"
            );
        }
    }
}

#[test]
fn shard_replay_and_global_recovery_are_differentially_identical() {
    // The two recovery modes are semantically interchangeable: same
    // dendrogram, bounds, schedule, and wire log as each other and as the
    // unfaulted run. Shard replay must never replay *more* machine-rounds
    // than a global rollback of the same fault would.
    let g = grid1d_graph(160, 23);
    let topo = (3, 2);
    let sync = SyncMode::Batched { vshards: VSHARDS };
    let clean = approx_run(&g, topo, 0.1, sync, Some(ExecOptions::default()));
    let faulted = |mode| {
        approx_run(
            &g,
            topo,
            0.1,
            sync,
            Some(ExecOptions {
                faults: vec![FaultSpec {
                    machine: 1,
                    round: 3,
                }],
                recovery_mode: mode,
                ..ExecOptions::default()
            }),
        )
    };
    let global = faulted(RecoveryMode::Global);
    let shard = faulted(RecoveryMode::ShardReplay);
    for (name, run) in [("global", &global), ("shard_replay", &shard)] {
        assert_eq!(
            clean.dendrogram.bitwise_merges(),
            run.dendrogram.bitwise_merges(),
            "{name}: dendrogram diverged from unfaulted"
        );
        assert_eq!(
            bounds_bits(&clean.bounds),
            bounds_bits(&run.bounds),
            "{name}: bounds trace diverged from unfaulted"
        );
        assert_eq!(
            sync_schedule(&clean.metrics),
            sync_schedule(&run.metrics),
            "{name}: sync schedule diverged from unfaulted"
        );
        assert!(
            !run.metrics.t_recover.is_zero(),
            "{name}: fault fired but t_recover is zero"
        );
    }
    assert!(clean.metrics.t_recover.is_zero(), "clean run recovered?");
    assert!(
        shard.metrics.recovery_rounds_replayed <= global.metrics.recovery_rounds_replayed,
        "shard replay replayed more machine-rounds ({}) than global rollback ({})",
        shard.metrics.recovery_rounds_replayed,
        global.metrics.recovery_rounds_replayed
    );
}

#[test]
fn delta_checkpoint_chains_restore_bitwise_at_every_cadence() {
    // checkpoint_full_every = 1 is the v1 behaviour (every cut a full
    // blob); longer cadences restore through full→delta→delta chains.
    let g = grid1d_graph(140, 29);
    let topo = (3, 2);
    let sync = SyncMode::Batched { vshards: VSHARDS };
    let clean = approx_run(&g, topo, 0.1, sync, Some(ExecOptions::default()));
    for full_every in [1, 2, 4, 7] {
        for mode in recovery_modes() {
            let recovered = approx_run(
                &g,
                topo,
                0.1,
                sync,
                Some(ExecOptions {
                    faults: vec![FaultSpec {
                        machine: 2,
                        round: 4,
                    }],
                    recovery_mode: mode,
                    checkpoint_full_every: full_every,
                    ..ExecOptions::default()
                }),
            );
            assert_eq!(
                clean.dendrogram.bitwise_merges(),
                recovered.dendrogram.bitwise_merges(),
                "full_every={full_every} mode={mode:?}: dendrogram diverged"
            );
            assert_eq!(
                bounds_bits(&clean.bounds),
                bounds_bits(&recovered.bounds),
                "full_every={full_every} mode={mode:?}: bounds trace diverged"
            );
        }
    }
}

#[test]
fn seeded_random_faults_recover_bitwise() {
    let g = grid1d_graph(120, 31);
    let topo = (3, 2);
    let clean = rac_run(&g, topo, Some(ExecOptions::default()));
    for mode in recovery_modes() {
        let recovered = rac_run(
            &g,
            topo,
            Some(ExecOptions {
                fault_rate: 0.08,
                fault_seed: 0xFA17,
                recovery_mode: mode,
                ..ExecOptions::default()
            }),
        );
        assert_eq!(
            clean.dendrogram.bitwise_merges(),
            recovered.dendrogram.bitwise_merges(),
            "mode={mode:?}: random fault campaign diverged"
        );
    }
}
