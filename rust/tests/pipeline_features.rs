//! Feature-level integration: flat cuts on engine output, ε-ball graphs
//! through the pipeline, config round trips, and input-validation failure
//! paths.

use rac_hac::config::{EngineSpec, GraphSpec, RunConfig};
use rac_hac::data::{gaussian_mixture, grid1d_graph};
use rac_hac::graph::{read_graph, write_graph, Graph};
use rac_hac::knn::epsilon_graph;
use rac_hac::linkage::Linkage;
use rac_hac::pipeline;
use rac_hac::rac::RacEngine;
use rac_hac::util::json::Json;

#[test]
fn epsilon_graph_pipeline() {
    let cfg = RunConfig::from_toml_str(
        "[dataset]\ntype = \"sift_like\"\nn = 150\nd = 8\nclusters = 3\nspread = 0.3\n\
         noise_frac = 0.0\n[graph]\ntype = \"epsilon\"\neps = 30.0\n\
         [cluster]\nlinkage = \"average\"\n[engine]\ntype = \"rac\"\n",
    )
    .unwrap();
    assert_eq!(cfg.graph, GraphSpec::Epsilon { eps: 30.0 });
    let out = pipeline::run(&cfg).unwrap();
    out.result.dendrogram.validate().unwrap();
    // Within-cluster distances << 30 at spread 0.3 => components merge.
    assert!(out.result.dendrogram.merges().len() > 100);
}

#[test]
fn threshold_cut_matches_k_cut_on_monotone_output() {
    let g = grid1d_graph(200, 9);
    let r = RacEngine::new(&g, Linkage::Single).run();
    let d = &r.dendrogram;
    // For a monotone dendrogram, cutting just above the (n-k)-th smallest
    // merge weight equals the k-cut.
    let mut ws: Vec<f64> = d.merges().iter().map(|m| m.weight).collect();
    ws.sort_by(|a, b| a.total_cmp(b));
    let k = 7;
    let thr = (ws[200 - k - 1] + ws[200 - k]) / 2.0;
    let by_thr = d.cut_threshold(thr);
    let by_k = d.cut_k(k).unwrap();
    for i in 0..200 {
        for j in (i + 1)..200 {
            assert_eq!(
                by_thr[i] == by_thr[j],
                by_k[i] == by_k[j],
                "co-membership mismatch at ({i},{j})"
            );
        }
    }
}

#[test]
fn epsilon_graph_respects_radius_on_mixture() {
    let ds = gaussian_mixture(100, 8, 4, 0.2, 0.0, 3);
    let g = epsilon_graph(&ds, 1.5);
    g.validate().unwrap();
    for u in 0..100u32 {
        for (v, w) in g.neighbors(u) {
            assert!(w < 1.5);
            assert!((ds.dissimilarity(u as usize, v as usize) - w).abs() < 1e-12);
        }
    }
}

#[test]
fn graph_io_large_roundtrip() {
    let ds = gaussian_mixture(300, 8, 6, 0.5, 0.02, 4);
    let g = rac_hac::knn::knn_graph(&ds, 7, rac_hac::knn::Backend::Native, None).unwrap();
    let dir = std::env::temp_dir().join(format!("racio-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("knn.bin");
    write_graph(&g, &path).unwrap();
    let g2 = read_graph(&path).unwrap();
    assert_eq!(g, g2);
    // The reloaded graph clusters identically.
    let a = RacEngine::new(&g, Linkage::Average).run();
    let b = RacEngine::new(&g2, Linkage::Average).run();
    assert!(a.dendrogram.same_clustering(&b.dendrogram, 1e-15));
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn truncated_graph_file_rejected() {
    let ds = gaussian_mixture(50, 4, 2, 0.5, 0.0, 5);
    let g = rac_hac::knn::knn_graph(&ds, 4, rac_hac::knn::Backend::Native, None).unwrap();
    let dir = std::env::temp_dir().join(format!("ractrunc-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("g.bin");
    write_graph(&g, &path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    // Truncate at several points: every prefix must fail cleanly.
    for cut in [8usize, 24, bytes.len() / 2, bytes.len() - 4] {
        std::fs::write(&path, &bytes[..cut]).unwrap();
        assert!(read_graph(&path).is_err(), "cut={cut} accepted");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn engine_spec_round_trip_through_pipeline() {
    for engine in ["naive_hac", "nn_chain", "rac", "dist_rac"] {
        let cfg = RunConfig::from_toml_str(&format!(
            "[dataset]\ntype = \"grid1d\"\nn = 80\n[cluster]\nlinkage = \"single\"\n\
             [engine]\ntype = \"{engine}\"\n"
        ))
        .unwrap();
        let out = pipeline::run(&cfg).unwrap();
        assert_eq!(out.result.dendrogram.merges().len(), 79, "{engine}");
    }
    let cfg = RunConfig::from_toml_str(
        "[engine]\ntype = \"nn_chain\"\n[cluster]\nlinkage = \"centroid\"\n\
         [dataset]\ntype = \"grid1d\"\nn = 10\n",
    )
    .unwrap();
    assert!(matches!(cfg.engine, EngineSpec::NnChain));
    assert!(pipeline::run(&cfg).is_err(), "centroid nn_chain must fail");
}

#[test]
fn metrics_out_writes_parseable_run_aggregates() {
    // The `--metrics-out FILE` flag mutates `cfg.output` after parsing
    // (see `apply_output_flags` in the CLI); pin that post-parse route
    // end to end: run the pipeline, read the JSON back, and check the
    // run-level aggregates against the in-memory metrics.
    let dir = std::env::temp_dir().join(format!("racmet-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let metrics_path = dir.join("metrics.json");
    let mut cfg = RunConfig::from_toml_str(
        "[dataset]\ntype = \"grid1d\"\nn = 90\n[cluster]\nlinkage = \"average\"\n\
         [engine]\ntype = \"dist_rac\"\nmachines = 3\ncpus = 2\n",
    )
    .unwrap();
    assert_eq!(cfg.output.metrics_out, None);
    cfg.output.metrics_out = Some(metrics_path.to_string_lossy().into_owned());
    let out = pipeline::run(&cfg).unwrap();
    let text = std::fs::read_to_string(&metrics_path).unwrap();
    let json = Json::parse(&text).unwrap();
    let m = &out.result.metrics;
    for (key, want) in [
        ("total_merges", m.total_merges()),
        ("merge_rounds", m.merge_rounds()),
        ("total_net_messages", m.total_net_messages()),
        ("total_net_bytes", m.total_net_bytes()),
        ("total_sync_points", m.total_sync_points()),
    ] {
        assert_eq!(
            json.get(key).and_then(|v| v.as_usize()),
            Some(want),
            "metrics-out field {key}"
        );
    }
    let per_round = json.get("rounds").and_then(|v| v.as_arr()).unwrap();
    assert_eq!(per_round.len(), m.rounds.len());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn degenerate_graphs_all_engines() {
    // Two nodes, one edge; star graph; path with equal weights.
    let tiny = Graph::from_edges(2, [(0, 1, 1.0)]);
    let star = Graph::from_edges(
        5,
        (1..5u32).map(|i| (0u32, i, 1.0 + i as f64 * 0.1)),
    );
    let equal = Graph::from_edges(4, [(0, 1, 1.0), (1, 2, 1.0), (2, 3, 1.0)]);
    for g in [&tiny, &star, &equal] {
        let hac = rac_hac::hac::naive_hac(g, Linkage::Average);
        let rac = RacEngine::new(g, Linkage::Average).run();
        assert!(hac.same_clustering(&rac.dendrogram, 1e-12));
        assert_eq!(rac.dendrogram.merges().len(), g.n() - 1);
    }
}
