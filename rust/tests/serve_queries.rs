//! Differential suite for the serving layer (`rac_hac::serve`).
//!
//! The contract under test: [`ServeIndex`] is a *pure representation
//! change*. Every query it answers — threshold cuts, k-cuts (including
//! their error cases), single-point membership, cluster extraction,
//! threshold-band diffs — must agree **bitwise** with the naive
//! [`Dendrogram`] implementation, across the outputs of all five engines,
//! on random sparse graphs (routinely disconnected), tie-heavy quantised
//! weights, and thresholds sitting exactly on merge weights.
//!
//! Plus the snapshot-swap property: readers holding an `Arc` from
//! [`ServeHandle::load`] keep getting answers consistent with *their*
//! snapshot while a publisher swaps new indexes underneath them.

use rac_hac::approx::ApproxEngine;
use rac_hac::data::{gaussian_mixture, random_sparse_graph, random_tied_graph};
use rac_hac::dendrogram::{CutError, Dendrogram};
use rac_hac::dist::{DistApproxEngine, DistConfig, DistRacEngine};
use rac_hac::graph::Graph;
use rac_hac::knn::{knn_graph, Backend};
use rac_hac::linkage::{Linkage, Weight};
use rac_hac::rac::baseline::HashRacEngine;
use rac_hac::rac::RacEngine;
use rac_hac::serve::{codec, ServeHandle, ServeIndex};
use rac_hac::util::prop::for_all_seeds;

/// The five engines' dendrograms for one graph.
fn engine_dendrograms(g: &Graph, l: Linkage) -> Vec<(&'static str, Dendrogram)> {
    vec![
        ("rac", RacEngine::new(g, l).run().dendrogram),
        ("hash_rac", HashRacEngine::new(g, l).run().dendrogram),
        ("approx", ApproxEngine::new(g, l, 0.1).run().dendrogram),
        (
            "dist_rac",
            DistRacEngine::new(g, l, DistConfig::new(3, 2)).run().dendrogram,
        ),
        (
            "dist_approx",
            DistApproxEngine::new(g, l, DistConfig::new(3, 2), 0.1)
                .run()
                .dendrogram,
        ),
    ]
}

/// Thresholds worth probing for a dendrogram: every merge weight itself
/// (the exclusive-boundary case), midpoints between distinct weights, and
/// the extremes.
fn probe_thresholds(d: &Dendrogram) -> Vec<Weight> {
    let mut ws: Vec<Weight> = d.merges().iter().map(|m| m.weight).collect();
    ws.sort_by(Weight::total_cmp);
    let mut ts = vec![0.0, -1.0, Weight::INFINITY, Weight::NEG_INFINITY];
    for i in 0..ws.len() {
        ts.push(ws[i]);
        if i + 1 < ws.len() && ws[i] < ws[i + 1] {
            ts.push((ws[i] + ws[i + 1]) / 2.0);
        }
    }
    if let (Some(first), Some(last)) = (ws.first(), ws.last()) {
        ts.push(first - 1.0);
        ts.push(last + 1.0);
    }
    ts
}

/// Naive cluster representative: the minimum point id sharing `p`'s label.
fn naive_rep(labels: &[u32], p: usize) -> u32 {
    labels
        .iter()
        .position(|&l| l == labels[p])
        .expect("p itself matches") as u32
}

/// Naive cluster extraction: all points sharing `p`'s label, ascending.
fn naive_members(labels: &[u32], p: usize) -> Vec<u32> {
    labels
        .iter()
        .enumerate()
        .filter(|&(_, &l)| l == labels[p])
        .map(|(i, _)| i as u32)
        .collect()
}

/// Pin every query class on one (dendrogram, index) pair.
fn pin_against_naive(name: &str, d: &Dendrogram) {
    let idx = ServeIndex::build(d).expect("engine output must index");
    let n = d.n();
    assert_eq!(idx.n(), n);
    assert_eq!(idx.components(), d.remaining_clusters(), "{name}");

    for t in probe_thresholds(d) {
        let naive = d.cut_threshold(t);
        assert_eq!(idx.cut_threshold(t), naive, "{name}: cut_threshold({t})");
        // Membership + extraction, sampled across the id range.
        for p in (0..n).step_by(1 + n / 17) {
            assert_eq!(
                idx.point_membership(p as u32, t).unwrap(),
                naive_rep(&naive, p),
                "{name}: point_membership({p}, {t})"
            );
            assert_eq!(
                idx.cluster_members(p as u32, t).unwrap(),
                naive_members(&naive, p),
                "{name}: cluster_members({p}, {t})"
            );
        }
    }

    // k-cuts: agreement over the whole range, errors included.
    for k in 0..=n + 1 {
        assert_eq!(idx.cut_k(k), d.cut_k(k), "{name}: cut_k({k})");
    }
}

#[test]
fn all_engines_all_queries_bitwise_on_random_sparse_graphs() {
    for_all_seeds(0x5E41, 8, |rng| {
        let g = random_sparse_graph(rng);
        for (name, d) in engine_dendrograms(&g, Linkage::Average) {
            pin_against_naive(name, &d);
        }
    });
}

#[test]
fn tie_heavy_graphs_cut_identically_at_tied_weights() {
    // Quantised weights put many merges at exactly the probed thresholds;
    // the exclusive boundary must land identically on both paths.
    for_all_seeds(0x5E42, 8, |rng| {
        let g = random_tied_graph(rng);
        for (name, d) in engine_dendrograms(&g, Linkage::Single) {
            pin_against_naive(name, &d);
        }
    });
}

#[test]
fn single_linkage_and_ward_shapes_also_agree() {
    // One more linkage over the sparse shape, plus a complete-graph Ward
    // run: different weight distributions, same bitwise contract.
    for_all_seeds(0x5E43, 4, |rng| {
        let g = random_sparse_graph(rng);
        for (name, d) in engine_dendrograms(&g, Linkage::Single) {
            pin_against_naive(name, &d);
        }
    });
    let pts = gaussian_mixture(60, 8, 4, 3.0, 0.3, 9);
    let g = rac_hac::knn::complete_graph(&pts);
    let d = RacEngine::new(&g, Linkage::Ward).run().dendrogram;
    pin_against_naive("rac/ward", &d);
}

/// Minimal lower-root-wins union-find, reimplemented here so the diff
/// replay check is independent of the crate's own union-find.
struct Uf(Vec<u32>);

impl Uf {
    fn new(n: usize) -> Uf {
        Uf((0..n as u32).collect())
    }
    fn find(&mut self, mut x: u32) -> u32 {
        while self.0[x as usize] != x {
            self.0[x as usize] = self.0[self.0[x as usize] as usize];
            x = self.0[x as usize];
        }
        x
    }
    fn union(&mut self, a: u32, b: u32) {
        let (ra, rb) = (self.find(a), self.find(b));
        let (lo, hi) = (ra.min(rb), ra.max(rb));
        self.0[hi as usize] = lo;
    }
    fn dense_labels(&mut self) -> Vec<u32> {
        let n = self.0.len();
        let mut map = std::collections::HashMap::new();
        let mut out = Vec::with_capacity(n);
        for x in 0..n as u32 {
            let r = self.find(x);
            let next = map.len() as u32;
            out.push(*map.entry(r).or_insert(next));
        }
        out
    }
}

#[test]
fn diff_replays_a_threshold_band_exactly() {
    for_all_seeds(0x5E44, 10, |rng| {
        let g = random_sparse_graph(rng);
        let d = RacEngine::new(&g, Linkage::Average).run().dendrogram;
        let idx = ServeIndex::build(&d).unwrap();
        // Sampled threshold pairs: the full probe list is quadratic in
        // merge count and this replay is itself O(n α) per pair.
        let all = probe_thresholds(&d);
        let ts: Vec<Weight> = all.iter().step_by(1 + all.len() / 12).copied().collect();
        for (i, &lo) in ts.iter().enumerate() {
            for &hi in &ts[i..] {
                // The probe list is not sorted; orient each pair (no NaNs
                // in it, so the swap is total).
                let (lo, hi) = if lo <= hi { (lo, hi) } else { (hi, lo) };
                let steps = idx.diff(lo, hi).unwrap();
                // Replay the band on top of the lo-cut with an
                // independent union-find; each step must name the two
                // clusters' *current* minimum members, and the result
                // must be exactly the hi-cut.
                let labels_lo = d.cut_threshold(lo);
                let mut uf = Uf::new(d.n());
                // Seed the lo-cut: union every point onto its label's
                // first occurrence (labels are dense first-encounter, so
                // the first occurrence is the cluster's minimum member).
                let mut first = vec![u32::MAX; labels_lo.len()];
                for (p, &l) in labels_lo.iter().enumerate() {
                    if first[l as usize] == u32::MAX {
                        first[l as usize] = p as u32;
                    } else {
                        uf.union(first[l as usize], p as u32);
                    }
                }
                for s in &steps {
                    assert!(s.into < s.absorbed, "step reps ordered");
                    assert_eq!(uf.find(s.into), s.into, "into is a live rep");
                    assert_eq!(uf.find(s.absorbed), s.absorbed, "absorbed is a live rep");
                    uf.union(s.into, s.absorbed);
                }
                assert_eq!(
                    uf.dense_labels(),
                    d.cut_threshold(hi),
                    "band [{lo}, {hi}) replay diverged"
                );
            }
        }
    });
}

#[test]
fn cut_k_on_a_disconnected_knn_graph_is_a_named_error() {
    // Two tight, far-apart blobs and a small k: the kNN graph cannot
    // connect them — the exact regression scenario for the old silent
    // `remaining_clusters()` fallback. Built deterministically so the
    // disconnection is structural, not a lucky seed.
    let mut rng = rac_hac::util::rng::Rng::seed_from(0x5E46);
    let (n, d) = (60usize, 8usize);
    let mut rows = vec![0.0f32; n * d];
    for (i, row) in rows.chunks_mut(d).enumerate() {
        let offset = if i < n / 2 { 0.0 } else { 1000.0 };
        for x in row {
            *x = (offset + rng.range_f64(0.0, 1.0)) as f32;
        }
    }
    let pts = rac_hac::data::Dataset {
        n,
        d,
        metric: rac_hac::data::Metric::L2,
        rows,
    };
    let g = knn_graph(&pts, 3, Backend::Native, None).unwrap();
    let d = RacEngine::new(&g, Linkage::Average).run().dendrogram;
    let components = d.remaining_clusters();
    assert!(
        components >= 2,
        "fixture must be disconnected, got {components} component(s)"
    );
    assert_eq!(
        d.cut_k(1),
        Err(CutError::Disconnected { k: 1, components })
    );
    // The indexed path agrees on the error, and on the first answerable k.
    let idx = ServeIndex::build(&d).unwrap();
    assert_eq!(idx.cut_k(1), d.cut_k(1));
    assert_eq!(idx.cut_k(components), d.cut_k(components));
    assert!(d.cut_k(components).is_ok());
}

#[test]
fn persisted_dendrogram_serves_identically() {
    let g = random_tied_graph(&mut rac_hac::util::rng::Rng::seed_from(0x5E45));
    let d = RacEngine::new(&g, Linkage::Average).run().dendrogram;
    let dir = std::env::temp_dir().join(format!("racserve-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("t.dend");
    codec::write_file(&d, &path).unwrap();
    let back = codec::read_file(&path).unwrap();
    assert_eq!(back.bitwise_merges(), d.bitwise_merges());
    pin_against_naive("rac/persisted", &back);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn snapshot_swap_keeps_live_readers_consistent() {
    // Two dendrograms with different n, so a reader can tell which
    // snapshot it is holding and check against the matching naive answer.
    let chain = |n: u32, scale: f64| {
        Graph::from_edges(
            n as usize,
            (1..n).map(move |v| (v - 1, v, scale * v as f64)),
        )
    };
    let d_a = RacEngine::new(&chain(40, 1.0), Linkage::Single).run().dendrogram;
    let d_b = RacEngine::new(&chain(31, 0.5), Linkage::Single).run().dendrogram;
    let t = 7.25;
    let naive_a = d_a.cut_threshold(t);
    let naive_b = d_b.cut_threshold(t);
    let handle = ServeHandle::new(ServeIndex::build(&d_a).unwrap());

    std::thread::scope(|s| {
        for _ in 0..4 {
            s.spawn(|| {
                for _ in 0..300 {
                    let snap = handle.load();
                    let labels = snap.cut_threshold(t);
                    let expect = if snap.n() == naive_a.len() {
                        &naive_a
                    } else {
                        &naive_b
                    };
                    assert_eq!(&labels, expect, "reader saw a torn snapshot");
                }
            });
        }
        s.spawn(|| {
            for i in 0..40 {
                let next = if i % 2 == 0 { &d_b } else { &d_a };
                handle.publish(ServeIndex::build(next).unwrap());
                std::thread::yield_now();
            }
        });
    });
    // The publisher's last swap (i = 39, odd) reinstated d_a.
    assert_eq!(handle.load().cut_threshold(t), naive_a);
}
