//! Differential property suite for the flat neighbor store: on random
//! sparse graphs, the arena-backed [`RacEngine`] must produce dendrograms
//! **bitwise identical** to the PR-1 hashmap oracle
//! ([`HashRacEngine`]) — for every `SPARSE_REDUCIBLE` linkage — and
//! identical to itself across thread counts 1/2/8. The distributed
//! engine is held to the same bit-level standard, so all three neighbor
//! representations (arena, hashmap, sharded arena) are pinned together.
//!
//! This is the contract that lets the perf work proceed safely: any
//! divergence isolates a bug in the store layer or the owner-sharded
//! apply, because every engine shares `rac::logic` for the arithmetic.

use rac_hac::dist::{DistConfig, DistRacEngine};
use rac_hac::graph::Graph;
use rac_hac::linkage::{Linkage, Weight};
use rac_hac::rac::baseline::HashRacEngine;
use rac_hac::rac::RacEngine;
use rac_hac::util::prop::for_all_seeds;
use rac_hac::util::rng::Rng;

/// Random sparse graph: a random tree (keeps most of the graph connected
/// so runs produce long merge sequences) plus random extra edges, with
/// occasional isolated tail nodes.
fn random_sparse_graph(rng: &mut Rng) -> Graph {
    let n = rng.range_usize(2, 140);
    let mut edges: Vec<(u32, u32, Weight)> = Vec::new();
    for v in 1..n {
        // ~1 node in 12 stays detached from the tree.
        if rng.bool_with(1.0 / 12.0) {
            continue;
        }
        let u = rng.below(v) as u32;
        edges.push((u, v as u32, rng.range_f64(0.1, 100.0)));
    }
    let extra = rng.range_usize(0, 3 * n);
    for _ in 0..extra {
        let u = rng.below(n) as u32;
        let v = rng.below(n) as u32;
        if u != v {
            edges.push((u.min(v), u.max(v), rng.range_f64(0.1, 100.0)));
        }
    }
    Graph::from_edges(n, edges)
}

#[test]
fn flat_store_matches_hashmap_oracle() {
    for_all_seeds(0x5708E, 35, |rng| {
        let g = random_sparse_graph(rng);
        for l in Linkage::SPARSE_REDUCIBLE {
            let oracle = HashRacEngine::new(&g, l).with_threads(1).run();
            let flat = RacEngine::new(&g, l).with_threads(1).run();
            assert_eq!(
                oracle.dendrogram.bitwise_merges(),
                flat.dendrogram.bitwise_merges(),
                "{l:?}: flat store diverged from hashmap oracle (n={})",
                g.n()
            );
        }
    });
}

#[test]
fn flat_store_identical_across_thread_counts() {
    for_all_seeds(0x7EAD5, 20, |rng| {
        let g = random_sparse_graph(rng);
        for l in Linkage::SPARSE_REDUCIBLE {
            let base = RacEngine::new(&g, l).with_threads(1).run();
            for threads in [2usize, 8] {
                let r = RacEngine::new(&g, l).with_threads(threads).run();
                assert_eq!(
                    base.dendrogram.bitwise_merges(),
                    r.dendrogram.bitwise_merges(),
                    "{l:?}: {threads} threads changed the dendrogram (n={})",
                    g.n()
                );
            }
        }
    });
}

#[test]
fn parallel_oracle_agrees_too() {
    // The oracle's own parallelism (phases 1/2-compute/3) must not change
    // anything either — pins the shared logic layer, not just the store.
    for_all_seeds(0x0AC1E, 12, |rng| {
        let g = random_sparse_graph(rng);
        for l in Linkage::SPARSE_REDUCIBLE {
            let oracle = HashRacEngine::new(&g, l).with_threads(4).run();
            let flat = RacEngine::new(&g, l).with_threads(4).run();
            assert_eq!(oracle.dendrogram.bitwise_merges(), flat.dendrogram.bitwise_merges(), "{l:?}");
        }
    });
}

#[test]
fn dist_engine_matches_flat_store() {
    for_all_seeds(0xD157, 12, |rng| {
        let g = random_sparse_graph(rng);
        for l in Linkage::SPARSE_REDUCIBLE {
            let flat = RacEngine::new(&g, l).with_threads(3).run();
            let dist = DistRacEngine::new(&g, l, DistConfig::new(5, 2)).run();
            assert_eq!(
                flat.dendrogram.bitwise_merges(),
                dist.dendrogram.bitwise_merges(),
                "{l:?}: dist engine diverged (n={})",
                g.n()
            );
        }
    });
}

/// Force heavy arena churn (large graph, many rounds) so compaction
/// triggers, and demand the oracle equivalence survives it.
#[test]
fn equivalence_survives_compaction() {
    let mut rng = Rng::seed_from(0xC0517AC7);
    let n = 2500;
    let mut edges: Vec<(u32, u32, Weight)> = Vec::new();
    for v in 1..n {
        let u = rng.below(v) as u32;
        edges.push((u, v as u32, rng.range_f64(0.1, 100.0)));
    }
    for _ in 0..4 * n {
        let u = rng.below(n) as u32;
        let v = rng.below(n) as u32;
        if u != v {
            edges.push((u.min(v), u.max(v), rng.range_f64(0.1, 100.0)));
        }
    }
    let g = Graph::from_edges(n, edges);
    for l in Linkage::SPARSE_REDUCIBLE {
        let oracle = HashRacEngine::new(&g, l).with_threads(4).run();
        let flat = RacEngine::new(&g, l).with_threads(4).run();
        assert_eq!(oracle.dendrogram.bitwise_merges(), flat.dendrogram.bitwise_merges(), "{l:?}");
    }
}
