//! Differential property suite for the engine core: on random sparse
//! graphs, **every** selector-backed engine must produce dendrograms
//! **bitwise identical** to the PR-1 hashmap oracle ([`HashRacEngine`]) —
//! for every `SPARSE_REDUCIBLE` linkage, across thread counts 1/2/8, and
//! across `dist` topologies — including on tie-heavy quantised-weight
//! graphs, the regime where the ε-good boundary rule and the stale-tie NN
//! caches interact.
//!
//! Since PR 4 all shared-memory engines run through one
//! [`engine::RoundDriver`] loop and share `rac::logic` for the
//! arithmetic, so any divergence isolates a bug in a store backend
//! ([`store::NeighborStore`] vs [`rac::baseline::HashStore`]), a selector
//! ([`engine::RnnSelector`] vs [`engine::GoodSelector`] at ε = 0), or the
//! dist accounting wrapper — not in mirrored loop bodies.

use rac_hac::data::{random_sparse_graph, random_tied_graph};
use rac_hac::dist::{DistApproxEngine, DistConfig, DistRacEngine};
use rac_hac::graph::Graph;
use rac_hac::linkage::{Linkage, Weight};
use rac_hac::rac::baseline::HashRacEngine;
use rac_hac::rac::RacEngine;
use rac_hac::util::prop::for_all_seeds;
use rac_hac::util::rng::Rng;

#[test]
fn flat_store_matches_hashmap_oracle() {
    for_all_seeds(0x5708E, 35, |rng| {
        let g = random_sparse_graph(rng);
        for l in Linkage::SPARSE_REDUCIBLE {
            let oracle = HashRacEngine::new(&g, l).with_threads(1).run();
            let flat = RacEngine::new(&g, l).with_threads(1).run();
            assert_eq!(
                oracle.dendrogram.bitwise_merges(),
                flat.dendrogram.bitwise_merges(),
                "{l:?}: flat store diverged from hashmap oracle (n={})",
                g.n()
            );
        }
    });
}

#[test]
fn flat_store_identical_across_thread_counts() {
    for_all_seeds(0x7EAD5, 20, |rng| {
        let g = random_sparse_graph(rng);
        for l in Linkage::SPARSE_REDUCIBLE {
            let base = RacEngine::new(&g, l).with_threads(1).run();
            for threads in [2usize, 8] {
                let r = RacEngine::new(&g, l).with_threads(threads).run();
                assert_eq!(
                    base.dendrogram.bitwise_merges(),
                    r.dendrogram.bitwise_merges(),
                    "{l:?}: {threads} threads changed the dendrogram (n={})",
                    g.n()
                );
            }
        }
    });
}

#[test]
fn parallel_oracle_agrees_too() {
    // The oracle's own parallelism (phases 1/2-compute/3) must not change
    // anything either — pins the shared driver + logic layers, not just
    // the store.
    for_all_seeds(0x0AC1E, 12, |rng| {
        let g = random_sparse_graph(rng);
        for l in Linkage::SPARSE_REDUCIBLE {
            let oracle = HashRacEngine::new(&g, l).with_threads(4).run();
            let flat = RacEngine::new(&g, l).with_threads(4).run();
            assert_eq!(oracle.dendrogram.bitwise_merges(), flat.dendrogram.bitwise_merges(), "{l:?}");
        }
    });
}

#[test]
fn dist_engine_matches_flat_store() {
    for_all_seeds(0xD157, 12, |rng| {
        let g = random_sparse_graph(rng);
        for l in Linkage::SPARSE_REDUCIBLE {
            let flat = RacEngine::new(&g, l).with_threads(3).run();
            let dist = DistRacEngine::new(&g, l, DistConfig::new(5, 2)).run();
            assert_eq!(
                flat.dendrogram.bitwise_merges(),
                dist.dendrogram.bitwise_merges(),
                "{l:?}: dist engine diverged (n={})",
                g.n()
            );
        }
    });
}

/// The full driver matrix: every selector-backed engine — exact flat,
/// ε=0 approx, exact dist, ε=0 dist_approx — pinned bitwise against the
/// hashmap oracle, across thread counts and topologies, on both
/// continuous-weight and tie-heavy quantised-weight graphs.
#[test]
fn every_selector_backed_engine_matches_the_oracle() {
    for_all_seeds(0x0D21E2, 10, |rng| {
        let tied = rng.bool_with(0.5);
        let g = if tied {
            random_tied_graph(rng)
        } else {
            random_sparse_graph(rng)
        };
        for l in Linkage::SPARSE_REDUCIBLE {
            let want = HashRacEngine::new(&g, l)
                .with_threads(1)
                .run()
                .dendrogram
                .bitwise_merges();
            for threads in [1usize, 2, 8] {
                let flat = RacEngine::new(&g, l).with_threads(threads).run();
                assert_eq!(
                    want,
                    flat.dendrogram.bitwise_merges(),
                    "{l:?} rac t={threads} tied={tied} (n={})",
                    g.n()
                );
                let hash = HashRacEngine::new(&g, l).with_threads(threads).run();
                assert_eq!(
                    want,
                    hash.dendrogram.bitwise_merges(),
                    "{l:?} oracle t={threads} tied={tied} (n={})",
                    g.n()
                );
                let approx = rac_hac::approx::ApproxEngine::new(&g, l, 0.0)
                    .with_threads(threads)
                    .run();
                assert_eq!(
                    want,
                    approx.dendrogram.bitwise_merges(),
                    "{l:?} approx(0) t={threads} tied={tied} (n={})",
                    g.n()
                );
            }
            for (machines, cores) in [(1usize, 1usize), (3, 2), (7, 4)] {
                let dist = DistRacEngine::new(&g, l, DistConfig::new(machines, cores)).run();
                assert_eq!(
                    want,
                    dist.dendrogram.bitwise_merges(),
                    "{l:?} dist_rac {machines}x{cores} tied={tied} (n={})",
                    g.n()
                );
                let dapprox =
                    DistApproxEngine::new(&g, l, DistConfig::new(machines, cores), 0.0).run();
                assert_eq!(
                    want,
                    dapprox.dendrogram.bitwise_merges(),
                    "{l:?} dist_approx(0) {machines}x{cores} tied={tied} (n={})",
                    g.n()
                );
            }
        }
    });
}

/// Force heavy arena churn (large graph, many rounds) so compaction
/// triggers, and demand the oracle equivalence survives it.
#[test]
fn equivalence_survives_compaction() {
    let mut rng = Rng::seed_from(0xC0517AC7);
    let n = 2500;
    let mut edges: Vec<(u32, u32, Weight)> = Vec::new();
    for v in 1..n {
        let u = rng.below(v) as u32;
        edges.push((u, v as u32, rng.range_f64(0.1, 100.0)));
    }
    for _ in 0..4 * n {
        let u = rng.below(n) as u32;
        let v = rng.below(n) as u32;
        if u != v {
            edges.push((u.min(v), u.max(v), rng.range_f64(0.1, 100.0)));
        }
    }
    let g = Graph::from_edges(n, edges);
    for l in Linkage::SPARSE_REDUCIBLE {
        let oracle = HashRacEngine::new(&g, l).with_threads(4).run();
        let flat = RacEngine::new(&g, l).with_threads(4).run();
        assert_eq!(oracle.dendrogram.bitwise_merges(), flat.dendrogram.bitwise_merges(), "{l:?}");
    }
}
