//! Theorem 1 property tests: for reducible linkages, every engine in the
//! crate — naive heap HAC, NN-chain, shared-memory RAC, distributed RAC —
//! produces the SAME clustering, on randomized graph families.
//!
//! These are the crate's core correctness guarantee; the generators are
//! seeded and a failure message reports the reproducing seed
//! (`util::prop`).

use rac_hac::data::{gaussian_mixture, grid1d_graph, random_regular_graph, topic_docs};
use rac_hac::dist::{DistConfig, DistRacEngine};
use rac_hac::graph::Graph;
use rac_hac::hac::{naive_hac, nn_chain};
use rac_hac::knn::{complete_graph, knn_graph, Backend};
use rac_hac::linkage::Linkage;
use rac_hac::rac::RacEngine;
use rac_hac::util::prop::for_all_seeds;
use rac_hac::util::rng::Rng;

/// Random sparse connected-ish graph with continuous weights (ties have
/// probability zero) — the harshest generic case for merge ordering.
fn random_sparse(rng: &mut Rng) -> Graph {
    let n = rng.range_usize(8, 120);
    let mut edges = Vec::new();
    // Random spanning chain + random extra edges.
    for i in 1..n {
        edges.push(((i - 1) as u32, i as u32, rng.range_f64(0.1, 10.0)));
    }
    let extra = rng.range_usize(0, 3 * n);
    for _ in 0..extra {
        let u = rng.below(n);
        let v = rng.below(n);
        if u != v {
            edges.push((u as u32, v as u32, rng.range_f64(0.1, 10.0)));
        }
    }
    Graph::from_edges(n, edges)
}

fn assert_all_engines_agree(g: &Graph, linkage: Linkage, ctx: &str) {
    let hac = naive_hac(g, linkage);
    hac.validate().unwrap_or_else(|e| panic!("{ctx}: HAC invalid: {e}"));
    let chain = nn_chain(g, linkage);
    assert!(
        hac.same_clustering(&chain, 1e-9),
        "{ctx}: nn_chain != naive_hac"
    );
    let rac = RacEngine::new(g, linkage).run();
    assert!(
        hac.same_clustering(&rac.dendrogram, 1e-9),
        "{ctx}: rac != naive_hac"
    );
    for machines in [2usize, 5] {
        let dist = DistRacEngine::new(
            g,
            linkage,
            DistConfig::new(machines, 2),
        )
        .run();
        assert!(
            hac.same_clustering(&dist.dendrogram, 1e-9),
            "{ctx}: dist_rac(m={machines}) != naive_hac"
        );
    }
}

#[test]
fn engines_agree_on_random_sparse_graphs() {
    for_all_seeds(0xA11CE, 30, |rng| {
        let g = random_sparse(rng);
        for linkage in Linkage::SPARSE_REDUCIBLE {
            assert_all_engines_agree(&g, linkage, &format!("sparse {linkage:?}"));
        }
    });
}

#[test]
fn engines_agree_on_knn_graphs() {
    for_all_seeds(0xB0B, 8, |rng| {
        let n = rng.range_usize(60, 200);
        let ds = gaussian_mixture(n, 8, 5, 0.5, 0.05, rng.next_u64());
        let g = knn_graph(&ds, 6, Backend::Native, None).unwrap();
        for linkage in Linkage::SPARSE_REDUCIBLE {
            assert_all_engines_agree(&g, linkage, &format!("knn {linkage:?}"));
        }
    });
}

#[test]
fn engines_agree_on_complete_graphs_with_ward() {
    for_all_seeds(0xC0FFEE, 6, |rng| {
        let n = rng.range_usize(16, 64);
        let ds = topic_docs(n, 16, 4, rng.next_u64());
        let g = complete_graph(&ds);
        for linkage in [
            Linkage::Single,
            Linkage::Complete,
            Linkage::Average,
            Linkage::WeightedAverage,
            Linkage::Ward,
        ] {
            // Ward on cosine "distances" is not geometrically meaningful
            // but the Lance–Williams algebra must still agree exactly.
            let hac = naive_hac(&g, linkage);
            let rac = RacEngine::new(&g, linkage).run();
            assert!(
                hac.same_clustering(&rac.dendrogram, 1e-6),
                "complete {linkage:?}"
            );
        }
    });
}

#[test]
fn engines_agree_on_grids_and_regular_graphs() {
    for_all_seeds(0xD1CE, 10, |rng| {
        let n = rng.range_usize(50, 400);
        let g = grid1d_graph(n, rng.next_u64());
        assert_all_engines_agree(&g, Linkage::Single, "grid single");
        let g = random_regular_graph(n, 4, rng.next_u64());
        assert_all_engines_agree(&g, Linkage::Average, "regular average");
    });
}

#[test]
fn duplicate_points_exact_ties() {
    // Duplicated points create exact zero-distance ties; the shared
    // (weight, id) tie-break must keep all engines in lockstep.
    for_all_seeds(0x7135, 10, |rng| {
        let n = rng.range_usize(20, 60);
        let mut ds = gaussian_mixture(n, 4, 3, 0.5, 0.0, rng.next_u64());
        // Duplicate a third of the rows onto earlier rows.
        for i in 0..n / 3 {
            let src = (2 * i).min(n - 1) * ds.d;
            let dst = (2 * i + 1).min(n - 1) * ds.d;
            let row: Vec<f32> = ds.rows[src..src + ds.d].to_vec();
            ds.rows[dst..dst + ds.d].copy_from_slice(&row);
        }
        let g = complete_graph(&ds);
        for linkage in [Linkage::Single, Linkage::Average] {
            assert_all_engines_agree(&g, linkage, &format!("ties {linkage:?}"));
        }
    });
}

#[test]
fn monotone_dendrograms_for_reducible_linkages() {
    for_all_seeds(0x11AD, 20, |rng| {
        let g = random_sparse(rng);
        for linkage in Linkage::SPARSE_REDUCIBLE {
            let r = RacEngine::new(&g, linkage).run();
            assert_eq!(
                r.dendrogram.inversions(),
                0,
                "reducible {linkage:?} produced an inversion"
            );
        }
    });
}

#[test]
fn flat_cuts_consistent_across_engines() {
    // Same clustering => same flat cuts (up to label renaming): compare
    // co-membership on sampled pairs.
    for_all_seeds(0xF1A7, 10, |rng| {
        let g = random_sparse(rng);
        let a = naive_hac(&g, Linkage::Average);
        let b = RacEngine::new(&g, Linkage::Average).run().dendrogram;
        let k = rng.range_usize(1, g.n().min(8));
        let (ca, cb) = (a.cut_k(k).unwrap(), b.cut_k(k).unwrap());
        for _ in 0..200 {
            let i = rng.below(g.n());
            let j = rng.below(g.n());
            assert_eq!(
                ca[i] == ca[j],
                cb[i] == cb[j],
                "cut co-membership differs for ({i},{j}) at k={k}"
            );
        }
    });
}
