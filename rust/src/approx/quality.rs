//! Quality instruments for the approximate engine: the per-merge
//! (1+ε)-bound audit, adjusted-Rand-index agreement between flat cuts,
//! and the exact-vs-approx cost comparison (rounds / edge scans) the
//! trade-off bench reports.
//!
//! These are *measurement* tools, deliberately independent of the engine
//! that produced the data: [`merge_quality_ratio`] recomputes the bound
//! from the raw `(weight, visible minimum)` pairs the engine recorded, so
//! a selection bug shows up as a ratio above `1+ε` instead of silently
//! passing its own criterion.

use crate::dendrogram::Dendrogram;
use crate::linkage::Weight;
use crate::metrics::RunMetrics;

/// One merge's quality evidence: the weight it merged at, and the
/// `(weight, id)`-minimal linkage visible to either endpoint at merge
/// time (the denominator of TeraHAC's goodness ratio).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MergeBound {
    pub weight: Weight,
    pub visible_min: Weight,
}

impl MergeBound {
    /// Goodness ratio `weight / visible_min`. A merge at exactly the
    /// visible minimum (every exact-engine merge) is 1.0; `0 / 0`
    /// (duplicate points) is also a perfect merge.
    pub fn ratio(self) -> f64 {
        if self.weight == self.visible_min {
            1.0
        } else {
            self.weight / self.visible_min
        }
    }
}

/// Maximum goodness ratio over a run's merges (1.0 for an empty run).
/// Every merge the ε-engine performs must keep this `<= 1 + ε`; the
/// `approx_quality` suite asserts it against the recorded trace.
pub fn merge_quality_ratio(bounds: &[MergeBound]) -> f64 {
    bounds.iter().map(|b| b.ratio()).fold(1.0, f64::max)
}

/// Total neighbor-row entries scanned across a run: NN rescans plus (for
/// the approximate engine) the per-round eligibility sweeps. The honest
/// compute-cost axis of the rounds-vs-work trade-off — the ε-engine buys
/// fewer rounds by scanning whole rows for good edges every round.
pub fn edge_scans(m: &RunMetrics) -> usize {
    m.rounds
        .iter()
        .map(|r| r.nn_scan_entries + r.eligibility_scan_entries)
        .sum()
}

/// Adjusted Rand index between two flat clusterings (label vectors of
/// equal length). 1.0 for identical partitions; ~0 for independent ones;
/// can be negative for adversarial disagreement. Pairs that cannot
/// disagree (both partitions all-singletons or all-one-cluster) score
/// 1.0 by the usual convention (expected index equals the index).
pub fn adjusted_rand_index(a: &[u32], b: &[u32]) -> f64 {
    assert_eq!(a.len(), b.len(), "label vectors must align");
    let n = a.len();
    if n == 0 {
        return 1.0;
    }
    let ka = 1 + *a.iter().max().unwrap() as usize;
    let kb = 1 + *b.iter().max().unwrap() as usize;
    // Contingency table; flat cuts produce dense labels so ka·kb is fine
    // at the scales the harness compares.
    let mut table = vec![0u64; ka * kb];
    let mut rows = vec![0u64; ka];
    let mut cols = vec![0u64; kb];
    for (&la, &lb) in a.iter().zip(b) {
        table[la as usize * kb + lb as usize] += 1;
        rows[la as usize] += 1;
        cols[lb as usize] += 1;
    }
    let comb2 = |x: u64| (x * x.saturating_sub(1) / 2) as f64;
    let sum_ij: f64 = table.iter().map(|&x| comb2(x)).sum();
    let sum_a: f64 = rows.iter().map(|&x| comb2(x)).sum();
    let sum_b: f64 = cols.iter().map(|&x| comb2(x)).sum();
    let total = comb2(n as u64);
    if total == 0.0 {
        return 1.0;
    }
    let expected = sum_a * sum_b / total;
    let max_index = 0.5 * (sum_a + sum_b);
    if max_index == expected {
        return 1.0;
    }
    (sum_ij - expected) / (max_index - expected)
}

/// Side-by-side cost/quality summary of an exact run and an approximate
/// run over the same graph — the row shape of `BENCH_approx_tradeoff`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Comparison {
    pub rounds_exact: usize,
    pub rounds_approx: usize,
    pub edge_scans_exact: usize,
    pub edge_scans_approx: usize,
    /// Adjusted Rand index between the two dendrograms' `cut_k(k)` flat
    /// clusterings.
    pub ari: f64,
}

/// Compare an exact and an approximate run at a `k`-cluster flat cut.
///
/// `k` is clamped into the range both dendrograms can answer —
/// `[max(components), n]` — so disconnected kNN graphs (where a literal
/// `cut_k(k)` is a named [`crate::dendrogram::CutError`]) still yield a
/// quality row: both sides are cut at the same effective `k`, which keeps
/// the ARI an apples-to-apples comparison. The clamp is this metric
/// layer's documented policy, not `cut_k`'s.
pub fn compare_runs(
    exact: (&Dendrogram, &RunMetrics),
    approx: (&Dendrogram, &RunMetrics),
    k: usize,
) -> Comparison {
    let n = exact.0.n();
    debug_assert_eq!(n, approx.0.n());
    let ari = if n == 0 {
        1.0
    } else {
        let k_eff = k
            .max(exact.0.remaining_clusters())
            .max(approx.0.remaining_clusters())
            .min(n);
        let cut = |d: &Dendrogram| {
            d.cut_k(k_eff)
                .expect("k_eff clamped into [components, n] is always answerable")
        };
        adjusted_rand_index(&cut(exact.0), &cut(approx.0))
    };
    Comparison {
        rounds_exact: exact.1.merge_rounds(),
        rounds_approx: approx.1.merge_rounds(),
        edge_scans_exact: edge_scans(exact.1),
        edge_scans_approx: edge_scans(approx.1),
        ari,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::RoundMetrics;

    #[test]
    fn ratio_of_exact_merges_is_one() {
        let b = MergeBound { weight: 2.5, visible_min: 2.5 };
        assert_eq!(b.ratio(), 1.0);
        let zero = MergeBound { weight: 0.0, visible_min: 0.0 };
        assert_eq!(zero.ratio(), 1.0);
    }

    #[test]
    fn quality_ratio_takes_the_worst_merge() {
        let bounds = [
            MergeBound { weight: 1.0, visible_min: 1.0 },
            MergeBound { weight: 1.08, visible_min: 1.0 },
            MergeBound { weight: 2.0, visible_min: 1.9 },
        ];
        let r = merge_quality_ratio(&bounds);
        assert!((r - 1.08).abs() < 1e-12, "{r}");
        assert_eq!(merge_quality_ratio(&[]), 1.0);
    }

    #[test]
    fn ari_identical_partitions() {
        let a = [0, 0, 1, 1, 2, 2];
        assert_eq!(adjusted_rand_index(&a, &a), 1.0);
        // Label permutation does not matter.
        let b = [1, 1, 2, 2, 0, 0];
        assert_eq!(adjusted_rand_index(&a, &b), 1.0);
    }

    #[test]
    fn ari_known_value() {
        // Classic worked example: ARI((0,0,1,1), (0,1,1,1)).
        // sum_ij C2 = 1, sum_a = 2, sum_b = 3, total = 6, E = 1,
        // max = 2.5 → (1-1)/(2.5-1) = 0.
        let a = [0, 0, 1, 1];
        let b = [0, 1, 1, 1];
        assert!(adjusted_rand_index(&a, &b).abs() < 1e-12);
    }

    #[test]
    fn ari_partial_agreement_is_between() {
        let a = [0, 0, 0, 1, 1, 1];
        let b = [0, 0, 1, 1, 1, 1];
        let r = adjusted_rand_index(&a, &b);
        assert!(r > 0.0 && r < 1.0, "{r}");
    }

    #[test]
    fn ari_degenerate_partitions() {
        assert_eq!(adjusted_rand_index(&[], &[]), 1.0);
        // All singletons vs all singletons: nothing can disagree.
        assert_eq!(adjusted_rand_index(&[0, 1, 2], &[0, 1, 2]), 1.0);
        // One big cluster vs one big cluster.
        assert_eq!(adjusted_rand_index(&[0, 0, 0], &[0, 0, 0]), 1.0);
    }

    #[test]
    fn edge_scans_sums_both_sources() {
        let m = RunMetrics {
            rounds: vec![
                RoundMetrics {
                    nn_scan_entries: 10,
                    eligibility_scan_entries: 100,
                    ..Default::default()
                },
                RoundMetrics {
                    nn_scan_entries: 5,
                    ..Default::default()
                },
            ],
            ..Default::default()
        };
        assert_eq!(edge_scans(&m), 115);
    }
}
