//! `approx` — the (1+ε)-approximate merge engine (TeraHAC-style), a third
//! engine alongside the exact shared-memory [`crate::rac`] and distributed
//! [`crate::dist`] engines.
//!
//! ## Why relax exactness
//!
//! The exact engine merges only reciprocal-nearest-neighbor pairs, so its
//! round count is governed by how many RNN pairs each round exposes. On
//! graphs with few reciprocal pairs — the Theorem-4 adversarial instance
//! is the extreme: one pair per round, Ω(n) rounds — the rounds collapse
//! and so does all parallelism. *TeraHAC* (arXiv:2308.03578) shows that
//! relaxing to (1+ε)-"good" merges cuts the round count by orders of
//! magnitude while provably bounding dendrogram distortion; *It's Hard to
//! HAC with Average Linkage!* (arXiv:2404.14730) shows this kind of
//! approximation knob is the only road past exact HAC's inherent
//! sequentiality.
//!
//! ## The round structure
//!
//! Same three phases as the exact engine — literally: this engine is the
//! shared [`crate::engine::RoundDriver`] over the same flat
//! [`crate::store::NeighborStore`], instantiated with the
//! [`crate::engine::GoodSelector`] instead of the exact engine's
//! reciprocal-NN selector. Only phase 1 differs:
//!
//! 1. **Find ε-good merges** — every active cluster scans its neighbor
//!    row for edges within the `(1+ε)` band of the minimum linkage
//!    visible to *either* endpoint ([`good::accepts`] — TeraHAC's
//!    good-merge criterion, with band-boundary ties resolved by the
//!    cached NN pointer), and a maximal conflict-free merge set is
//!    selected deterministically ([`good::select_matching`]).
//! 2. **Update cluster dissimilarities** — unchanged: union maps from the
//!    engine-shared [`crate::rac::logic`], applied by the lock-free
//!    owner-sharded [`crate::store::NeighborStore::par_apply_round`].
//! 3. **Update nearest neighbors** — unchanged rescan rule (`C` merged or
//!    `C`'s cached NN merged), including the exact engine's documented
//!    stale-tie-id caching behavior, which the ε=0 anchor depends on.
//!
//! ## Guarantees
//!
//! * **ε = 0 is exact, bitwise** — acceptance degenerates to the
//!   reciprocal-NN condition (see [`good`]'s docs), RNN pairs are always
//!   conflict-free so selection keeps all of them, and phases 2/3 share
//!   the exact engine's arithmetic and ordering — so the dendrogram is
//!   bit-for-bit [`crate::rac::RacEngine`]'s, across linkages and thread
//!   counts (`rust/tests/approx_quality.rs`).
//! * **Every merge is (1+ε)-good** — `W(A,B) <= (1+ε) ·
//!   min(best(A), best(B))` at merge time, recorded per merge in
//!   [`ApproxResult::bounds`] and audited independently by
//!   [`quality::merge_quality_ratio`]. TeraHAC shows this local invariant
//!   bounds global dendrogram distortion to the same `(1+ε)` factor.
//! * **Progress** — the globally `(weight, id)`-minimal active edge is
//!   always good and always selected, so the engine terminates without
//!   leaning on the round cap.
//!
//! The trade: phase 1 scans whole neighbor rows (O(edges) per round, vs
//! the exact engine's O(active) pointer checks) to buy strictly more
//! merges per round. [`quality::edge_scans`] and
//! `benches/approx_tradeoff.rs` quantify both sides.

pub mod good;
pub mod quality;

use crate::dendrogram::Dendrogram;
use crate::engine::{GoodSelector, RoundDriver};
use crate::graph::Graph;
use crate::linkage::Linkage;
use crate::metrics::RunMetrics;
use crate::store::NeighborStore;
use crate::trace::TraceSink;

use quality::MergeBound;

/// Result of an approximate clustering run: the dendrogram, the usual
/// round metrics, and the per-merge quality trace.
#[derive(Debug)]
pub struct ApproxResult {
    pub dendrogram: Dendrogram,
    pub metrics: RunMetrics,
    /// Per merge, in recording order: `(weight, visible minimum)` at
    /// merge time. `quality::merge_quality_ratio(&bounds) <= 1 + ε` is
    /// the engine's quality contract.
    pub bounds: Vec<MergeBound>,
}

/// Shared-memory (1+ε)-approximate merge engine over the flat store.
pub struct ApproxEngine {
    driver: RoundDriver<NeighborStore>,
    epsilon: f64,
}

impl ApproxEngine {
    /// Build an engine over a dissimilarity graph.
    ///
    /// # Panics
    /// If `epsilon` is negative or non-finite, if the linkage is not
    /// reducible (the goodness band is anchored on cached minima, which
    /// reducibility keeps valid between rescans), or if a
    /// complete-graph-only linkage is given a sparse graph — the same
    /// guards as [`crate::rac::RacEngine::new`].
    pub fn new(g: &Graph, linkage: Linkage, epsilon: f64) -> Self {
        assert!(
            epsilon >= 0.0 && epsilon.is_finite(),
            "epsilon must be finite and >= 0, got {epsilon}"
        );
        assert!(
            linkage.is_reducible(),
            "the approximate engine requires a reducible linkage \
             (cached visible minima must stay valid between rescans)"
        );
        if !linkage.supports_sparse() {
            let n = g.n();
            assert!(
                g.m() == n * (n - 1) / 2,
                "{linkage:?} linkage requires a complete graph"
            );
        }
        ApproxEngine {
            driver: RoundDriver::new(NeighborStore::from_graph(g), g.n(), linkage),
            epsilon,
        }
    }

    /// Limit the worker-thread count.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.driver.set_threads(threads);
        self
    }

    /// Override the round safety cap.
    pub fn with_max_rounds(mut self, max_rounds: usize) -> Self {
        self.driver.set_max_rounds(max_rounds);
        self
    }

    /// Stream structured trace events into `sink` (see [`crate::trace`]).
    /// Tracing is purely observational: the dendrogram and bounds trace
    /// are bitwise identical with or without it.
    pub fn with_trace(mut self, sink: &TraceSink) -> Self {
        self.driver.set_trace(sink.clone(), "approx");
        self
    }

    /// Run to completion; returns the dendrogram, metrics, and the
    /// per-merge quality trace.
    pub fn run(self) -> ApproxResult {
        let mut selector = GoodSelector::new(self.epsilon);
        let r = self.driver.run(&mut selector);
        ApproxResult {
            dendrogram: r.dendrogram,
            metrics: r.metrics,
            bounds: r.bounds,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::hac::naive_hac;
    use crate::rac::RacEngine;

    #[test]
    fn zero_epsilon_matches_exact_engine() {
        let g = data::grid1d_graph(200, 17);
        for l in Linkage::SPARSE_REDUCIBLE {
            let exact = RacEngine::new(&g, l).run();
            let approx = ApproxEngine::new(&g, l, 0.0).run();
            assert_eq!(
                exact.dendrogram.bitwise_merges(),
                approx.dendrogram.bitwise_merges(),
                "{l:?}"
            );
        }
    }

    #[test]
    fn zero_epsilon_bounds_are_all_exact() {
        let g = data::grid1d_graph(100, 3);
        let r = ApproxEngine::new(&g, Linkage::Average, 0.0).run();
        assert_eq!(r.bounds.len(), r.dendrogram.merges().len());
        assert_eq!(quality::merge_quality_ratio(&r.bounds), 1.0);
    }

    #[test]
    fn relaxed_run_is_valid_and_within_band() {
        let g = data::grid1d_graph(300, 11);
        for eps in [0.01, 0.1, 1.0] {
            let r = ApproxEngine::new(&g, Linkage::Average, eps).run();
            r.dendrogram.validate().unwrap();
            assert_eq!(r.dendrogram.merges().len(), 299);
            let ratio = quality::merge_quality_ratio(&r.bounds);
            assert!(
                ratio <= 1.0 + eps + 1e-12,
                "eps={eps}: ratio {ratio} breaks the band"
            );
        }
    }

    #[test]
    fn adversarial_rounds_collapse_with_epsilon() {
        // The Theorem-4 instance: the exact engine needs Ω(n) rounds (one
        // reciprocal pair at a time); a relaxed band restores parallelism.
        let g = data::adversarial_thm4(6); // n = 64
        let exact = RacEngine::new(&g, Linkage::Average).run();
        let approx = ApproxEngine::new(&g, Linkage::Average, 1.0).run();
        assert_eq!(approx.dendrogram.merges().len(), 63);
        assert!(
            approx.metrics.merge_rounds() < exact.metrics.merge_rounds() / 2,
            "approx {} rounds vs exact {}",
            approx.metrics.merge_rounds(),
            exact.metrics.merge_rounds()
        );
    }

    #[test]
    fn relaxed_merges_stay_close_to_hac() {
        // Well-separated stable hierarchy: even ε = 1 cannot cross the
        // base^level separation bands, so flat cuts agree with exact HAC.
        let g = data::stable_hierarchy(5, 4.0, 23); // n = 32
        let hac = naive_hac(&g, Linkage::Average);
        let approx = ApproxEngine::new(&g, Linkage::Average, 1.0).run();
        let ari = quality::adjusted_rand_index(
            &hac.cut_k(4).unwrap(),
            &approx.dendrogram.cut_k(4).unwrap(),
        );
        assert_eq!(ari, 1.0);
    }

    #[test]
    fn thread_count_does_not_change_result() {
        let g = data::grid1d_graph(300, 5);
        for eps in [0.0, 0.1] {
            let base = ApproxEngine::new(&g, Linkage::Average, eps)
                .with_threads(1)
                .run();
            for t in [2, 4, 8] {
                let r = ApproxEngine::new(&g, Linkage::Average, eps)
                    .with_threads(t)
                    .run();
                assert_eq!(
                    base.dendrogram.bitwise_merges(),
                    r.dendrogram.bitwise_merges(),
                    "eps={eps} t={t}"
                );
            }
        }
    }

    #[test]
    fn disconnected_components() {
        let g = Graph::from_edges(6, [(0, 1, 1.0), (2, 3, 1.0), (3, 4, 2.0)]);
        let r = ApproxEngine::new(&g, Linkage::Single, 0.5).run();
        assert_eq!(r.dendrogram.merges().len(), 3);
        assert_eq!(r.dendrogram.remaining_clusters(), 3);
    }

    #[test]
    fn empty_and_singleton() {
        let r = ApproxEngine::new(&Graph::from_edges(0, []), Linkage::Average, 0.1).run();
        assert!(r.dendrogram.merges().is_empty());
        let r = ApproxEngine::new(&Graph::from_edges(1, []), Linkage::Average, 0.1).run();
        assert!(r.dendrogram.merges().is_empty());
    }

    #[test]
    #[should_panic(expected = "reducible")]
    fn rejects_centroid() {
        let g = data::stable_hierarchy(2, 4.0, 0);
        ApproxEngine::new(&g, Linkage::Centroid, 0.1);
    }

    #[test]
    #[should_panic(expected = "epsilon")]
    fn rejects_negative_epsilon() {
        let g = data::grid1d_graph(4, 0);
        ApproxEngine::new(&g, Linkage::Average, -0.5);
    }

    #[test]
    fn eligibility_scans_are_accounted() {
        let g = data::grid1d_graph(64, 1);
        let r = ApproxEngine::new(&g, Linkage::Average, 0.1).run();
        assert!(quality::edge_scans(&r.metrics) > 0);
        assert!(r.metrics.rounds[0].eligibility_scan_entries > 0);
    }
}
