//! `approx` — the (1+ε)-approximate merge engine (TeraHAC-style), a third
//! engine alongside the exact shared-memory [`crate::rac`] and distributed
//! [`crate::dist`] engines.
//!
//! ## Why relax exactness
//!
//! The exact engine merges only reciprocal-nearest-neighbor pairs, so its
//! round count is governed by how many RNN pairs each round exposes. On
//! graphs with few reciprocal pairs — the Theorem-4 adversarial instance
//! is the extreme: one pair per round, Ω(n) rounds — the rounds collapse
//! and so does all parallelism. *TeraHAC* (arXiv:2308.03578) shows that
//! relaxing to (1+ε)-"good" merges cuts the round count by orders of
//! magnitude while provably bounding dendrogram distortion; *It's Hard to
//! HAC with Average Linkage!* (arXiv:2404.14730) shows this kind of
//! approximation knob is the only road past exact HAC's inherent
//! sequentiality.
//!
//! ## The round structure
//!
//! Same three phases as the exact engine, over the same flat
//! [`crate::store::NeighborStore`]; only phase 1 differs:
//!
//! 1. **Find ε-good merges** — every active cluster scans its neighbor
//!    row for edges within the `(1+ε)` band of the minimum linkage
//!    visible to *either* endpoint ([`good::accepts`] — TeraHAC's
//!    good-merge criterion, with band-boundary ties resolved by the
//!    cached NN pointer), and a maximal conflict-free merge set is
//!    selected deterministically ([`good::select_matching`]).
//! 2. **Update cluster dissimilarities** — unchanged: union maps from the
//!    engine-shared [`crate::rac::logic`], applied by the lock-free
//!    owner-sharded [`crate::store::NeighborStore::par_apply_round`].
//! 3. **Update nearest neighbors** — unchanged rescan rule (`C` merged or
//!    `C`'s cached NN merged), including the exact engine's documented
//!    stale-tie-id caching behavior, which the ε=0 anchor depends on.
//!
//! ## Guarantees
//!
//! * **ε = 0 is exact, bitwise** — acceptance degenerates to the
//!   reciprocal-NN condition (see [`good`]'s docs), RNN pairs are always
//!   conflict-free so selection keeps all of them, and phases 2/3 share
//!   the exact engine's arithmetic and ordering — so the dendrogram is
//!   bit-for-bit [`crate::rac::RacEngine`]'s, across linkages and thread
//!   counts (`rust/tests/approx_quality.rs`).
//! * **Every merge is (1+ε)-good** — `W(A,B) <= (1+ε) ·
//!   min(best(A), best(B))` at merge time, recorded per merge in
//!   [`ApproxResult::bounds`] and audited independently by
//!   [`quality::merge_quality_ratio`]. TeraHAC shows this local invariant
//!   bounds global dendrogram distortion to the same `(1+ε)` factor.
//! * **Progress** — the globally `(weight, id)`-minimal active edge is
//!   always good and always selected, so the engine terminates without
//!   leaning on the round cap.
//!
//! The trade: phase 1 scans whole neighbor rows (O(edges) per round, vs
//! the exact engine's O(active) pointer checks) to buy strictly more
//! merges per round. [`quality::edge_scans`] and
//! `benches/approx_tradeoff.rs` quantify both sides.

pub mod good;
pub mod quality;

use std::time::Instant;

use crate::dendrogram::{Dendrogram, Merge};
use crate::graph::Graph;
use crate::linkage::{EdgeState, Linkage, Weight};
use crate::metrics::{RoundMetrics, RunMetrics};
use crate::rac::logic::{compute_union_map, scan_nn, PairView};
use crate::rac::NO_NN;
use crate::store::{NeighborStore, UnionRow};
use crate::util::parallel::default_threads;
use crate::util::pool::Pool;

use good::MergePair;
use quality::MergeBound;

/// Result of an approximate clustering run: the dendrogram, the usual
/// round metrics, and the per-merge quality trace.
#[derive(Debug)]
pub struct ApproxResult {
    pub dendrogram: Dendrogram,
    pub metrics: RunMetrics,
    /// Per merge, in recording order: `(weight, visible minimum)` at
    /// merge time. `quality::merge_quality_ratio(&bounds) <= 1 + ε` is
    /// the engine's quality contract.
    pub bounds: Vec<MergeBound>,
}

/// Shared-memory (1+ε)-approximate merge engine over the flat store.
pub struct ApproxEngine {
    linkage: Linkage,
    epsilon: f64,
    n: usize,
    active: Vec<bool>,
    active_ids: Vec<u32>,
    size: Vec<u64>,
    nn: Vec<u32>,
    nn_weight: Vec<Weight>,
    /// Selected for a merge this round (the exact engine's `will_merge`).
    matched: Vec<bool>,
    /// This round's merge partner (valid only while `matched`).
    partner: Vec<u32>,
    /// This round's merge weight (valid only while `matched`).
    pair_weight: Vec<Weight>,
    store: NeighborStore,
    threads: usize,
    max_rounds: usize,
}

impl ApproxEngine {
    /// Build an engine over a dissimilarity graph.
    ///
    /// # Panics
    /// If `epsilon` is negative or non-finite, if the linkage is not
    /// reducible (the goodness band is anchored on cached minima, which
    /// reducibility keeps valid between rescans), or if a
    /// complete-graph-only linkage is given a sparse graph — the same
    /// guards as [`crate::rac::RacEngine::new`].
    pub fn new(g: &Graph, linkage: Linkage, epsilon: f64) -> Self {
        assert!(
            epsilon >= 0.0 && epsilon.is_finite(),
            "epsilon must be finite and >= 0, got {epsilon}"
        );
        assert!(
            linkage.is_reducible(),
            "the approximate engine requires a reducible linkage \
             (cached visible minima must stay valid between rescans)"
        );
        if !linkage.supports_sparse() {
            let n = g.n();
            assert!(
                g.m() == n * (n - 1) / 2,
                "{linkage:?} linkage requires a complete graph"
            );
        }
        let n = g.n();
        ApproxEngine {
            linkage,
            epsilon,
            n,
            active: vec![true; n],
            active_ids: (0..n as u32).collect(),
            size: vec![1; n],
            nn: vec![NO_NN; n],
            nn_weight: vec![Weight::INFINITY; n],
            matched: vec![false; n],
            partner: vec![NO_NN; n],
            pair_weight: vec![0.0; n],
            store: NeighborStore::from_graph(g),
            threads: default_threads(),
            max_rounds: 4 * n + 64,
        }
    }

    /// Limit the worker-thread count.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Override the round safety cap.
    pub fn with_max_rounds(mut self, max_rounds: usize) -> Self {
        self.max_rounds = max_rounds;
        self
    }

    /// Run to completion; returns the dendrogram, metrics, and the
    /// per-merge quality trace.
    pub fn run(mut self) -> ApproxResult {
        let pool = Pool::new(self.threads);
        self.run_inner(&pool)
    }

    fn run_inner(&mut self, pool: &Pool) -> ApproxResult {
        let t0 = Instant::now();
        let mut merges: Vec<Merge> = Vec::with_capacity(self.n.saturating_sub(1));
        let mut bounds: Vec<MergeBound> = Vec::with_capacity(self.n.saturating_sub(1));
        let mut metrics = RunMetrics::default();

        let init: Vec<(u32, Weight)> =
            pool.par_map_indexed(self.n, |c| scan_nn(self.store.row(c as u32)));
        for (c, (nn, w)) in init.into_iter().enumerate() {
            self.nn[c] = nn;
            self.nn_weight[c] = w;
        }

        let mut n_active = self.n;
        for round in 0..self.max_rounds {
            let mut rm = RoundMetrics {
                round,
                clusters: n_active,
                ..Default::default()
            };

            // ---- Phase 1: find ε-good merges ----------------------------
            // Each active cluster scans its row once for edges both
            // endpoints accept (candidates are oriented a < b so every
            // edge is tested exactly once, from its lower endpoint).
            let t = Instant::now();
            let scans: Vec<(Vec<(Weight, u32)>, usize)> =
                pool.par_map(&self.active_ids, |&a| {
                    let row = self.store.row(a);
                    let mut out = Vec::new();
                    for (b, e) in row.iter() {
                        if b > a
                            && good::accepts(
                                e.weight,
                                b,
                                self.epsilon,
                                self.nn_weight[a as usize],
                                self.nn[a as usize],
                            )
                            && good::accepts(
                                e.weight,
                                a,
                                self.epsilon,
                                self.nn_weight[b as usize],
                                self.nn[b as usize],
                            )
                        {
                            out.push((e.weight, b));
                        }
                    }
                    (out, row.live_len())
                });
            let mut candidates: Vec<good::Candidate> = Vec::new();
            for (&a, (row_cands, scanned)) in self.active_ids.iter().zip(scans) {
                rm.eligibility_scan_entries += scanned;
                candidates.extend(row_cands.into_iter().map(|(w, b)| (w, a, b)));
            }
            let pairs: Vec<MergePair> = good::select_matching(candidates, &mut self.matched);
            for p in &pairs {
                self.partner[p.leader as usize] = p.partner;
                self.partner[p.partner as usize] = p.leader;
                self.pair_weight[p.leader as usize] = p.weight;
                self.pair_weight[p.partner as usize] = p.weight;
            }
            rm.t_find = t.elapsed();
            rm.merges = pairs.len();

            if pairs.is_empty() {
                metrics.rounds.push(rm);
                break;
            }

            // ---- Phase 2: update cluster dissimilarities ----------------
            let t = Instant::now();
            let unions: Vec<UnionRow> =
                pool.par_map(&pairs, |p| (p.leader, self.union_map(p.leader)));

            for p in &pairs {
                merges.push(Merge {
                    a: p.leader,
                    b: p.partner,
                    weight: p.weight,
                });
                bounds.push(MergeBound {
                    weight: p.weight,
                    visible_min: self.nn_weight[p.leader as usize]
                        .min(self.nn_weight[p.partner as usize]),
                });
            }
            {
                let store = &mut self.store;
                let partner = &self.partner;
                let matched = &self.matched;
                store.par_apply_round(
                    pool,
                    &unions,
                    |l| partner[l as usize],
                    |t| !matched[t as usize],
                );
            }
            for p in &pairs {
                self.size[p.leader as usize] += self.size[p.partner as usize];
                self.active[p.partner as usize] = false;
            }
            self.store.maybe_compact();
            n_active -= rm.merges;
            self.active_ids.retain(|&c| self.active[c as usize]);
            rm.t_merge = t.elapsed();

            // ---- Phase 3: update nearest neighbors ----------------------
            // Same rescan rule as the exact engine: only a cluster that
            // merged, or whose cached NN merged, can see its row minimum
            // change (reducibility: patches never lower a row's minimum).
            let t = Instant::now();
            let updates: Vec<(u32, u32, Weight, usize)> = {
                let ids = &self.active_ids;
                pool.par_filter_map_indexed(ids.len(), |idx| {
                    let c = ids[idx];
                    let needs_rescan = self.matched[c as usize]
                        || (self.nn[c as usize] != NO_NN
                            && self.matched[self.nn[c as usize] as usize]);
                    needs_rescan.then(|| {
                        let row = self.store.row(c);
                        let (nn, w) = scan_nn(row);
                        (c, nn, w, row.live_len())
                    })
                })
            };
            rm.nn_updates = updates.len();
            for (c, nn, w, scanned) in updates {
                self.nn[c as usize] = nn;
                self.nn_weight[c as usize] = w;
                rm.nn_scan_entries += scanned;
            }
            // Clear this round's selection (cheaper than the exact
            // engine's full recompute; equivalent — retired partners'
            // stale flags are unreachable, no live `nn` points at them).
            for p in &pairs {
                self.matched[p.leader as usize] = false;
                self.matched[p.partner as usize] = false;
            }
            rm.t_update_nn = t.elapsed();
            metrics.rounds.push(rm);

            if n_active <= 1 {
                break;
            }
        }

        metrics.total_time = t0.elapsed();
        ApproxResult {
            dendrogram: Dendrogram::new(self.n, merges),
            metrics,
            bounds,
        }
    }

    /// Union map of `L ∪ partner(L)` — the exact engine's computation,
    /// with pair identity taken from this round's matching instead of the
    /// NN cache (at ε = 0 the two coincide, bitwise).
    fn union_map(&self, l: u32) -> Vec<(u32, EdgeState)> {
        let p = self.partner[l as usize];
        compute_union_map(
            self.linkage,
            l,
            p,
            self.pair_weight[l as usize],
            self.size[l as usize],
            self.size[p as usize],
            self.store.row(l),
            self.store.row(p),
            |x| PairView {
                merging: self.matched[x as usize],
                partner: self.partner[x as usize],
                size: self.size[x as usize],
                pair_weight: self.pair_weight[x as usize],
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::hac::naive_hac;
    use crate::rac::RacEngine;

    #[test]
    fn zero_epsilon_matches_exact_engine() {
        let g = data::grid1d_graph(200, 17);
        for l in Linkage::SPARSE_REDUCIBLE {
            let exact = RacEngine::new(&g, l).run();
            let approx = ApproxEngine::new(&g, l, 0.0).run();
            assert_eq!(
                exact.dendrogram.bitwise_merges(),
                approx.dendrogram.bitwise_merges(),
                "{l:?}"
            );
        }
    }

    #[test]
    fn zero_epsilon_bounds_are_all_exact() {
        let g = data::grid1d_graph(100, 3);
        let r = ApproxEngine::new(&g, Linkage::Average, 0.0).run();
        assert_eq!(r.bounds.len(), r.dendrogram.merges().len());
        assert_eq!(quality::merge_quality_ratio(&r.bounds), 1.0);
    }

    #[test]
    fn relaxed_run_is_valid_and_within_band() {
        let g = data::grid1d_graph(300, 11);
        for eps in [0.01, 0.1, 1.0] {
            let r = ApproxEngine::new(&g, Linkage::Average, eps).run();
            r.dendrogram.validate().unwrap();
            assert_eq!(r.dendrogram.merges().len(), 299);
            let ratio = quality::merge_quality_ratio(&r.bounds);
            assert!(
                ratio <= 1.0 + eps + 1e-12,
                "eps={eps}: ratio {ratio} breaks the band"
            );
        }
    }

    #[test]
    fn adversarial_rounds_collapse_with_epsilon() {
        // The Theorem-4 instance: the exact engine needs Ω(n) rounds (one
        // reciprocal pair at a time); a relaxed band restores parallelism.
        let g = data::adversarial_thm4(6); // n = 64
        let exact = RacEngine::new(&g, Linkage::Average).run();
        let approx = ApproxEngine::new(&g, Linkage::Average, 1.0).run();
        assert_eq!(approx.dendrogram.merges().len(), 63);
        assert!(
            approx.metrics.merge_rounds() < exact.metrics.merge_rounds() / 2,
            "approx {} rounds vs exact {}",
            approx.metrics.merge_rounds(),
            exact.metrics.merge_rounds()
        );
    }

    #[test]
    fn relaxed_merges_stay_close_to_hac() {
        // Well-separated stable hierarchy: even ε = 1 cannot cross the
        // base^level separation bands, so flat cuts agree with exact HAC.
        let g = data::stable_hierarchy(5, 4.0, 23); // n = 32
        let hac = naive_hac(&g, Linkage::Average);
        let approx = ApproxEngine::new(&g, Linkage::Average, 1.0).run();
        let ari = quality::adjusted_rand_index(&hac.cut_k(4), &approx.dendrogram.cut_k(4));
        assert_eq!(ari, 1.0);
    }

    #[test]
    fn thread_count_does_not_change_result() {
        let g = data::grid1d_graph(300, 5);
        for eps in [0.0, 0.1] {
            let base = ApproxEngine::new(&g, Linkage::Average, eps)
                .with_threads(1)
                .run();
            for t in [2, 4, 8] {
                let r = ApproxEngine::new(&g, Linkage::Average, eps)
                    .with_threads(t)
                    .run();
                assert_eq!(
                    base.dendrogram.bitwise_merges(),
                    r.dendrogram.bitwise_merges(),
                    "eps={eps} t={t}"
                );
            }
        }
    }

    #[test]
    fn disconnected_components() {
        let g = Graph::from_edges(6, [(0, 1, 1.0), (2, 3, 1.0), (3, 4, 2.0)]);
        let r = ApproxEngine::new(&g, Linkage::Single, 0.5).run();
        assert_eq!(r.dendrogram.merges().len(), 3);
        assert_eq!(r.dendrogram.remaining_clusters(), 3);
    }

    #[test]
    fn empty_and_singleton() {
        let r = ApproxEngine::new(&Graph::from_edges(0, []), Linkage::Average, 0.1).run();
        assert!(r.dendrogram.merges().is_empty());
        let r = ApproxEngine::new(&Graph::from_edges(1, []), Linkage::Average, 0.1).run();
        assert!(r.dendrogram.merges().is_empty());
    }

    #[test]
    #[should_panic(expected = "reducible")]
    fn rejects_centroid() {
        let g = data::stable_hierarchy(2, 4.0, 0);
        ApproxEngine::new(&g, Linkage::Centroid, 0.1);
    }

    #[test]
    #[should_panic(expected = "epsilon")]
    fn rejects_negative_epsilon() {
        let g = data::grid1d_graph(4, 0);
        ApproxEngine::new(&g, Linkage::Average, -0.5);
    }

    #[test]
    fn eligibility_scans_are_accounted() {
        let g = data::grid1d_graph(64, 1);
        let r = ApproxEngine::new(&g, Linkage::Average, 0.1).run();
        assert!(quality::edge_scans(&r.metrics) > 0);
        assert!(r.metrics.rounds[0].eligibility_scan_entries > 0);
    }
}
