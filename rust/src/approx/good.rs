//! Merge eligibility for the (1+ε)-approximate engines: TeraHAC's
//! good-merge criterion lowered onto this repo's deterministic
//! `(weight, id)` total order, plus the conflict-free merge selection.
//!
//! Consumed by both ε-good phase-1 implementations — the shared-memory
//! driver selector ([`crate::engine::GoodSelector`]) and the sharded
//! [`crate::dist::DistApproxEngine`] — so acceptance and matching are one
//! function everywhere, which is what makes the sharded engine bitwise
//! identical to the shared-memory one per topology.
//!
//! ## The ε-good criterion
//!
//! Let `(nn_weight[C], nn[C])` be cluster `C`'s cached nearest-neighbor
//! edge (the same value the exact engine keeps — the weight is always the
//! true row minimum, the *id* may be a stale tie, see below). Cluster `C`
//! **accepts** a merge with neighbor `X` at weight `w` iff
//!
//! ```text
//! w < (1+ε) · nn_weight[C],   or
//! w == (1+ε) · nn_weight[C]  and  X == nn[C]
//! ```
//!
//! and the edge `(A, B)` is **ε-good** iff both endpoints accept it.
//! This is TeraHAC's criterion — the merge weight is within a `(1+ε)`
//! factor of the minimum linkage visible to either endpoint — made
//! deterministic at the exact band boundary by accepting only the cached
//! NN pointer there.
//!
//! At `ε = 0` every edge satisfies `w >= nn_weight[C]`, so acceptance
//! forces `w == nn_weight[C]` and `X == nn[C]`: both endpoints accepting
//! is *pointer reciprocity* (`nn[A] == B && nn[B] == A`) — exactly the
//! exact engine's phase-1 test — which is what makes
//! [`super::ApproxEngine`] bitwise-identical to it at `ε = 0`
//! (property-tested in `rust/tests/approx_quality.rs`, including
//! tie-heavy quantised weights).
//!
//! Two weaker boundary rules both break that anchor on weight ties:
//! a weight-only band (`w <= (1+ε)·nn_weight[C]`) accepts any tied
//! partner, and even an id tie-break (`X <= nn[C]`) diverges because the
//! engines' NN caches are deliberately *stale on tie ids* — a round that
//! patches `C`'s row can create an equal-weight edge toward a lower id
//! without triggering a rescan, and the exact engine still merges along
//! its cached pointer. Requiring `X == nn[C]` at the boundary mirrors the
//! pointer semantics regardless of staleness — see
//! `stale_tie_cache_boundary_follows_the_pointer` below.
//!
//! ## Selection
//!
//! Good edges form a candidate graph; we take a **maximal conflict-free
//! set** (a maximal matching — each cluster merges at most once per
//! round, so the result flows through the exact engine's owner-sharded
//! apply unchanged) greedily in ascending `(weight, a, b)` order.
//! Progress: for `ε > 0` the globally minimal positive-weight edge sits
//! strictly inside both endpoints' bands, so it is always good and sorts
//! first; at `ε = 0` (or on an all-zero-weight plateau) the candidate set
//! is exactly the exact engine's reciprocal-pointer pairs, which exist
//! whenever it would make progress. Either way a round with mergeable
//! edges merges at least one pair.

use crate::linkage::Weight;
use crate::store::NeighborsRef;

/// A candidate or selected merge edge `(weight, a, b)` with `a < b`.
pub type Candidate = (Weight, u32, u32);

/// One selected merge: `leader < partner`, merging at `weight`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MergePair {
    pub leader: u32,
    pub partner: u32,
    pub weight: Weight,
}

/// Does cluster `c` accept a merge with `partner` at weight `w`, given
/// `c`'s cached nearest-neighbor edge `(nn_weight, nn_id)`? Strictly
/// inside the `(1+ε)` band: yes; on the exact boundary: only the cached
/// pointer itself (module docs — this is what collapses to the exact
/// engine's pointer reciprocity at ε = 0, stale tie ids included).
/// `epsilon` must be finite and `>= 0`.
#[inline]
pub fn accepts(w: Weight, partner: u32, epsilon: f64, nn_weight: Weight, nn_id: u32) -> bool {
    let thr = (1.0 + epsilon) * nn_weight;
    w < thr || (w == thr && partner == nn_id)
}

/// Scan one cluster's neighbor row for ε-good candidate edges. Candidates
/// are oriented `b > a`, so every edge is tested exactly once, from its
/// lower endpoint; an edge qualifies iff **both** endpoints [`accepts`] it
/// against their cached NN edges. Returns the accepted `(weight, b)`
/// partners in row-visit order plus the number of live entries scanned
/// (the `eligibility_scan_entries` accounting unit).
///
/// This is the single implementation of the per-edge eligibility test,
/// shared by the shared-memory selector
/// ([`crate::engine::GoodSelector`]) and the sharded engine
/// ([`crate::dist::DistApproxEngine`]) — keeping the criterion
/// single-sourced is what makes the two bitwise-interchangeable.
pub fn scan_row_candidates<N: NeighborsRef>(
    row: N,
    a: u32,
    epsilon: f64,
    nn_weight: &[Weight],
    nn: &[u32],
) -> (Vec<(Weight, u32)>, usize) {
    scan_row_candidates_scoped(row, a, epsilon, nn_weight, nn, |_, _| true)
}

/// [`scan_row_candidates`] restricted to a caller-supplied edge scope:
/// only edges with `scope(a, b)` true are eligibility-tested. The hook
/// behind the subgraph-batching engines — a scope admitting only edges
/// whose endpoints share a (virtual) shard turns the sweep into the
/// shard-local phase of TeraHAC-style batching
/// ([`crate::engine::EdgeScope`], `crate::dist`'s batched `SyncMode`).
/// The whole row is still scanned (and accounted): a real shard owns its
/// rows and must look at every live entry to find the in-scope ones.
pub fn scan_row_candidates_scoped<N: NeighborsRef>(
    row: N,
    a: u32,
    epsilon: f64,
    nn_weight: &[Weight],
    nn: &[u32],
    scope: impl Fn(u32, u32) -> bool,
) -> (Vec<(Weight, u32)>, usize) {
    // `a`'s own acceptance band is loop-invariant, so it is hoisted into
    // the row sweep ([`NeighborsRef::for_each_band`]) — on the flat store
    // that is the dispatched SIMD band kernel ([`crate::store::scan`]),
    // which applies exactly [`accepts`]' `w < thr || (w == thr && b ==
    // nn)` test per lane. Only survivors pay the scope check and the
    // partner-side band lookup.
    let thr = (1.0 + epsilon) * nn_weight[a as usize];
    let nn_a = nn[a as usize];
    let mut out = Vec::new();
    row.for_each_band(a, thr, nn_a, |b, w| {
        if scope(a, b) && accepts(w, a, epsilon, nn_weight[b as usize], nn[b as usize]) {
            out.push((w, b));
        }
    });
    (out, row.live_len())
}

/// Select a maximal conflict-free merge set from `candidates`: greedy
/// maximal matching in ascending `(weight, a, b)` order (ties broken by
/// the id pair, so the result is a pure function of the candidate *set*).
/// Marks both endpoints of every selected pair in `matched` (which the
/// caller must have cleared for all active clusters) and returns the
/// pairs sorted by ascending leader id — the order the owner-sharded
/// apply pass and the dendrogram recording require.
pub fn select_matching(mut candidates: Vec<Candidate>, matched: &mut [bool]) -> Vec<MergePair> {
    candidates.sort_unstable_by(crate::store::scan::cmp_weight_pair);
    let mut pairs = Vec::new();
    for (w, a, b) in candidates {
        debug_assert!(a < b, "candidates must be oriented a < b");
        if !matched[a as usize] && !matched[b as usize] {
            matched[a as usize] = true;
            matched[b as usize] = true;
            pairs.push(MergePair {
                leader: a,
                partner: b,
                weight: w,
            });
        }
    }
    // Greedy emits in (weight, a, b) order; the engine consumes merges in
    // ascending-leader order (matching the exact engine's recording).
    pairs.sort_unstable_by_key(|p| p.leader);
    pairs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_epsilon_is_the_pointer_condition() {
        // c's cached NN edge is (1.0, id 4). Only that exact pointer is
        // accepted at the minimum weight.
        assert!(accepts(1.0, 4, 0.0, 1.0, 4));
        assert!(!accepts(1.0, 7, 0.0, 1.0, 4)); // weight tie, other id
        assert!(!accepts(1.0, 2, 0.0, 1.0, 4)); // weight tie, lower id too
        assert!(!accepts(1.5, 4, 0.0, 1.0, 4)); // above the minimum
    }

    #[test]
    fn zero_epsilon_rejects_non_argmin_ties() {
        // The weight-tie trap that breaks a weight-only criterion:
        // cluster 0 sees 1 and 2 both at weight 1.0, so nn[0] = 1. Edge
        // (0, 2) is weight-minimal at both endpoints yet is NOT a
        // reciprocal-NN pair; the pointer rule must reject it.
        assert!(!accepts(1.0, 2, 0.0, 1.0, 1)); // 0 does not accept 2
        assert!(accepts(1.0, 0, 0.0, 1.0, 0)); // 2 would accept 0
    }

    #[test]
    fn stale_tie_cache_boundary_follows_the_pointer() {
        // After a patch, cluster 4's row holds an equal-weight edge to
        // the new union leader 2 while its cache still points at the old
        // tie (5, 1.0) — no rescan happened (neither 4 nor 5 merged).
        // The exact engine would still merge 4 along its pointer to 5,
        // so at ε = 0 the boundary must accept ONLY the pointer: an
        // `X <= nn` tie-break would merge (2, 4) here and break the
        // bitwise anchor.
        assert!(!accepts(1.0, 2, 0.0, 1.0, 5)); // lower-id tie: rejected
        assert!(accepts(1.0, 5, 0.0, 1.0, 5)); // the pointer: accepted
    }

    #[test]
    fn relaxed_epsilon_admits_near_minimal_edges() {
        // Strictly within the (1+ε) band: any partner id.
        assert!(accepts(1.05, 9, 0.1, 1.0, 4));
        // On the exact boundary only the cached pointer is accepted.
        let thr = (1.0 + 0.1) * 1.0;
        assert!(accepts(thr, 4, 0.1, 1.0, 4));
        assert!(!accepts(thr, 3, 0.1, 1.0, 4));
        assert!(!accepts(thr, 5, 0.1, 1.0, 4));
        // Beyond the band: rejected.
        assert!(!accepts(1.2, 1, 0.1, 1.0, 4));
    }

    #[test]
    fn isolated_cluster_threshold_is_infinite() {
        // No edges → nn_weight = ∞; the threshold stays ∞ and any finite
        // weight would be accepted (vacuous — isolated rows yield no
        // candidates), without NaN poisoning.
        assert!(accepts(5.0, 1, 0.5, Weight::INFINITY, u32::MAX));
    }

    #[test]
    fn scan_row_candidates_orients_and_filters() {
        use crate::graph::Graph;
        use crate::store::NeighborStore;
        let g = Graph::from_edges(4, [(0, 1, 1.0), (1, 2, 1.05), (1, 3, 2.0)]);
        let s = NeighborStore::from_graph(&g);
        let nn = [1u32, 0, 1, 1];
        let nn_weight = [1.0, 1.0, 1.05, 2.0];
        // From cluster 1 only b > 1 is tested: (1,2) sits inside both
        // endpoints' 1.1× bands; (1,3) fails 1's own band; (0,1) is
        // cluster 0's to test.
        let (cands, scanned) = scan_row_candidates(s.row(1), 1, 0.1, &nn_weight, &nn);
        assert_eq!(scanned, 3);
        assert_eq!(cands, vec![(1.05, 2)]);
        let (cands, _) = scan_row_candidates(s.row(0), 0, 0.1, &nn_weight, &nn);
        assert_eq!(cands, vec![(1.0, 1)]);
    }

    #[test]
    fn scoped_scan_filters_but_still_accounts_the_whole_row() {
        use crate::graph::Graph;
        use crate::store::NeighborStore;
        let g = Graph::from_edges(4, [(0, 1, 1.0), (1, 2, 1.05), (1, 3, 2.0)]);
        let s = NeighborStore::from_graph(&g);
        let nn = [1u32, 0, 1, 1];
        let nn_weight = [1.0, 1.0, 1.05, 2.0];
        // Unscoped, cluster 1 yields (1.05, 2); a scope that splits
        // {0, 1} from {2, 3} rejects it without touching the criterion.
        let scope = |a: u32, b: u32| (a < 2) == (b < 2);
        let (cands, scanned) =
            scan_row_candidates_scoped(s.row(1), 1, 0.1, &nn_weight, &nn, scope);
        assert_eq!(scanned, 3, "scope must not shrink the scan accounting");
        assert!(cands.is_empty());
        // Edges inside the scope still pass (cluster 0 tests (0, 1)).
        let (cands, _) = scan_row_candidates_scoped(s.row(0), 0, 0.1, &nn_weight, &nn, scope);
        assert_eq!(cands, vec![(1.0, 1)]);
        // A pass-all scope is exactly the unscoped scan.
        let (all, _) = scan_row_candidates(s.row(1), 1, 0.1, &nn_weight, &nn);
        let (scoped_all, _) =
            scan_row_candidates_scoped(s.row(1), 1, 0.1, &nn_weight, &nn, |_, _| true);
        assert_eq!(all, scoped_all);
    }

    #[test]
    fn greedy_matching_is_maximal_and_deterministic() {
        // Path 0-1-2-3 with ascending weights: (0,1) and (2,3) survive.
        let cands = vec![(1.0, 0, 1), (2.0, 1, 2), (3.0, 2, 3)];
        let mut matched = vec![false; 4];
        let pairs = select_matching(cands.clone(), &mut matched);
        assert_eq!(
            pairs,
            vec![
                MergePair { leader: 0, partner: 1, weight: 1.0 },
                MergePair { leader: 2, partner: 3, weight: 3.0 },
            ]
        );
        assert!(matched.iter().all(|&m| m));

        // Input order must not matter (selection sorts internally).
        let mut matched = vec![false; 4];
        let shuffled = vec![(3.0, 2, 3), (1.0, 0, 1), (2.0, 1, 2)];
        assert_eq!(select_matching(shuffled, &mut matched), pairs);
    }

    #[test]
    fn weight_ties_break_by_id_pair() {
        // Star around 1: both edges weigh the same; (0,1) wins by ids.
        let cands = vec![(1.0, 1, 2), (1.0, 0, 1)];
        let mut matched = vec![false; 3];
        let pairs = select_matching(cands, &mut matched);
        assert_eq!(pairs.len(), 1);
        assert_eq!((pairs[0].leader, pairs[0].partner), (0, 1));
        assert!(!matched[2]);
    }

    #[test]
    fn output_is_sorted_by_leader() {
        // Selection order by weight is (4,5) then (0,1); output re-sorts.
        let cands = vec![(9.0, 0, 1), (1.0, 4, 5)];
        let mut matched = vec![false; 6];
        let pairs = select_matching(cands, &mut matched);
        assert_eq!(pairs[0].leader, 0);
        assert_eq!(pairs[1].leader, 4);
    }
}
