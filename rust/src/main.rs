//! `rac` — the coordinator CLI.
//!
//! Subcommands (hand-rolled arg parsing; `clap` is not in the offline
//! vendored crate set):
//!
//! ```text
//! rac run --config <file.toml> [--json]      full pipeline from a config
//! rac cluster [overrides...] [--json]        pipeline from CLI flags
//! rac verify [--n N] [--seeds S]             RAC vs HAC exactness sweep
//! rac graph-info --config <file.toml>        build the graph, print stats
//! rac kernels [--artifacts DIR]              list + smoke the AOT kernels
//! rac trace-report --trace <file> [--json]   analyze a recorded trace
//! rac query <op> --dendrogram <file> ...     flat-cut queries on a saved dendrogram
//! ```
//!
//! `cluster` flags: `--dataset sift_like|docs_like|grid1d|adversarial|stable|random_regular`,
//! `--n`, `--d`, `--k`, `--xla`, `--linkage L`,
//! `--engine rac|dist_rac|approx|dist_approx|naive_hac|nn_chain`,
//! `--machines M`, `--cpus C`, `--epsilon E`, `--seed S`
//! (`dist_approx` takes the topology knobs *and* the ε band:
//! `--engine dist_approx --machines 8 --cpus 4 --epsilon 0.1`, plus the
//! synchronisation schedule: `--sync-mode batched [--vshards V]` drains
//! shard-local merges between global syncs).
//!
//! The distributed engines also take `--exec-mode executed` to run real
//! thread-per-machine shards over channels instead of the simulation,
//! with `--latency-us N` / `--jitter-us N` per-link delay injection and
//! a fault campaign: `--fault-at M:R[,M:R...]` kills the listed machines
//! at the listed rounds (repeats allowed — a machine can die again while
//! its recovery is still fresh), `--fault-rate P --fault-seed S` adds
//! seeded random faults, `--recovery-mode global|shard_replay` picks
//! between BSP global rollback and journaled single-shard replay, and
//! `--checkpoint-full-every N` sets the delta-checkpoint cadence (every
//! Nth cut is a full blob; the rest are dirty-row deltas).
//!
//! Both pipeline subcommands take `--force-scalar` to pin the row-scan
//! kernels to the scalar fallback instead of the detected SIMD dispatch
//! (bitwise-identical results; see `store::scan`). The `RAC_FORCE_SCALAR`
//! environment variable does the same without a flag.
//!
//! Observability flags (`run` and `cluster`): `--trace FILE` records a
//! structured event trace (`--trace-format jsonl|chrome`; `chrome` loads
//! directly in Perfetto), `--metrics-out FILE` writes the run's metrics
//! JSON. `rac trace-report --trace FILE` folds a recorded trace into
//! per-machine phase time, barrier stragglers, the wire matrix, and the
//! checkpoint/recovery timeline.
//!
//! Serving: `--dendrogram-out FILE` (`run` and `cluster`, or `[output]
//! dendrogram_path`) persists the dendrogram in the versioned binary
//! format ([`rac_hac::serve::codec`]); `rac query` answers flat-cut
//! queries against such a file through the read-optimised
//! [`rac_hac::serve::ServeIndex`]: `cut-k --k K`, `cut-threshold
//! --threshold T`, `member --point P --threshold T`, and `diff --from T1
//! --to T2` (the merges separating two thresholds).

use std::process::ExitCode;

use anyhow::{anyhow, Context, Result};

use rac_hac::config::RunConfig;
use rac_hac::data::{gaussian_mixture, grid1d_graph};
use rac_hac::hac::naive_hac;
use rac_hac::knn::{knn_graph, Backend};
use rac_hac::linkage::Linkage;
use rac_hac::pipeline;
use rac_hac::rac::RacEngine;
use rac_hac::runtime::{default_artifacts_dir, KernelRuntime};
use rac_hac::serve::{self, ServeIndex};
use rac_hac::trace::{self, TraceFormat};
use rac_hac::util::json::{obj, Json};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let result = match args.first().map(String::as_str) {
        Some("run") => cmd_run(&args[1..]),
        Some("cluster") => cmd_cluster(&args[1..]),
        Some("verify") => cmd_verify(&args[1..]),
        Some("graph-info") => cmd_graph_info(&args[1..]),
        Some("kernels") => cmd_kernels(&args[1..]),
        Some("trace-report") => cmd_trace_report(&args[1..]),
        Some("query") => cmd_query(&args[1..]),
        Some("help") | Some("--help") | Some("-h") | None => {
            print!("{HELP}");
            Ok(())
        }
        Some(other) => Err(anyhow!("unknown subcommand {other:?}; see `rac help`")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

const HELP: &str = "\
rac — Reciprocal Agglomerative Clustering coordinator

USAGE:
  rac run --config <file.toml> [--trace FILE] [--trace-format jsonl|chrome]
          [--metrics-out FILE] [--dendrogram-out FILE] [--force-scalar]
          [--json]
  rac cluster [--dataset T] [--n N] [--d D] [--k K] [--xla] [--linkage L]
              [--engine E] [--machines M] [--cpus C] [--epsilon E]
              [--sync-mode per_round|batched] [--vshards V]
              [--exec-mode simulated|executed] [--latency-us N]
              [--jitter-us N] [--fault-at M:R[,M:R...]] [--fault-rate P]
              [--fault-seed S] [--recovery-mode global|shard_replay]
              [--checkpoint-full-every N]
              [--trace FILE] [--trace-format jsonl|chrome]
              [--metrics-out FILE] [--dendrogram-out FILE] [--force-scalar]
              [--seed S] [--json]
  rac verify [--n N] [--seeds S]
  rac graph-info --config <file.toml>
  rac kernels [--artifacts DIR]
  rac trace-report --trace <file> [--json]
  rac query cut-k          --dendrogram <file> --k K [--json]
  rac query cut-threshold  --dendrogram <file> --threshold T [--json]
  rac query member         --dendrogram <file> --point P --threshold T [--json]
  rac query diff           --dendrogram <file> --from T1 --to T2 [--json]
";

/// Tiny flag parser: `--key value` pairs plus boolean `--key` switches.
struct Flags {
    pairs: std::collections::BTreeMap<String, String>,
    switches: std::collections::BTreeSet<String>,
}

impl Flags {
    const BOOL_FLAGS: &'static [&'static str] = &["json", "xla", "force-scalar"];

    fn parse(args: &[String]) -> Result<Flags> {
        let mut pairs = std::collections::BTreeMap::new();
        let mut switches = std::collections::BTreeSet::new();
        let mut i = 0;
        while i < args.len() {
            let key = args[i]
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, found {:?}", args[i]))?;
            if Self::BOOL_FLAGS.contains(&key) {
                switches.insert(key.to_string());
                i += 1;
            } else {
                let val = args
                    .get(i + 1)
                    .ok_or_else(|| anyhow!("--{key} needs a value"))?;
                pairs.insert(key.to_string(), val.clone());
                i += 2;
            }
        }
        Ok(Flags { pairs, switches })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.pairs.get(key).map(String::as_str)
    }

    fn has(&self, key: &str) -> bool {
        self.switches.contains(key)
    }

    fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?}")),
        }
    }
}

fn report(out: &pipeline::RunOutput, json: bool) {
    let m = &out.result.metrics;
    if json {
        let doc = obj([
            ("graph_nodes", out.graph_nodes.into()),
            ("graph_edges", out.graph_edges.into()),
            ("graph_max_degree", out.graph_max_degree.into()),
            ("t_graph_us", (out.t_graph.as_micros() as usize).into()),
            ("merges", out.result.dendrogram.merges().len().into()),
            ("tree_height", out.result.dendrogram.height().into()),
            ("metrics", m.to_json()),
        ]);
        println!("{doc}");
        return;
    }
    println!(
        "graph: {} nodes, {} edges, max degree {}",
        out.graph_nodes, out.graph_edges, out.graph_max_degree
    );
    println!(
        "graph construction: {:.3?} ({}% of total; paper's edge-loading share was 15-50%)",
        out.t_graph,
        (100.0 * out.t_graph.as_secs_f64() / (out.t_graph + m.total_time).as_secs_f64()).round()
    );
    println!(
        "clustering: {} merges in {} rounds, {:.3?} total",
        m.total_merges(),
        m.merge_rounds(),
        m.total_time
    );
    println!(
        "tree height {}; min alpha {:.3}; mean beta {:.2}; net: {} msgs / {} bytes",
        out.result.dendrogram.height(),
        m.min_alpha(),
        m.mean_beta(),
        m.total_net_messages(),
        m.total_net_bytes()
    );
    // Distributed runs also carry the critical-path time model (Table 2)
    // and the synchronisation schedule (sync points < rounds under the
    // batched dist_approx mode).
    if m.total_sim_time() > std::time::Duration::ZERO {
        println!(
            "simulated fleet time (critical path): {:.3?}; {} sync points over {} rounds",
            m.total_sim_time(),
            m.total_sync_points(),
            m.rounds.len()
        );
    }
    // Executed runs report the measured wall clock instead.
    if m.total_exec_time() > std::time::Duration::ZERO {
        println!(
            "executed fleet time (measured): {:.3?}; {} sync points over {} rounds",
            m.total_exec_time(),
            m.total_sync_points(),
            m.rounds.len()
        );
    }
    // Runs that survived faults also report what recovery cost.
    if m.t_recover > std::time::Duration::ZERO {
        println!(
            "recovery: {} machine-rounds / {} bytes replayed in {:.3?} \
             ({} checkpoint bytes cut)",
            m.recovery_rounds_replayed,
            m.recovery_bytes_replayed,
            m.t_recover,
            m.checkpoint_bytes
        );
    }
}

/// Output overrides shared by `run` and `cluster`: `--trace` /
/// `--trace-format` / `--metrics-out` / `--dendrogram-out` beat the
/// config's `[output]` section, validated with the same rules as the
/// TOML fields.
fn apply_output_flags(cfg: &mut RunConfig, flags: &Flags) -> Result<()> {
    if let Some(p) = flags.get("trace") {
        cfg.output.trace_path = Some(p.to_string());
    }
    if let Some(f) = flags.get("trace-format") {
        if cfg.output.trace_path.is_none() {
            return Err(anyhow!(
                "--trace-format needs a trace destination (--trace FILE or output.trace_path)"
            ));
        }
        cfg.output.trace_format = TraceFormat::parse(f).ok_or_else(|| {
            anyhow!("unknown --trace-format {f:?} (expected \"jsonl\" or \"chrome\")")
        })?;
    }
    if let Some(p) = flags.get("metrics-out") {
        cfg.output.metrics_out = Some(p.to_string());
    }
    if let Some(p) = flags.get("dendrogram-out") {
        cfg.output.dendrogram_path = Some(p.to_string());
    }
    Ok(())
}

fn cmd_run(args: &[String]) -> Result<()> {
    let flags = Flags::parse(args)?;
    let path = flags
        .get("config")
        .ok_or_else(|| anyhow!("--config <file.toml> required"))?;
    let mut cfg = RunConfig::from_file(std::path::Path::new(path))?;
    apply_output_flags(&mut cfg, &flags)?;
    if flags.has("force-scalar") {
        cfg.force_scalar = true;
    }
    let out = pipeline::run(&cfg)?;
    report(&out, flags.has("json"));
    Ok(())
}

fn cmd_cluster(args: &[String]) -> Result<()> {
    let flags = Flags::parse(args)?;
    // Assemble a TOML doc from flags, reusing the config defaults.
    let mut text = String::new();
    text.push_str("[dataset]\n");
    if let Some(t) = flags.get("dataset") {
        text.push_str(&format!("type = \"{t}\"\n"));
    }
    for key in [
        "n", "d", "clusters", "topics", "levels", "depth", "degree", "seed",
    ] {
        if let Some(v) = flags.get(key) {
            text.push_str(&format!("{key} = {v}\n"));
        }
    }
    text.push_str("[graph]\n");
    if let Some(t) = flags.get("graph") {
        text.push_str(&format!("type = \"{t}\"\n"));
    }
    if let Some(k) = flags.get("k") {
        text.push_str(&format!("k = {k}\n"));
    }
    if flags.has("xla") {
        text.push_str("xla = true\n");
    }
    text.push_str("[cluster]\n");
    if let Some(l) = flags.get("linkage") {
        text.push_str(&format!("linkage = \"{l}\"\n"));
    }
    text.push_str("[engine]\n");
    if let Some(e) = flags.get("engine") {
        text.push_str(&format!("type = \"{e}\"\n"));
    }
    if flags.has("force-scalar") {
        text.push_str("force_scalar = true\n");
    }
    if let Some(m) = flags.get("sync-mode") {
        text.push_str(&format!("sync_mode = \"{m}\"\n"));
    }
    if let Some(m) = flags.get("exec-mode") {
        text.push_str(&format!("exec_mode = \"{m}\"\n"));
    }
    if let Some(v) = flags.get("latency-us") {
        text.push_str(&format!("link_latency_us = {v}\n"));
    }
    if let Some(v) = flags.get("jitter-us") {
        text.push_str(&format!("link_jitter_us = {v}\n"));
    }
    if let Some(spec) = flags.get("fault-at") {
        // Light shape check here for a CLI-flavoured error; the config
        // layer re-parses each entry and validates machines against the
        // topology.
        for entry in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            if entry.split_once(':').is_none() {
                return Err(anyhow!(
                    "--fault-at expects MACHINE:ROUND[,MACHINE:ROUND...], got {entry:?}"
                ));
            }
        }
        text.push_str(&format!("faults = \"{spec}\"\n"));
    }
    if let Some(v) = flags.get("fault-rate") {
        text.push_str(&format!("fault_rate = {v}\n"));
    }
    if let Some(v) = flags.get("fault-seed") {
        text.push_str(&format!("fault_seed = {v}\n"));
    }
    if let Some(v) = flags.get("recovery-mode") {
        text.push_str(&format!("recovery_mode = \"{v}\"\n"));
    }
    if let Some(v) = flags.get("checkpoint-full-every") {
        text.push_str(&format!("checkpoint_full_every = {v}\n"));
    }
    for key in ["machines", "cpus", "threads", "epsilon", "vshards"] {
        if let Some(v) = flags.get(key) {
            text.push_str(&format!("{key} = {v}\n"));
        }
    }
    let mut cfg = RunConfig::from_toml_str(&text)?;
    apply_output_flags(&mut cfg, &flags)?;
    let out = pipeline::run(&cfg)?;
    report(&out, flags.has("json"));
    Ok(())
}

/// Fold a recorded trace into the straggler/critical-path report
/// (human-readable by default, `--json` for the machine shape). The
/// events are schema-validated before analysis, so a malformed or
/// hand-edited trace fails loudly instead of folding into nonsense.
fn cmd_trace_report(args: &[String]) -> Result<()> {
    let flags = Flags::parse(args)?;
    let path = flags
        .get("trace")
        .ok_or_else(|| anyhow!("--trace <file> required"))?;
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
    let events = trace::parse_any(&text).map_err(|e| anyhow!("parsing trace {path:?}: {e}"))?;
    trace::analyze::validate_events(&events)
        .map_err(|e| anyhow!("invalid trace {path:?}: {e}"))?;
    let report = trace::analyze::analyze(&events);
    if flags.has("json") {
        println!("{}", trace::analyze::report_json(&report));
    } else {
        print!("{}", trace::analyze::render(&report));
    }
    Ok(())
}

/// Flat-cut queries against a persisted dendrogram (`--dendrogram-out` /
/// `[output] dendrogram_path`), served through the read-optimised
/// [`ServeIndex`] — the same code path `benches/serve.rs` hammers. The
/// file is fully validated on load; invalid or hostile bytes fail with a
/// named error before any query runs.
fn cmd_query(args: &[String]) -> Result<()> {
    const USAGE: &str =
        "usage: rac query <cut-k|cut-threshold|member|diff> --dendrogram <file> ...";
    let op = match args.first() {
        Some(a) if !a.starts_with("--") => a.as_str(),
        _ => return Err(anyhow!(USAGE)),
    };
    let flags = Flags::parse(&args[1..])?;
    let f64_flag = |key: &str| -> Result<f64> {
        let v = flags
            .get(key)
            .ok_or_else(|| anyhow!("--{key} <number> required for `rac query {op}`"))?;
        v.parse().with_context(|| format!("--{key} {v:?}"))
    };
    let path = flags
        .get("dendrogram")
        .ok_or_else(|| anyhow!("--dendrogram <file> required; {USAGE}"))?;
    let d = serve::codec::read_file(path).map_err(|e| anyhow!(e))?;
    let index = ServeIndex::build(&d).map_err(|e| anyhow!("{e}"))?;
    let json = flags.has("json");
    match op {
        "cut-k" => {
            let k = flags
                .get("k")
                .ok_or_else(|| anyhow!("--k <clusters> required for `rac query cut-k`"))?
                .parse::<usize>()
                .context("--k")?;
            let labels = index.cut_k(k).map_err(|e| anyhow!("{e}"))?;
            print_cut(&labels, json);
        }
        "cut-threshold" => {
            let labels = index.cut_threshold(f64_flag("threshold")?);
            print_cut(&labels, json);
        }
        "member" => {
            let p = flags
                .get("point")
                .ok_or_else(|| anyhow!("--point <id> required for `rac query member`"))?
                .parse::<u32>()
                .context("--point")?;
            let t = f64_flag("threshold")?;
            let rep = index.point_membership(p, t).map_err(|e| anyhow!("{e}"))?;
            let members = index.cluster_members(p, t).map_err(|e| anyhow!("{e}"))?;
            if json {
                let doc = obj([
                    ("point", (p as usize).into()),
                    ("threshold", t.into()),
                    ("rep", (rep as usize).into()),
                    ("size", members.len().into()),
                    (
                        "members",
                        members.iter().map(|&m| m as usize).collect::<Vec<_>>().into(),
                    ),
                ]);
                println!("{doc}");
            } else {
                println!(
                    "point {p} at threshold {t}: cluster rep {rep}, {} members",
                    members.len()
                );
                println!("{}", preview_u32(&members, 20));
            }
        }
        "diff" => {
            let (from, to) = (f64_flag("from")?, f64_flag("to")?);
            let steps = index.diff(from, to).map_err(|e| anyhow!("{e}"))?;
            if json {
                let arr: Vec<Json> = steps
                    .iter()
                    .map(|s| {
                        obj([
                            ("weight", s.weight.into()),
                            ("into", (s.into as usize).into()),
                            ("absorbed", (s.absorbed as usize).into()),
                        ])
                    })
                    .collect();
                let doc = obj([
                    ("from", from.into()),
                    ("to", to.into()),
                    ("steps", Json::Arr(arr)),
                ]);
                println!("{doc}");
            } else {
                println!("{} merges in band [{from}, {to})", steps.len());
                for s in steps.iter().take(32) {
                    println!("  @{:<12} cluster {} absorbs cluster {}", s.weight, s.into, s.absorbed);
                }
                if steps.len() > 32 {
                    println!("  ... {} more (use --json for all)", steps.len() - 32);
                }
            }
        }
        other => return Err(anyhow!("unknown query op {other:?}; {USAGE}")),
    }
    Ok(())
}

/// Render a flat cut: cluster count and sizes (full labels under `--json`).
fn print_cut(labels: &[u32], json: bool) {
    let clusters = labels.iter().copied().max().map_or(0, |m| m as usize + 1);
    let mut sizes = vec![0usize; clusters];
    for &l in labels {
        sizes[l as usize] += 1;
    }
    if json {
        let doc = obj([
            ("points", labels.len().into()),
            ("clusters", clusters.into()),
            ("sizes", sizes.clone().into()),
            (
                "labels",
                labels.iter().map(|&l| l as usize).collect::<Vec<_>>().into(),
            ),
        ]);
        println!("{doc}");
        return;
    }
    println!("{} clusters over {} points", clusters, labels.len());
    let mut ranked: Vec<usize> = sizes;
    ranked.sort_unstable_by(|a, b| b.cmp(a));
    ranked.truncate(20);
    println!(
        "largest sizes: {:?}{}",
        ranked,
        if clusters > 20 { " ..." } else { "" }
    );
}

/// First `cap` ids, with an ellipsis marker when truncated.
fn preview_u32(ids: &[u32], cap: usize) -> String {
    let shown: Vec<String> = ids.iter().take(cap).map(u32::to_string).collect();
    if ids.len() > cap {
        format!("members: [{}, ...]", shown.join(", "))
    } else {
        format!("members: [{}]", shown.join(", "))
    }
}

/// Exactness sweep: RAC (shared and distributed) vs sequential HAC on
/// random kNN graphs and 1-d grids, all sparse reducible linkages. The
/// approximate engines are pinned at their ε = 0 anchors: `Approx(0)` and
/// `DistApprox(0, per_round)` must be bitwise-exact RAC, hence exact HAC;
/// the batched `DistApprox(0)` regroups merges across rounds (so its
/// Lance–Williams folds associate differently — engine docs) and is
/// pinned dendrogram-wise against HAC instead. Failures name the exact
/// check that broke, not a bare boolean.
fn cmd_verify(args: &[String]) -> Result<()> {
    use rac_hac::dist::{DistApproxEngine, DistConfig, DistRacEngine, SyncMode};

    let flags = Flags::parse(args)?;
    let n = flags.usize_or("n", 300)?;
    let seeds = flags.usize_or("seeds", 5)?;
    const CHECKS: [&str; 6] = [
        "rac_matches_hac",
        "dist_rac_matches_hac",
        "approx_eps0_bitwise_rac",
        "dist_approx_eps0_unbatched_bitwise_rac",
        "dist_approx_eps0_batched_tree_matches_hac",
        "dist_approx_batched_sync_points_le_rounds",
    ];
    let mut checked = 0usize;
    for seed in 0..seeds as u64 {
        for linkage in Linkage::SPARSE_REDUCIBLE {
            let knn = {
                let ds = gaussian_mixture(n, 16, 8, 0.6, 0.05, seed);
                knn_graph(&ds, 8, Backend::Native, None)?
            };
            let grid = grid1d_graph(n, seed);
            for (gname, g) in [("knn", &knn), ("grid1d", &grid)] {
                let fail = |check: &str| {
                    anyhow!(
                        "verify FAILED at check {check:?} \
                         (linkage={linkage:?} seed={seed} graph={gname})"
                    )
                };
                let hac = naive_hac(g, linkage);
                let rac = RacEngine::new(g, linkage).run();
                if !hac.same_clustering(&rac.dendrogram, 1e-9) {
                    return Err(fail(CHECKS[0]));
                }
                let dist = DistRacEngine::new(g, linkage, DistConfig::new(4, 2)).run();
                if !hac.same_clustering(&dist.dendrogram, 1e-9) {
                    return Err(fail(CHECKS[1]));
                }
                // The approximate engines' correctness anchor: ε = 0 is
                // bitwise-exact RAC, hence exact HAC.
                let approx = rac_hac::approx::ApproxEngine::new(g, linkage, 0.0).run();
                if rac.dendrogram.bitwise_merges() != approx.dendrogram.bitwise_merges() {
                    return Err(fail(CHECKS[2]));
                }
                let unbatched = DistApproxEngine::new(g, linkage, DistConfig::new(4, 2), 0.0)
                    .with_sync_mode(SyncMode::PerRound)
                    .run();
                if rac.dendrogram.bitwise_merges() != unbatched.dendrogram.bitwise_merges() {
                    return Err(fail(CHECKS[3]));
                }
                let batched = DistApproxEngine::new(g, linkage, DistConfig::new(4, 2), 0.0)
                    .with_sync_mode(SyncMode::Batched { vshards: 8 })
                    .run();
                if !hac.same_clustering(&batched.dendrogram, 1e-9) {
                    return Err(fail(CHECKS[4]));
                }
                if batched.metrics.total_sync_points() > batched.metrics.rounds.len() {
                    return Err(fail(CHECKS[5]));
                }
                checked += CHECKS.len();
            }
        }
    }
    println!(
        "verify OK: {checked} checks ({}) across {seeds} seeds match sequential HAC (Theorem 1)",
        CHECKS.join(", ")
    );
    Ok(())
}

fn cmd_graph_info(args: &[String]) -> Result<()> {
    let flags = Flags::parse(args)?;
    let path = flags
        .get("config")
        .ok_or_else(|| anyhow!("--config <file.toml> required"))?;
    let cfg = RunConfig::from_file(std::path::Path::new(path))?;
    let g = pipeline::build_graph(&cfg)?;
    g.validate().map_err(|e| anyhow!("invalid graph: {e}"))?;
    println!(
        "nodes {}  edges {}  mean degree {:.1}  max degree {}  components {}",
        g.n(),
        g.m(),
        g.mean_degree(),
        g.max_degree(),
        g.components()
    );
    println!("degree histogram (<=64): {:?}", g.degree_histogram(64));
    Ok(())
}

fn cmd_kernels(args: &[String]) -> Result<()> {
    let flags = Flags::parse(args)?;
    let dir = flags
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(default_artifacts_dir);
    let rt = KernelRuntime::open(&dir)?;
    println!("PJRT platform: {}", rt.platform());
    for v in &rt.manifest().variants {
        print!(
            "  {:<32} {:<8} {:<6} x[{},{}] y[{},{}]",
            v.name,
            v.kind,
            v.metric.name(),
            v.m,
            v.d,
            v.n,
            v.d
        );
        if let Some(k) = v.k {
            print!(" k={k}");
        }
        // Smoke: execute on zeros and report output size.
        let x = vec![0f32; v.m * v.d];
        let y = vec![0f32; v.n * v.d];
        let status = if v.kind == "distance" {
            rt.distance_block(v, &x, &y).map(|o| o.len())
        } else {
            rt.knn_block(v, &x, &y).map(|(vals, _)| vals.len())
        };
        match status {
            Ok(len) => println!("  OK ({len} outputs)"),
            Err(e) => println!("  FAILED: {e}"),
        }
    }
    Ok(())
}
