//! Serving layer: a compact, read-optimised dendrogram index.
//!
//! The batch engines produce a dendrogram once; a service answers flat-cut
//! queries against it millions of times. The naive path rebuilds a
//! `UnionFind` over all `n` points per query
//! (`Dendrogram::cut_threshold` / `cut_k`), which is O(n α(n)) *per
//! query*. [`ServeIndex`] pays that cost once at build time and turns the
//! hot queries into array reads:
//!
//! - Merges are sorted by the crate-wide `(weight, a, b)` total order into
//!   flat arrays, so "how many merges apply below threshold t" is one
//!   binary search.
//! - The merge forest is laid out so every internal node covers a
//!   *contiguous interval* of a fixed leaf order (children ordered so the
//!   subtree holding the cluster's minimum member comes first). A flat cut
//!   is then: find the "top" nodes for the chosen merge prefix and paint
//!   their intervals — O(n) total work with O(1) amortised per point, no
//!   union-find, no hashing.
//! - A binary-lifting ancestor table makes single-point membership
//!   (`point_membership`) O(log n), and membership diffs between two
//!   thresholds walk only the merges in the band between them.
//!
//! Every query is *bitwise-pinned* against the naive `Dendrogram`
//! implementation (`rust/tests/serve_queries.rs`, `benches/serve.rs`): the
//! index is a pure representation change, never a semantic one.
//!
//! [`ServeHandle`] adds snapshot semantics: readers [`ServeHandle::load`]
//! an `Arc<ServeIndex>` and answer from that immutable snapshot while a
//! re-cluster [`ServeHandle::publish`]es a replacement atomically.
//! Persistence lives in [`codec`]: a versioned little-endian binary
//! dendrogram format written by the pipeline (`[output] dendrogram_path` /
//! `--dendrogram-out`) and loaded by the `rac query` subcommand.

pub mod codec;

use std::sync::{Arc, RwLock};

use crate::dendrogram::{CutError, Dendrogram, UnionFind};
use crate::linkage::Weight;

/// Sentinel node/parent id ("none").
const NONE: u32 = u32::MAX;

/// Why a [`ServeIndex`] could not be built.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The dendrogram failed [`Dendrogram::validate`]; the message is the
    /// validator's.
    InvalidDendrogram(String),
    /// `n + merges` would overflow the index's `u32` node-id space.
    TooLarge { n: usize, merges: usize },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::InvalidDendrogram(e) => {
                write!(f, "refusing to index an invalid dendrogram: {e}")
            }
            ServeError::TooLarge { n, merges } => write!(
                f,
                "dendrogram too large to index: {n} points + {merges} merges \
                 exceeds the u32 node-id space"
            ),
        }
    }
}

impl std::error::Error for ServeError {}

/// Why a point/band query could not be answered.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum QueryError {
    /// The queried point id is not in `[0, n)`.
    PointOutOfRange { p: u32, n: usize },
    /// The diff band is not an ordered pair of thresholds (`lo > hi`, or
    /// either side NaN).
    BadBand { lo: Weight, hi: Weight },
}

impl std::fmt::Display for QueryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            QueryError::PointOutOfRange { p, n } => {
                write!(f, "point {p} out of range for {n} points")
            }
            QueryError::BadBand { lo, hi } => {
                write!(f, "diff band [{lo}, {hi}) is not ordered")
            }
        }
    }
}

impl std::error::Error for QueryError {}

/// One merge inside a threshold band, reported by [`ServeIndex::diff`] in
/// `(weight, a, b)` order: the cluster represented by `absorbed`
/// disappears into the one represented by `into` (`into < absorbed`, and
/// `into` is the merged cluster's minimum member, matching the engines'
/// lower-representative-survives rule).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MergeStep {
    pub weight: Weight,
    pub into: u32,
    pub absorbed: u32,
}

/// Read-optimised dendrogram index. Build once with [`ServeIndex::build`],
/// then query concurrently — all queries take `&self`.
///
/// Node ids: `0..n` are leaves (point ids); `n + i` is the internal node
/// for the `i`-th merge in the sorted `(weight, a, b)` order.
pub struct ServeIndex {
    n: usize,
    /// Merge weights in sorted order (the binary-search axis).
    weights: Vec<Weight>,
    /// Children of internal node `i`: `left` holds the merged cluster's
    /// minimum member, so DFS visits the minimum first.
    left: Vec<u32>,
    right: Vec<u32>,
    /// Minimum member (= surviving representative) of internal node `i`.
    min_member: Vec<u32>,
    /// For every forest node, the *sorted merge index* of its parent
    /// (`NONE` for roots). Strictly increases along leaf-to-root paths.
    parent: Vec<u32>,
    /// DFS leaf order: `pos[p]` is point `p`'s leaf position,
    /// `order[pos] = p`.
    pos: Vec<u32>,
    order: Vec<u32>,
    /// Leaf-position interval `[lo[i], hi[i])` covered by internal node `i`.
    lo: Vec<u32>,
    hi: Vec<u32>,
    /// Binary lifting: `up[k][v]` is node `v`'s `2^k`-th ancestor (node
    /// id), `NONE` past the root.
    up: Vec<Vec<u32>>,
}

impl ServeIndex {
    /// Build the index from a dendrogram, refusing invalid input.
    pub fn build(d: &Dendrogram) -> Result<ServeIndex, ServeError> {
        let n = d.n();
        let m = d.merges().len();
        // Size gate *before* validate: validate allocates O(n), and a
        // hostile decoded header can claim an absurd n with few merges.
        if (n as u64).saturating_add(m as u64) >= NONE as u64 {
            return Err(ServeError::TooLarge { n, merges: m });
        }
        d.validate().map_err(ServeError::InvalidDendrogram)?;

        // Sort merge indices by the crate-wide (weight, a, b) order.
        let merges = d.merges();
        let mut idx: Vec<u32> = (0..m as u32).collect();
        idx.sort_by(|&x, &y| {
            let (mx, my) = (&merges[x as usize], &merges[y as usize]);
            mx.weight
                .total_cmp(&my.weight)
                .then(mx.a.cmp(&my.a))
                .then(mx.b.cmp(&my.b))
        });

        // Replay the sorted merges to build the forest. A valid merge list
        // is a spanning forest over point ids (each merge retires `b` for
        // good), and forest edges union cleanly in *any* order, so sorted
        // replay never hits an already-joined pair. With lower-root-wins
        // the union-find root is always the component's minimum member.
        let mut uf = UnionFind::new(n);
        let mut node_of: Vec<u32> = (0..n as u32).collect();
        let mut weights = Vec::with_capacity(m);
        let mut left = Vec::with_capacity(m);
        let mut right = Vec::with_capacity(m);
        let mut min_member = Vec::with_capacity(m);
        let mut parent = vec![NONE; n + m];
        for (i, &mi) in idx.iter().enumerate() {
            let mr = merges[mi as usize];
            let (ra, rb) = (uf.find(mr.a), uf.find(mr.b));
            debug_assert_ne!(ra, rb, "valid dendrograms form a forest");
            let (rlo, rhi) = if ra < rb { (ra, rb) } else { (rb, ra) };
            let (cl, cr) = (node_of[rlo as usize], node_of[rhi as usize]);
            parent[cl as usize] = i as u32;
            parent[cr as usize] = i as u32;
            uf.union(ra, rb);
            node_of[rlo as usize] = (n + i) as u32;
            weights.push(mr.weight);
            left.push(cl);
            right.push(cr);
            min_member.push(rlo);
        }

        // Pre-order DFS from each component root (ascending minimum
        // member), left child first: every subtree covers a contiguous
        // leaf interval whose first leaf is its minimum member.
        let mut pos = vec![0u32; n];
        let mut order = Vec::with_capacity(n);
        let mut stack: Vec<u32> = Vec::new();
        for p in 0..n as u32 {
            if uf.find(p) != p {
                continue;
            }
            stack.push(node_of[p as usize]);
            while let Some(v) = stack.pop() {
                if (v as usize) < n {
                    pos[v as usize] = order.len() as u32;
                    order.push(v);
                } else {
                    let i = v as usize - n;
                    stack.push(right[i]);
                    stack.push(left[i]);
                }
            }
        }
        debug_assert_eq!(order.len(), n);

        // Subtree sizes bottom-up (children always have a smaller merge
        // index than their parent), then intervals: a subtree's first
        // leaf is its minimum member.
        let mut size = vec![0u32; m];
        for i in 0..m {
            let s = |c: u32| {
                if (c as usize) < n {
                    1
                } else {
                    size[c as usize - n]
                }
            };
            size[i] = s(left[i]) + s(right[i]);
        }
        let mut lo = vec![0u32; m];
        let mut hi = vec![0u32; m];
        for i in 0..m {
            lo[i] = pos[min_member[i] as usize];
            hi[i] = lo[i] + size[i];
        }

        // Binary-lifting table over parent pointers.
        let total = n + m;
        let mut levels = 1usize;
        while (1usize << levels) < total.max(1) {
            levels += 1;
        }
        let mut up0 = vec![NONE; total];
        for v in 0..total {
            if parent[v] != NONE {
                up0[v] = (n as u32) + parent[v];
            }
        }
        let mut up = vec![up0];
        for k in 1..levels {
            let prev = &up[k - 1];
            let mut cur = vec![NONE; total];
            for v in 0..total {
                let a = prev[v];
                if a != NONE {
                    cur[v] = prev[a as usize];
                }
            }
            up.push(cur);
        }

        Ok(ServeIndex {
            n,
            weights,
            left,
            right,
            min_member,
            parent,
            pos,
            order,
            lo,
            hi,
            up,
        })
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn num_merges(&self) -> usize {
        self.weights.len()
    }

    /// Connected components of the input graph (clusters at +infinity).
    pub fn components(&self) -> usize {
        self.n - self.weights.len()
    }

    /// Merge weights in the sorted `(weight, a, b)` order — useful for
    /// choosing interesting thresholds.
    pub fn merge_weights(&self) -> &[Weight] {
        &self.weights
    }

    /// Number of merges with `weight < t` — the prefix a threshold cut
    /// applies. One binary search; valid because the weights are sorted
    /// under `total_cmp`, which agrees with `<` on the finite weights
    /// `validate` guarantees.
    fn prefix_len(&self, t: Weight) -> usize {
        self.weights.partition_point(|&w| w < t)
    }

    /// Highest ancestor of leaf `p` whose merge index is `< l` (or `p`
    /// itself if none). Merge indices strictly increase along leaf-to-root
    /// paths, so the greedy high-to-low lifting descent is exact.
    fn top_of(&self, p: u32, l: usize) -> u32 {
        let mut v = p;
        if l == 0 {
            return v;
        }
        for tab in self.up.iter().rev() {
            let a = tab[v as usize];
            if a != NONE && (a as usize - self.n) < l {
                v = a;
            }
        }
        v
    }

    /// The leaf-position interval a node covers.
    fn span(&self, v: u32) -> (usize, usize) {
        if (v as usize) < self.n {
            let p = self.pos[v as usize] as usize;
            (p, p + 1)
        } else {
            let i = v as usize - self.n;
            (self.lo[i] as usize, self.hi[i] as usize)
        }
    }

    /// A node's cluster representative: its minimum member.
    fn rep_of(&self, v: u32) -> u32 {
        if (v as usize) < self.n {
            v
        } else {
            self.min_member[v as usize - self.n]
        }
    }

    /// Labels for the cut that applies the first `l` sorted merges,
    /// bitwise-identical to the naive `UnionFind::labels()` output: dense
    /// labels assigned by first encounter over points in id order.
    fn labels_for_prefix(&self, l: usize) -> Vec<u32> {
        let n = self.n;
        // Paint each top node's interval with its node id; each position
        // is painted exactly once, so this is O(n) plus one lifting walk
        // per *cluster*, not per point.
        let mut top_at = vec![NONE; n];
        let mut p = 0usize;
        while p < n {
            let top = self.top_of(self.order[p], l);
            let (s, e) = self.span(top);
            debug_assert_eq!(s, p);
            for q in s..e {
                top_at[q] = top;
            }
            p = e;
        }
        let mut node_label = vec![NONE; n + self.weights.len()];
        let mut out = Vec::with_capacity(n);
        let mut next = 0u32;
        for point in 0..n {
            let t = top_at[self.pos[point] as usize] as usize;
            if node_label[t] == NONE {
                node_label[t] = next;
                next += 1;
            }
            out.push(node_label[t]);
        }
        out
    }

    /// Flat clustering at dissimilarity `threshold` (exclusive).
    /// Bitwise-equal to [`Dendrogram::cut_threshold`].
    pub fn cut_threshold(&self, threshold: Weight) -> Vec<u32> {
        self.labels_for_prefix(self.prefix_len(threshold))
    }

    /// Flat clustering with exactly `k` clusters. Same error contract as
    /// [`Dendrogram::cut_k`], same labels bitwise.
    pub fn cut_k(&self, k: usize) -> Result<Vec<u32>, CutError> {
        if k < 1 || k > self.n {
            return Err(CutError::KOutOfRange { k, n: self.n });
        }
        let components = self.components();
        if k < components {
            return Err(CutError::Disconnected { k, components });
        }
        Ok(self.labels_for_prefix(self.n - k))
    }

    /// The representative (minimum member) of point `p`'s cluster at
    /// `threshold`. O(log n): one binary search + one lifting walk.
    pub fn point_membership(&self, p: u32, threshold: Weight) -> Result<u32, QueryError> {
        if p as usize >= self.n {
            return Err(QueryError::PointOutOfRange { p, n: self.n });
        }
        let top = self.top_of(p, self.prefix_len(threshold));
        Ok(self.rep_of(top))
    }

    /// All members of point `p`'s cluster at `threshold`, ascending.
    /// Subtree extraction: one interval slice, no traversal of the rest
    /// of the forest.
    pub fn cluster_members(&self, p: u32, threshold: Weight) -> Result<Vec<u32>, QueryError> {
        if p as usize >= self.n {
            return Err(QueryError::PointOutOfRange { p, n: self.n });
        }
        let top = self.top_of(p, self.prefix_len(threshold));
        let (s, e) = self.span(top);
        let mut members = self.order[s..e].to_vec();
        members.sort_unstable();
        Ok(members)
    }

    /// The merges that separate the clustering at `lo` from the one at
    /// `hi` (`lo <= hi`), in `(weight, a, b)` order — exactly the work a
    /// subscriber replays to move a materialised cut between thresholds.
    /// Walks only the band, not the whole merge list.
    pub fn diff(&self, lo: Weight, hi: Weight) -> Result<Vec<MergeStep>, QueryError> {
        if !(lo <= hi) {
            return Err(QueryError::BadBand { lo, hi });
        }
        let (l0, l1) = (self.prefix_len(lo), self.prefix_len(hi));
        let mut out = Vec::with_capacity(l1 - l0);
        for i in l0..l1 {
            let into = self.min_member[i];
            let absorbed = self.rep_of(self.left[i]).max(self.rep_of(self.right[i]));
            debug_assert_eq!(into, self.rep_of(self.left[i]).min(self.rep_of(self.right[i])));
            out.push(MergeStep {
                weight: self.weights[i],
                into,
                absorbed,
            });
        }
        Ok(out)
    }

    /// Rough in-memory footprint, for capacity planning.
    pub fn memory_bytes(&self) -> usize {
        let u32s = self.left.len() * 5 // left, right, min_member, lo, hi
            + self.parent.len()
            + self.pos.len()
            + self.order.len()
            + self.up.iter().map(Vec::len).sum::<usize>();
        u32s * 4 + self.weights.len() * 8
    }
}

/// Shared handle with snapshot semantics. Readers [`load`](Self::load) an
/// `Arc<ServeIndex>` and answer any number of queries from that immutable
/// snapshot; a re-cluster [`publish`](Self::publish)es a new index
/// atomically. In-flight readers keep their old snapshot (self-consistent
/// answers), new loads observe the new one; the old index frees when the
/// last reader drops it.
pub struct ServeHandle {
    current: RwLock<Arc<ServeIndex>>,
}

impl ServeHandle {
    pub fn new(index: ServeIndex) -> ServeHandle {
        ServeHandle {
            current: RwLock::new(Arc::new(index)),
        }
    }

    /// Snapshot the current index. The lock is held only for the `Arc`
    /// clone, never across queries.
    pub fn load(&self) -> Arc<ServeIndex> {
        self.current.read().expect("serve handle poisoned").clone()
    }

    /// Atomically replace the served index; returns the new snapshot.
    pub fn publish(&self, index: ServeIndex) -> Arc<ServeIndex> {
        let next = Arc::new(index);
        *self.current.write().expect("serve handle poisoned") = next.clone();
        next
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dendrogram::Merge;

    fn chain4() -> Dendrogram {
        Dendrogram::new(
            4,
            vec![
                Merge { a: 0, b: 1, weight: 1.0 },
                Merge { a: 2, b: 3, weight: 2.0 },
                Merge { a: 0, b: 2, weight: 3.0 },
            ],
        )
    }

    #[test]
    fn build_rejects_invalid() {
        let dead = Dendrogram::new(
            3,
            vec![
                Merge { a: 0, b: 1, weight: 1.0 },
                Merge { a: 1, b: 2, weight: 2.0 },
            ],
        );
        assert!(matches!(
            ServeIndex::build(&dead),
            Err(ServeError::InvalidDendrogram(_))
        ));
        let ghost = Dendrogram::new(0, vec![Merge { a: 0, b: 1, weight: 1.0 }]);
        assert!(matches!(
            ServeIndex::build(&ghost),
            Err(ServeError::InvalidDendrogram(_))
        ));
    }

    #[test]
    fn cut_threshold_matches_naive() {
        let d = chain4();
        let idx = ServeIndex::build(&d).unwrap();
        for t in [-1.0, 0.0, 0.5, 1.0, 1.5, 2.0, 2.5, 3.0, 10.0, f64::NAN] {
            assert_eq!(idx.cut_threshold(t), d.cut_threshold(t), "t={t}");
        }
    }

    #[test]
    fn cut_k_matches_naive_including_errors() {
        let d = chain4();
        let idx = ServeIndex::build(&d).unwrap();
        for k in 0..=5 {
            assert_eq!(idx.cut_k(k), d.cut_k(k), "k={k}");
        }
        let disc = Dendrogram::new(4, vec![Merge { a: 0, b: 1, weight: 1.0 }]);
        let idx = ServeIndex::build(&disc).unwrap();
        for k in 0..=5 {
            assert_eq!(idx.cut_k(k), disc.cut_k(k), "disconnected k={k}");
        }
    }

    #[test]
    fn membership_and_members() {
        let d = chain4();
        let idx = ServeIndex::build(&d).unwrap();
        assert_eq!(idx.point_membership(3, 2.5).unwrap(), 2);
        assert_eq!(idx.point_membership(3, 10.0).unwrap(), 0);
        assert_eq!(idx.cluster_members(3, 2.5).unwrap(), vec![2, 3]);
        assert_eq!(idx.cluster_members(3, 10.0).unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(idx.cluster_members(1, 0.5).unwrap(), vec![1]);
        assert!(matches!(
            idx.point_membership(4, 1.0),
            Err(QueryError::PointOutOfRange { p: 4, n: 4 })
        ));
    }

    #[test]
    fn diff_walks_only_the_band() {
        let d = chain4();
        let idx = ServeIndex::build(&d).unwrap();
        let steps = idx.diff(1.5, 3.5).unwrap();
        assert_eq!(
            steps,
            vec![
                MergeStep { weight: 2.0, into: 2, absorbed: 3 },
                MergeStep { weight: 3.0, into: 0, absorbed: 2 },
            ]
        );
        assert!(idx.diff(3.5, 1.5).is_err());
        assert!(idx.diff(f64::NAN, 1.0).is_err());
        assert_eq!(idx.diff(0.0, 0.5).unwrap(), vec![]);
    }

    #[test]
    fn empty_index() {
        let d = Dendrogram::new(0, vec![]);
        let idx = ServeIndex::build(&d).unwrap();
        assert_eq!(idx.cut_threshold(1.0), Vec::<u32>::new());
        assert!(idx.cut_k(1).is_err());
    }

    #[test]
    fn handle_swaps_atomically() {
        let h = ServeHandle::new(ServeIndex::build(&chain4()).unwrap());
        let old = h.load();
        let disc = Dendrogram::new(4, vec![Merge { a: 0, b: 1, weight: 1.0 }]);
        h.publish(ServeIndex::build(&disc).unwrap());
        // The old snapshot still answers from the old tree...
        assert_eq!(old.cut_threshold(10.0), vec![0, 0, 0, 0]);
        // ...while new loads see the replacement.
        assert_eq!(h.load().cut_threshold(10.0), vec![0, 0, 1, 2]);
    }
}
