//! Versioned little-endian binary dendrogram format — the durable artifact
//! behind `[output] dendrogram_path` / `--dendrogram-out`, loaded back by
//! `rac query`.
//!
//! Layout (all little-endian):
//!
//! ```text
//! magic    u64   "RACDEND1"
//! version  u32   1
//! n        u64   number of points
//! count    u64   number of merges, < max(n, 1)
//! count ×  { a: u32, b: u32, weight: f64 }   merges in recorded order
//! ```
//!
//! The recorded (engine) merge order is preserved, so a round trip is
//! bit-exact under [`Dendrogram::bitwise_merges`].
//!
//! Decoding follows the `graph/io` + `dist/checkpoint` hostile-bytes
//! rules: the count is guarded against the remaining byte budget *before*
//! any allocation, trailing bytes are rejected, and the merge list is
//! checked against the full [`Dendrogram::validate`] contract. The
//! structural checks here are deliberately count-bounded (a seen-set over
//! the ≤ count retired representatives instead of validate's `O(n)`
//! bitmap) so a 32-byte header claiming 2^60 points cannot make the
//! decoder allocate anything proportional to the *claim* — only to the
//! bytes actually present.

use std::path::Path;

use rustc_hash::FxHashSet;

use crate::dendrogram::{Dendrogram, Merge};
use crate::dist::network::{put_f64, put_u32, put_u64, Reader};

pub const MAGIC: u64 = u64::from_le_bytes(*b"RACDEND1");
pub const VERSION: u32 = 1;
const HEADER_BYTES: usize = 8 + 4 + 8 + 8;
const RECORD_BYTES: usize = 4 + 4 + 8;

/// Serialise a dendrogram. Panics if the merge list is structurally
/// impossible to represent (more merges than points allow) — encode is for
/// engine output, which is valid by construction; use
/// [`Dendrogram::validate`] first when in doubt.
pub fn encode(d: &Dendrogram) -> Vec<u8> {
    assert!(
        d.merges().len() < d.n().max(1),
        "refusing to encode an invalid dendrogram: {} merges for {} points",
        d.merges().len(),
        d.n()
    );
    let mut buf = Vec::with_capacity(HEADER_BYTES + d.merges().len() * RECORD_BYTES);
    put_u64(&mut buf, MAGIC);
    put_u32(&mut buf, VERSION);
    put_u64(&mut buf, d.n() as u64);
    put_u64(&mut buf, d.merges().len() as u64);
    for m in d.merges() {
        put_u32(&mut buf, m.a);
        put_u32(&mut buf, m.b);
        put_f64(&mut buf, m.weight);
    }
    buf
}

/// Decode and fully validate a dendrogram. Every failure is a named,
/// descriptive error; no failure path allocates proportionally to a
/// corrupt count or point claim.
pub fn decode(bytes: &[u8]) -> Result<Dendrogram, String> {
    let mut r = Reader::new(bytes);
    let magic = r.u64().map_err(|e| format!("dendrogram header: {e}"))?;
    if magic != MAGIC {
        return Err(format!(
            "bad dendrogram magic {magic:#018x} (want {MAGIC:#018x})"
        ));
    }
    let version = r.u32().map_err(|e| format!("dendrogram header: {e}"))?;
    if version != VERSION {
        return Err(format!(
            "unsupported dendrogram version {version} (this build reads {VERSION})"
        ));
    }
    let n = r.u64().map_err(|e| format!("dendrogram header: {e}"))?;
    let n = usize::try_from(n).map_err(|_| format!("point count {n} overflows usize"))?;
    let count = r.u64().map_err(|e| format!("dendrogram header: {e}"))?;
    let count =
        usize::try_from(count).map_err(|_| format!("merge count {count} overflows usize"))?;
    if count >= n.max(1) {
        return Err(format!(
            "corrupt merge count {count} for {n} points (max {})",
            n.saturating_sub(1)
        ));
    }
    r.check_count(count, RECORD_BYTES, "dendrogram merge")?;

    // Structural validation inline, equivalent to `Dendrogram::validate`
    // but bounded by `count` (which the byte budget above justifies)
    // rather than by the claimed `n`.
    let mut merges = Vec::with_capacity(count);
    let mut dead: FxHashSet<u32> = FxHashSet::default();
    dead.reserve(count);
    for i in 0..count {
        let a = r.u32().map_err(|e| format!("merge {i}: {e}"))?;
        let b = r.u32().map_err(|e| format!("merge {i}: {e}"))?;
        let weight = r.f64().map_err(|e| format!("merge {i}: {e}"))?;
        if a >= b {
            return Err(format!("merge {i}: a >= b ({a} >= {b})"));
        }
        if b as usize >= n {
            return Err(format!("merge {i}: id {b} out of range for {n} points"));
        }
        if dead.contains(&a) || dead.contains(&b) {
            return Err(format!("merge {i}: uses a dead representative"));
        }
        dead.insert(b);
        if !weight.is_finite() {
            return Err(format!("merge {i}: non-finite weight"));
        }
        merges.push(Merge { a, b, weight });
    }
    if r.remaining() != 0 {
        return Err(format!(
            "{} trailing bytes after dendrogram payload",
            r.remaining()
        ));
    }
    Ok(Dendrogram::new(n, merges))
}

/// Write a dendrogram file.
pub fn write_file(d: &Dendrogram, path: impl AsRef<Path>) -> std::io::Result<()> {
    std::fs::write(path, encode(d))
}

/// Read and validate a dendrogram file.
pub fn read_file(path: impl AsRef<Path>) -> Result<Dendrogram, String> {
    let path = path.as_ref();
    let bytes =
        std::fs::read(path).map_err(|e| format!("read {}: {e}", path.display()))?;
    decode(&bytes).map_err(|e| format!("{}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Dendrogram {
        Dendrogram::new(
            5,
            vec![
                // Deliberately not in sorted-weight order: recorded order
                // must survive the round trip.
                Merge { a: 2, b: 3, weight: 2.0 },
                Merge { a: 0, b: 1, weight: 1.0 },
                Merge { a: 0, b: 2, weight: 3.0 },
            ],
        )
    }

    #[test]
    fn round_trip_is_bit_exact() {
        let d = sample();
        let back = decode(&encode(&d)).unwrap();
        assert_eq!(back.n(), d.n());
        assert_eq!(back.bitwise_merges(), d.bitwise_merges());
    }

    #[test]
    fn round_trip_empty_and_disconnected() {
        for d in [
            Dendrogram::new(0, vec![]),
            Dendrogram::new(7, vec![]),
            Dendrogram::new(4, vec![Merge { a: 1, b: 3, weight: 0.5 }]),
        ] {
            let back = decode(&encode(&d)).unwrap();
            assert_eq!(back.n(), d.n());
            assert_eq!(back.bitwise_merges(), d.bitwise_merges());
        }
    }

    #[test]
    fn rejects_bad_magic_and_version() {
        let mut bytes = encode(&sample());
        bytes[0] ^= 0xff;
        assert!(decode(&bytes).unwrap_err().contains("magic"));
        let mut bytes = encode(&sample());
        bytes[8] = 99;
        assert!(decode(&bytes).unwrap_err().contains("version"));
    }

    #[test]
    fn rejects_truncation_and_trailing() {
        let bytes = encode(&sample());
        assert!(decode(&bytes[..bytes.len() - 1]).is_err());
        assert!(decode(&bytes[..HEADER_BYTES - 2]).is_err());
        let mut padded = bytes.clone();
        padded.push(0);
        assert!(decode(&padded).unwrap_err().contains("trailing"));
    }

    #[test]
    fn corrupt_count_fails_fast() {
        // A count far beyond the payload must be rejected by the byte
        // budget (or the n bound) before any element loop or allocation.
        let mut bytes = encode(&sample());
        bytes[20..28].copy_from_slice(&u64::MAX.to_le_bytes());
        let err = decode(&bytes).unwrap_err();
        assert!(err.contains("corrupt merge count"), "got: {err}");
    }

    #[test]
    fn rejects_invalid_structure() {
        // Dead representative reuse, encoded by hand.
        let mut buf = Vec::new();
        put_u64(&mut buf, MAGIC);
        put_u32(&mut buf, VERSION);
        put_u64(&mut buf, 3);
        put_u64(&mut buf, 2);
        for (a, b, w) in [(0u32, 1u32, 1.0f64), (1, 2, 2.0)] {
            put_u32(&mut buf, a);
            put_u32(&mut buf, b);
            put_f64(&mut buf, w);
        }
        assert!(decode(&buf).unwrap_err().contains("dead representative"));
    }
}
