//! Cophenetic analysis: the dissimilarity level at which two points first
//! share a cluster, and the cophenetic correlation — the standard quality
//! check that a dendrogram faithfully represents its input dissimilarities
//! (Sokal 1958, the UPGMA paper the RAC paper builds on).

use std::collections::HashMap;

use crate::graph::Graph;
use crate::linkage::Weight;

use super::Dendrogram;

impl Dendrogram {
    /// Cophenetic distance matrix (condensed, row-major upper triangle):
    /// `out[idx(i, j)]` = merge weight at which `i` and `j` first joined,
    /// or `+inf` if they never did (disconnected input). O(n²) memory —
    /// intended for validation at small n.
    pub fn cophenetic(&self) -> Vec<Weight> {
        let n = self.n();
        let idx = |i: usize, j: usize| {
            debug_assert!(i < j);
            i * n - i * (i + 1) / 2 + (j - i - 1)
        };
        let mut out = vec![Weight::INFINITY; n * (n - 1) / 2];
        // members[rep] = points of the live cluster represented by rep.
        let mut members: HashMap<u32, Vec<u32>> = HashMap::new();
        for m in self.merges() {
            let la = members.remove(&m.a).unwrap_or_else(|| vec![m.a]);
            let lb = members.remove(&m.b).unwrap_or_else(|| vec![m.b]);
            for &x in &la {
                for &y in &lb {
                    let (i, j) = (x.min(y) as usize, x.max(y) as usize);
                    out[idx(i, j)] = m.weight;
                }
            }
            let mut merged = la;
            merged.extend(lb);
            members.insert(m.a, merged);
        }
        out
    }

    /// Cophenetic correlation coefficient against the input graph's edge
    /// dissimilarities (Pearson over the edges present in `g`).
    ///
    /// Values near 1 mean the hierarchy preserves the pairwise structure;
    /// classic rule of thumb: > 0.75 is a faithful dendrogram.
    pub fn cophenetic_correlation(&self, g: &Graph) -> f64 {
        assert_eq!(g.n(), self.n());
        let n = g.n();
        let idx = |i: usize, j: usize| i * n - i * (i + 1) / 2 + (j - i - 1);
        let coph = self.cophenetic();
        let mut xs: Vec<f64> = Vec::with_capacity(g.m());
        let mut ys: Vec<f64> = Vec::with_capacity(g.m());
        for u in 0..n as u32 {
            for (v, w) in g.neighbors(u) {
                if u < v {
                    let c = coph[idx(u as usize, v as usize)];
                    if c.is_finite() {
                        xs.push(w);
                        ys.push(c);
                    }
                }
            }
        }
        pearson(&xs, &ys)
    }
}

fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    let n = xs.len() as f64;
    if n < 2.0 {
        return f64::NAN;
    }
    let (mx, my) = (
        xs.iter().sum::<f64>() / n,
        ys.iter().sum::<f64>() / n,
    );
    let (mut sxy, mut sxx, mut syy) = (0.0, 0.0, 0.0);
    for (&x, &y) in xs.iter().zip(ys) {
        let (dx, dy) = (x - mx, y - my);
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    sxy / (sxx.sqrt() * syy.sqrt()).max(f64::MIN_POSITIVE)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gaussian_mixture, grid1d_graph};
    use crate::hac::naive_hac;
    use crate::knn::complete_graph;
    use crate::linkage::Linkage;
    use crate::rac::RacEngine;

    #[test]
    fn cophenetic_of_simple_tree() {
        use crate::dendrogram::Merge;
        // ((0,1)@1, (2,3)@2, (01,23)@5
        let d = Dendrogram::new(
            4,
            vec![
                Merge { a: 0, b: 1, weight: 1.0 },
                Merge { a: 2, b: 3, weight: 2.0 },
                Merge { a: 0, b: 2, weight: 5.0 },
            ],
        );
        let c = d.cophenetic();
        let n = 4;
        let idx = |i: usize, j: usize| i * n - i * (i + 1) / 2 + (j - i - 1);
        assert_eq!(c[idx(0, 1)], 1.0);
        assert_eq!(c[idx(2, 3)], 2.0);
        for (i, j) in [(0, 2), (0, 3), (1, 2), (1, 3)] {
            assert_eq!(c[idx(i, j)], 5.0);
        }
    }

    #[test]
    fn single_linkage_cophenetic_is_ultrametric_floor() {
        // For single linkage, cophenetic distance <= edge weight on every
        // edge (the path minimax is never above the direct edge).
        let g = grid1d_graph(100, 8);
        let d = naive_hac(&g, Linkage::Single);
        let coph = d.cophenetic();
        let n = 100;
        let idx = |i: usize, j: usize| i * n - i * (i + 1) / 2 + (j - i - 1);
        for u in 0..100u32 {
            for (v, w) in g.neighbors(u) {
                if u < v {
                    assert!(coph[idx(u as usize, v as usize)] <= w + 1e-12);
                }
            }
        }
    }

    #[test]
    fn correlation_high_on_clustered_data() {
        let ds = gaussian_mixture(120, 8, 4, 0.3, 0.0, 6);
        let g = complete_graph(&ds);
        let r = RacEngine::new(&g, Linkage::Average).run();
        let ccc = r.dendrogram.cophenetic_correlation(&g);
        assert!(ccc > 0.8, "cophenetic correlation {ccc:.3} too low");
    }

    #[test]
    fn disconnected_pairs_are_infinite() {
        let g = crate::graph::Graph::from_edges(4, [(0, 1, 1.0), (2, 3, 1.0)]);
        let d = naive_hac(&g, Linkage::Single);
        let coph = d.cophenetic();
        let idx = |i: usize, j: usize| i * 4 - i * (i + 1) / 2 + (j - i - 1);
        assert_eq!(coph[idx(0, 1)], 1.0);
        assert!(coph[idx(0, 2)].is_infinite());
    }
}
