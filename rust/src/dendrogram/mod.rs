//! Dendrograms: merge lists, the cluster tree, flat cuts, and the
//! order-independent comparison used to verify Theorem 1 (RAC = HAC).
//!
//! HAC/RAC output an unordered list of merges (paper Algorithm 1 returns
//! `M`). We record each merge as `(a, b, weight)` where `a < b` are the
//! *representative* ids of the merged clusters (the lower id survives, per
//! the paper's §5 ownership rule), and derive everything else — the tree,
//! its height, flat clusterings — from that list.

mod cophenetic;

use std::collections::HashMap;

use crate::linkage::Weight;

/// A single cluster merge: representatives `a < b` merged at `weight`.
/// After the merge the combined cluster is represented by `a`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Merge {
    pub a: u32,
    pub b: u32,
    pub weight: Weight,
}

/// Why a count-based flat cut ([`Dendrogram::cut_k`]) cannot be answered.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CutError {
    /// `k` lies outside `[1, n]`: no partition of `n` points has that
    /// many parts.
    KOutOfRange { k: usize, n: usize },
    /// The input graph was disconnected: the merge list bottoms out at
    /// `components` clusters, so no cut can produce fewer.
    Disconnected { k: usize, components: usize },
}

impl std::fmt::Display for CutError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            CutError::KOutOfRange { k, n } => {
                write!(f, "cut_k: k = {k} outside [1, {n}]")
            }
            CutError::Disconnected { k, components } => write!(
                f,
                "cut_k: k = {k} below the {components} connected components \
                 the merge list bottoms out at"
            ),
        }
    }
}

impl std::error::Error for CutError {}

/// The full output of a clustering run over `n` points.
#[derive(Debug, Clone, Default)]
pub struct Dendrogram {
    n: usize,
    merges: Vec<Merge>,
}

impl Dendrogram {
    /// Create from a merge list. Representatives are normalised to `a < b`.
    pub fn new(n: usize, merges: Vec<Merge>) -> Self {
        let merges = merges
            .into_iter()
            .map(|m| {
                if m.a < m.b {
                    m
                } else {
                    Merge {
                        a: m.b,
                        b: m.a,
                        weight: m.weight,
                    }
                }
            })
            .collect();
        Dendrogram { n, merges }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn merges(&self) -> &[Merge] {
        &self.merges
    }

    /// Structural validation: each representative merged away (appearing as
    /// `b`) never reappears; ids in range; merge count consistent with a
    /// forest over `n` leaves.
    pub fn validate(&self) -> Result<(), String> {
        if self.n == 0 {
            // A forest over zero leaves has no internal nodes; without this
            // guard a non-empty merge list would sail through the per-merge
            // loop only if it were also empty, but the count bound below is
            // skipped entirely (`n - 1` underflows), so reject explicitly.
            return if self.merges.is_empty() {
                Ok(())
            } else {
                Err(format!("{} merges for 0 points", self.merges.len()))
            };
        }
        if self.merges.len() >= self.n {
            return Err(format!(
                "{} merges for {} points (max {})",
                self.merges.len(),
                self.n,
                self.n - 1
            ));
        }
        let mut dead = vec![false; self.n];
        for (i, m) in self.merges.iter().enumerate() {
            if m.a >= m.b {
                return Err(format!("merge {i}: a >= b ({} >= {})", m.a, m.b));
            }
            if m.b as usize >= self.n {
                return Err(format!("merge {i}: id {} out of range", m.b));
            }
            if dead[m.a as usize] || dead[m.b as usize] {
                return Err(format!("merge {i}: uses a dead representative"));
            }
            dead[m.b as usize] = true;
            if !m.weight.is_finite() {
                return Err(format!("merge {i}: non-finite weight"));
            }
        }
        Ok(())
    }

    /// Number of clusters remaining after all merges (1 for a connected
    /// input graph; one per component otherwise).
    pub fn remaining_clusters(&self) -> usize {
        self.n - self.merges.len()
    }

    /// Height of the cluster tree: longest root-to-leaf path in merges.
    pub fn height(&self) -> usize {
        // height[rep] = height of the current cluster represented by rep.
        let mut height: HashMap<u32, usize> = HashMap::new();
        let mut max_h = 0;
        for m in &self.merges {
            let ha = height.get(&m.a).copied().unwrap_or(0);
            let hb = height.get(&m.b).copied().unwrap_or(0);
            let h = ha.max(hb) + 1;
            height.insert(m.a, h);
            height.remove(&m.b);
            max_h = max_h.max(h);
        }
        max_h
    }

    /// Flat clustering: stop merging at dissimilarity `threshold`
    /// (exclusive). Returns a label per point in `[0, n_clusters)`.
    ///
    /// Note: RAC/HAC merge weights are non-decreasing only for reducible
    /// linkages applied in HAC order; for RAC output we apply every merge
    /// with `weight < threshold`, which matches HAC's cut because the
    /// merge *set* is identical (Theorem 1) — see `cut_k` for count-based
    /// cuts.
    pub fn cut_threshold(&self, threshold: Weight) -> Vec<u32> {
        let mut uf = UnionFind::new(self.n);
        for m in &self.merges {
            if m.weight < threshold {
                uf.union(m.a, m.b);
            }
        }
        uf.labels()
    }

    /// Flat clustering with exactly `k` clusters (applies the `n - k`
    /// smallest merges).
    ///
    /// Merges are ordered by the crate-wide total order `(weight, a, b)`,
    /// so weight ties cut deterministically regardless of the order the
    /// engine recorded them in. Where the boundary between the applied
    /// and withheld merges falls at a *strict* weight increase, this
    /// agrees with [`Dendrogram::cut_threshold`] at the first withheld
    /// weight (property-tested in `rust/tests/approx_quality.rs`); a
    /// threshold cut cannot split a tie, but `cut_k` can.
    ///
    /// Errors rather than clamping: on the disconnected kNN graphs the
    /// pipeline routinely produces, the merge list bottoms out at one
    /// cluster per component, and `k` below that is unanswerable — the
    /// old code silently returned `remaining_clusters()` labels, which
    /// downstream quality metrics then mistook for a `k`-way cut. Callers
    /// that want the clamp can do `k.max(d.remaining_clusters())`
    /// explicitly.
    pub fn cut_k(&self, k: usize) -> Result<Vec<u32>, CutError> {
        if k < 1 || k > self.n {
            return Err(CutError::KOutOfRange { k, n: self.n });
        }
        let components = self.remaining_clusters();
        if k < components {
            return Err(CutError::Disconnected { k, components });
        }
        let mut order: Vec<&Merge> = self.merges.iter().collect();
        order.sort_by(|x, y| {
            x.weight
                .total_cmp(&y.weight)
                .then(x.a.cmp(&y.a))
                .then(x.b.cmp(&y.b))
        });
        let mut uf = UnionFind::new(self.n);
        for m in order.into_iter().take(self.n - k) {
            uf.union(m.a, m.b);
        }
        Ok(uf.labels())
    }

    /// Canonical fingerprint for order-independent equality: the multiset
    /// of (sorted leaf set, quantised weight) over all internal nodes.
    ///
    /// Two dendrograms over the same points are the same clustering iff
    /// they produce the same set of internal-node leaf sets — the order in
    /// which independent merges are recorded is irrelevant (Lemma 3).
    /// Weights are quantised to `tol` to absorb floating-point noise
    /// between differently-ordered but algebraically identical updates.
    ///
    /// `tol` must be positive and finite — a zero, negative, or NaN
    /// tolerance has no well-defined bucket width, and the old code's
    /// `w / tol` happily produced garbage buckets for them (panics).
    pub fn canonical(&self, tol: Weight) -> Vec<(Vec<u32>, i128)> {
        assert!(
            tol.is_finite() && tol > 0.0,
            "canonical: tolerance must be positive and finite, got {tol}"
        );
        let mut members: HashMap<u32, Vec<u32>> = HashMap::new();
        let mut out = Vec::with_capacity(self.merges.len());
        for m in &self.merges {
            let mut la = members.remove(&m.a).unwrap_or_else(|| vec![m.a]);
            let lb = members.remove(&m.b).unwrap_or_else(|| vec![m.b]);
            la.extend(lb);
            la.sort_unstable();
            out.push((la.clone(), quantise(m.weight, tol)));
            members.insert(m.a, la);
        }
        out.sort();
        out
    }

    /// Order-independent equality against another dendrogram.
    pub fn same_clustering(&self, other: &Dendrogram, tol: Weight) -> bool {
        self.n == other.n && self.canonical(tol) == other.canonical(tol)
    }

    /// The merge list as `(a, b, weight bits)` triples — the *bit-exact*
    /// fingerprint used by the engine-equivalence suites
    /// (`rust/tests/store_equivalence.rs` and the dist topology tests),
    /// where `same_clustering`'s tolerance would be too forgiving.
    pub fn bitwise_merges(&self) -> Vec<(u32, u32, u64)> {
        self.merges
            .iter()
            .map(|m| (m.a, m.b, m.weight.to_bits()))
            .collect()
    }

    /// Monotonicity violations ("inversions"): internal nodes whose merge
    /// weight is lower than a child's merge weight. Zero for reducible
    /// linkages; typically positive for centroid linkage.
    pub fn inversions(&self) -> usize {
        let mut last: HashMap<u32, Weight> = HashMap::new();
        let mut inv = 0;
        for m in &self.merges {
            let wa = last.get(&m.a).copied().unwrap_or(Weight::NEG_INFINITY);
            let wb = last.get(&m.b).copied().unwrap_or(Weight::NEG_INFINITY);
            if m.weight < wa.max(wb) - 1e-12 {
                inv += 1;
            }
            last.insert(m.a, m.weight);
            last.remove(&m.b);
        }
        inv
    }
}

/// Quantise a merge weight to `tol`-sized buckets. A plain
/// `(w / tol).round() as i64` saturates every quotient beyond ±2^63 to
/// `i64::MIN`/`MAX`, collapsing *distinct* huge weights (or ordinary
/// weights over a tiny tolerance) into one bucket and letting
/// `same_clustering` claim equality for different dendrograms. In-range
/// quotients keep their exact value; out-of-range ones fall back to the
/// weight's bit pattern offset into a disjoint region of the `i128`
/// bucket space, so they compare equal only when bit-identical — the
/// tolerance is meaningless at that magnitude anyway, since `tol` is
/// below the weight's ULP there.
fn quantise(w: Weight, tol: Weight) -> i128 {
    let q = (w / tol).round();
    if (-9.007199254740992e15..9.007199254740992e15).contains(&q) {
        // |q| < 2^53: q is an exactly-represented integer, cast is lossless.
        q as i64 as i128
    } else {
        (w.to_bits() as i128) + (1i128 << 64)
    }
}

/// Small path-compressing union-find used for flat cuts (and by the
/// serve-layer index build, which needs the same lower-root-wins rule).
pub(crate) struct UnionFind {
    parent: Vec<u32>,
}

impl UnionFind {
    pub(crate) fn new(n: usize) -> Self {
        UnionFind {
            parent: (0..n as u32).collect(),
        }
    }

    pub(crate) fn find(&mut self, mut x: u32) -> u32 {
        while self.parent[x as usize] != x {
            self.parent[x as usize] = self.parent[self.parent[x as usize] as usize];
            x = self.parent[x as usize];
        }
        x
    }

    pub(crate) fn union(&mut self, a: u32, b: u32) {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra != rb {
            // Lower root wins, matching the merge-representative rule.
            let (lo, hi) = if ra < rb { (ra, rb) } else { (rb, ra) };
            self.parent[hi as usize] = lo;
        }
    }

    /// Dense labels in `[0, n_clusters)`, stable by root id.
    pub(crate) fn labels(&mut self) -> Vec<u32> {
        let n = self.parent.len();
        let mut label: HashMap<u32, u32> = HashMap::new();
        let mut out = Vec::with_capacity(n);
        for x in 0..n as u32 {
            let r = self.find(x);
            let next = label.len() as u32;
            out.push(*label.entry(r).or_insert(next));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain4() -> Dendrogram {
        // ((0,1)@1, (2,3)@2, (0,2)@3)
        Dendrogram::new(
            4,
            vec![
                Merge { a: 0, b: 1, weight: 1.0 },
                Merge { a: 2, b: 3, weight: 2.0 },
                Merge { a: 0, b: 2, weight: 3.0 },
            ],
        )
    }

    #[test]
    fn validates_ok() {
        chain4().validate().unwrap();
    }

    #[test]
    fn normalises_representatives() {
        let d = Dendrogram::new(2, vec![Merge { a: 1, b: 0, weight: 1.0 }]);
        assert_eq!(d.merges()[0].a, 0);
        assert_eq!(d.merges()[0].b, 1);
    }

    #[test]
    fn catches_dead_representative() {
        let d = Dendrogram::new(
            3,
            vec![
                Merge { a: 0, b: 1, weight: 1.0 },
                Merge { a: 1, b: 2, weight: 2.0 }, // 1 is dead
            ],
        );
        assert!(d.validate().is_err());
    }

    #[test]
    fn height_balanced_vs_chain() {
        assert_eq!(chain4().height(), 2);
        let caterpillar = Dendrogram::new(
            4,
            vec![
                Merge { a: 0, b: 1, weight: 1.0 },
                Merge { a: 0, b: 2, weight: 2.0 },
                Merge { a: 0, b: 3, weight: 3.0 },
            ],
        );
        assert_eq!(caterpillar.height(), 3);
    }

    #[test]
    fn cut_threshold_labels() {
        let d = chain4();
        assert_eq!(d.cut_threshold(0.5), vec![0, 1, 2, 3]);
        let two = d.cut_threshold(2.5);
        assert_eq!(two[0], two[1]);
        assert_eq!(two[2], two[3]);
        assert_ne!(two[0], two[2]);
        let one = d.cut_threshold(10.0);
        assert!(one.iter().all(|&l| l == 0));
    }

    #[test]
    fn cut_k_counts() {
        let d = chain4();
        for k in 1..=4 {
            let labels = d.cut_k(k).unwrap();
            let distinct: std::collections::HashSet<_> = labels.iter().collect();
            assert_eq!(distinct.len(), k);
        }
    }

    #[test]
    fn cut_k_rejects_out_of_range() {
        let d = chain4();
        assert_eq!(d.cut_k(0), Err(CutError::KOutOfRange { k: 0, n: 4 }));
        assert_eq!(d.cut_k(5), Err(CutError::KOutOfRange { k: 5, n: 4 }));
    }

    #[test]
    fn cut_k_disconnected_is_a_named_error_not_a_lie() {
        // 4 points, one merge: the graph had 3 components. The old code
        // returned 3 labels for cut_k(1) and cut_k(2) without complaint.
        let d = Dendrogram::new(4, vec![Merge { a: 0, b: 1, weight: 1.0 }]);
        for k in 1..=2 {
            assert_eq!(d.cut_k(k), Err(CutError::Disconnected { k, components: 3 }));
        }
        let three = d.cut_k(3).unwrap();
        let distinct: std::collections::HashSet<_> = three.iter().collect();
        assert_eq!(distinct.len(), 3);
        assert_eq!(d.cut_k(4).unwrap(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn cut_k_ties_are_deterministic_across_recording_order() {
        // Two independent weight-1.0 merges: whichever the engine
        // recorded first, cut_k(3) must apply the (weight, a, b)-smaller
        // one, i.e. (0,1).
        let forward = Dendrogram::new(
            4,
            vec![
                Merge { a: 0, b: 1, weight: 1.0 },
                Merge { a: 2, b: 3, weight: 1.0 },
                Merge { a: 0, b: 2, weight: 5.0 },
            ],
        );
        let reversed = Dendrogram::new(
            4,
            vec![
                Merge { a: 2, b: 3, weight: 1.0 },
                Merge { a: 0, b: 1, weight: 1.0 },
                Merge { a: 0, b: 2, weight: 5.0 },
            ],
        );
        let (lf, lr) = (forward.cut_k(3).unwrap(), reversed.cut_k(3).unwrap());
        assert_eq!(lf, lr);
        assert_eq!(lf[0], lf[1], "the (weight, id)-first tie must merge");
        assert_ne!(lf[2], lf[3]);
    }

    #[test]
    fn canonical_ignores_order() {
        let d1 = chain4();
        let d2 = Dendrogram::new(
            4,
            vec![
                Merge { a: 2, b: 3, weight: 2.0 },
                Merge { a: 0, b: 1, weight: 1.0 },
                Merge { a: 0, b: 2, weight: 3.0 },
            ],
        );
        assert!(d1.same_clustering(&d2, 1e-9));
    }

    #[test]
    fn canonical_detects_different_trees() {
        let d1 = chain4();
        let d2 = Dendrogram::new(
            4,
            vec![
                Merge { a: 0, b: 1, weight: 1.0 },
                Merge { a: 0, b: 2, weight: 2.0 },
                Merge { a: 0, b: 3, weight: 3.0 },
            ],
        );
        assert!(!d1.same_clustering(&d2, 1e-9));
    }

    #[test]
    fn inversions_detected() {
        let inv = Dendrogram::new(
            3,
            vec![
                Merge { a: 0, b: 1, weight: 2.0 },
                Merge { a: 0, b: 2, weight: 1.0 }, // parent below child
            ],
        );
        assert_eq!(inv.inversions(), 1);
        assert_eq!(chain4().inversions(), 0);
    }

    #[test]
    fn remaining_clusters_disconnected() {
        let d = Dendrogram::new(4, vec![Merge { a: 0, b: 1, weight: 1.0 }]);
        assert_eq!(d.remaining_clusters(), 3);
    }

    #[test]
    fn empty_dendrogram() {
        let d = Dendrogram::new(0, vec![]);
        d.validate().unwrap();
        assert_eq!(d.height(), 0);
    }

    #[test]
    fn validate_rejects_merges_over_zero_points() {
        // Previously both count bounds were skipped for n == 0, so a merge
        // list attached to nothing validated iff its ids happened to trip
        // the per-merge range check — and (0, 1) does, but only because
        // b >= n; the count itself was never rejected.
        let d = Dendrogram {
            n: 0,
            merges: vec![Merge { a: 0, b: 1, weight: 1.0 }],
        };
        let err = d.validate().unwrap_err();
        assert!(err.contains("0 points"), "unexpected error: {err}");
    }

    #[test]
    #[should_panic(expected = "positive and finite")]
    fn canonical_rejects_zero_tol() {
        chain4().canonical(0.0);
    }

    #[test]
    #[should_panic(expected = "positive and finite")]
    fn canonical_rejects_negative_tol() {
        chain4().canonical(-1e-9);
    }

    #[test]
    #[should_panic(expected = "positive and finite")]
    fn canonical_rejects_nan_tol() {
        chain4().canonical(Weight::NAN);
    }

    #[test]
    #[should_panic(expected = "positive and finite")]
    fn canonical_rejects_infinite_tol() {
        chain4().canonical(Weight::INFINITY);
    }

    #[test]
    fn canonical_distinguishes_huge_weights() {
        // Both quotients saturate past i64::MAX under the old cast, so the
        // old fingerprint put 1e300 and 2e300 in the same bucket and
        // same_clustering reported equality for different dendrograms.
        let d1 = Dendrogram::new(2, vec![Merge { a: 0, b: 1, weight: 1e300 }]);
        let d2 = Dendrogram::new(2, vec![Merge { a: 0, b: 1, weight: 2e300 }]);
        assert!(!d1.same_clustering(&d2, 1e-9));
        assert!(d1.same_clustering(&d1.clone(), 1e-9));
        // Negative huge weights must not alias the positive ones either.
        let d3 = Dendrogram::new(2, vec![Merge { a: 0, b: 1, weight: -1e300 }]);
        assert!(!d1.same_clustering(&d3, 1e-9));
    }

    #[test]
    fn canonical_quantises_in_range_weights() {
        // Ordinary weights within a bucket still compare equal...
        let d1 = Dendrogram::new(2, vec![Merge { a: 0, b: 1, weight: 1.0 }]);
        let d2 = Dendrogram::new(2, vec![Merge { a: 0, b: 1, weight: 1.0 + 1e-12 }]);
        assert!(d1.same_clustering(&d2, 1e-9));
        // ...and across buckets do not.
        let d3 = Dendrogram::new(2, vec![Merge { a: 0, b: 1, weight: 1.1 }]);
        assert!(!d1.same_clustering(&d3, 1e-9));
    }
}
