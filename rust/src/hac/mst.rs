//! Single-linkage HAC via the minimum spanning tree (Kruskal + union-find).
//!
//! The paper (§1) notes single linkage is the historical exception to
//! HAC's scaling woes "because of its unique connection to the minimum
//! spanning tree problem" (Rammal et al. 1985). This module implements
//! that connection directly: sort edges, union components in weight
//! order — every union IS a single-linkage merge. `O(m log m)`, no
//! cluster-graph maintenance at all.
//!
//! Serves as a third independent oracle for single linkage (vs the heap
//! baseline and NN-chain) and as the fast path a practitioner would
//! actually use for single linkage.

use crate::dendrogram::{Dendrogram, Merge};
use crate::graph::Graph;
use crate::linkage::Weight;
use crate::store::scan::cmp_weight_pair;

/// Exact single-linkage HAC via Kruskal's MST.
///
/// Ties are broken by `(weight, min id, max id)` — consistent with the
/// crate-wide `(weight, id)` convention, so the output matches the other
/// engines even on tied inputs.
pub fn mst_single_linkage(g: &Graph) -> Dendrogram {
    let n = g.n();
    let mut edges: Vec<(Weight, u32, u32)> = Vec::with_capacity(g.m());
    for u in 0..n as u32 {
        for (v, w) in g.neighbors(u) {
            if u < v {
                edges.push((w, u, v));
            }
        }
    }
    edges.sort_unstable_by(cmp_weight_pair);

    // Union-find tracking the REPRESENTATIVE (lowest member id) of each
    // component, matching the merge-record convention of the engines.
    let mut parent: Vec<u32> = (0..n as u32).collect();
    let mut rep: Vec<u32> = (0..n as u32).collect();
    fn find(parent: &mut [u32], mut x: u32) -> u32 {
        while parent[x as usize] != x {
            parent[x as usize] = parent[parent[x as usize] as usize];
            x = parent[x as usize];
        }
        x
    }

    let mut merges = Vec::with_capacity(n.saturating_sub(1));
    for (w, u, v) in edges {
        let (ru, rv) = (find(&mut parent, u), find(&mut parent, v));
        if ru == rv {
            continue;
        }
        let (ra, rb) = (rep[ru as usize], rep[rv as usize]);
        merges.push(Merge {
            a: ra.min(rb),
            b: ra.max(rb),
            weight: w,
        });
        // Union: attach higher root under lower root, keep the lower rep.
        let (lo, hi) = if ru < rv { (ru, rv) } else { (rv, ru) };
        parent[hi as usize] = lo;
        rep[lo as usize] = ra.min(rb);
        if merges.len() == n - 1 {
            break;
        }
    }
    Dendrogram::new(n, merges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gaussian_mixture, grid1d_graph, random_regular_graph};
    use crate::hac::naive_hac;
    use crate::knn::{knn_graph, Backend};
    use crate::linkage::Linkage;
    use crate::rac::RacEngine;

    #[test]
    fn matches_heap_hac_on_grid() {
        let g = grid1d_graph(500, 11);
        let a = naive_hac(&g, Linkage::Single);
        let b = mst_single_linkage(&g);
        assert!(a.same_clustering(&b, 1e-12));
    }

    #[test]
    fn matches_rac_on_knn_graph() {
        let ds = gaussian_mixture(300, 8, 6, 0.5, 0.05, 2);
        let g = knn_graph(&ds, 6, Backend::Native, None).unwrap();
        let a = RacEngine::new(&g, Linkage::Single).run();
        let b = mst_single_linkage(&g);
        assert!(a.dendrogram.same_clustering(&b, 1e-12));
    }

    #[test]
    fn matches_on_random_ranked_graph_with_ties_impossible() {
        let g = random_regular_graph(400, 6, 7);
        let a = naive_hac(&g, Linkage::Single);
        let b = mst_single_linkage(&g);
        assert!(a.same_clustering(&b, 1e-12));
    }

    #[test]
    fn disconnected_components() {
        let g = crate::graph::Graph::from_edges(6, [(0, 1, 1.0), (2, 3, 2.0), (3, 4, 3.0)]);
        let d = mst_single_linkage(&g);
        assert_eq!(d.merges().len(), 3);
        assert_eq!(d.remaining_clusters(), 3);
        d.validate().unwrap();
    }

    #[test]
    fn merge_weights_are_sorted() {
        // Kruskal order implies a monotone dendrogram.
        let g = grid1d_graph(200, 4);
        let d = mst_single_linkage(&g);
        let ws: Vec<f64> = d.merges().iter().map(|m| m.weight).collect();
        assert!(ws.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(d.inversions(), 0);
    }

    #[test]
    fn exact_ties_agree_on_components_per_level() {
        // Under exact ties the single-linkage DENDROGRAM is not unique
        // (different tie orders give different intermediate trees), but
        // the flat components below any threshold are — compare those.
        let g = crate::graph::Graph::from_edges(
            6,
            [
                (0, 1, 1.0),
                (1, 2, 1.0),
                (2, 3, 1.0),
                (3, 4, 2.0),
                (4, 5, 2.0),
                (0, 5, 3.0),
            ],
        );
        let a = naive_hac(&g, Linkage::Single);
        let b = mst_single_linkage(&g);
        for thr in [0.5, 1.5, 2.5, 3.5] {
            let (ca, cb) = (a.cut_threshold(thr), b.cut_threshold(thr));
            for i in 0..6 {
                for j in (i + 1)..6 {
                    assert_eq!(ca[i] == ca[j], cb[i] == cb[j], "thr={thr} ({i},{j})");
                }
            }
        }
    }
}
