//! Algorithm 1: exact HAC via a lazy global min-heap.
//!
//! Every candidate edge `(w, a, b)` is pushed to a binary heap; stale
//! entries (dead endpoints or superseded weights) are discarded on pop.
//! This is the textbook `O(m log m)` generic-linkage HAC and the ground
//! truth for every correctness test in the crate.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::dendrogram::{Dendrogram, Merge};
use crate::graph::Graph;
use crate::linkage::{Linkage, Weight};

use super::state::ClusterStore;

/// Heap key ordered by `(weight, a, b)` — the crate-wide deterministic
/// tie-break ([`crate::store::scan::cmp_weight_pair`], same as
/// [`ClusterStore::nearest_neighbor`]), so all algorithms agree even on
/// tied inputs.
#[derive(PartialEq)]
struct Key(Weight, u32, u32);

impl Eq for Key {}

impl PartialOrd for Key {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Key {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        crate::store::scan::cmp_weight_pair(
            &(self.0, self.1, self.2),
            &(other.0, other.1, other.2),
        )
    }
}

/// Run exact sequential HAC (paper Algorithm 1) over a dissimilarity graph.
///
/// Works on connected and disconnected graphs (each component is clustered
/// to a single root). Supports every [`Linkage`]; note that for
/// non-reducible linkages (Centroid) the merge sequence is still "globally
/// closest pair first" but the dendrogram may contain inversions.
pub fn naive_hac(g: &Graph, linkage: Linkage) -> Dendrogram {
    let mut store = ClusterStore::from_graph(g, linkage);
    let mut heap: BinaryHeap<Reverse<Key>> = BinaryHeap::new();
    for u in 0..g.n() as u32 {
        for (v, w) in g.neighbors(u) {
            if u < v {
                heap.push(Reverse(Key(w, u, v)));
            }
        }
    }

    let mut merges = Vec::with_capacity(g.n().saturating_sub(1));
    while let Some(Reverse(Key(w, a, b))) = heap.pop() {
        if !store.active[a as usize] || !store.active[b as usize] {
            continue;
        }
        // Superseded entry? The live weight is authoritative.
        match store.weight(a, b) {
            Some(cur) if cur == w => {}
            _ => continue,
        }
        let (rep, weight) = store.merge(a, b);
        merges.push(Merge { a, b, weight });
        for (&c, e) in &store.neighbors[rep as usize] {
            let (x, y) = if rep < c { (rep, c) } else { (c, rep) };
            heap.push(Reverse(Key(e.weight, x, y)));
        }
    }
    Dendrogram::new(g.n(), merges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    #[test]
    fn merges_closest_first() {
        let g = Graph::from_edges(
            4,
            [
                (0, 1, 1.0),
                (2, 3, 0.5),
                (1, 2, 5.0),
                (0, 3, 6.0),
            ],
        );
        let d = naive_hac(&g, Linkage::Average);
        assert_eq!(d.merges().len(), 3);
        assert_eq!((d.merges()[0].a, d.merges()[0].b), (2, 3));
        assert_eq!((d.merges()[1].a, d.merges()[1].b), (0, 1));
        d.validate().unwrap();
    }

    #[test]
    fn single_linkage_is_mst_order() {
        // Single-linkage merge weights = MST edges in increasing order.
        let g = Graph::from_edges(
            5,
            [
                (0, 1, 1.0),
                (1, 2, 4.0),
                (2, 3, 2.0),
                (3, 4, 3.0),
                (0, 4, 10.0),
            ],
        );
        let d = naive_hac(&g, Linkage::Single);
        let ws: Vec<f64> = d.merges().iter().map(|m| m.weight).collect();
        assert_eq!(ws, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn disconnected_graph_stops_per_component() {
        let g = Graph::from_edges(4, [(0, 1, 1.0), (2, 3, 2.0)]);
        let d = naive_hac(&g, Linkage::Complete);
        assert_eq!(d.merges().len(), 2);
        assert_eq!(d.remaining_clusters(), 2);
    }

    #[test]
    fn monotone_for_reducible() {
        let g = crate::data::grid1d_graph(64, 9);
        for l in Linkage::SPARSE_REDUCIBLE {
            let d = naive_hac(&g, l);
            assert_eq!(d.inversions(), 0, "{l:?}");
            assert_eq!(d.merges().len(), 63);
        }
    }

    #[test]
    fn complete_graph_all_linkages_terminate() {
        let g = crate::data::stable_hierarchy(3, 4.0, 1);
        for l in Linkage::ALL {
            let d = naive_hac(&g, l);
            assert_eq!(d.merges().len(), 7, "{l:?}");
            d.validate().unwrap();
        }
    }

    #[test]
    fn singleton_input() {
        let g = Graph::from_edges(1, []);
        let d = naive_hac(&g, Linkage::Average);
        assert!(d.merges().is_empty());
    }
}
