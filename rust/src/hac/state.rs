//! Mutable cluster-graph state shared by the sequential HAC baselines.
//!
//! Clusters are identified by their *representative* id: the lowest point
//! id they contain (the same lower-id-wins rule the paper's distributed
//! implementation uses for merge ownership, §5). Each active cluster keeps
//! a hash map of neighbor representative → [`EdgeState`].

use rustc_hash::FxHashMap;

use crate::graph::Graph;
use crate::linkage::{EdgeState, Linkage, MergeCtx, Weight};

/// Mutable clustering state over a dissimilarity graph.
pub struct ClusterStore {
    pub linkage: Linkage,
    /// `sizes[rep]` = point count; meaningful only while `active[rep]`.
    pub sizes: Vec<u64>,
    pub active: Vec<bool>,
    /// Neighbor maps keyed by representative id.
    pub neighbors: Vec<FxHashMap<u32, EdgeState>>,
    n_active: usize,
}

impl ClusterStore {
    /// Singleton clusters over the graph's nodes.
    pub fn from_graph(g: &Graph, linkage: Linkage) -> Self {
        if !linkage.supports_sparse() {
            // Ward/Centroid require every cluster pair to stay connected;
            // a complete input graph guarantees that invariant.
            let n = g.n();
            assert!(
                g.m() == n * (n - 1) / 2,
                "{linkage:?} linkage requires a complete graph"
            );
        }
        let n = g.n();
        let mut neighbors = Vec::with_capacity(n);
        for u in 0..n as u32 {
            neighbors.push(
                g.neighbors(u)
                    .map(|(v, w)| (v, EdgeState::point(w)))
                    .collect::<FxHashMap<_, _>>(),
            );
        }
        ClusterStore {
            linkage,
            sizes: vec![1; n],
            active: vec![true; n],
            neighbors,
            n_active: n,
        }
    }

    pub fn n(&self) -> usize {
        self.sizes.len()
    }

    pub fn n_active(&self) -> usize {
        self.n_active
    }

    /// Current dissimilarity between two active clusters, if connected.
    pub fn weight(&self, a: u32, b: u32) -> Option<Weight> {
        self.neighbors[a as usize].get(&b).map(|e| e.weight)
    }

    /// Nearest neighbor of `c` by `(weight, id)` — the deterministic
    /// tie-break every algorithm in this crate shares
    /// ([`crate::rac::logic::scan_nn`]), so that outputs are comparable
    /// even in the presence of exact ties.
    pub fn nearest_neighbor(&self, c: u32) -> Option<(u32, Weight)> {
        match crate::rac::logic::scan_nn(&self.neighbors[c as usize]) {
            (crate::rac::NO_NN, _) => None,
            (v, w) => Some((v, w)),
        }
    }

    /// Merge clusters `a` and `b` (both active, connected or not): the
    /// lower representative survives. Returns `(survivor, merge_weight)`.
    ///
    /// All affected neighbor maps are updated symmetrically; the dead
    /// representative disappears from every map.
    pub fn merge(&mut self, a: u32, b: u32) -> (u32, Weight) {
        assert!(a != b);
        assert!(self.active[a as usize] && self.active[b as usize]);
        let (lo, hi) = if a < b { (a, b) } else { (b, a) };
        let pair_weight = self
            .weight(lo, hi)
            .expect("merging disconnected clusters");
        let ctx_sizes = (self.sizes[lo as usize], self.sizes[hi as usize]);

        // Take both maps to appease the borrow checker; they are disjoint
        // from every map we touch below (no self-edges).
        let lo_map = std::mem::take(&mut self.neighbors[lo as usize]);
        let hi_map = std::mem::take(&mut self.neighbors[hi as usize]);

        let mut merged: FxHashMap<u32, EdgeState> =
            FxHashMap::with_capacity_and_hasher(lo_map.len() + hi_map.len(), Default::default());
        for (&c, &e_lo) in &lo_map {
            if c == hi {
                continue;
            }
            let e_hi = hi_map.get(&c).copied();
            let ctx = MergeCtx {
                size_a: ctx_sizes.0,
                size_b: ctx_sizes.1,
                size_c: self.sizes[c as usize],
                pair_weight,
            };
            let e = self.linkage.merge(Some(e_lo), e_hi, ctx).unwrap();
            merged.insert(c, e);
        }
        for (&c, &e_hi) in &hi_map {
            if c == lo || lo_map.contains_key(&c) {
                continue;
            }
            let ctx = MergeCtx {
                size_a: ctx_sizes.0,
                size_b: ctx_sizes.1,
                size_c: self.sizes[c as usize],
                pair_weight,
            };
            let e = self.linkage.merge(None, Some(e_hi), ctx).unwrap();
            merged.insert(c, e);
        }

        // Symmetric updates on the neighbors.
        for (&c, &e) in &merged {
            let map = &mut self.neighbors[c as usize];
            map.remove(&hi);
            map.insert(lo, e);
        }
        // Neighbors of hi not in merged (i.e. `lo` itself) already handled.

        self.neighbors[lo as usize] = merged;
        self.sizes[lo as usize] += self.sizes[hi as usize];
        self.active[hi as usize] = false;
        self.n_active -= 1;
        (lo, pair_weight)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    fn triangle() -> Graph {
        Graph::from_edges(3, [(0, 1, 1.0), (1, 2, 2.0), (0, 2, 3.0)])
    }

    #[test]
    fn init_from_graph() {
        let s = ClusterStore::from_graph(&triangle(), Linkage::Average);
        assert_eq!(s.n_active(), 3);
        assert_eq!(s.weight(0, 1), Some(1.0));
        assert_eq!(s.nearest_neighbor(2), Some((1, 2.0)));
    }

    #[test]
    fn merge_updates_all_maps() {
        let mut s = ClusterStore::from_graph(&triangle(), Linkage::Average);
        let (rep, w) = s.merge(0, 1);
        assert_eq!(rep, 0);
        assert_eq!(w, 1.0);
        assert!(!s.active[1]);
        assert_eq!(s.sizes[0], 2);
        // Average of (1-2)=2.0 and (0-2)=3.0 → 2.5 with count 2.
        assert_eq!(s.weight(0, 2), Some(2.5));
        assert_eq!(s.weight(2, 0), Some(2.5));
        assert!(s.neighbors[2].get(&1).is_none());
    }

    #[test]
    fn merge_without_common_neighbor() {
        // Path 0-1-2-3: merge (0,1); 0 inherits edge to 2 untouched.
        let g = Graph::from_edges(4, [(0, 1, 1.0), (1, 2, 2.0), (2, 3, 3.0)]);
        let mut s = ClusterStore::from_graph(&g, Linkage::Single);
        s.merge(0, 1);
        assert_eq!(s.weight(0, 2), Some(2.0));
        assert_eq!(s.weight(0, 3), None);
    }

    #[test]
    fn higher_into_lower() {
        let mut s = ClusterStore::from_graph(&triangle(), Linkage::Single);
        let (rep, _) = s.merge(2, 1); // arguments in either order
        assert_eq!(rep, 1);
        assert!(s.active[1] && !s.active[2]);
    }

    #[test]
    fn nn_tie_break_by_id() {
        let g = Graph::from_edges(3, [(0, 1, 1.0), (0, 2, 1.0)]);
        let s = ClusterStore::from_graph(&g, Linkage::Single);
        assert_eq!(s.nearest_neighbor(0), Some((1, 1.0)));
    }

    #[test]
    #[should_panic(expected = "requires a complete graph")]
    fn ward_rejects_sparse() {
        let g = Graph::from_edges(3, [(0, 1, 1.0), (1, 2, 2.0)]);
        ClusterStore::from_graph(&g, Linkage::Ward);
    }
}
