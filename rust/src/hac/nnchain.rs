//! The nearest-neighbor-chain algorithm (Murtagh 1983/84) — the sequential
//! reciprocal-NN merge strategy that RAC parallelises (paper §3).
//!
//! Follow nearest-neighbor pointers from an arbitrary cluster; because
//! chain dissimilarities are non-increasing, the walk must reach a
//! *reciprocal* nearest-neighbor pair, which (for reducible linkages) is
//! safe to merge immediately even if it is not the global minimum.

use crate::dendrogram::{Dendrogram, Merge};
use crate::graph::Graph;
use crate::linkage::Linkage;

use super::state::ClusterStore;

/// Run NN-chain HAC over a dissimilarity graph.
///
/// Exact for reducible linkages (identical clustering to [`super::naive_hac`],
/// possibly in a different merge order — compare with
/// [`Dendrogram::same_clustering`]). Ties are broken by `(weight, id)`,
/// which provably prevents chain cycles longer than 2.
pub fn nn_chain(g: &Graph, linkage: Linkage) -> Dendrogram {
    assert!(
        linkage.is_reducible(),
        "NN-chain requires a reducible linkage"
    );
    let n = g.n();
    let mut store = ClusterStore::from_graph(g, linkage);
    let mut merges = Vec::with_capacity(n.saturating_sub(1));
    let mut chain: Vec<u32> = Vec::with_capacity(64);
    // `cursor` scans for unvisited starts; merged-away or exhausted
    // (isolated) clusters are skipped.
    let mut done = vec![false; n];

    for start in 0..n as u32 {
        if done[start as usize] || !store.active[start as usize] {
            continue;
        }
        chain.clear();
        chain.push(start);
        while let Some(&top) = chain.last() {
            match store.nearest_neighbor(top) {
                None => {
                    // Isolated cluster: its component is fully merged.
                    done[top as usize] = true;
                    chain.pop();
                }
                Some((nn, _)) => {
                    if chain.len() >= 2 && chain[chain.len() - 2] == nn {
                        // Reciprocal pair found: merge top two.
                        let a = chain.pop().unwrap();
                        let b = chain.pop().unwrap();
                        let (rep, weight) = store.merge(a, b);
                        merges.push(Merge { a, b, weight });
                        let dead = if rep == a { b } else { a };
                        done[dead as usize] = true;
                        // Continue the chain from the survivor's position:
                        // the suffix below the pair is still a valid chain.
                        if chain.is_empty() {
                            chain.push(rep);
                        }
                    } else {
                        chain.push(nn);
                    }
                }
            }
        }
    }
    Dendrogram::new(n, merges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hac::naive_hac;

    #[test]
    fn matches_naive_on_path() {
        let g = crate::data::grid1d_graph(128, 4);
        for l in Linkage::SPARSE_REDUCIBLE {
            let a = naive_hac(&g, l);
            let b = nn_chain(&g, l);
            assert!(a.same_clustering(&b, 1e-9), "{l:?} diverged");
        }
    }

    #[test]
    fn matches_naive_on_complete_graph() {
        let g = crate::data::stable_hierarchy(4, 4.0, 7);
        for l in [
            Linkage::Single,
            Linkage::Complete,
            Linkage::Average,
            Linkage::WeightedAverage,
            Linkage::Ward,
        ] {
            let a = naive_hac(&g, l);
            let b = nn_chain(&g, l);
            assert!(a.same_clustering(&b, 1e-6), "{l:?} diverged");
        }
    }

    #[test]
    fn handles_disconnected() {
        let g = crate::graph::Graph::from_edges(5, [(0, 1, 1.0), (2, 3, 1.0), (3, 4, 2.0)]);
        let d = nn_chain(&g, Linkage::Average);
        assert_eq!(d.merges().len(), 3);
        assert_eq!(d.remaining_clusters(), 2);
    }

    #[test]
    #[should_panic(expected = "reducible")]
    fn rejects_centroid() {
        let g = crate::data::stable_hierarchy(2, 4.0, 0);
        nn_chain(&g, Linkage::Centroid);
    }

    #[test]
    fn exact_ties_still_terminate() {
        // Complete graph with all-equal weights: worst case for chains.
        let m = vec![
            0.0, 1.0, 1.0, 1.0, //
            1.0, 0.0, 1.0, 1.0, //
            1.0, 1.0, 0.0, 1.0, //
            1.0, 1.0, 1.0, 0.0,
        ];
        let g = crate::graph::Graph::from_dense(4, &m);
        let d = nn_chain(&g, Linkage::Average);
        assert_eq!(d.merges().len(), 3);
    }
}
