//! Exact sequential HAC baselines (paper Algorithm 1 and the
//! nearest-neighbor-chain algorithm).
//!
//! These are the correctness oracles for the RAC engine (Theorem 1 says
//! their output must be identical for reducible linkages) and the
//! sequential baselines in the benchmark harness.
//!
//! * [`naive_hac`] — Algorithm 1 with a lazy global min-heap over candidate
//!   edges: always merges the globally closest pair, `O(m log m)`-ish.
//! * [`nn_chain`] — Murtagh's nearest-neighbor-chain algorithm: follows NN
//!   pointers until a reciprocal pair is found; merges are locally optimal
//!   only, but the resulting dendrogram is identical for reducible
//!   linkages. This is the algorithm RAC parallelises.
//! * [`mst_single_linkage`] — single linkage via Kruskal's MST (the
//!   paper's §1 "unique connection to the minimum spanning tree").

mod mst;
mod naive;
mod nnchain;
pub mod state;

pub use mst::mst_single_linkage;
pub use naive::naive_hac;
pub use nnchain::nn_chain;
