//! Run configuration: a TOML-subset parser (offline `toml` substitute)
//! and the typed [`RunConfig`] the launcher consumes.
//!
//! Supported TOML subset — everything the run configs need:
//! `[section]` headers, `key = value` with string / integer / float /
//! boolean / homogeneous scalar arrays, `#` comments. See
//! `examples/configs/*.toml` for complete examples.

mod toml;

pub use toml::{TomlDoc, TomlValue};

use std::path::Path;
use std::str::FromStr;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::data::Metric;
use crate::dist::{ExecOptions, FaultSpec, RecoveryMode, SyncMode, DEFAULT_VSHARDS};
use crate::linkage::Linkage;
use crate::trace::TraceFormat;

/// Which dataset generator to run (DESIGN.md §1 substitutions).
#[derive(Debug, Clone, PartialEq)]
pub enum DatasetSpec {
    /// SIFT-like Gaussian mixture: `n`, `d`, `clusters`, `spread`,
    /// `noise_frac`.
    SiftLike {
        n: usize,
        d: usize,
        clusters: usize,
        spread: f64,
        noise_frac: f64,
    },
    /// Web/doc-like Zipfian topic mixture: `n`, `d`, `topics`.
    DocsLike { n: usize, d: usize, topics: usize },
    /// §4.2.2 1-d grid (path graph; skips graph construction).
    Grid1d { n: usize },
    /// Theorem-4 adversarial sequence (complete graph).
    Adversarial { levels: u32 },
    /// Theorem-5 stable hierarchy (complete graph).
    Stable { depth: u32, base: f64 },
    /// §4.2.2 bounded-degree random graph with random edge ranks.
    RandomRegular { n: usize, degree: usize },
}

/// How to turn vectors into a dissimilarity graph.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum GraphSpec {
    Knn { k: usize, xla: bool },
    Epsilon { eps: f64 },
    Complete,
}

/// Which engine executes the clustering.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EngineSpec {
    /// Exact sequential baselines.
    NaiveHac,
    NnChain,
    /// Shared-memory RAC with `threads` workers.
    Rac { threads: usize },
    /// Distributed RAC over `machines × cpus` (paper §5).
    DistRac { machines: usize, cpus: usize },
    /// Shared-memory (1+ε)-approximate engine (TeraHAC-style good
    /// merges); `epsilon = 0` is bitwise-exact RAC.
    Approx { epsilon: f64, threads: usize },
    /// Distributed (1+ε)-approximate engine: ε-good merges over sharded
    /// state; with `sync: PerRound` bitwise-identical to `Approx` for
    /// every topology and to `DistRac` at `epsilon = 0`; with
    /// `sync: Batched` runs TeraHAC-style shard-local merge batching
    /// (`sync_mode = "batched"`, optional `vshards`).
    DistApprox {
        machines: usize,
        cpus: usize,
        epsilon: f64,
        sync: SyncMode,
    },
}

/// Where run artifacts land (the `[output]` section). Everything is
/// optional; the default writes nothing beyond stdout.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct OutputSpec {
    /// Record a structured event trace ([`crate::trace`]) and write it
    /// here. Setting a path is what turns tracing on.
    pub trace_path: Option<String>,
    /// On-disk trace format (`jsonl` or `chrome`); only meaningful with
    /// `trace_path` set — rejected otherwise.
    pub trace_format: TraceFormat,
    /// Write the run's `RunMetrics` JSON here (machine-readable sibling
    /// of the stdout report).
    pub metrics_out: Option<String>,
    /// Persist the dendrogram here in the versioned binary format
    /// ([`crate::serve::codec`]), making the hierarchy a durable artifact
    /// `rac query` can serve flat cuts from.
    pub dendrogram_path: Option<String>,
}

/// A full clustering run.
#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    pub dataset: DatasetSpec,
    pub seed: u64,
    pub graph: GraphSpec,
    pub linkage: Linkage,
    pub engine: EngineSpec,
    /// `Some` switches the distributed engines from simulated accounting
    /// to executed mode (thread-per-machine shards over real channels;
    /// `exec_mode = "executed"` plus the latency/jitter/fault knobs).
    /// `None` (the default) keeps the pure simulation.
    pub exec: Option<ExecOptions>,
    /// Trace/metrics output destinations (`[output]` section).
    pub output: OutputSpec,
    /// Pin the row-scan kernels to the scalar fallback
    /// (`engine.force_scalar`, or the `RAC_FORCE_SCALAR` environment
    /// variable / `--force-scalar` CLI flag). The config pin is scoped
    /// to the run that carries it (the pipeline holds a
    /// [`crate::store::scan::KernelPin`] and restores the entry dispatch
    /// after); only the environment variable pins process-wide. Results
    /// are bitwise identical either way ([`crate::store::scan`]); this
    /// exists for differential testing and benchmarking the dispatch.
    pub force_scalar: bool,
}

impl RunConfig {
    pub fn from_file(path: &Path) -> Result<RunConfig> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
        Self::from_toml_str(&text)
    }

    pub fn from_toml_str(text: &str) -> Result<RunConfig> {
        let doc = TomlDoc::parse(text).map_err(|e| anyhow!("config parse: {e}"))?;

        let dtype = doc.str_or("dataset", "type", "sift_like")?;
        let dataset = match dtype.as_str() {
            "sift_like" => DatasetSpec::SiftLike {
                n: doc.usize_or("dataset", "n", 2000)?,
                d: doc.usize_or("dataset", "d", 128)?,
                clusters: doc.usize_or("dataset", "clusters", 50)?,
                spread: doc.f64_or("dataset", "spread", 0.8)?,
                noise_frac: doc.f64_or("dataset", "noise_frac", 0.02)?,
            },
            "docs_like" => DatasetSpec::DocsLike {
                n: doc.usize_or("dataset", "n", 2000)?,
                d: doc.usize_or("dataset", "d", 64)?,
                topics: doc.usize_or("dataset", "topics", 20)?,
            },
            "grid1d" => DatasetSpec::Grid1d {
                n: doc.usize_or("dataset", "n", 10000)?,
            },
            "adversarial" => DatasetSpec::Adversarial {
                levels: doc.usize_or("dataset", "levels", 8)? as u32,
            },
            "stable" => DatasetSpec::Stable {
                depth: doc.usize_or("dataset", "depth", 8)? as u32,
                base: doc.f64_or("dataset", "base", 4.0)?,
            },
            "random_regular" => DatasetSpec::RandomRegular {
                n: doc.usize_or("dataset", "n", 10000)?,
                degree: doc.usize_or("dataset", "degree", 8)?,
            },
            other => bail!("unknown dataset.type {other:?}"),
        };

        let gtype = doc.str_or("graph", "type", "knn")?;
        let graph = match gtype.as_str() {
            "knn" => GraphSpec::Knn {
                k: doc.usize_or("graph", "k", 20)?,
                xla: doc.bool_or("graph", "xla", false)?,
            },
            "epsilon" => GraphSpec::Epsilon {
                eps: doc.f64_or("graph", "eps", 1.0)?,
            },
            "complete" => GraphSpec::Complete,
            other => bail!("unknown graph.type {other:?}"),
        };

        let linkage = Linkage::from_str(&doc.str_or("cluster", "linkage", "average")?)
            .map_err(|e| anyhow!(e))?;

        let etype = doc.str_or("engine", "type", "rac")?;
        let engine = match etype.as_str() {
            "naive_hac" => EngineSpec::NaiveHac,
            "nn_chain" => EngineSpec::NnChain,
            "rac" => EngineSpec::Rac {
                threads: doc.usize_or("engine", "threads", 0)?,
            },
            "dist_rac" => {
                let (machines, cpus) = parse_topology(&doc, "dist_rac")?;
                EngineSpec::DistRac { machines, cpus }
            }
            "approx" => EngineSpec::Approx {
                epsilon: parse_epsilon(&doc)?,
                threads: doc.usize_or("engine", "threads", 0)?,
            },
            "dist_approx" => {
                let (machines, cpus) = parse_topology(&doc, "dist_approx")?;
                EngineSpec::DistApprox {
                    machines,
                    cpus,
                    epsilon: parse_epsilon(&doc)?,
                    sync: parse_sync_mode(&doc)?,
                }
            }
            other => bail!("unknown engine.type {other:?}"),
        };

        let exec = parse_exec(&doc, &engine)?;
        let output = parse_output(&doc)?;

        Ok(RunConfig {
            dataset,
            seed: doc.usize_or("dataset", "seed", 42)? as u64,
            graph,
            linkage,
            engine,
            exec,
            output,
            force_scalar: doc.bool_or("engine", "force_scalar", false)?,
        })
    }

    /// The dataset's natural metric (for graph construction).
    pub fn metric(&self) -> Option<Metric> {
        match self.dataset {
            DatasetSpec::SiftLike { .. } => Some(Metric::L2),
            DatasetSpec::DocsLike { .. } => Some(Metric::Cosine),
            _ => None, // graph-native datasets
        }
    }
}

/// Parse + validate a distributed engine's `(machines, cpus)` topology.
/// Zero is rejected here with a descriptive error instead of surfacing as
/// a confusing downstream clamp or divide-by-zero.
fn parse_topology(doc: &TomlDoc, engine: &str) -> Result<(usize, usize)> {
    let machines = doc.usize_or("engine", "machines", 4)?;
    let cpus = doc.usize_or("engine", "cpus", 2)?;
    if machines == 0 {
        bail!("engine.machines must be >= 1 for {engine} (got 0; use 1 for a single-machine run)");
    }
    if cpus == 0 {
        bail!("engine.cpus must be >= 1 for {engine} (got 0)");
    }
    Ok((machines, cpus))
}

/// Parse + validate the approximate engines' `epsilon` band.
fn parse_epsilon(doc: &TomlDoc) -> Result<f64> {
    let epsilon = doc.f64_or("engine", "epsilon", 0.1)?;
    if !(epsilon >= 0.0 && epsilon.is_finite()) {
        bail!("engine.epsilon must be finite and >= 0, got {epsilon}");
    }
    Ok(epsilon)
}

/// Parse + validate `dist_approx`'s synchronisation schedule:
/// `sync_mode = "per_round"` (default) or `"batched"`, with an optional
/// `vshards` block count that only makes sense when batching.
fn parse_sync_mode(doc: &TomlDoc) -> Result<SyncMode> {
    let mode = doc.str_or("engine", "sync_mode", "per_round")?;
    match mode.as_str() {
        "per_round" => {
            if doc.get("engine", "vshards").is_some() {
                bail!(
                    "engine.vshards only applies to sync_mode = \"batched\" \
                     (per_round has no subgraph partition)"
                );
            }
            Ok(SyncMode::PerRound)
        }
        "batched" => {
            let vshards = doc.usize_or("engine", "vshards", DEFAULT_VSHARDS as usize)?;
            if vshards == 0 {
                bail!("engine.vshards must be >= 1 (got 0)");
            }
            let vshards = u32::try_from(vshards)
                .map_err(|_| anyhow!("engine.vshards must fit in u32 (got {vshards})"))?;
            Ok(SyncMode::Batched { vshards })
        }
        other => bail!(
            "unknown engine.sync_mode {other:?} (expected \"per_round\" or \"batched\")"
        ),
    }
}

/// Parse one `"machine:round"` fault point.
fn parse_fault_point(s: &str, machines: usize, key: &str) -> Result<FaultSpec> {
    let Some((machine, round)) = s.split_once(':') else {
        bail!("engine.{key} entry {s:?} must be \"machine:round\"");
    };
    let machine: usize = machine
        .trim()
        .parse()
        .map_err(|_| anyhow!("engine.{key} entry {s:?}: bad machine"))?;
    let round: usize = round
        .trim()
        .parse()
        .map_err(|_| anyhow!("engine.{key} entry {s:?}: bad round"))?;
    if machine >= machines {
        bail!(
            "engine.{key}: fault machine must be < machines \
             (got {machine} with machines = {machines})"
        );
    }
    Ok(FaultSpec { machine, round })
}

/// Parse + validate the executed-mode block: `exec_mode = "simulated"`
/// (default) or `"executed"`, with per-link latency/jitter and the fault
/// campaign / recovery knobs that only make sense when actually
/// executing: `faults = "m:r,m:r"` (plus the single-fault convenience
/// pair `fault_machine`/`fault_round`), seeded random faults
/// (`fault_rate`/`fault_seed`), `recovery_mode = "global" |
/// "shard_replay"`, and the delta-checkpoint cadence
/// `checkpoint_full_every`. Executed mode needs real shards to run on,
/// so it is rejected for the shared-memory engines with the engine name
/// in the error.
fn parse_exec(doc: &TomlDoc, engine: &EngineSpec) -> Result<Option<ExecOptions>> {
    let mode = doc.str_or("engine", "exec_mode", "simulated")?;
    let executed = match mode.as_str() {
        "simulated" => false,
        "executed" => true,
        other => bail!(
            "unknown engine.exec_mode {other:?} (expected \"simulated\" or \"executed\")"
        ),
    };
    if !executed {
        for key in [
            "link_latency_us",
            "link_jitter_us",
            "fault_machine",
            "fault_round",
            "faults",
            "fault_rate",
            "fault_seed",
            "recovery_mode",
            "checkpoint_full_every",
        ] {
            if doc.get("engine", key).is_some() {
                bail!(
                    "engine.{key} only applies to exec_mode = \"executed\" \
                     (the simulation has no physical links to fault or delay)"
                );
            }
        }
        return Ok(None);
    }
    let machines = match engine {
        EngineSpec::DistRac { machines, .. } | EngineSpec::DistApprox { machines, .. } => {
            *machines
        }
        _ => bail!(
            "exec_mode = \"executed\" requires a distributed engine \
             (dist_rac or dist_approx); shared-memory engines have no shards to execute"
        ),
    };
    let latency = Duration::from_micros(doc.usize_or("engine", "link_latency_us", 0)? as u64);
    let jitter = Duration::from_micros(doc.usize_or("engine", "link_jitter_us", 0)? as u64);
    let mut faults: Vec<FaultSpec> = Vec::new();
    let campaign = doc.str_or("engine", "faults", "")?;
    for entry in campaign.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        faults.push(parse_fault_point(entry, machines, "faults")?);
    }
    // Single-fault convenience pair, appended to the campaign.
    match (
        doc.get("engine", "fault_machine"),
        doc.get("engine", "fault_round"),
    ) {
        (None, None) => {}
        (Some(_), Some(_)) => {
            let machine = doc.usize_or("engine", "fault_machine", 0)?;
            let round = doc.usize_or("engine", "fault_round", 0)?;
            if machine >= machines {
                bail!(
                    "engine.fault_machine must be < machines \
                     (got {machine} with machines = {machines})"
                );
            }
            faults.push(FaultSpec { machine, round });
        }
        _ => bail!(
            "engine.fault_machine and engine.fault_round must be set together \
             (a fault is a (machine, round) point)"
        ),
    }
    let fault_rate = doc.f64_or("engine", "fault_rate", 0.0)?;
    if !(0.0..=1.0).contains(&fault_rate) {
        bail!("engine.fault_rate must be in [0, 1] (got {fault_rate})");
    }
    let fault_seed = doc.usize_or("engine", "fault_seed", 0)? as u64;
    let recovery_mode = match doc.str_or("engine", "recovery_mode", "global")?.as_str() {
        "global" => RecoveryMode::Global,
        "shard_replay" => RecoveryMode::ShardReplay,
        other => bail!(
            "unknown engine.recovery_mode {other:?} \
             (expected \"global\" or \"shard_replay\")"
        ),
    };
    let default_full_every = ExecOptions::default().checkpoint_full_every;
    let checkpoint_full_every =
        doc.usize_or("engine", "checkpoint_full_every", default_full_every)?;
    if checkpoint_full_every == 0 {
        bail!("engine.checkpoint_full_every must be at least 1 (every cut full)");
    }
    Ok(Some(ExecOptions {
        latency,
        jitter,
        faults,
        fault_rate,
        fault_seed,
        recovery_mode,
        checkpoint_full_every,
    }))
}

/// Parse + validate the `[output]` block: optional `trace_path` /
/// `metrics_out` / `dendrogram_path` file destinations and the
/// `trace_format` selector, which is meaningless (and therefore rejected)
/// without a trace path.
fn parse_output(doc: &TomlDoc) -> Result<OutputSpec> {
    let path_field = |key: &str| -> Result<Option<String>> {
        match doc.get("output", key) {
            None => Ok(None),
            Some(v) => {
                let s = v
                    .as_str()
                    .ok_or_else(|| anyhow!("output.{key} must be a string path"))?;
                if s.is_empty() {
                    bail!("output.{key} must not be empty");
                }
                Ok(Some(s.to_string()))
            }
        }
    };
    let trace_path = path_field("trace_path")?;
    let metrics_out = path_field("metrics_out")?;
    let dendrogram_path = path_field("dendrogram_path")?;
    let trace_format = match doc.get("output", "trace_format") {
        None => TraceFormat::default(),
        Some(v) => {
            let s = v
                .as_str()
                .ok_or_else(|| anyhow!("output.trace_format must be a string"))?;
            let format = TraceFormat::parse(s).ok_or_else(|| {
                anyhow!("unknown output.trace_format {s:?} (expected \"jsonl\" or \"chrome\")")
            })?;
            if trace_path.is_none() {
                bail!(
                    "output.trace_format only applies when output.trace_path is set \
                     (there is no trace to format)"
                );
            }
            format
        }
    };
    Ok(OutputSpec {
        trace_path,
        trace_format,
        metrics_out,
        dendrogram_path,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const EXAMPLE: &str = r#"
# SIFT200K-scale run (DESIGN.md E-Tab4 row 4)
[dataset]
type = "sift_like"
n = 20000
d = 128
clusters = 200
spread = 0.8
noise_frac = 0.02
seed = 7

[graph]
type = "knn"
k = 50
xla = true

[cluster]
linkage = "complete"

[engine]
type = "dist_rac"
machines = 8
cpus = 4
"#;

    #[test]
    fn parses_full_config() {
        let cfg = RunConfig::from_toml_str(EXAMPLE).unwrap();
        assert_eq!(
            cfg.dataset,
            DatasetSpec::SiftLike {
                n: 20000,
                d: 128,
                clusters: 200,
                spread: 0.8,
                noise_frac: 0.02
            }
        );
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.graph, GraphSpec::Knn { k: 50, xla: true });
        assert_eq!(cfg.linkage, Linkage::Complete);
        assert_eq!(
            cfg.engine,
            EngineSpec::DistRac {
                machines: 8,
                cpus: 4
            }
        );
        assert_eq!(cfg.metric(), Some(Metric::L2));
    }

    #[test]
    fn defaults_apply() {
        let cfg = RunConfig::from_toml_str("").unwrap();
        assert!(matches!(cfg.dataset, DatasetSpec::SiftLike { n: 2000, .. }));
        assert_eq!(cfg.linkage, Linkage::Average);
        assert!(matches!(cfg.engine, EngineSpec::Rac { threads: 0 }));
        assert!(!cfg.force_scalar);
    }

    #[test]
    fn force_scalar_parses() {
        let cfg =
            RunConfig::from_toml_str("[engine]\ntype = \"rac\"\nforce_scalar = true\n").unwrap();
        assert!(cfg.force_scalar);
        let cfg =
            RunConfig::from_toml_str("[engine]\ntype = \"rac\"\nforce_scalar = false\n").unwrap();
        assert!(!cfg.force_scalar);
    }

    #[test]
    fn rejects_unknown_types() {
        assert!(RunConfig::from_toml_str("[dataset]\ntype = \"mnist\"\n").is_err());
        assert!(RunConfig::from_toml_str("[engine]\ntype = \"spark\"\n").is_err());
        assert!(RunConfig::from_toml_str("[cluster]\nlinkage = \"magic\"\n").is_err());
    }

    #[test]
    fn approx_engine_parses_with_defaults_and_overrides() {
        let cfg = RunConfig::from_toml_str("[engine]\ntype = \"approx\"\n").unwrap();
        assert_eq!(
            cfg.engine,
            EngineSpec::Approx {
                epsilon: 0.1,
                threads: 0
            }
        );
        // Integer-literal epsilon must parse as a float (TOML subset
        // coerces ints in float position).
        let cfg = RunConfig::from_toml_str(
            "[engine]\ntype = \"approx\"\nepsilon = 0\nthreads = 4\n",
        )
        .unwrap();
        assert_eq!(
            cfg.engine,
            EngineSpec::Approx {
                epsilon: 0.0,
                threads: 4
            }
        );
        assert!(RunConfig::from_toml_str(
            "[engine]\ntype = \"approx\"\nepsilon = -0.5\n"
        )
        .is_err());
    }

    #[test]
    fn dist_approx_parses_with_defaults_and_overrides() {
        let cfg = RunConfig::from_toml_str("[engine]\ntype = \"dist_approx\"\n").unwrap();
        assert_eq!(
            cfg.engine,
            EngineSpec::DistApprox {
                machines: 4,
                cpus: 2,
                epsilon: 0.1,
                sync: SyncMode::PerRound
            }
        );
        // Integer-literal epsilon coerces, as for `approx`.
        let cfg = RunConfig::from_toml_str(
            "[engine]\ntype = \"dist_approx\"\nmachines = 8\ncpus = 3\nepsilon = 0\n",
        )
        .unwrap();
        assert_eq!(
            cfg.engine,
            EngineSpec::DistApprox {
                machines: 8,
                cpus: 3,
                epsilon: 0.0,
                sync: SyncMode::PerRound
            }
        );
        assert!(RunConfig::from_toml_str(
            "[engine]\ntype = \"dist_approx\"\nepsilon = -1.0\n"
        )
        .is_err());
    }

    #[test]
    fn dist_approx_sync_mode_parses_and_validates() {
        // Batched with the documented default block count.
        let cfg = RunConfig::from_toml_str(
            "[engine]\ntype = \"dist_approx\"\nsync_mode = \"batched\"\n",
        )
        .unwrap();
        assert_eq!(
            cfg.engine,
            EngineSpec::DistApprox {
                machines: 4,
                cpus: 2,
                epsilon: 0.1,
                sync: SyncMode::Batched {
                    vshards: DEFAULT_VSHARDS
                }
            }
        );
        // Explicit vshards.
        let cfg = RunConfig::from_toml_str(
            "[engine]\ntype = \"dist_approx\"\nsync_mode = \"batched\"\nvshards = 16\n",
        )
        .unwrap();
        assert_eq!(
            cfg.engine,
            EngineSpec::DistApprox {
                machines: 4,
                cpus: 2,
                epsilon: 0.1,
                sync: SyncMode::Batched { vshards: 16 }
            }
        );
        // Explicit per_round round-trips to the default.
        let cfg = RunConfig::from_toml_str(
            "[engine]\ntype = \"dist_approx\"\nsync_mode = \"per_round\"\n",
        )
        .unwrap();
        assert!(matches!(
            cfg.engine,
            EngineSpec::DistApprox {
                sync: SyncMode::PerRound,
                ..
            }
        ));
        // vshards without batching is a configuration error, named.
        let err = RunConfig::from_toml_str("[engine]\ntype = \"dist_approx\"\nvshards = 8\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("vshards") && err.contains("batched"), "{err}");
        // Zero blocks, u32 overflow, and unknown modes are rejected with
        // the field name.
        let err = RunConfig::from_toml_str(
            "[engine]\ntype = \"dist_approx\"\nsync_mode = \"batched\"\nvshards = 0\n",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("vshards"), "{err}");
        let err = RunConfig::from_toml_str(
            "[engine]\ntype = \"dist_approx\"\nsync_mode = \"batched\"\nvshards = 4294967296\n",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("vshards"), "{err}");
        let err = RunConfig::from_toml_str(
            "[engine]\ntype = \"dist_approx\"\nsync_mode = \"eventually\"\n",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("sync_mode"), "{err}");
    }

    #[test]
    fn dist_topologies_reject_zero_machines_and_cpus() {
        for engine in ["dist_rac", "dist_approx"] {
            for (key, other) in [("machines", "cpus"), ("cpus", "machines")] {
                let text =
                    format!("[engine]\ntype = \"{engine}\"\n{key} = 0\n{other} = 2\n");
                let err = RunConfig::from_toml_str(&text).unwrap_err().to_string();
                assert!(
                    err.contains(key) && err.contains(engine),
                    "{engine}/{key}: error not descriptive: {err}"
                );
            }
            // The valid minimum still parses.
            let text = format!("[engine]\ntype = \"{engine}\"\nmachines = 1\ncpus = 1\n");
            assert!(RunConfig::from_toml_str(&text).is_ok());
        }
    }

    #[test]
    fn exec_mode_defaults_to_simulated() {
        let cfg = RunConfig::from_toml_str("[engine]\ntype = \"dist_rac\"\n").unwrap();
        assert_eq!(cfg.exec, None);
        let cfg = RunConfig::from_toml_str(
            "[engine]\ntype = \"dist_approx\"\nexec_mode = \"simulated\"\n",
        )
        .unwrap();
        assert_eq!(cfg.exec, None);
    }

    #[test]
    fn exec_mode_parses_with_knobs() {
        let cfg = RunConfig::from_toml_str(
            "[engine]\ntype = \"dist_approx\"\nmachines = 3\ncpus = 2\n\
             exec_mode = \"executed\"\nlink_latency_us = 50\nlink_jitter_us = 10\n\
             fault_machine = 1\nfault_round = 3\n",
        )
        .unwrap();
        assert_eq!(
            cfg.exec,
            Some(ExecOptions {
                latency: Duration::from_micros(50),
                jitter: Duration::from_micros(10),
                faults: vec![FaultSpec {
                    machine: 1,
                    round: 3
                }],
                ..Default::default()
            })
        );
        // Bare executed mode: zero latency, zero jitter, no faults.
        let cfg = RunConfig::from_toml_str(
            "[engine]\ntype = \"dist_rac\"\nexec_mode = \"executed\"\n",
        )
        .unwrap();
        assert_eq!(cfg.exec, Some(ExecOptions::default()));
    }

    #[test]
    fn exec_mode_parses_fault_campaign_and_recovery_knobs() {
        // A faults list plus the convenience pair: the pair is appended
        // after the list, so repeated and multi-machine campaigns compose.
        let cfg = RunConfig::from_toml_str(
            "[engine]\ntype = \"dist_approx\"\nmachines = 4\ncpus = 2\n\
             exec_mode = \"executed\"\nfaults = \"0:2, 2:5, 0:2\"\n\
             fault_machine = 3\nfault_round = 1\n\
             fault_rate = 0.25\nfault_seed = 99\n\
             recovery_mode = \"shard_replay\"\ncheckpoint_full_every = 8\n",
        )
        .unwrap();
        assert_eq!(
            cfg.exec,
            Some(ExecOptions {
                faults: vec![
                    FaultSpec { machine: 0, round: 2 },
                    FaultSpec { machine: 2, round: 5 },
                    FaultSpec { machine: 0, round: 2 },
                    FaultSpec { machine: 3, round: 1 },
                ],
                fault_rate: 0.25,
                fault_seed: 99,
                recovery_mode: RecoveryMode::ShardReplay,
                checkpoint_full_every: 8,
                ..Default::default()
            })
        );
        // recovery_mode = "global" is the explicit spelling of the default.
        let cfg = RunConfig::from_toml_str(
            "[engine]\ntype = \"dist_rac\"\nexec_mode = \"executed\"\n\
             recovery_mode = \"global\"\n",
        )
        .unwrap();
        assert_eq!(cfg.exec.unwrap().recovery_mode, RecoveryMode::Global);
    }

    #[test]
    fn exec_mode_validates_fault_campaign_and_recovery_knobs() {
        let base = "[engine]\ntype = \"dist_rac\"\nmachines = 3\ncpus = 1\n\
                    exec_mode = \"executed\"\n";
        // Malformed campaign entries are named with the offending entry.
        for bad in ["faults = \"0\"", "faults = \"a:1\"", "faults = \"0:b\""] {
            let err = RunConfig::from_toml_str(&format!("{base}{bad}\n"))
                .unwrap_err()
                .to_string();
            assert!(err.contains("faults"), "{bad}: {err}");
        }
        // Campaign machines must exist in the topology.
        let err = RunConfig::from_toml_str(&format!("{base}faults = \"3:0\"\n"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("machines"), "{err}");
        // fault_rate outside [0, 1] is rejected.
        for bad in ["-0.1", "1.5"] {
            let err = RunConfig::from_toml_str(&format!("{base}fault_rate = {bad}\n"))
                .unwrap_err()
                .to_string();
            assert!(err.contains("fault_rate"), "{bad}: {err}");
        }
        // Unknown recovery modes are rejected with the field name.
        let err = RunConfig::from_toml_str(&format!("{base}recovery_mode = \"psychic\"\n"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("recovery_mode"), "{err}");
        // A zero full-checkpoint cadence would never cut a full blob.
        let err =
            RunConfig::from_toml_str(&format!("{base}checkpoint_full_every = 0\n"))
                .unwrap_err()
                .to_string();
        assert!(err.contains("checkpoint_full_every"), "{err}");
    }

    #[test]
    fn exec_mode_validates() {
        // Executed mode is a distributed-engine feature.
        for engine in ["rac", "approx", "naive_hac"] {
            let err = RunConfig::from_toml_str(&format!(
                "[engine]\ntype = \"{engine}\"\nexec_mode = \"executed\"\n"
            ))
            .unwrap_err()
            .to_string();
            assert!(err.contains("exec_mode"), "{engine}: {err}");
        }
        // Exec knobs without executed mode are configuration errors, named.
        for key in [
            "link_latency_us",
            "link_jitter_us",
            "fault_machine",
            "fault_round",
            "faults",
            "fault_rate",
            "fault_seed",
            "recovery_mode",
            "checkpoint_full_every",
        ] {
            let err = RunConfig::from_toml_str(&format!(
                "[engine]\ntype = \"dist_rac\"\n{key} = 1\n"
            ))
            .unwrap_err()
            .to_string();
            assert!(err.contains(key) && err.contains("executed"), "{key}: {err}");
        }
        // A fault is a (machine, round) point: half a fault is an error.
        for key in ["fault_machine", "fault_round"] {
            let err = RunConfig::from_toml_str(&format!(
                "[engine]\ntype = \"dist_rac\"\nexec_mode = \"executed\"\n{key} = 1\n"
            ))
            .unwrap_err()
            .to_string();
            assert!(err.contains("together"), "{key}: {err}");
        }
        // The fault target must exist in the topology.
        let err = RunConfig::from_toml_str(
            "[engine]\ntype = \"dist_rac\"\nmachines = 3\ncpus = 1\n\
             exec_mode = \"executed\"\nfault_machine = 3\nfault_round = 0\n",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("fault_machine"), "{err}");
        // Unknown modes are rejected with the field name.
        let err = RunConfig::from_toml_str(
            "[engine]\ntype = \"dist_rac\"\nexec_mode = \"real\"\n",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("exec_mode"), "{err}");
    }

    #[test]
    fn output_section_defaults_to_nothing() {
        let cfg = RunConfig::from_toml_str("").unwrap();
        assert_eq!(cfg.output, OutputSpec::default());
        assert_eq!(cfg.output.trace_path, None);
        assert_eq!(cfg.output.trace_format, TraceFormat::Jsonl);
        assert_eq!(cfg.output.metrics_out, None);
        assert_eq!(cfg.output.dendrogram_path, None);
    }

    #[test]
    fn output_section_parses_trace_and_metrics_destinations() {
        let cfg = RunConfig::from_toml_str(
            "[output]\ntrace_path = \"run.trace.jsonl\"\n\
             trace_format = \"chrome\"\nmetrics_out = \"metrics.json\"\n\
             dendrogram_path = \"run.dend\"\n",
        )
        .unwrap();
        assert_eq!(
            cfg.output,
            OutputSpec {
                trace_path: Some("run.trace.jsonl".to_string()),
                trace_format: TraceFormat::Chrome,
                metrics_out: Some("metrics.json".to_string()),
                dendrogram_path: Some("run.dend".to_string()),
            }
        );
        // The format defaults to jsonl when only a path is given.
        let cfg =
            RunConfig::from_toml_str("[output]\ntrace_path = \"t.jsonl\"\n").unwrap();
        assert_eq!(cfg.output.trace_format, TraceFormat::Jsonl);
    }

    #[test]
    fn output_section_validates() {
        // A format without a trace is a configuration error, named.
        let err = RunConfig::from_toml_str("[output]\ntrace_format = \"chrome\"\n")
            .unwrap_err()
            .to_string();
        assert!(
            err.contains("trace_format") && err.contains("trace_path"),
            "{err}"
        );
        // Unknown formats are rejected with the candidates.
        let err = RunConfig::from_toml_str(
            "[output]\ntrace_path = \"t\"\ntrace_format = \"protobuf\"\n",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("trace_format") && err.contains("chrome"), "{err}");
        // Paths must be non-empty strings.
        for bad in [
            "trace_path = \"\"",
            "metrics_out = \"\"",
            "trace_path = 3",
            "metrics_out = true",
            "dendrogram_path = \"\"",
            "dendrogram_path = 7",
        ] {
            let err = RunConfig::from_toml_str(&format!("[output]\n{bad}\n"))
                .unwrap_err()
                .to_string();
            assert!(err.contains("output."), "{bad}: {err}");
        }
    }

    #[test]
    fn theory_datasets() {
        let cfg = RunConfig::from_toml_str(
            "[dataset]\ntype = \"adversarial\"\nlevels = 6\n[graph]\ntype = \"complete\"\n",
        )
        .unwrap();
        assert_eq!(cfg.dataset, DatasetSpec::Adversarial { levels: 6 });
        assert_eq!(cfg.metric(), None);
    }
}
