//! A TOML-subset parser: `[section]`, `key = value`, `#` comments.
//! Values: basic strings, integers, floats, booleans, homogeneous scalar
//! arrays. Exactly the shape our run configs use — nothing more.

use std::collections::BTreeMap;

/// A scalar or array value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(x) => Some(*x),
            TomlValue::Int(x) => Some(*x as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed document: `sections[section][key] = value`. Keys outside any
/// `[section]` live under the empty-string section.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TomlDoc {
    pub sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, String> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let value = parse_value(value.trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            doc.sections
                .entry(section.clone())
                .or_default()
                .insert(key.trim().to_string(), value);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    pub fn str_or(&self, section: &str, key: &str, default: &str) -> Result<String, anyhow::Error> {
        match self.get(section, key) {
            None => Ok(default.to_string()),
            Some(v) => v
                .as_str()
                .map(str::to_string)
                .ok_or_else(|| anyhow::anyhow!("{section}.{key}: expected string")),
        }
    }

    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> Result<usize, anyhow::Error> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v
                .as_i64()
                .filter(|&x| x >= 0)
                .map(|x| x as usize)
                .ok_or_else(|| anyhow::anyhow!("{section}.{key}: expected non-negative integer")),
        }
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> Result<f64, anyhow::Error> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("{section}.{key}: expected number")),
        }
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> Result<bool, anyhow::Error> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v
                .as_bool()
                .ok_or_else(|| anyhow::anyhow!("{section}.{key}: expected boolean")),
        }
    }
}

/// Strip a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| format!("unterminated string: {s}"))?;
        // Basic-string escapes sufficient for config values.
        let mut out = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    other => return Err(format!("bad escape \\{other:?}")),
                }
            } else {
                out.push(c);
            }
        }
        return Ok(TomlValue::Str(out));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| format!("unterminated array: {s}"))?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in split_top_level(trimmed) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(TomlValue::Arr(items));
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.replace('_', "").parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value: {s}"))
}

/// Split a flat array body on commas (no nested arrays in our subset, but
/// strings may contain commas).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = TomlDoc::parse(
            "top = 1\n[a]\nx = \"hi\" # comment\ny = 2.5\nz = true\nn = 1_000\n[b]\nempty = []\narr = [1, 2, 3]\n",
        )
        .unwrap();
        assert_eq!(doc.get("", "top"), Some(&TomlValue::Int(1)));
        assert_eq!(doc.get("a", "x").unwrap().as_str(), Some("hi"));
        assert_eq!(doc.get("a", "y").unwrap().as_f64(), Some(2.5));
        assert_eq!(doc.get("a", "z").unwrap().as_bool(), Some(true));
        assert_eq!(doc.get("a", "n").unwrap().as_i64(), Some(1000));
        assert_eq!(doc.get("b", "empty"), Some(&TomlValue::Arr(vec![])));
        assert_eq!(
            doc.get("b", "arr"),
            Some(&TomlValue::Arr(vec![
                TomlValue::Int(1),
                TomlValue::Int(2),
                TomlValue::Int(3)
            ]))
        );
    }

    #[test]
    fn comments_respect_strings() {
        let doc = TomlDoc::parse("[s]\nv = \"a # b\"\n").unwrap();
        assert_eq!(doc.get("s", "v").unwrap().as_str(), Some("a # b"));
    }

    #[test]
    fn escapes_in_strings() {
        let doc = TomlDoc::parse("[s]\nv = \"a\\nb\\\"c\"\n").unwrap();
        assert_eq!(doc.get("s", "v").unwrap().as_str(), Some("a\nb\"c"));
    }

    #[test]
    fn error_reporting() {
        assert!(TomlDoc::parse("[oops\n").unwrap_err().contains("line 1"));
        assert!(TomlDoc::parse("[a]\nbad line\n").unwrap_err().contains("line 2"));
        assert!(TomlDoc::parse("[a]\nx = @@\n").is_err());
    }

    #[test]
    fn typed_accessors_with_defaults() {
        let doc = TomlDoc::parse("[e]\nthreads = 8\n").unwrap();
        assert_eq!(doc.usize_or("e", "threads", 1).unwrap(), 8);
        assert_eq!(doc.usize_or("e", "missing", 3).unwrap(), 3);
        assert!(doc.str_or("e", "threads", "x").is_err());
        assert_eq!(doc.f64_or("e", "threads", 0.0).unwrap(), 8.0);
    }
}
