//! Run metrics: the quantities the paper's evaluation reports.
//!
//! Per round (paper Fig 2, Fig 3d, Table 4): number of clusters, merges
//! (α = merges / clusters), nearest-neighbor updates (β = NN updates per
//! merge, Theorem 9), phase wall-times, and — in the distributed engine —
//! simulated network traffic (messages and bytes, Table 2's "network"
//! resource).

use std::time::Duration;

use crate::util::json::{obj, Json};

/// Metrics for one RAC round.
#[derive(Debug, Clone, Default)]
pub struct RoundMetrics {
    pub round: usize,
    /// Active clusters at the start of the round.
    pub clusters: usize,
    /// Reciprocal-NN pairs merged this round.
    pub merges: usize,
    /// Clusters whose cached nearest neighbor had to be recomputed.
    pub nn_updates: usize,
    /// Neighbor-map entries scanned during NN recomputation (compute cost
    /// of the "update nearest neighbors" phase).
    pub nn_scan_entries: usize,
    /// Neighbor-map entries scanned while testing merge eligibility
    /// (approximate engine only: the per-round ε-good sweep reads whole
    /// rows, where the exact engine's phase 1 is O(active) pointer
    /// checks). Zero for the exact engines.
    pub eligibility_scan_entries: usize,
    /// Wall time of the find-reciprocal-NN phase.
    pub t_find: Duration,
    /// Wall time of the merge / update-dissimilarities phase.
    pub t_merge: Duration,
    /// Wall time of the update-nearest-neighbors phase.
    pub t_update_nn: Duration,
    /// Simulated cross-shard messages (distributed engine only).
    pub net_messages: usize,
    /// Simulated cross-shard payload bytes (distributed engine only).
    pub net_bytes: usize,
    /// Simulated critical-path round time (distributed engine only):
    /// per-phase max-across-machines compute (divided by CPUs/machine for
    /// cluster-parallel phases) plus the network model's exchange cost.
    /// This is what a real fleet's wall clock would track; in-process
    /// wall clock cannot show scaling on this 1-CPU testbed (DESIGN.md §1).
    pub t_sim: Duration,
    /// Measured wall-clock round time of the *executed* distributed mode
    /// (thread-per-machine shards exchanging real channel-backed batches;
    /// [`crate::dist::exec`]) — the empirical sibling of the modeled
    /// `t_sim`. Zero for simulated runs, and `t_sim` is zero for executed
    /// runs: each mode reports the clock it actually has.
    pub t_exec: Duration,
    /// Global synchronisation barriers this round required (distributed
    /// engines only; zero for the shared-memory engines). Every
    /// bulk-synchronous round of the per-round engines is one sync point;
    /// the batched `dist_approx` engine's shard-local rounds are zero —
    /// TeraHAC's claim is that coordination scales with sync points, not
    /// merges. Counted per the *algorithm's* schedule, so it is a pure
    /// function of the run (topology-invariant), unlike `net_messages`,
    /// which is zero whenever `machines == 1`.
    pub sync_points: usize,
}

impl RoundMetrics {
    /// Fraction of clusters merged away this round (each merge removes 1).
    pub fn alpha(&self) -> f64 {
        if self.clusters == 0 {
            0.0
        } else {
            self.merges as f64 / self.clusters as f64
        }
    }

    /// NN updates per merge (the paper's β numerator; Fig 2a).
    pub fn beta(&self) -> f64 {
        if self.merges == 0 {
            0.0
        } else {
            self.nn_updates as f64 / self.merges as f64
        }
    }

    pub fn total_time(&self) -> Duration {
        self.t_find + self.t_merge + self.t_update_nn
    }

    pub fn to_json(&self) -> Json {
        obj([
            ("round", self.round.into()),
            ("clusters", self.clusters.into()),
            ("merges", self.merges.into()),
            ("nn_updates", self.nn_updates.into()),
            ("nn_scan_entries", self.nn_scan_entries.into()),
            (
                "eligibility_scan_entries",
                self.eligibility_scan_entries.into(),
            ),
            ("t_find_us", (self.t_find.as_micros() as usize).into()),
            ("t_merge_us", (self.t_merge.as_micros() as usize).into()),
            (
                "t_update_nn_us",
                (self.t_update_nn.as_micros() as usize).into(),
            ),
            ("net_messages", self.net_messages.into()),
            ("net_bytes", self.net_bytes.into()),
            ("t_sim_us", (self.t_sim.as_micros() as usize).into()),
            ("t_exec_us", (self.t_exec.as_micros() as usize).into()),
            ("sync_points", self.sync_points.into()),
        ])
    }
}

/// Aggregated metrics for a full clustering run.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub rounds: Vec<RoundMetrics>,
    /// Wall time of the whole run (excludes graph loading, matching the
    /// paper's "merge time" convention for Table 4).
    pub total_time: Duration,
    /// Machine-rounds re-executed by fault recovery (executed mode): a
    /// global rollback charges `rounds_since_cut × machines`, a shard
    /// replay charges `rounds_since_cut` — the fleet-width saving the
    /// recovery benchmark pins. Zero for unfaulted and simulated runs.
    pub recovery_rounds_replayed: usize,
    /// Bytes re-shipped by fault recovery (executed mode): discarded
    /// round traffic for a global rollback, injected journal payload for
    /// a shard replay.
    pub recovery_bytes_replayed: usize,
    /// Wall time spent inside recovery (teardown, restore, replay) —
    /// reported next to `t_exec`, never mixed into it.
    pub t_recover: Duration,
    /// Total checkpoint blob bytes cut over the run (executed mode),
    /// full blobs and deltas alike — the delta-vs-full saving the
    /// recovery benchmark pins.
    pub checkpoint_bytes: usize,
}

impl RunMetrics {
    pub fn total_merges(&self) -> usize {
        self.rounds.iter().map(|r| r.merges).sum()
    }

    /// Rounds that performed at least one merge (paper's "merge rounds").
    pub fn merge_rounds(&self) -> usize {
        self.rounds.iter().filter(|r| r.merges > 0).count()
    }

    /// Minimum per-round α over rounds with ≥ 2 clusters (Theorem 6's
    /// lower-bound diagnostic).
    pub fn min_alpha(&self) -> f64 {
        self.rounds
            .iter()
            .filter(|r| r.clusters > 1 && r.merges > 0)
            .map(|r| r.alpha())
            .fold(f64::INFINITY, f64::min)
    }

    /// Mean β across rounds with merges.
    pub fn mean_beta(&self) -> f64 {
        let rs: Vec<f64> = self
            .rounds
            .iter()
            .filter(|r| r.merges > 0)
            .map(|r| r.beta())
            .collect();
        if rs.is_empty() {
            0.0
        } else {
            rs.iter().sum::<f64>() / rs.len() as f64
        }
    }

    /// Maximum β across rounds with merges (Theorem 9's boundedness check).
    pub fn max_beta(&self) -> f64 {
        self.rounds
            .iter()
            .filter(|r| r.merges > 0)
            .map(|r| r.beta())
            .fold(0.0, f64::max)
    }

    pub fn total_net_bytes(&self) -> usize {
        self.rounds.iter().map(|r| r.net_bytes).sum()
    }

    /// Total simulated critical-path time (see [`RoundMetrics::t_sim`]).
    pub fn total_sim_time(&self) -> Duration {
        self.rounds.iter().map(|r| r.t_sim).sum()
    }

    /// Total measured executed-mode wall time (see
    /// [`RoundMetrics::t_exec`]). Zero for simulated runs.
    pub fn total_exec_time(&self) -> Duration {
        self.rounds.iter().map(|r| r.t_exec).sum()
    }

    pub fn total_net_messages(&self) -> usize {
        self.rounds.iter().map(|r| r.net_messages).sum()
    }

    /// Total global synchronisation barriers (see
    /// [`RoundMetrics::sync_points`]). For the per-round distributed
    /// engines this equals the recorded round count; the batched engine's
    /// headline is pushing it strictly below.
    pub fn total_sync_points(&self) -> usize {
        self.rounds.iter().map(|r| r.sync_points).sum()
    }

    /// (merges, merge-phase seconds) pairs — the Fig 3d scatter.
    pub fn merge_time_series(&self) -> Vec<(usize, f64)> {
        self.rounds
            .iter()
            .filter(|r| r.merges > 0)
            .map(|r| (r.merges, r.t_merge.as_secs_f64()))
            .collect()
    }

    pub fn to_json(&self) -> Json {
        obj([
            (
                "rounds",
                Json::Arr(self.rounds.iter().map(|r| r.to_json()).collect()),
            ),
            (
                "total_time_us",
                (self.total_time.as_micros() as usize).into(),
            ),
            ("total_merges", self.total_merges().into()),
            ("merge_rounds", self.merge_rounds().into()),
            ("total_net_messages", self.total_net_messages().into()),
            ("total_net_bytes", self.total_net_bytes().into()),
            ("total_sync_points", self.total_sync_points().into()),
            (
                "total_sim_time_us",
                (self.total_sim_time().as_micros() as usize).into(),
            ),
            (
                "total_exec_time_us",
                (self.total_exec_time().as_micros() as usize).into(),
            ),
            (
                "recovery_rounds_replayed",
                self.recovery_rounds_replayed.into(),
            ),
            (
                "recovery_bytes_replayed",
                self.recovery_bytes_replayed.into(),
            ),
            (
                "t_recover_us",
                (self.t_recover.as_micros() as usize).into(),
            ),
            ("checkpoint_bytes", self.checkpoint_bytes.into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round(clusters: usize, merges: usize, nn_updates: usize) -> RoundMetrics {
        RoundMetrics {
            clusters,
            merges,
            nn_updates,
            ..Default::default()
        }
    }

    #[test]
    fn alpha_beta() {
        let r = round(100, 25, 50);
        assert!((r.alpha() - 0.25).abs() < 1e-12);
        assert!((r.beta() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_round_is_safe() {
        let r = round(0, 0, 0);
        assert_eq!(r.alpha(), 0.0);
        assert_eq!(r.beta(), 0.0);
    }

    #[test]
    fn run_aggregates() {
        let run = RunMetrics {
            rounds: vec![round(100, 40, 40), round(60, 20, 10), round(40, 0, 0)],
            total_time: Duration::from_millis(5),
            ..Default::default()
        };
        assert_eq!(run.total_merges(), 60);
        assert_eq!(run.merge_rounds(), 2);
        assert!((run.min_alpha() - 1.0 / 3.0).abs() < 1e-9);
        assert!((run.mean_beta() - 0.75).abs() < 1e-9);
        assert!((run.max_beta() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn sync_points_aggregate_and_serialize() {
        let run = RunMetrics {
            rounds: vec![
                RoundMetrics {
                    sync_points: 1,
                    ..round(10, 5, 5)
                },
                RoundMetrics {
                    sync_points: 0,
                    ..round(5, 2, 2)
                },
                RoundMetrics {
                    sync_points: 1,
                    ..round(3, 1, 1)
                },
            ],
            ..Default::default()
        };
        assert_eq!(run.total_sync_points(), 2);
        let js = run.to_json().to_string();
        assert!(js.contains("\"sync_points\":1"), "{js}");
    }

    #[test]
    fn exec_time_aggregates_and_serializes() {
        let run = RunMetrics {
            rounds: vec![
                RoundMetrics {
                    t_exec: Duration::from_micros(40),
                    ..round(10, 5, 5)
                },
                RoundMetrics {
                    t_exec: Duration::from_micros(2),
                    ..round(5, 2, 2)
                },
            ],
            ..Default::default()
        };
        assert_eq!(run.total_exec_time(), Duration::from_micros(42));
        let js = run.to_json().to_string();
        assert!(js.contains("\"t_exec_us\":40"), "{js}");
    }

    #[test]
    fn serializes_to_json() {
        let run = RunMetrics {
            rounds: vec![round(10, 5, 5)],
            total_time: Duration::from_micros(123),
            ..Default::default()
        };
        let js = run.to_json().to_string();
        assert!(js.contains("\"merges\":5"), "{js}");
        assert!(js.contains("\"total_time_us\":123"), "{js}");
        // Parseable by our own reader.
        crate::util::json::Json::parse(&js).unwrap();
    }

    #[test]
    fn run_level_aggregates_serialize() {
        let run = RunMetrics {
            rounds: vec![
                RoundMetrics {
                    net_messages: 3,
                    net_bytes: 100,
                    sync_points: 1,
                    t_sim: Duration::from_micros(7),
                    t_exec: Duration::from_micros(11),
                    ..round(10, 5, 5)
                },
                RoundMetrics {
                    net_messages: 2,
                    net_bytes: 28,
                    sync_points: 1,
                    t_sim: Duration::from_micros(5),
                    t_exec: Duration::from_micros(31),
                    ..round(5, 2, 2)
                },
            ],
            ..Default::default()
        };
        let js = run.to_json().to_string();
        assert!(js.contains("\"total_net_messages\":5"), "{js}");
        assert!(js.contains("\"total_net_bytes\":128"), "{js}");
        assert!(js.contains("\"total_sync_points\":2"), "{js}");
        assert!(js.contains("\"total_sim_time_us\":12"), "{js}");
        assert!(js.contains("\"total_exec_time_us\":42"), "{js}");
        // Round-trip through our own parser and read the fields back.
        let v = crate::util::json::Json::parse(&js).unwrap();
        assert_eq!(v.get("total_net_bytes").unwrap().as_usize(), Some(128));
        assert_eq!(v.get("total_sync_points").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn recovery_metrics_serialize() {
        let run = RunMetrics {
            rounds: vec![round(10, 5, 5)],
            recovery_rounds_replayed: 6,
            recovery_bytes_replayed: 512,
            t_recover: Duration::from_micros(77),
            checkpoint_bytes: 4096,
            ..Default::default()
        };
        let js = run.to_json().to_string();
        assert!(js.contains("\"recovery_rounds_replayed\":6"), "{js}");
        assert!(js.contains("\"recovery_bytes_replayed\":512"), "{js}");
        assert!(js.contains("\"t_recover_us\":77"), "{js}");
        assert!(js.contains("\"checkpoint_bytes\":4096"), "{js}");
        crate::util::json::Json::parse(&js).unwrap();
    }
}
