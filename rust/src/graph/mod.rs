//! Sparse weighted dissimilarity graphs — the input substrate for RAC/HAC.
//!
//! The paper clusters graphs built over vector datasets (complete graphs,
//! kNN graphs, ε-ball graphs). This module provides an immutable CSR
//! representation with builders, validation, statistics, and a compact
//! binary on-disk format so the CLI pipeline (`rac generate` →
//! `rac build-graph` → `rac cluster`) can stage multi-step runs.
//!
//! Graphs are undirected: every edge is stored in both adjacency rows, and
//! [`Graph::validate`] checks symmetry. Weights are dissimilarities
//! (lower = more similar).

mod io;

pub use io::{read_graph, write_graph};

use crate::linkage::Weight;

/// Immutable undirected weighted graph in CSR form.
#[derive(Debug, Clone, PartialEq)]
pub struct Graph {
    n: usize,
    offsets: Vec<usize>,
    targets: Vec<u32>,
    weights: Vec<Weight>,
}

impl Graph {
    /// Build from an edge iterator `(u, v, w)`. Edges are symmetrised and
    /// deduplicated (last weight wins for duplicates); self-loops are
    /// rejected.
    ///
    /// # Panics
    /// If any endpoint is `>= n` or `u == v`.
    pub fn from_edges(n: usize, edges: impl IntoIterator<Item = (u32, u32, Weight)>) -> Self {
        let mut adj: Vec<Vec<(u32, Weight)>> = vec![Vec::new(); n];
        for (u, v, w) in edges {
            assert!(u != v, "self-loop {u}");
            assert!((u as usize) < n && (v as usize) < n, "endpoint out of range");
            adj[u as usize].push((v, w));
            adj[v as usize].push((u, w));
        }
        for row in &mut adj {
            row.sort_unstable_by_key(|&(v, _)| v);
            row.dedup_by_key(|&mut (v, _)| v);
        }
        Self::from_adjacency(adj)
    }

    /// Build from per-node adjacency rows (must already be symmetric and
    /// sorted; use [`Graph::from_edges`] otherwise).
    pub fn from_adjacency(adj: Vec<Vec<(u32, Weight)>>) -> Self {
        let n = adj.len();
        let mut offsets = Vec::with_capacity(n + 1);
        offsets.push(0);
        let total: usize = adj.iter().map(|r| r.len()).sum();
        let mut targets = Vec::with_capacity(total);
        let mut weights = Vec::with_capacity(total);
        for row in &adj {
            for &(v, w) in row {
                targets.push(v);
                weights.push(w);
            }
            offsets.push(targets.len());
        }
        Graph {
            n,
            offsets,
            targets,
            weights,
        }
    }

    /// Complete graph from a dense dissimilarity matrix (row-major, n×n).
    /// The diagonal is ignored.
    pub fn from_dense(n: usize, matrix: &[Weight]) -> Self {
        assert_eq!(matrix.len(), n * n);
        let mut adj: Vec<Vec<(u32, Weight)>> = vec![Vec::with_capacity(n - 1); n];
        for u in 0..n {
            for v in 0..n {
                if u != v {
                    adj[u].push((v as u32, matrix[u * n + v]));
                }
            }
        }
        Self::from_adjacency(adj)
    }

    /// Number of nodes.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of undirected edges.
    #[inline]
    pub fn m(&self) -> usize {
        self.targets.len() / 2
    }

    /// Neighbors of `u` as `(target, weight)` pairs, sorted by target id.
    #[inline]
    pub fn neighbors(&self, u: u32) -> impl Iterator<Item = (u32, Weight)> + '_ {
        let (lo, hi) = (self.offsets[u as usize], self.offsets[u as usize + 1]);
        self.targets[lo..hi]
            .iter()
            .copied()
            .zip(self.weights[lo..hi].iter().copied())
    }

    /// Degree of `u`.
    #[inline]
    pub fn degree(&self, u: u32) -> usize {
        self.offsets[u as usize + 1] - self.offsets[u as usize]
    }

    /// Weight of edge `(u, v)` if present (binary search).
    pub fn weight(&self, u: u32, v: u32) -> Option<Weight> {
        let (lo, hi) = (self.offsets[u as usize], self.offsets[u as usize + 1]);
        self.targets[lo..hi]
            .binary_search(&v)
            .ok()
            .map(|i| self.weights[lo + i])
    }

    /// Maximum degree (the paper's `k`/`d` bound, Theorem 9).
    pub fn max_degree(&self) -> usize {
        (0..self.n as u32).map(|u| self.degree(u)).max().unwrap_or(0)
    }

    /// Mean degree.
    pub fn mean_degree(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        self.targets.len() as f64 / self.n as f64
    }

    /// Number of connected components (union-find).
    pub fn components(&self) -> usize {
        let mut parent: Vec<u32> = (0..self.n as u32).collect();
        fn find(p: &mut [u32], mut x: u32) -> u32 {
            while p[x as usize] != x {
                p[x as usize] = p[p[x as usize] as usize];
                x = p[x as usize];
            }
            x
        }
        let mut comps = self.n;
        for u in 0..self.n as u32 {
            for (v, _) in self.neighbors(u) {
                let (ru, rv) = (find(&mut parent, u), find(&mut parent, v));
                if ru != rv {
                    parent[ru as usize] = rv;
                    comps -= 1;
                }
            }
        }
        comps
    }

    /// Structural validation: symmetric, sorted rows, no self-loops, finite
    /// non-negative weights. Returns a description of the first violation.
    pub fn validate(&self) -> Result<(), String> {
        for u in 0..self.n as u32 {
            let mut prev: Option<u32> = None;
            for (v, w) in self.neighbors(u) {
                if v == u {
                    return Err(format!("self-loop at {u}"));
                }
                if let Some(p) = prev {
                    if v <= p {
                        return Err(format!("row {u} not strictly sorted at {v}"));
                    }
                }
                prev = Some(v);
                if !w.is_finite() || w < 0.0 {
                    return Err(format!("bad weight {w} on ({u},{v})"));
                }
                match self.weight(v, u) {
                    Some(wr) if wr == w => {}
                    Some(wr) => return Err(format!("asymmetric weight ({u},{v}): {w} vs {wr}")),
                    None => return Err(format!("missing reverse edge ({v},{u})")),
                }
            }
        }
        Ok(())
    }

    /// Degree histogram up to `buckets` (last bucket is overflow), for the
    /// bounded-degree diagnostics in the bench harness.
    pub fn degree_histogram(&self, buckets: usize) -> Vec<usize> {
        let mut h = vec![0usize; buckets + 1];
        for u in 0..self.n as u32 {
            let d = self.degree(u);
            h[d.min(buckets)] += 1;
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Graph {
        // 0-1, 1-2, 2-3, 3-0, 0-2
        Graph::from_edges(
            4,
            [
                (0, 1, 1.0),
                (1, 2, 2.0),
                (2, 3, 3.0),
                (3, 0, 4.0),
                (0, 2, 5.0),
            ],
        )
    }

    #[test]
    fn csr_basics() {
        let g = diamond();
        assert_eq!(g.n(), 4);
        assert_eq!(g.m(), 5);
        assert_eq!(g.degree(0), 3);
        assert_eq!(g.degree(1), 2);
        let n0: Vec<_> = g.neighbors(0).collect();
        assert_eq!(n0, vec![(1, 1.0), (2, 5.0), (3, 4.0)]);
    }

    #[test]
    fn weight_lookup() {
        let g = diamond();
        assert_eq!(g.weight(2, 3), Some(3.0));
        assert_eq!(g.weight(3, 2), Some(3.0));
        assert_eq!(g.weight(1, 3), None);
    }

    #[test]
    fn duplicate_edges_dedup() {
        let g = Graph::from_edges(3, [(0, 1, 1.0), (1, 0, 1.0), (1, 2, 2.0)]);
        assert_eq!(g.m(), 2);
        assert_eq!(g.degree(1), 2);
    }

    #[test]
    #[should_panic(expected = "self-loop")]
    fn rejects_self_loop() {
        Graph::from_edges(2, [(0, 0, 1.0)]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn rejects_out_of_range() {
        Graph::from_edges(2, [(0, 5, 1.0)]);
    }

    #[test]
    fn from_dense_complete() {
        let m = vec![
            0.0, 1.0, 2.0, //
            1.0, 0.0, 3.0, //
            2.0, 3.0, 0.0,
        ];
        let g = Graph::from_dense(3, &m);
        assert_eq!(g.m(), 3);
        assert_eq!(g.weight(0, 2), Some(2.0));
        g.validate().unwrap();
    }

    #[test]
    fn validate_catches_asymmetry() {
        let g = Graph::from_adjacency(vec![vec![(1, 1.0)], vec![(0, 2.0)]]);
        assert!(g.validate().unwrap_err().contains("asymmetric"));
    }

    #[test]
    fn validate_catches_missing_reverse() {
        let g = Graph::from_adjacency(vec![vec![(1, 1.0)], vec![]]);
        assert!(g.validate().unwrap_err().contains("missing reverse"));
    }

    #[test]
    fn components_counts() {
        let g = Graph::from_edges(5, [(0, 1, 1.0), (1, 2, 1.0), (3, 4, 1.0)]);
        assert_eq!(g.components(), 2);
        assert_eq!(diamond().components(), 1);
    }

    #[test]
    fn degree_stats() {
        let g = diamond();
        assert_eq!(g.max_degree(), 3);
        assert!((g.mean_degree() - 2.5).abs() < 1e-12);
        let h = g.degree_histogram(4);
        assert_eq!(h[2], 2);
        assert_eq!(h[3], 2);
    }

    #[test]
    fn empty_graph() {
        let g = Graph::from_edges(0, []);
        assert_eq!(g.n(), 0);
        assert_eq!(g.m(), 0);
        assert_eq!(g.components(), 0);
        g.validate().unwrap();
    }
}
