//! Compact binary on-disk graph format for staging pipeline runs.
//!
//! Layout (little-endian):
//! ```text
//! magic  u64   "RACGRPH1"
//! n      u64   node count
//! nnz    u64   directed entry count (= 2m)
//! offsets[n+1] u64
//! targets[nnz] u32
//! weights[nnz] f64
//! ```
//! The loader in the paper's infrastructure streamed edges from a
//! distributed filesystem (accounting for 15–50% of total runtime); here
//! disk I/O plays the same role for the CLI pipeline and the edge-loading
//! share is reported by `rac cluster --stats`.

use std::fs::File;
use std::io::{self, BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::Graph;

const MAGIC: u64 = u64::from_le_bytes(*b"RACGRPH1");

/// Serialise a graph to `path`. Each section is staged through one bulk
/// byte buffer and written with a single `write_all` (mirroring the
/// reader's chunked path) — a per-element `write_all` costs a `BufWriter`
/// bounds check and branch per number, which dominates serialisation time
/// at bench-workload sizes.
pub fn write_graph(g: &Graph, path: &Path) -> io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(&MAGIC.to_le_bytes())?;
    w.write_all(&(g.n as u64).to_le_bytes())?;
    w.write_all(&(g.targets.len() as u64).to_le_bytes())?;
    let mut buf: Vec<u8> = Vec::with_capacity(8 * (g.offsets.len().max(g.targets.len())));
    for &o in &g.offsets {
        buf.extend_from_slice(&(o as u64).to_le_bytes());
    }
    w.write_all(&buf)?;
    buf.clear();
    for &t in &g.targets {
        buf.extend_from_slice(&t.to_le_bytes());
    }
    w.write_all(&buf)?;
    buf.clear();
    for &wt in &g.weights {
        buf.extend_from_slice(&wt.to_le_bytes());
    }
    w.write_all(&buf)?;
    w.flush()
}

fn read_u64(r: &mut impl Read) -> io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Load a graph written by [`write_graph`].
pub fn read_graph(path: &Path) -> io::Result<Graph> {
    let mut r = BufReader::new(File::open(path)?);
    if read_u64(&mut r)? != MAGIC {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad magic"));
    }
    let n = read_u64(&mut r)? as usize;
    let nnz = read_u64(&mut r)? as usize;
    let mut offsets = Vec::with_capacity(n + 1);
    for _ in 0..=n {
        offsets.push(read_u64(&mut r)? as usize);
    }
    // Full monotonicity check, not just the endpoints: every offset pair
    // is used to slice adjacency rows, so a corrupt interior offset would
    // otherwise surface later as an out-of-bounds panic (or a silently
    // wrong graph) instead of an I/O error here.
    if offsets.first() != Some(&0)
        || offsets.last() != Some(&nnz)
        || offsets.windows(2).any(|w| w[0] > w[1])
    {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad offsets"));
    }
    let mut targets = vec![0u32; nnz];
    {
        let mut buf = vec![0u8; nnz * 4];
        r.read_exact(&mut buf)?;
        for (i, c) in buf.chunks_exact(4).enumerate() {
            targets[i] = u32::from_le_bytes(c.try_into().unwrap());
        }
    }
    if targets.iter().any(|&t| t as usize >= n) {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            "target out of range",
        ));
    }
    let mut weights = vec![0f64; nnz];
    {
        let mut buf = vec![0u8; nnz * 8];
        r.read_exact(&mut buf)?;
        for (i, c) in buf.chunks_exact(8).enumerate() {
            weights[i] = f64::from_le_bytes(c.try_into().unwrap());
        }
    }
    Ok(Graph {
        n,
        offsets,
        targets,
        weights,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let g = Graph::from_edges(
            5,
            [
                (0, 1, 0.5),
                (1, 2, 1.25),
                (2, 3, 2.0),
                (3, 4, 4.0),
                (0, 4, 8.0),
            ],
        );
        let dir = std::env::temp_dir().join(format!("racgraph-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("g.bin");
        write_graph(&g, &path).unwrap();
        let g2 = read_graph(&path).unwrap();
        assert_eq!(g, g2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Handcraft a file for n=2, nnz=2 (one undirected edge) with the
    /// given offsets/targets, to exercise the corruption checks.
    fn craft(offsets: [u64; 3], targets: [u32; 2]) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(&MAGIC.to_le_bytes());
        b.extend_from_slice(&2u64.to_le_bytes()); // n
        b.extend_from_slice(&2u64.to_le_bytes()); // nnz
        for o in offsets {
            b.extend_from_slice(&o.to_le_bytes());
        }
        for t in targets {
            b.extend_from_slice(&t.to_le_bytes());
        }
        for w in [1.0f64, 1.0f64] {
            b.extend_from_slice(&w.to_le_bytes());
        }
        b
    }

    fn read_bytes(name: &str, bytes: &[u8]) -> io::Result<Graph> {
        let dir = std::env::temp_dir().join(format!("racgraph-{name}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("g.bin");
        std::fs::write(&path, bytes).unwrap();
        let r = read_graph(&path);
        std::fs::remove_dir_all(&dir).unwrap();
        r
    }

    #[test]
    fn well_formed_crafted_file_reads_back() {
        let g = read_bytes("ok", &craft([0, 1, 2], [1, 0])).unwrap();
        assert_eq!(g.n(), 2);
        assert_eq!(g.m(), 1);
        g.validate().unwrap();
    }

    #[test]
    fn rejects_non_monotone_interior_offset() {
        // Endpoints are fine (0 and nnz) but the interior offset runs
        // backwards — before this check it would slice rows out of order
        // (or panic) downstream.
        let err = read_bytes("mono", &craft([0, 3, 2], [1, 0])).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        // Interior offset beyond nnz is equally rejected (last check).
        assert!(read_bytes("over", &craft([0, 5, 2], [1, 0])).is_err());
    }

    #[test]
    fn rejects_out_of_range_target() {
        let err = read_bytes("target", &craft([0, 1, 2], [9, 0])).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("racgraph-bad-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"not a graph file at all").unwrap();
        assert!(read_graph(&path).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
