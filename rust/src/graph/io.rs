//! Compact binary on-disk graph format for staging pipeline runs.
//!
//! Layout (little-endian):
//! ```text
//! magic  u64   "RACGRPH1"
//! n      u64   node count
//! nnz    u64   directed entry count (= 2m)
//! offsets[n+1] u64
//! targets[nnz] u32
//! weights[nnz] f64
//! ```
//! The loader in the paper's infrastructure streamed edges from a
//! distributed filesystem (accounting for 15–50% of total runtime); here
//! disk I/O plays the same role for the CLI pipeline and the edge-loading
//! share is reported by `rac cluster --stats`.

use std::fs::File;
use std::io::{self, BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::Graph;

const MAGIC: u64 = u64::from_le_bytes(*b"RACGRPH1");

/// Serialise a graph to `path`.
pub fn write_graph(g: &Graph, path: &Path) -> io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(&MAGIC.to_le_bytes())?;
    w.write_all(&(g.n as u64).to_le_bytes())?;
    w.write_all(&(g.targets.len() as u64).to_le_bytes())?;
    for &o in &g.offsets {
        w.write_all(&(o as u64).to_le_bytes())?;
    }
    for &t in &g.targets {
        w.write_all(&t.to_le_bytes())?;
    }
    for &wt in &g.weights {
        w.write_all(&wt.to_le_bytes())?;
    }
    w.flush()
}

fn read_u64(r: &mut impl Read) -> io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Load a graph written by [`write_graph`].
pub fn read_graph(path: &Path) -> io::Result<Graph> {
    let mut r = BufReader::new(File::open(path)?);
    if read_u64(&mut r)? != MAGIC {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad magic"));
    }
    let n = read_u64(&mut r)? as usize;
    let nnz = read_u64(&mut r)? as usize;
    let mut offsets = Vec::with_capacity(n + 1);
    for _ in 0..=n {
        offsets.push(read_u64(&mut r)? as usize);
    }
    if offsets.first() != Some(&0) || offsets.last() != Some(&nnz) {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad offsets"));
    }
    let mut targets = vec![0u32; nnz];
    {
        let mut buf = vec![0u8; nnz * 4];
        r.read_exact(&mut buf)?;
        for (i, c) in buf.chunks_exact(4).enumerate() {
            targets[i] = u32::from_le_bytes(c.try_into().unwrap());
        }
    }
    let mut weights = vec![0f64; nnz];
    {
        let mut buf = vec![0u8; nnz * 8];
        r.read_exact(&mut buf)?;
        for (i, c) in buf.chunks_exact(8).enumerate() {
            weights[i] = f64::from_le_bytes(c.try_into().unwrap());
        }
    }
    Ok(Graph {
        n,
        offsets,
        targets,
        weights,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let g = Graph::from_edges(
            5,
            [
                (0, 1, 0.5),
                (1, 2, 1.25),
                (2, 3, 2.0),
                (3, 4, 4.0),
                (0, 4, 8.0),
            ],
        );
        let dir = std::env::temp_dir().join(format!("racgraph-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("g.bin");
        write_graph(&g, &path).unwrap();
        let g2 = read_graph(&path).unwrap();
        assert_eq!(g, g2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("racgraph-bad-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"not a graph file at all").unwrap();
        assert!(read_graph(&path).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
