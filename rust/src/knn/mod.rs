//! Dissimilarity-graph construction: kNN and ε-ball graphs over vector
//! datasets (the inputs of paper Table 3).
//!
//! Two backends produce identical graphs (tested against each other):
//!
//! * [`Backend::Xla`] — streams dataset tiles through the AOT-compiled
//!   Pallas kernels via [`crate::runtime::KernelRuntime`]. `knn` variants
//!   fuse the per-tile top-k on-device so only `(m, k)` values + indices
//!   cross the PJRT boundary; Rust k-way-merges candidates across y tiles.
//! * [`Backend::Native`] — pure-Rust brute force (exact oracle and
//!   fallback for feature dims the AOT set does not cover).
//!
//! Both paths exclude self-edges and symmetrise the union of row-wise
//! results (standard kNN-graph convention: edge `(i, j)` exists if `j` is
//! in `i`'s top-k **or** vice versa).

use anyhow::{bail, Result};

use crate::data::Dataset;
use crate::graph::Graph;
use crate::linkage::Weight;
use crate::runtime::KernelRuntime;
use crate::util::parallel::{default_threads, par_map_indexed};

/// Which compute path builds the per-row candidate lists.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// AOT XLA kernels (Pallas distance tiles + fused top-k).
    Xla,
    /// Pure-Rust brute force.
    Native,
}

/// Per-row top-k accumulator (max-heap by distance so the worst candidate
/// is evicted first), with deterministic `(weight, id)` ordering.
struct TopK {
    k: usize,
    /// `(weight, id)` max-heap via sorted insertion; k is small (≤ 128).
    items: Vec<(Weight, u32)>,
}

impl TopK {
    fn new(k: usize) -> TopK {
        TopK {
            k,
            items: Vec::with_capacity(k + 1),
        }
    }

    #[inline]
    fn push(&mut self, w: Weight, id: u32) {
        // Ordered by the crate-wide (weight, id) lex order
        // ([`crate::store::scan::nn_better`]); under it a NaN distance
        // never beats anything, so NaNs can never enter a full list.
        use crate::store::scan::nn_better;
        if self.items.len() == self.k {
            // Full: reject if not better than the current worst.
            let &(ww, wid) = self.items.last().unwrap();
            if !nn_better(w, id, ww, wid) {
                return;
            }
            self.items.pop();
        }
        let pos = self
            .items
            .partition_point(|&(pw, pid)| nn_better(pw, pid, w, id));
        self.items.insert(pos, (w, id));
    }

    fn into_sorted(self) -> Vec<(Weight, u32)> {
        self.items
    }
}

/// Build the exact kNN graph of a dataset.
pub fn knn_graph(
    ds: &Dataset,
    k: usize,
    backend: Backend,
    runtime: Option<&KernelRuntime>,
) -> Result<Graph> {
    assert!(k >= 1 && k < ds.n.max(2));
    let rows = match backend {
        Backend::Native => native_rows(ds, k),
        Backend::Xla => {
            let rt = match runtime {
                Some(rt) => rt,
                None => bail!("XLA backend requires a KernelRuntime"),
            };
            xla_rows(ds, k, rt)?
        }
    };
    Ok(symmetrize(ds.n, rows))
}

/// Build the ε-ball graph: every pair with dissimilarity < `eps`.
/// Exact (brute force over pairs), parallel over rows; row `i`'s slice is
/// hoisted out of the inner loop and the per-pair computation bails out
/// early once the partial distance reaches `eps`
/// ([`crate::data::Metric::dissimilarity_within`] — included edges are
/// bitwise identical to the full computation).
pub fn epsilon_graph(ds: &Dataset, eps: Weight) -> Graph {
    let rows: Vec<Vec<(Weight, u32)>> = par_map_indexed(default_threads(), ds.n, |i| {
        let a = ds.row(i);
        let mut out = Vec::new();
        for j in 0..ds.n {
            if i == j {
                continue;
            }
            if let Some(w) = ds.metric.dissimilarity_within(a, ds.row(j), eps) {
                out.push((w, j as u32));
            }
        }
        out
    });
    symmetrize(ds.n, rows)
}

/// Dense complete graph over the dataset (small n only).
pub fn complete_graph(ds: &Dataset) -> Graph {
    let n = ds.n;
    let mut m = vec![0.0 as Weight; n * n];
    let rows: Vec<Vec<Weight>> = par_map_indexed(default_threads(), n, |i| {
        (0..n).map(|j| ds.dissimilarity(i, j)).collect()
    });
    for (i, row) in rows.into_iter().enumerate() {
        m[i * n..(i + 1) * n].copy_from_slice(&row);
    }
    Graph::from_dense(n, &m)
}

/// Pure-Rust per-row top-k candidates.
fn native_rows(ds: &Dataset, k: usize) -> Vec<Vec<(Weight, u32)>> {
    par_map_indexed(default_threads(), ds.n, |i| {
        let mut top = TopK::new(k);
        for j in 0..ds.n {
            if i != j {
                top.push(ds.dissimilarity(i, j), j as u32);
            }
        }
        top.into_sorted()
    })
}

/// XLA per-row top-k: stream x tiles × y tiles through the AOT kernels and
/// k-way merge tile candidates per row.
fn xla_rows(ds: &Dataset, k: usize, rt: &KernelRuntime) -> Result<Vec<Vec<(Weight, u32)>>> {
    let meta = match rt.manifest().find("knn", ds.metric, ds.d) {
        Some(m) => m.clone(),
        None => bail!(
            "no knn AOT variant for metric={} d={} (available dims: {:?}); \
             use Backend::Native or add the variant to python/compile/model.py",
            ds.metric.name(),
            ds.d,
            rt.manifest().supported_dims("knn", ds.metric)
        ),
    };
    let kk = meta.k.expect("knn variant has k");
    if k > kk {
        bail!("requested k={k} exceeds AOT tile top-k {kk}");
    }
    let (tm, tn, d) = (meta.m, meta.n, meta.d);

    // Padding rows land far away for L2 (1e4 per coord) so they never enter
    // a real row's top-k before real candidates; for cosine any pad could
    // tie with real distances, so pad indices are filtered during merge
    // (they are filtered for L2 too — the far placement just keeps the
    // on-device top-k from wasting slots when n is tiny).
    let pad = |rows: &mut Vec<f32>, count: usize| {
        for c in 0..count * d {
            rows.push(1.0e4 + (c % d) as f32);
        }
    };

    let x_tiles = ds.n.div_ceil(tm);
    let y_tiles = ds.n.div_ceil(tn);
    let mut out: Vec<Vec<(Weight, u32)>> = Vec::with_capacity(ds.n);

    for xt in 0..x_tiles {
        let x_lo = xt * tm;
        let x_hi = (x_lo + tm).min(ds.n);
        let mut x_rows: Vec<f32> = ds.rows[x_lo * d..x_hi * d].to_vec();
        pad(&mut x_rows, tm - (x_hi - x_lo));

        let mut tops: Vec<TopK> = (0..x_hi - x_lo).map(|_| TopK::new(k)).collect();
        for yt in 0..y_tiles {
            let y_lo = yt * tn;
            let y_hi = (y_lo + tn).min(ds.n);
            let mut y_rows: Vec<f32> = ds.rows[y_lo * d..y_hi * d].to_vec();
            pad(&mut y_rows, tn - (y_hi - y_lo));

            let (vals, idx) = rt.knn_block(&meta, &x_rows, &y_rows)?;
            for r in 0..x_hi - x_lo {
                let gi = (x_lo + r) as u32;
                for c in 0..kk {
                    let j_local = idx[r * kk + c];
                    let j = y_lo + j_local as usize;
                    if j >= y_hi || j as u32 == gi {
                        continue; // padding or self
                    }
                    tops[r].push(vals[r * kk + c] as Weight, j as u32);
                }
            }
        }
        out.extend(tops.into_iter().map(TopK::into_sorted));
    }
    Ok(out)
}

/// Union-symmetrise per-row candidate lists into an undirected graph.
fn symmetrize(n: usize, rows: Vec<Vec<(Weight, u32)>>) -> Graph {
    let mut adj: Vec<Vec<(u32, Weight)>> = vec![Vec::new(); n];
    for (i, row) in rows.into_iter().enumerate() {
        for (w, j) in row {
            adj[i].push((j, w));
            adj[j as usize].push((i as u32, w));
        }
    }
    for row in &mut adj {
        row.sort_unstable_by(|a, b| a.0.cmp(&b.0).then(a.1.total_cmp(&b.1)));
        row.dedup_by_key(|&mut (v, _)| v);
    }
    Graph::from_adjacency(adj)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gaussian_mixture, topic_docs, Metric};

    #[test]
    fn topk_keeps_k_smallest_sorted() {
        let mut t = TopK::new(3);
        for (w, id) in [(5.0, 1), (1.0, 2), (4.0, 3), (0.5, 4), (2.0, 5)] {
            t.push(w, id);
        }
        assert_eq!(t.into_sorted(), vec![(0.5, 4), (1.0, 2), (2.0, 5)]);
    }

    #[test]
    fn topk_tie_break_by_id() {
        let mut t = TopK::new(2);
        for id in [9, 3, 7] {
            t.push(1.0, id);
        }
        assert_eq!(t.into_sorted(), vec![(1.0, 3), (1.0, 7)]);
    }

    #[test]
    fn native_knn_graph_is_valid_and_exact() {
        let ds = gaussian_mixture(60, 8, 3, 0.5, 0.0, 11);
        let g = knn_graph(&ds, 5, Backend::Native, None).unwrap();
        g.validate().unwrap();
        assert_eq!(g.n(), 60);
        // Every node has at least k neighbors (union symmetrisation).
        for u in 0..60u32 {
            assert!(g.degree(u) >= 5);
        }
        // Spot-check: node 0's rows contain its true nearest neighbor.
        let mut best = (f64::INFINITY, 0u32);
        for j in 1..60 {
            let w = ds.dissimilarity(0, j);
            if w < best.0 {
                best = (w, j as u32);
            }
        }
        assert_eq!(g.weight(0, best.1), Some(best.0));
    }

    /// Five 1-d points whose squared distances are tiny integers —
    /// the hand-checkable fixture for the symmetrize pinning tests.
    fn line5() -> Dataset {
        Dataset {
            n: 5,
            d: 1,
            metric: Metric::L2,
            rows: vec![0.0, 1.0, 3.0, 6.0, 10.0],
        }
    }

    fn adj(g: &crate::graph::Graph, u: u32) -> Vec<(u32, f64)> {
        g.neighbors(u).collect()
    }

    #[test]
    fn symmetrize_pins_sorted_dedup_rows_via_epsilon_graph() {
        // Squared gaps: (0,1)=1 (0,2)=9 (1,2)=4 (2,3)=9 are < 10; all
        // other pairs are >= 16. Every edge enters symmetrize from BOTH
        // endpoints' rows, so this also pins the dedup.
        let g = epsilon_graph(&line5(), 10.0);
        g.validate().unwrap();
        assert_eq!(adj(&g, 0), vec![(1, 1.0), (2, 9.0)]);
        assert_eq!(adj(&g, 1), vec![(0, 1.0), (2, 4.0)]);
        assert_eq!(adj(&g, 2), vec![(0, 9.0), (1, 4.0), (3, 9.0)]);
        assert_eq!(adj(&g, 3), vec![(2, 9.0)]);
        assert_eq!(adj(&g, 4), vec![]);
    }

    #[test]
    fn symmetrize_pins_knn_union_rows() {
        // 1-NN of each point: 0→1, 1→0, 2→1, 3→2, 4→3. The union
        // symmetrisation gives node 1 degree 2 despite k = 1, and the
        // reciprocal (0,1) candidate pair dedups to a single edge.
        let g = knn_graph(&line5(), 1, Backend::Native, None).unwrap();
        g.validate().unwrap();
        assert_eq!(adj(&g, 0), vec![(1, 1.0)]);
        assert_eq!(adj(&g, 1), vec![(0, 1.0), (2, 4.0)]);
        assert_eq!(adj(&g, 2), vec![(1, 4.0), (3, 9.0)]);
        assert_eq!(adj(&g, 3), vec![(2, 9.0), (4, 16.0)]);
        assert_eq!(adj(&g, 4), vec![(3, 16.0)]);
    }

    #[test]
    fn epsilon_graph_thresholds() {
        let ds = gaussian_mixture(40, 4, 2, 0.3, 0.0, 5);
        let g = epsilon_graph(&ds, 2.0);
        g.validate().unwrap();
        for u in 0..40u32 {
            for (_, w) in g.neighbors(u) {
                assert!(w < 2.0);
            }
        }
    }

    #[test]
    fn complete_graph_matches_oracle() {
        let ds = topic_docs(12, 16, 3, 2);
        let g = complete_graph(&ds);
        assert_eq!(g.m(), 12 * 11 / 2);
        assert_eq!(g.weight(3, 7), Some(ds.dissimilarity(3, 7)));
    }

    #[test]
    fn xla_backend_requires_runtime() {
        let ds = gaussian_mixture(10, 8, 2, 0.5, 0.0, 1);
        assert!(knn_graph(&ds, 3, Backend::Xla, None).is_err());
    }

    #[test]
    fn knn_of_cosine_dataset() {
        let ds = topic_docs(50, 32, 5, 3);
        assert_eq!(ds.metric, Metric::Cosine);
        let g = knn_graph(&ds, 4, Backend::Native, None).unwrap();
        g.validate().unwrap();
    }
}
