//! Structured event tracing for the clustering engines.
//!
//! A run can record a stream of [`TraceEvent`]s — span and instant
//! events with a stable, versioned schema — stamped with the engine,
//! the machine id (or [`COORD`] for coordinator/driver-level events),
//! an OS-thread tag, the round, and nanoseconds on one monotonic clock
//! shared by every participant (the sink's origin). The stream is the
//! ground truth the round-level [`crate::metrics`] aggregates summarize:
//! `trace/analyze` folds it back into per-machine phase time, barrier
//! stragglers, the wire-traffic matrix and the checkpoint/recovery
//! timeline, and asserts its totals equal the metrics counters.
//!
//! ## Threading model
//!
//! The hot path takes no lock: each participant owns a [`TraceBuf`]
//! (a plain `Vec` push; a disabled buf is a single branch), and buffers
//! are merged into the shared [`TraceSink`] once — at thread join for
//! the executed fleet's machines, at run end for the coordinator.
//! Executed-mode machine events ride the existing per-round report
//! channel (`NetStats`), so tracing adds no synchronization the engine
//! did not already have. Tracing is purely observational: it never
//! branches on or mutates algorithm state, so traced runs are bitwise
//! identical to untraced runs (pinned in `rust/tests/trace_invariance.rs`).
//!
//! ## Writers
//!
//! Two on-disk formats, selected by `trace_format`:
//! * `jsonl` — one event object per line ([`write_jsonl`]), the native
//!   format `rac trace-report` and the analyzer consume.
//! * `chrome` — Chrome trace-event JSON ([`write_chrome`]), loadable
//!   directly in Perfetto (<https://ui.perfetto.dev>) or
//!   `chrome://tracing`; each machine renders as a process, spans as
//!   slices, instants as marks. The full native event is carried in
//!   `args`, so the format round-trips losslessly.

pub mod analyze;

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::{obj, Json};

/// Sentinel machine id for coordinator/driver-level events (the
/// shared-memory engines, the simulated round loop, and the executed
/// fleet's driver thread).
pub const COORD: u32 = u32::MAX;

/// Engine names that may stamp events (the closed set lets parsed
/// events reuse `&'static str` like freshly recorded ones).
const ENGINES: [&str; 4] = ["rac", "approx", "dist_rac", "dist_approx"];

fn intern_engine(s: &str) -> Option<&'static str> {
    ENGINES.iter().find(|e| **e == s).copied()
}

/// The three phases of every bulk-synchronous round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Find,
    Merge,
    UpdateNn,
}

impl Phase {
    pub fn as_str(self) -> &'static str {
        match self {
            Phase::Find => "find",
            Phase::Merge => "merge",
            Phase::UpdateNn => "update_nn",
        }
    }

    pub fn parse(s: &str) -> Option<Phase> {
        match s {
            "find" => Some(Phase::Find),
            "merge" => Some(Phase::Merge),
            "update_nn" => Some(Phase::UpdateNn),
            _ => None,
        }
    }
}

/// Stages of executed-mode fault recovery.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecoveryStage {
    Teardown,
    Restore,
    Replay,
}

impl RecoveryStage {
    pub fn as_str(self) -> &'static str {
        match self {
            RecoveryStage::Teardown => "teardown",
            RecoveryStage::Restore => "restore",
            RecoveryStage::Replay => "replay",
        }
    }

    pub fn parse(s: &str) -> Option<RecoveryStage> {
        match s {
            "teardown" => Some(RecoveryStage::Teardown),
            "restore" => Some(RecoveryStage::Restore),
            "replay" => Some(RecoveryStage::Replay),
            _ => None,
        }
    }
}

/// What happened. Span kinds carry a duration; instant kinds are points.
///
/// | kind             | span? | payload                          | emitted by |
/// |------------------|-------|----------------------------------|------------|
/// | `run`            | yes   | —                                | every traced engine, once |
/// | `round`          | yes   | —                                | round loop / exec driver |
/// | `phase`          | yes   | `phase`                          | round loop + exec machines |
/// | `barrier_wait`   | yes   | `step`                           | exec machines (`Wire::collect`) |
/// | `wire_send`      | no    | `dst`, `step`, `msgs`, `bytes`   | exec machines (`Wire::post`); sim rounds emit one coordinator-level aggregate |
/// | `wire_recv`      | no    | `src`, `step`, `bytes`           | exec machines (`Wire::collect`) |
/// | `sync_point`     | no    | —                                | round loop / exec driver |
/// | `checkpoint_cut` | no    | `full`, `bytes`                  | exec driver |
/// | `fault`          | no    | `target`                         | exec driver |
/// | `recovery`       | mixed | `stage`, `target`, `rounds`, `bytes` | exec driver (`teardown`/`restore` spans, `replay` instants) |
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    Run,
    Round,
    Phase(Phase),
    BarrierWait {
        step: u8,
    },
    WireSend {
        dst: u32,
        step: u8,
        msgs: usize,
        bytes: usize,
    },
    WireRecv {
        src: u32,
        step: u8,
        bytes: usize,
    },
    SyncPoint,
    CheckpointCut {
        full: bool,
        bytes: usize,
    },
    Fault {
        target: u32,
    },
    Recovery {
        stage: RecoveryStage,
        target: u32,
        rounds: usize,
        bytes: usize,
    },
}

impl EventKind {
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Run => "run",
            EventKind::Round => "round",
            EventKind::Phase(_) => "phase",
            EventKind::BarrierWait { .. } => "barrier_wait",
            EventKind::WireSend { .. } => "wire_send",
            EventKind::WireRecv { .. } => "wire_recv",
            EventKind::SyncPoint => "sync_point",
            EventKind::CheckpointCut { .. } => "checkpoint_cut",
            EventKind::Fault { .. } => "fault",
            EventKind::Recovery { .. } => "recovery",
        }
    }

    /// Span kinds may carry a nonzero duration; instants must not.
    pub fn is_span(&self) -> bool {
        matches!(
            self,
            EventKind::Run
                | EventKind::Round
                | EventKind::Phase(_)
                | EventKind::BarrierWait { .. }
                | EventKind::Recovery {
                    stage: RecoveryStage::Teardown | RecoveryStage::Restore,
                    ..
                }
        )
    }

    fn payload(&self) -> Vec<(&'static str, Json)> {
        match self {
            EventKind::Run | EventKind::Round | EventKind::SyncPoint => Vec::new(),
            EventKind::Phase(p) => vec![("phase", p.as_str().into())],
            EventKind::BarrierWait { step } => vec![("step", (*step as usize).into())],
            EventKind::WireSend {
                dst,
                step,
                msgs,
                bytes,
            } => vec![
                ("dst", (*dst as usize).into()),
                ("step", (*step as usize).into()),
                ("msgs", (*msgs).into()),
                ("bytes", (*bytes).into()),
            ],
            EventKind::WireRecv { src, step, bytes } => vec![
                ("src", (*src as usize).into()),
                ("step", (*step as usize).into()),
                ("bytes", (*bytes).into()),
            ],
            EventKind::CheckpointCut { full, bytes } => {
                vec![("full", (*full).into()), ("bytes", (*bytes).into())]
            }
            EventKind::Fault { target } => vec![("target", (*target as usize).into())],
            EventKind::Recovery {
                stage,
                target,
                rounds,
                bytes,
            } => vec![
                ("stage", stage.as_str().into()),
                ("target", (*target as usize).into()),
                ("rounds", (*rounds).into()),
                ("bytes", (*bytes).into()),
            ],
        }
    }
}

/// One recorded event. Timestamps are nanoseconds since the owning
/// sink's origin — a single monotonic clock for the whole run, so
/// events from different machine threads order correctly.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub t_ns: u64,
    /// Span duration in nanoseconds; 0 for instant events.
    pub dur_ns: u64,
    pub engine: &'static str,
    /// Machine id, or [`COORD`] for coordinator-level events.
    pub machine: u32,
    /// OS-thread tag: the coordinator is 0, machine `m` is `m + 1`.
    pub thread: u32,
    pub round: u32,
    pub kind: EventKind,
}

impl TraceEvent {
    /// Display label for trace viewers (`phase.find`, `recovery.replay`).
    pub fn display_name(&self) -> String {
        match &self.kind {
            EventKind::Phase(p) => format!("phase.{}", p.as_str()),
            EventKind::Recovery { stage, .. } => format!("recovery.{}", stage.as_str()),
            k => k.name().to_string(),
        }
    }

    pub fn to_json(&self) -> Json {
        let base = vec![
            ("t_ns", (self.t_ns as usize).into()),
            ("dur_ns", (self.dur_ns as usize).into()),
            ("engine", self.engine.into()),
            ("machine", (self.machine as usize).into()),
            ("thread", (self.thread as usize).into()),
            ("round", (self.round as usize).into()),
            ("kind", self.kind.name().into()),
        ];
        obj(base.into_iter().chain(self.kind.payload()))
    }

    pub fn from_json(v: &Json) -> Result<TraceEvent, String> {
        let num = |k: &str| {
            v.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| format!("trace event missing numeric field {k:?}"))
        };
        let text = |k: &str| {
            v.get(k)
                .and_then(Json::as_str)
                .ok_or_else(|| format!("trace event missing string field {k:?}"))
        };
        let ename = text("engine")?;
        let engine =
            intern_engine(ename).ok_or_else(|| format!("unknown engine {ename:?} in trace event"))?;
        let kind = decode_kind(text("kind")?, v)?;
        let as_u32 = |k: &str| -> Result<u32, String> {
            let x = num(k)?;
            u32::try_from(x).map_err(|_| format!("trace event field {k:?} out of range: {x}"))
        };
        Ok(TraceEvent {
            t_ns: num("t_ns")? as u64,
            dur_ns: num("dur_ns")? as u64,
            engine,
            machine: as_u32("machine")?,
            thread: as_u32("thread")?,
            round: as_u32("round")?,
            kind,
        })
    }
}

fn decode_kind(name: &str, v: &Json) -> Result<EventKind, String> {
    let num = |k: &str| {
        v.get(k)
            .and_then(Json::as_usize)
            .ok_or_else(|| format!("{name} event missing numeric field {k:?}"))
    };
    let small = |k: &str| -> Result<u32, String> {
        let x = num(k)?;
        u32::try_from(x).map_err(|_| format!("{name} event field {k:?} out of range: {x}"))
    };
    match name {
        "run" => Ok(EventKind::Run),
        "round" => Ok(EventKind::Round),
        "sync_point" => Ok(EventKind::SyncPoint),
        "phase" => {
            let p = v
                .get("phase")
                .and_then(Json::as_str)
                .ok_or("phase event missing \"phase\" field")?;
            Phase::parse(p)
                .map(EventKind::Phase)
                .ok_or_else(|| format!("unknown phase {p:?}"))
        }
        "barrier_wait" => Ok(EventKind::BarrierWait {
            step: small("step")? as u8,
        }),
        "wire_send" => Ok(EventKind::WireSend {
            dst: small("dst")?,
            step: small("step")? as u8,
            msgs: num("msgs")?,
            bytes: num("bytes")?,
        }),
        "wire_recv" => Ok(EventKind::WireRecv {
            src: small("src")?,
            step: small("step")? as u8,
            bytes: num("bytes")?,
        }),
        "checkpoint_cut" => Ok(EventKind::CheckpointCut {
            full: v
                .get("full")
                .and_then(Json::as_bool)
                .ok_or("checkpoint_cut event missing boolean \"full\" field")?,
            bytes: num("bytes")?,
        }),
        "fault" => Ok(EventKind::Fault {
            target: small("target")?,
        }),
        "recovery" => {
            let s = v
                .get("stage")
                .and_then(Json::as_str)
                .ok_or("recovery event missing \"stage\" field")?;
            let stage =
                RecoveryStage::parse(s).ok_or_else(|| format!("unknown recovery stage {s:?}"))?;
            Ok(EventKind::Recovery {
                stage,
                target: small("target")?,
                rounds: num("rounds")?,
                bytes: num("bytes")?,
            })
        }
        other => Err(format!("unknown trace event kind {other:?}")),
    }
}

struct SinkInner {
    origin: Instant,
    events: Mutex<Vec<TraceEvent>>,
}

/// Shared collection point for a run's events. Clonable and cheap to
/// pass around; the disabled sink (the default) carries nothing and
/// every operation on it — and on buffers minted from it — is a no-op
/// (overhead pinned in `benches/hot_paths.rs`).
#[derive(Clone, Default)]
pub struct TraceSink {
    inner: Option<Arc<SinkInner>>,
}

impl TraceSink {
    /// A live sink whose origin is now. Create it once per run, before
    /// any participant mints a buffer, so all timestamps share a clock.
    pub fn enabled() -> TraceSink {
        TraceSink {
            inner: Some(Arc::new(SinkInner {
                origin: Instant::now(),
                events: Mutex::new(Vec::new()),
            })),
        }
    }

    pub fn disabled() -> TraceSink {
        TraceSink::default()
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Mint a thread-local buffer bound to this sink's clock.
    pub fn buf(&self, engine: &'static str, machine: u32, thread: u32) -> TraceBuf {
        TraceBuf {
            enabled: self.inner.is_some(),
            origin: self.inner.as_ref().map_or_else(Instant::now, |i| i.origin),
            engine,
            machine,
            thread,
            round: 0,
            events: Vec::new(),
        }
    }

    /// Merge a buffer's events in (one lock per merge, never per event).
    pub fn absorb(&self, buf: TraceBuf) {
        self.absorb_events(buf.events);
    }

    /// Merge a raw event batch (events shipped over report channels).
    pub fn absorb_events(&self, events: Vec<TraceEvent>) {
        if let Some(inner) = &self.inner {
            if !events.is_empty() {
                inner.events.lock().unwrap().extend(events);
            }
        }
    }

    /// Drain the collected events, ordered by timestamp.
    pub fn take(&self) -> Vec<TraceEvent> {
        match &self.inner {
            None => Vec::new(),
            Some(inner) => {
                let mut events = std::mem::take(&mut *inner.events.lock().unwrap());
                events.sort_by_key(|e| (e.t_ns, e.machine, e.thread));
                events
            }
        }
    }
}

/// A participant's private event buffer: the hot path is a branch and a
/// `Vec` push, no locks. Disabled buffers (from a disabled sink) return
/// immediately from every call.
pub struct TraceBuf {
    enabled: bool,
    origin: Instant,
    engine: &'static str,
    machine: u32,
    thread: u32,
    round: u32,
    events: Vec<TraceEvent>,
}

impl Default for TraceBuf {
    fn default() -> TraceBuf {
        TraceSink::disabled().buf("rac", COORD, 0)
    }
}

impl TraceBuf {
    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    #[inline]
    pub fn set_round(&mut self, round: usize) {
        self.round = round as u32;
    }

    /// Nanoseconds since the sink origin (0 when disabled): the start
    /// stamp for a later [`TraceBuf::span`].
    #[inline]
    pub fn now(&self) -> u64 {
        if self.enabled {
            self.origin.elapsed().as_nanos() as u64
        } else {
            0
        }
    }

    /// Record an instant event.
    #[inline]
    pub fn instant(&mut self, kind: EventKind) {
        if let Some(e) = self.make_instant(kind) {
            self.events.push(e);
        }
    }

    /// Record a span from `start_ns` (a prior [`TraceBuf::now`]) to now.
    #[inline]
    pub fn span(&mut self, start_ns: u64, kind: EventKind) {
        if let Some(e) = self.make_span(start_ns, kind) {
            self.events.push(e);
        }
    }

    /// Build an instant event without storing it (for callers that keep
    /// events in an accumulator with different rewind semantics than
    /// this buffer — the executed driver's rollback handling).
    #[inline]
    pub fn make_instant(&self, kind: EventKind) -> Option<TraceEvent> {
        if !self.enabled {
            return None;
        }
        Some(TraceEvent {
            t_ns: self.now(),
            dur_ns: 0,
            engine: self.engine,
            machine: self.machine,
            thread: self.thread,
            round: self.round,
            kind,
        })
    }

    /// Build a span event without storing it.
    #[inline]
    pub fn make_span(&self, start_ns: u64, kind: EventKind) -> Option<TraceEvent> {
        if !self.enabled {
            return None;
        }
        let end = self.now();
        Some(TraceEvent {
            t_ns: start_ns,
            dur_ns: end.saturating_sub(start_ns),
            engine: self.engine,
            machine: self.machine,
            thread: self.thread,
            round: self.round,
            kind,
        })
    }

    /// Take the buffered events (for shipping over a report channel).
    pub fn drain(&mut self) -> Vec<TraceEvent> {
        std::mem::take(&mut self.events)
    }
}

/// On-disk trace format.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TraceFormat {
    /// One event object per line; the native analyzer format.
    #[default]
    Jsonl,
    /// Chrome trace-event JSON, loadable in Perfetto.
    Chrome,
}

impl TraceFormat {
    pub fn as_str(self) -> &'static str {
        match self {
            TraceFormat::Jsonl => "jsonl",
            TraceFormat::Chrome => "chrome",
        }
    }

    pub fn parse(s: &str) -> Option<TraceFormat> {
        match s {
            "jsonl" => Some(TraceFormat::Jsonl),
            "chrome" => Some(TraceFormat::Chrome),
            _ => None,
        }
    }
}

/// Serialize in the given format.
pub fn write(events: &[TraceEvent], format: TraceFormat) -> String {
    match format {
        TraceFormat::Jsonl => write_jsonl(events),
        TraceFormat::Chrome => write_chrome(events),
    }
}

/// Native format: one event object per line.
pub fn write_jsonl(events: &[TraceEvent]) -> String {
    let mut out = String::new();
    for e in events {
        out.push_str(&e.to_json().to_string());
        out.push('\n');
    }
    out
}

pub fn parse_jsonl(text: &str) -> Result<Vec<TraceEvent>, String> {
    text.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty())
        .map(|line| TraceEvent::from_json(&Json::parse(line)?))
        .collect()
}

/// Chrome trace-event JSON. Spans become `ph:"X"` complete events,
/// instants `ph:"i"` marks; `pid` is the machine, `tid` the thread, and
/// `args` carries the full native event so the format round-trips.
pub fn write_chrome(events: &[TraceEvent]) -> String {
    let mut entries = Vec::new();
    let mut pids: Vec<u32> = events.iter().map(|e| e.machine).collect();
    pids.sort_unstable();
    pids.dedup();
    for pid in pids {
        let label = if pid == COORD {
            "coordinator".to_string()
        } else {
            format!("machine {pid}")
        };
        entries.push(obj([
            ("ph", "M".into()),
            ("name", "process_name".into()),
            ("pid", (pid as usize).into()),
            ("args", obj([("name", label.into())])),
        ]));
    }
    for e in events {
        let span = e.kind.is_span();
        let mut pairs = vec![
            ("name", e.display_name().into()),
            ("cat", e.engine.into()),
            ("ph", if span { "X" } else { "i" }.into()),
            ("ts", (e.t_ns as f64 / 1000.0).into()),
            ("pid", (e.machine as usize).into()),
            ("tid", (e.thread as usize).into()),
            ("args", e.to_json()),
        ];
        if span {
            pairs.push(("dur", (e.dur_ns as f64 / 1000.0).into()));
        } else {
            // Thread-scoped instant (renders as a mark, not a flash).
            pairs.push(("s", "t".into()));
        }
        entries.push(obj(pairs));
    }
    obj([
        ("traceEvents", Json::Arr(entries)),
        ("displayTimeUnit", "ns".into()),
    ])
    .to_string()
}

pub fn parse_chrome(text: &str) -> Result<Vec<TraceEvent>, String> {
    parse_chrome_value(&Json::parse(text)?)
}

fn parse_chrome_value(v: &Json) -> Result<Vec<TraceEvent>, String> {
    let entries = v
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or("not a Chrome trace: missing \"traceEvents\" array")?;
    let mut events = Vec::new();
    for entry in entries {
        if entry.get("ph").and_then(Json::as_str) == Some("M") {
            continue;
        }
        let args = entry
            .get("args")
            .ok_or("Chrome trace entry missing \"args\"")?;
        events.push(TraceEvent::from_json(args)?);
    }
    Ok(events)
}

/// Parse either format: a single JSON document with `traceEvents` is a
/// Chrome trace, anything else is treated as JSONL.
pub fn parse_any(text: &str) -> Result<Vec<TraceEvent>, String> {
    if let Ok(v) = Json::parse(text) {
        if v.get("traceEvents").is_some() {
            return parse_chrome_value(&v);
        }
    }
    parse_jsonl(text)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_events() -> Vec<TraceEvent> {
        let sink = TraceSink::enabled();
        let mut coord = sink.buf("dist_rac", COORD, 0);
        let run_start = coord.now();
        coord.set_round(0);
        let t = coord.now();
        coord.span(t, EventKind::Phase(Phase::Find));
        coord.instant(EventKind::SyncPoint);
        coord.instant(EventKind::CheckpointCut {
            full: true,
            bytes: 128,
        });
        coord.instant(EventKind::Fault { target: 1 });
        coord.instant(EventKind::Recovery {
            stage: RecoveryStage::Replay,
            target: 1,
            rounds: 2,
            bytes: 64,
        });
        let mut m0 = sink.buf("dist_rac", 0, 1);
        m0.set_round(0);
        m0.instant(EventKind::WireSend {
            dst: 1,
            step: 0,
            msgs: 1,
            bytes: 32,
        });
        m0.instant(EventKind::WireRecv {
            src: 1,
            step: 0,
            bytes: 16,
        });
        let t = m0.now();
        m0.span(t, EventKind::BarrierWait { step: 0 });
        sink.absorb(m0);
        let t = coord.now();
        coord.span(t, EventKind::Round);
        coord.span(run_start, EventKind::Run);
        sink.absorb(coord);
        sink.take()
    }

    #[test]
    fn disabled_sink_records_nothing() {
        let sink = TraceSink::disabled();
        assert!(!sink.is_enabled());
        let mut buf = sink.buf("rac", COORD, 0);
        assert!(!buf.is_enabled());
        assert_eq!(buf.now(), 0);
        buf.instant(EventKind::SyncPoint);
        let t = buf.now();
        buf.span(t, EventKind::Round);
        assert!(buf.make_instant(EventKind::SyncPoint).is_none());
        sink.absorb(buf);
        assert!(sink.take().is_empty());
    }

    #[test]
    fn sink_merges_and_orders_buffers() {
        let events = sample_events();
        assert_eq!(events.len(), 10);
        // Timestamp-ordered regardless of which buffer recorded what.
        for pair in events.windows(2) {
            assert!(pair[0].t_ns <= pair[1].t_ns);
        }
        // One run span covering the whole recording.
        let runs: Vec<_> = events
            .iter()
            .filter(|e| matches!(e.kind, EventKind::Run))
            .collect();
        assert_eq!(runs.len(), 1);
        // Sink is drained by take().
        // (A fresh take on the same sink would return nothing, but
        // sample_events consumed the sink; pin the schema instead.)
        assert!(events.iter().all(|e| e.engine == "dist_rac"));
    }

    #[test]
    fn jsonl_roundtrip_is_lossless() {
        let events = sample_events();
        let text = write_jsonl(&events);
        assert_eq!(text.lines().count(), events.len());
        let back = parse_jsonl(&text).unwrap();
        assert_eq!(events, back);
        let any = parse_any(&text).unwrap();
        assert_eq!(events, any);
    }

    #[test]
    fn chrome_roundtrip_is_lossless_and_parseable() {
        let events = sample_events();
        let text = write_chrome(&events);
        let doc = Json::parse(&text).unwrap();
        let entries = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // Metadata names every pid (machine 0 + coordinator).
        let meta: Vec<_> = entries
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("M"))
            .collect();
        assert_eq!(meta.len(), 2);
        let back = parse_chrome(&text).unwrap();
        assert_eq!(events, back);
        let any = parse_any(&text).unwrap();
        assert_eq!(events, any);
    }

    #[test]
    fn chrome_span_and_instant_phases() {
        let events = sample_events();
        let text = write_chrome(&events);
        let doc = Json::parse(&text).unwrap();
        for entry in doc.get("traceEvents").unwrap().as_arr().unwrap() {
            match entry.get("ph").and_then(Json::as_str) {
                Some("M") => {}
                Some("X") => assert!(entry.get("dur").is_some()),
                Some("i") => {
                    assert_eq!(entry.get("s").and_then(Json::as_str), Some("t"));
                    assert!(entry.get("dur").is_none());
                }
                other => panic!("unexpected ph {other:?}"),
            }
        }
    }

    #[test]
    fn instants_have_zero_duration_spans_measure() {
        let events = sample_events();
        for e in &events {
            if !e.kind.is_span() {
                assert_eq!(e.dur_ns, 0, "{:?}", e.kind);
            }
        }
        let run = events
            .iter()
            .find(|e| matches!(e.kind, EventKind::Run))
            .unwrap();
        // The run span covers every other event's start.
        assert!(events
            .iter()
            .all(|e| e.t_ns >= run.t_ns && e.t_ns <= run.t_ns + run.dur_ns));
    }

    #[test]
    fn rejects_malformed_events() {
        assert!(parse_jsonl("{\"kind\":\"run\"}").is_err());
        assert!(
            TraceEvent::from_json(&Json::parse(
                "{\"t_ns\":0,\"dur_ns\":0,\"engine\":\"warp\",\"machine\":0,\
                 \"thread\":0,\"round\":0,\"kind\":\"run\"}"
            )
            .unwrap())
            .is_err(),
            "unknown engine must be rejected"
        );
        assert!(
            TraceEvent::from_json(&Json::parse(
                "{\"t_ns\":0,\"dur_ns\":0,\"engine\":\"rac\",\"machine\":0,\
                 \"thread\":0,\"round\":0,\"kind\":\"quux\"}"
            )
            .unwrap())
            .is_err(),
            "unknown kind must be rejected"
        );
        assert!(parse_chrome("{\"no\":1}").is_err());
    }

    #[test]
    fn format_parse() {
        assert_eq!(TraceFormat::parse("jsonl"), Some(TraceFormat::Jsonl));
        assert_eq!(TraceFormat::parse("chrome"), Some(TraceFormat::Chrome));
        assert_eq!(TraceFormat::parse("perfetto"), None);
        assert_eq!(TraceFormat::default(), TraceFormat::Jsonl);
    }
}
