//! Fold a trace back into the numbers the paper cares about.
//!
//! [`analyze`] turns an event stream into a [`TraceReport`]: per-machine
//! per-phase time, barrier-idle and the straggler machine per sync
//! point, the wire-traffic matrix, the checkpoint/recovery timeline,
//! and per-round critical-path attribution (which machine's slowest
//! phase bounded the round). The report's totals are *defined* from the
//! same events the engines emit at their accounting sites, so they must
//! equal the [`crate::metrics::RunMetrics`] counters — `net_messages`,
//! `net_bytes`, `sync_points`, `checkpoint_bytes`, `recovery_*` — even
//! on faulted executed runs (asserted in
//! `rust/tests/trace_invariance.rs`). [`validate_events`] is the schema
//! check `rac trace-report` and `make trace-smoke` run on every event.

use std::collections::BTreeMap;

use super::{EventKind, Phase, RecoveryStage, TraceEvent, COORD};
use crate::util::json::{obj, Json};

/// Accumulated per-machine time by phase, plus what it sent.
#[derive(Debug, Clone, Default)]
pub struct MachineSummary {
    pub machine: u32,
    pub find_ns: u64,
    pub merge_ns: u64,
    pub update_nn_ns: u64,
    pub barrier_wait_ns: u64,
    pub sent_msgs: usize,
    pub sent_bytes: usize,
}

/// One barrier synchronisation: who idled, for how long, and who the
/// straggler was. Every participant waits until the last packet lands,
/// so the machine that waited *least* arrived last — the straggler.
#[derive(Debug, Clone)]
pub struct BarrierPoint {
    pub round: u32,
    pub step: u8,
    pub waiters: usize,
    pub total_wait_ns: u64,
    pub max_wait_ns: u64,
    pub straggler: u32,
}

/// The phase span that bounded a round (critical-path attribution).
#[derive(Debug, Clone)]
pub struct RoundPath {
    pub round: u32,
    pub machine: u32,
    pub phase: Phase,
    pub dur_ns: u64,
}

/// One checkpoint/fault/recovery event, in timeline order.
#[derive(Debug, Clone)]
pub struct TimelineEntry {
    pub t_ns: u64,
    pub label: String,
}

/// Everything [`analyze`] extracts from a trace.
#[derive(Debug, Clone, Default)]
pub struct TraceReport {
    pub engine: String,
    /// Duration of the `run` span.
    pub run_ns: u64,
    /// Completed rounds (count of `round` spans).
    pub rounds: usize,
    pub machines: Vec<MachineSummary>,
    pub barriers: Vec<BarrierPoint>,
    /// `(src, dst, msgs, bytes)` wire-traffic matrix from `wire_send`
    /// events, sorted by `(src, dst)`.
    pub wire: Vec<(u32, u32, usize, usize)>,
    pub critical_path: Vec<RoundPath>,
    pub timeline: Vec<TimelineEntry>,
    // Totals, defined from the same accounting sites as RunMetrics.
    pub net_messages: usize,
    pub net_bytes: usize,
    pub sync_points: usize,
    pub checkpoint_cuts: usize,
    pub checkpoint_bytes: usize,
    pub faults: usize,
    pub recovery_rounds_replayed: usize,
    pub recovery_bytes_replayed: usize,
}

/// Schema validation: every event must be well-formed on its own and
/// obey the emitter conventions (exactly one `run` span; instants carry
/// no duration; barrier/receive events come from machines, while
/// checkpoint/fault/recovery events come from the coordinator).
pub fn validate_events(events: &[TraceEvent]) -> Result<(), String> {
    if events.is_empty() {
        return Err("empty trace".into());
    }
    let runs = events
        .iter()
        .filter(|e| matches!(e.kind, EventKind::Run))
        .count();
    if runs != 1 {
        return Err(format!("expected exactly one run event, found {runs}"));
    }
    for (i, e) in events.iter().enumerate() {
        let fail = |msg: &str| Err(format!("event {i} ({}): {msg}", e.kind.name()));
        if super::intern_engine(e.engine).is_none() {
            return fail("unknown engine");
        }
        if !e.kind.is_span() && e.dur_ns != 0 {
            return fail("instant event with nonzero duration");
        }
        match e.kind {
            EventKind::BarrierWait { .. } | EventKind::WireRecv { .. } => {
                if e.machine == COORD {
                    return fail("machine-level event stamped with the coordinator id");
                }
            }
            EventKind::CheckpointCut { .. } | EventKind::Fault { .. } | EventKind::Recovery { .. } => {
                if e.machine != COORD {
                    return fail("driver-level event stamped with a machine id");
                }
            }
            EventKind::WireSend { msgs, bytes, .. } => {
                if msgs == 0 || bytes == 0 {
                    return fail("wire_send with zero traffic");
                }
            }
            _ => {}
        }
        // Our convention ties the thread tag to the machine id.
        let expect_thread = if e.machine == COORD { 0 } else { e.machine + 1 };
        if e.thread != expect_thread {
            return fail("thread tag does not match machine id convention");
        }
    }
    Ok(())
}

/// Fold an event stream into a [`TraceReport`]. The input need not be
/// sorted; the report's timeline and barrier lists come out ordered.
pub fn analyze(events: &[TraceEvent]) -> TraceReport {
    let mut r = TraceReport::default();
    let mut machines: BTreeMap<u32, MachineSummary> = BTreeMap::new();
    let mut barriers: BTreeMap<(u32, u8), Vec<(u32, u64, u64)>> = BTreeMap::new();
    let mut wire: BTreeMap<(u32, u32), (usize, usize)> = BTreeMap::new();
    let mut paths: BTreeMap<u32, RoundPath> = BTreeMap::new();
    let mut timeline: Vec<TimelineEntry> = Vec::new();
    for e in events {
        match &e.kind {
            EventKind::Run => {
                r.engine = e.engine.to_string();
                r.run_ns = r.run_ns.max(e.dur_ns);
            }
            EventKind::Round => r.rounds += 1,
            EventKind::Phase(p) => {
                let m = machines.entry(e.machine).or_default();
                m.machine = e.machine;
                match p {
                    Phase::Find => m.find_ns += e.dur_ns,
                    Phase::Merge => m.merge_ns += e.dur_ns,
                    Phase::UpdateNn => m.update_nn_ns += e.dur_ns,
                }
                let best = paths.entry(e.round).or_insert_with(|| RoundPath {
                    round: e.round,
                    machine: e.machine,
                    phase: *p,
                    dur_ns: e.dur_ns,
                });
                if e.dur_ns > best.dur_ns {
                    *best = RoundPath {
                        round: e.round,
                        machine: e.machine,
                        phase: *p,
                        dur_ns: e.dur_ns,
                    };
                }
            }
            EventKind::BarrierWait { step } => {
                let m = machines.entry(e.machine).or_default();
                m.machine = e.machine;
                m.barrier_wait_ns += e.dur_ns;
                barriers
                    .entry((e.round, *step))
                    .or_default()
                    .push((e.machine, e.dur_ns, e.t_ns));
            }
            EventKind::WireSend {
                dst, msgs, bytes, ..
            } => {
                r.net_messages += msgs;
                r.net_bytes += bytes;
                let m = machines.entry(e.machine).or_default();
                m.machine = e.machine;
                m.sent_msgs += msgs;
                m.sent_bytes += bytes;
                let cell = wire.entry((e.machine, *dst)).or_default();
                cell.0 += msgs;
                cell.1 += bytes;
            }
            EventKind::WireRecv { .. } => {}
            EventKind::SyncPoint => r.sync_points += 1,
            EventKind::CheckpointCut { full, bytes } => {
                r.checkpoint_cuts += 1;
                r.checkpoint_bytes += bytes;
                timeline.push(TimelineEntry {
                    t_ns: e.t_ns,
                    label: format!(
                        "round {}: checkpoint cut ({}, {bytes} bytes)",
                        e.round,
                        if *full { "full" } else { "delta" }
                    ),
                });
            }
            EventKind::Fault { target } => {
                r.faults += 1;
                timeline.push(TimelineEntry {
                    t_ns: e.t_ns,
                    label: format!("round {}: machine {target} down", e.round),
                });
            }
            EventKind::Recovery {
                stage,
                target,
                rounds,
                bytes,
            } => {
                if *stage == RecoveryStage::Replay {
                    r.recovery_rounds_replayed += rounds;
                    r.recovery_bytes_replayed += bytes;
                }
                let who = if *target == COORD {
                    "fleet".to_string()
                } else {
                    format!("machine {target}")
                };
                timeline.push(TimelineEntry {
                    t_ns: e.t_ns,
                    label: format!(
                        "round {}: recovery {} of {who} ({rounds} machine-rounds, {bytes} bytes)",
                        e.round,
                        stage.as_str()
                    ),
                });
            }
        }
    }
    r.machines = machines.into_values().collect();
    r.barriers = barriers
        .into_iter()
        .map(|((round, step), waits)| {
            let total: u64 = waits.iter().map(|w| w.1).sum();
            let max = waits.iter().map(|w| w.1).max().unwrap_or(0);
            // Everyone waits for the last arrival, so the shortest wait
            // marks the straggler; break ties on the latest start.
            let straggler = waits
                .iter()
                .min_by_key(|(m, dur, t)| (*dur, u64::MAX - *t, *m))
                .map(|w| w.0)
                .unwrap_or(COORD);
            BarrierPoint {
                round,
                step,
                waiters: waits.len(),
                total_wait_ns: total,
                max_wait_ns: max,
                straggler,
            }
        })
        .collect();
    r.critical_path = paths.into_values().collect();
    timeline.sort_by_key(|t| t.t_ns);
    r.timeline = timeline;
    r
}

fn ms(ns: u64) -> f64 {
    ns as f64 / 1e6
}

/// Human-readable report (`rac trace-report`).
pub fn render(r: &TraceReport) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "trace: engine {} · {:.3} ms run · {} rounds · {} sync points",
        r.engine,
        ms(r.run_ns),
        r.rounds,
        r.sync_points
    );
    let _ = writeln!(
        out,
        "wire: {} msgs / {} bytes · checkpoints: {} cuts / {} bytes · \
         faults: {} · recovery: {} machine-rounds / {} bytes replayed",
        r.net_messages,
        r.net_bytes,
        r.checkpoint_cuts,
        r.checkpoint_bytes,
        r.faults,
        r.recovery_rounds_replayed,
        r.recovery_bytes_replayed
    );
    if !r.machines.is_empty() {
        let _ = writeln!(
            out,
            "\nper-machine phase time (ms):\n  {:<12} {:>9} {:>9} {:>10} {:>13} {:>12}",
            "machine", "find", "merge", "update_nn", "barrier_idle", "sent_bytes"
        );
        for m in &r.machines {
            let name = if m.machine == COORD {
                "coordinator".to_string()
            } else {
                format!("machine {}", m.machine)
            };
            let _ = writeln!(
                out,
                "  {:<12} {:>9.3} {:>9.3} {:>10.3} {:>13.3} {:>12}",
                name,
                ms(m.find_ns),
                ms(m.merge_ns),
                ms(m.update_nn_ns),
                ms(m.barrier_wait_ns),
                m.sent_bytes
            );
        }
    }
    if !r.barriers.is_empty() {
        let idle: u64 = r.barriers.iter().map(|b| b.total_wait_ns).sum();
        let span_total: u64 = r.run_ns.max(1) * r.machines.len().max(1) as u64;
        let _ = writeln!(
            out,
            "\nbarriers: {} sync waits · {:.3} ms total idle ({:.1}% of fleet time); \
             worst stragglers:",
            r.barriers.len(),
            ms(idle),
            100.0 * idle as f64 / span_total as f64
        );
        let mut worst: Vec<&BarrierPoint> = r.barriers.iter().collect();
        worst.sort_by_key(|b| u64::MAX - b.max_wait_ns);
        for b in worst.iter().take(5) {
            let _ = writeln!(
                out,
                "  round {:>3} step {}: machine {} arrived last \
                 ({} waiting, {:.3} ms idle, max {:.3} ms)",
                b.round,
                b.step,
                b.straggler,
                b.waiters,
                ms(b.total_wait_ns),
                ms(b.max_wait_ns)
            );
        }
    }
    if !r.wire.is_empty() {
        let _ = writeln!(out, "\nwire matrix (src -> dst: msgs / bytes):");
        for (src, dst, msgs, bytes) in &r.wire {
            let s = if *src == COORD {
                "coord".to_string()
            } else {
                src.to_string()
            };
            let d = if *dst == COORD {
                "round".to_string()
            } else {
                dst.to_string()
            };
            let _ = writeln!(out, "  {s:>5} -> {d:<5}: {msgs:>6} / {bytes}");
        }
    }
    if !r.critical_path.is_empty() {
        let _ = writeln!(out, "\nper-round critical path (slowest phase span):");
        for p in &r.critical_path {
            let name = if p.machine == COORD {
                "coordinator".to_string()
            } else {
                format!("machine {}", p.machine)
            };
            let _ = writeln!(
                out,
                "  round {:>3}: {} {} {:.3} ms",
                p.round,
                name,
                p.phase.as_str(),
                ms(p.dur_ns)
            );
        }
    }
    if !r.timeline.is_empty() {
        let _ = writeln!(out, "\ncheckpoint / fault / recovery timeline:");
        for t in &r.timeline {
            let _ = writeln!(out, "  {:>12.3} ms  {}", ms(t.t_ns), t.label);
        }
    }
    out
}

/// Machine-readable report (`rac trace-report --json`).
pub fn report_json(r: &TraceReport) -> Json {
    obj([
        ("schema", "trace_report/v1".into()),
        ("engine", r.engine.clone().into()),
        ("run_ns", (r.run_ns as usize).into()),
        ("rounds", r.rounds.into()),
        ("net_messages", r.net_messages.into()),
        ("net_bytes", r.net_bytes.into()),
        ("sync_points", r.sync_points.into()),
        ("checkpoint_cuts", r.checkpoint_cuts.into()),
        ("checkpoint_bytes", r.checkpoint_bytes.into()),
        ("faults", r.faults.into()),
        (
            "recovery_rounds_replayed",
            r.recovery_rounds_replayed.into(),
        ),
        (
            "recovery_bytes_replayed",
            r.recovery_bytes_replayed.into(),
        ),
        (
            "machines",
            Json::Arr(
                r.machines
                    .iter()
                    .map(|m| {
                        obj([
                            ("machine", (m.machine as usize).into()),
                            ("find_ns", (m.find_ns as usize).into()),
                            ("merge_ns", (m.merge_ns as usize).into()),
                            ("update_nn_ns", (m.update_nn_ns as usize).into()),
                            ("barrier_wait_ns", (m.barrier_wait_ns as usize).into()),
                            ("sent_msgs", m.sent_msgs.into()),
                            ("sent_bytes", m.sent_bytes.into()),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "barriers",
            Json::Arr(
                r.barriers
                    .iter()
                    .map(|b| {
                        obj([
                            ("round", (b.round as usize).into()),
                            ("step", (b.step as usize).into()),
                            ("waiters", b.waiters.into()),
                            ("total_wait_ns", (b.total_wait_ns as usize).into()),
                            ("max_wait_ns", (b.max_wait_ns as usize).into()),
                            ("straggler", (b.straggler as usize).into()),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "wire",
            Json::Arr(
                r.wire
                    .iter()
                    .map(|(src, dst, msgs, bytes)| {
                        obj([
                            ("src", (*src as usize).into()),
                            ("dst", (*dst as usize).into()),
                            ("msgs", (*msgs).into()),
                            ("bytes", (*bytes).into()),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "critical_path",
            Json::Arr(
                r.critical_path
                    .iter()
                    .map(|p| {
                        obj([
                            ("round", (p.round as usize).into()),
                            ("machine", (p.machine as usize).into()),
                            ("phase", p.phase.as_str().into()),
                            ("dur_ns", (p.dur_ns as usize).into()),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::super::{TraceBuf, TraceSink};
    use super::*;

    fn ev(machine: u32, round: u32, dur_ns: u64, kind: EventKind) -> TraceEvent {
        TraceEvent {
            t_ns: 0,
            dur_ns,
            engine: "dist_rac",
            machine,
            thread: if machine == COORD { 0 } else { machine + 1 },
            round,
            kind,
        }
    }

    fn fleet_trace() -> Vec<TraceEvent> {
        let sink = TraceSink::enabled();
        let mut bufs: Vec<TraceBuf> = (0..2).map(|m| sink.buf("dist_rac", m, m + 1)).collect();
        let mut coord = sink.buf("dist_rac", COORD, 0);
        let run_start = coord.now();
        for round in 0..2usize {
            coord.set_round(round);
            let round_start = coord.now();
            for (m, buf) in bufs.iter_mut().enumerate() {
                buf.set_round(round);
                let t = buf.now();
                buf.span(t, EventKind::Phase(Phase::Find));
                buf.instant(EventKind::WireSend {
                    dst: (1 - m) as u32,
                    step: 0,
                    msgs: 1,
                    bytes: 100 + m,
                });
                buf.instant(EventKind::WireRecv {
                    src: (1 - m) as u32,
                    step: 0,
                    bytes: 100 + (1 - m),
                });
                let t = buf.now();
                std::thread::sleep(std::time::Duration::from_micros(50 * (m as u64 + 1)));
                buf.span(t, EventKind::BarrierWait { step: 0 });
                let t = buf.now();
                buf.span(t, EventKind::Phase(Phase::Merge));
            }
            coord.instant(EventKind::SyncPoint);
            coord.instant(EventKind::CheckpointCut {
                full: round == 0,
                bytes: 64,
            });
            coord.span(round_start, EventKind::Round);
        }
        coord.instant(EventKind::Fault { target: 1 });
        coord.instant(EventKind::Recovery {
            stage: RecoveryStage::Replay,
            target: 1,
            rounds: 3,
            bytes: 77,
        });
        coord.span(run_start, EventKind::Run);
        for buf in bufs {
            sink.absorb(buf);
        }
        sink.absorb(coord);
        sink.take()
    }

    #[test]
    fn totals_fold_from_events() {
        let events = fleet_trace();
        validate_events(&events).unwrap();
        let r = analyze(&events);
        assert_eq!(r.engine, "dist_rac");
        assert_eq!(r.rounds, 2);
        assert_eq!(r.sync_points, 2);
        assert_eq!(r.net_messages, 4);
        assert_eq!(r.net_bytes, 2 * (100 + 101));
        assert_eq!(r.checkpoint_cuts, 2);
        assert_eq!(r.checkpoint_bytes, 128);
        assert_eq!(r.faults, 1);
        assert_eq!(r.recovery_rounds_replayed, 3);
        assert_eq!(r.recovery_bytes_replayed, 77);
        assert!(r.run_ns > 0);
    }

    #[test]
    fn per_machine_and_wire_matrix() {
        let r = analyze(&fleet_trace());
        assert_eq!(r.machines.len(), 2);
        for m in &r.machines {
            assert_eq!(m.sent_msgs, 2);
            assert!(m.barrier_wait_ns > 0);
        }
        // Both directions present, aggregated across rounds.
        assert_eq!(r.wire.len(), 2);
        assert_eq!(r.wire[0], (0, 1, 2, 200));
        assert_eq!(r.wire[1], (1, 0, 2, 202));
    }

    #[test]
    fn straggler_is_shortest_wait() {
        let r = analyze(&fleet_trace());
        assert_eq!(r.barriers.len(), 2);
        for b in &r.barriers {
            assert_eq!(b.waiters, 2);
            // Machine 0 sleeps least inside its barrier span, so it is
            // the straggler by the shortest-wait rule.
            assert_eq!(b.straggler, 0);
            assert!(b.total_wait_ns >= b.max_wait_ns);
        }
    }

    #[test]
    fn critical_path_and_timeline() {
        let r = analyze(&fleet_trace());
        assert_eq!(r.critical_path.len(), 2);
        for p in &r.critical_path {
            assert!(p.dur_ns > 0 || p.machine < 2);
        }
        assert_eq!(r.timeline.len(), 4, "2 cuts + fault + replay");
        for pair in r.timeline.windows(2) {
            assert!(pair[0].t_ns <= pair[1].t_ns);
        }
    }

    #[test]
    fn render_and_json_shapes() {
        let r = analyze(&fleet_trace());
        let text = render(&r);
        assert!(text.contains("per-machine phase time"));
        assert!(text.contains("wire matrix"));
        assert!(text.contains("recovery replay of machine 1"));
        let js = report_json(&r).to_string();
        let back = Json::parse(&js).unwrap();
        assert_eq!(
            back.get("schema").and_then(Json::as_str),
            Some("trace_report/v1")
        );
        assert_eq!(back.get("net_messages").and_then(Json::as_usize), Some(4));
    }

    #[test]
    fn validate_rejects_malformed_streams() {
        assert!(validate_events(&[]).is_err(), "empty trace");
        let run = ev(COORD, 0, 10, EventKind::Run);
        assert!(
            validate_events(&[run.clone(), run.clone()]).is_err(),
            "duplicate run span"
        );
        let mut bad_instant = ev(COORD, 0, 0, EventKind::SyncPoint);
        bad_instant.dur_ns = 5;
        assert!(
            validate_events(&[run.clone(), bad_instant]).is_err(),
            "instant with duration"
        );
        let coord_barrier = ev(COORD, 0, 3, EventKind::BarrierWait { step: 0 });
        assert!(
            validate_events(&[run.clone(), coord_barrier]).is_err(),
            "coordinator barrier"
        );
        let machine_cut = ev(1, 0, 0, EventKind::CheckpointCut { full: true, bytes: 1 });
        assert!(
            validate_events(&[run.clone(), machine_cut]).is_err(),
            "machine-level checkpoint"
        );
        let empty_send = ev(
            0,
            0,
            0,
            EventKind::WireSend {
                dst: 1,
                step: 0,
                msgs: 0,
                bytes: 0,
            },
        );
        assert!(
            validate_events(&[run.clone(), empty_send]).is_err(),
            "zero-traffic send"
        );
        let mut wrong_thread = ev(0, 0, 0, EventKind::WireSend {
            dst: 1,
            step: 0,
            msgs: 1,
            bytes: 8,
        });
        wrong_thread.thread = 9;
        assert!(
            validate_events(&[run, wrong_thread]).is_err(),
            "thread convention"
        );
    }
}
