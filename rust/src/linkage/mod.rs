//! Linkage functions (paper §2, Table 1) as associative Lance–Williams
//! updates over sparse dissimilarity graphs.
//!
//! A linkage defines the dissimilarity between two *clusters* from the
//! dissimilarities of their constituents, and — crucially for both HAC and
//! RAC — an O(1) *update formula*: given `W(A,C)` and `W(B,C)`, compute
//! `W(A∪B, C)` without touching the underlying points.
//!
//! ## Sparse-graph semantics
//!
//! The paper clusters kNN graphs, so an edge may exist between `A, C` but
//! not `B, C`. We adopt the observed-pairs convention used by graph-based
//! HAC systems: update formulas combine only the *present* edges:
//!
//! * **Single**: `min` over present edges (exact: missing = +∞).
//! * **Complete**: `max` over present edges (missing edges are *skipped*,
//!   not treated as +∞ — treating them as +∞ would forbid every merge on a
//!   non-complete graph).
//! * **Average**: mean over *observed* point pairs. Each cluster edge
//!   carries the number of underlying point pairs it aggregates
//!   ([`EdgeState::count`]), so the merge `(w1·c1 + w2·c2)/(c1+c2)` is
//!   exact and associative. On complete graphs this equals the paper's
//!   `Σ W_ab / (|A||B|)` definition exactly.
//! * **WeightedAverage** (McQuitty/WPGMA): unweighted mean of the two
//!   parent dissimilarities.
//! * **Ward**: the Lance–Williams Ward update; requires the pair
//!   dissimilarity `W(A,B)` and all edges present, so it is restricted to
//!   complete graphs (validated by [`Linkage::supports_sparse`]).
//! * **Centroid**: intentionally included although **not reducible** —
//!   used by tests/benches to demonstrate where RAC's exactness guarantee
//!   (Theorem 1) breaks down.
//!
//! All merge paths are associative in the sense RAC needs: combining
//! `(A,B)→U` against `C` and `D` separately and then `(C,D)→V` against `U`
//! yields the same value as HAC's sequential order (property-tested in
//! `rust/tests/`).

/// Weight type used throughout the coordinator. `f64` so that theory
/// workloads (e.g. the Theorem-4 adversarial instance, which needs ~`3n`
/// bits of mantissa) resolve exactly at the sizes we test.
pub type Weight = f64;

/// A cluster-to-cluster dissimilarity together with the number of
/// underlying point pairs it aggregates (needed only by average linkage;
/// 1 for point-point edges).
///
/// `repr(C)` pins the field layout: `store::Entry` (also `repr(C)`) embeds
/// this struct in the flat arena rows the `store::scan` SIMD kernels read.
#[derive(Debug, Clone, Copy, PartialEq)]
#[repr(C)]
pub struct EdgeState {
    /// Current linkage value between the two clusters.
    pub weight: Weight,
    /// Number of observed underlying point pairs contributing to `weight`.
    pub count: u64,
}

impl EdgeState {
    /// A fresh point-to-point edge.
    #[inline]
    pub fn point(weight: Weight) -> Self {
        EdgeState { weight, count: 1 }
    }

    /// An aggregated edge.
    #[inline]
    pub fn new(weight: Weight, count: u64) -> Self {
        EdgeState { weight, count }
    }
}

/// Context for a Lance–Williams update `W(A∪B, C)`.
///
/// `size_*` are cluster cardinalities (numbers of points). `pair_weight`
/// is `W(A,B)` — the dissimilarity at which A and B merge — required by
/// Ward and Centroid.
#[derive(Debug, Clone, Copy)]
pub struct MergeCtx {
    pub size_a: u64,
    pub size_b: u64,
    pub size_c: u64,
    pub pair_weight: Weight,
}

/// The linkage functions of paper Table 1 (plus Ward/McQuitty/Centroid).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Linkage {
    /// `min` over point pairs (SLINK).
    Single,
    /// `max` over point pairs (CLINK).
    Complete,
    /// Mean over observed point pairs (UPGMA).
    Average,
    /// Unweighted pair-group mean (WPGMA / McQuitty).
    WeightedAverage,
    /// Ward's minimum-variance criterion on squared euclidean distances.
    Ward,
    /// Centroid linkage (UPGMC) — **not reducible**; kept to demonstrate
    /// RAC's failure mode outside Theorem 1's hypothesis.
    Centroid,
}

impl Linkage {
    /// Reducibility (paper §2): `W(A∪B, C) >= min(W(A,C), W(B,C))` for all
    /// disjoint A, B, C. Theorem 1 (RAC = HAC) holds exactly for reducible
    /// linkages.
    pub fn is_reducible(self) -> bool {
        !matches!(self, Linkage::Centroid)
    }

    /// Whether the update formula is well-defined when one of the two
    /// parent edges is absent (sparse graphs).
    ///
    /// * Ward and Centroid need both edges plus the pair weight.
    /// * WeightedAverage (WPGMA) is subtler: with an observed-edges
    ///   passthrough its value depends on the ORDER independent merges are
    ///   applied (e.g. edges AC, BC, BD: merging (A,B) before (C,D) yields
    ///   `AC/4 + BC/4 + BD/2` for `W(A∪B, C∪D)`, the other order
    ///   `AC/2 + BC/4 + BD/4`), so "exact HAC" is ill-defined on sparse
    ///   graphs and we restrict it to complete graphs, where the value
    ///   depends only on the merge tree.
    ///
    /// Single (min), Complete (max over observed) and Average
    /// (count-weighted mean) are grouping-invariant over the observed
    /// pair multiset, hence well-defined for any merge order.
    pub fn supports_sparse(self) -> bool {
        matches!(self, Linkage::Single | Linkage::Complete | Linkage::Average)
    }

    /// All linkages, for sweeps and property tests.
    pub const ALL: [Linkage; 6] = [
        Linkage::Single,
        Linkage::Complete,
        Linkage::Average,
        Linkage::WeightedAverage,
        Linkage::Ward,
        Linkage::Centroid,
    ];

    /// Reducible linkages usable on sparse graphs.
    pub const SPARSE_REDUCIBLE: [Linkage; 3] =
        [Linkage::Single, Linkage::Complete, Linkage::Average];

    /// Canonical lowercase name (used by configs and CLI flags).
    pub fn name(self) -> &'static str {
        match self {
            Linkage::Single => "single",
            Linkage::Complete => "complete",
            Linkage::Average => "average",
            Linkage::WeightedAverage => "weighted_average",
            Linkage::Ward => "ward",
            Linkage::Centroid => "centroid",
        }
    }

    /// Lance–Williams update: dissimilarity between `A ∪ B` and `C`, given
    /// the (possibly absent) parent edges `W(A,C)` and `W(B,C)`.
    ///
    /// At least one parent edge must be present; returns `None` when both
    /// are absent (no relation between the union and C — the edge simply
    /// does not exist in the output graph).
    ///
    /// # Panics
    /// Ward/Centroid panic if either parent edge is missing (they are
    /// complete-graph-only; [`supports_sparse`](Self::supports_sparse)
    /// gates this at configuration time).
    pub fn merge(
        self,
        ac: Option<EdgeState>,
        bc: Option<EdgeState>,
        ctx: MergeCtx,
    ) -> Option<EdgeState> {
        match (ac, bc) {
            (None, None) => None,
            (Some(e), None) | (None, Some(e)) => {
                assert!(
                    self.supports_sparse(),
                    "{self:?} linkage requires complete graphs (missing edge)"
                );
                // Union inherits the single observed relation unchanged:
                // min/max/mean over the same observed set.
                Some(e)
            }
            (Some(ac), Some(bc)) => Some(self.merge_both(ac, bc, ctx)),
        }
    }

    #[inline]
    fn merge_both(self, ac: EdgeState, bc: EdgeState, ctx: MergeCtx) -> EdgeState {
        let count = ac.count + bc.count;
        let w = match self {
            Linkage::Single => ac.weight.min(bc.weight),
            Linkage::Complete => ac.weight.max(bc.weight),
            Linkage::Average => {
                // Exact mean over observed pairs; associative by counts.
                (ac.weight * ac.count as Weight + bc.weight * bc.count as Weight)
                    / count as Weight
            }
            Linkage::WeightedAverage => 0.5 * (ac.weight + bc.weight),
            Linkage::Ward => {
                let (sa, sb, sc) = (
                    ctx.size_a as Weight,
                    ctx.size_b as Weight,
                    ctx.size_c as Weight,
                );
                let denom = sa + sb + sc;
                ((sa + sc) * ac.weight + (sb + sc) * bc.weight - sc * ctx.pair_weight)
                    / denom
            }
            Linkage::Centroid => {
                let (sa, sb) = (ctx.size_a as Weight, ctx.size_b as Weight);
                let s = sa + sb;
                (sa * ac.weight + sb * bc.weight) / s
                    - (sa * sb * ctx.pair_weight) / (s * s)
            }
        };
        EdgeState::new(w, count)
    }

    /// Cluster dissimilarity computed from scratch over point-pair
    /// dissimilarities (the Table-1 *definition* column). Used by tests as
    /// the from-first-principles oracle for the update formulas.
    ///
    /// `pairs` iterates the observed point-pair dissimilarities between the
    /// two clusters. Returns `None` on an empty iterator.
    pub fn from_pairs(self, pairs: impl IntoIterator<Item = Weight>) -> Option<EdgeState> {
        let mut it = pairs.into_iter();
        let first = it.next()?;
        let (mut acc, mut count) = (first, 1u64);
        for w in it {
            count += 1;
            acc = match self {
                Linkage::Single => acc.min(w),
                Linkage::Complete => acc.max(w),
                Linkage::Average => acc + w, // normalised below
                _ => panic!("from_pairs: only defined for single/complete/average"),
            };
        }
        let weight = match self {
            Linkage::Average => acc / count as Weight,
            _ => acc,
        };
        Some(EdgeState::new(weight, count))
    }
}

impl std::str::FromStr for Linkage {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "single" => Ok(Linkage::Single),
            "complete" => Ok(Linkage::Complete),
            "average" => Ok(Linkage::Average),
            "weighted_average" | "mcquitty" | "wpgma" => Ok(Linkage::WeightedAverage),
            "ward" => Ok(Linkage::Ward),
            "centroid" => Ok(Linkage::Centroid),
            other => Err(format!(
                "unknown linkage {other:?} (expected one of \
                 single|complete|average|weighted_average|ward|centroid)"
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn name_roundtrips_through_fromstr() {
        for l in Linkage::ALL {
            assert_eq!(l.name().parse::<Linkage>().unwrap(), l);
        }
        assert!("nope".parse::<Linkage>().is_err());
    }

    fn ctx(a: u64, b: u64, c: u64, pw: Weight) -> MergeCtx {
        MergeCtx {
            size_a: a,
            size_b: b,
            size_c: c,
            pair_weight: pw,
        }
    }

    #[test]
    fn single_is_min() {
        let e = Linkage::Single
            .merge(
                Some(EdgeState::point(3.0)),
                Some(EdgeState::point(1.5)),
                ctx(1, 1, 1, 0.5),
            )
            .unwrap();
        assert_eq!(e.weight, 1.5);
        assert_eq!(e.count, 2);
    }

    #[test]
    fn complete_is_max() {
        let e = Linkage::Complete
            .merge(
                Some(EdgeState::point(3.0)),
                Some(EdgeState::point(1.5)),
                ctx(1, 1, 1, 0.5),
            )
            .unwrap();
        assert_eq!(e.weight, 3.0);
    }

    #[test]
    fn average_weights_by_counts() {
        // A has 3 observed pairs at mean 2.0; B has 1 at 6.0.
        let e = Linkage::Average
            .merge(
                Some(EdgeState::new(2.0, 3)),
                Some(EdgeState::new(6.0, 1)),
                ctx(3, 1, 1, 1.0),
            )
            .unwrap();
        assert!((e.weight - 3.0).abs() < 1e-12);
        assert_eq!(e.count, 4);
    }

    #[test]
    fn weighted_average_ignores_counts() {
        let e = Linkage::WeightedAverage
            .merge(
                Some(EdgeState::new(2.0, 3)),
                Some(EdgeState::new(6.0, 1)),
                ctx(3, 1, 1, 1.0),
            )
            .unwrap();
        assert_eq!(e.weight, 4.0);
    }

    #[test]
    fn missing_edge_passthrough() {
        for l in Linkage::SPARSE_REDUCIBLE {
            let e = l
                .merge(Some(EdgeState::new(2.5, 2)), None, ctx(2, 1, 1, 1.0))
                .unwrap();
            assert_eq!(e.weight, 2.5);
            assert_eq!(e.count, 2);
        }
    }

    #[test]
    fn both_missing_is_none() {
        assert!(Linkage::Average.merge(None, None, ctx(1, 1, 1, 0.0)).is_none());
    }

    #[test]
    #[should_panic(expected = "requires complete graphs")]
    fn ward_requires_both_edges() {
        Linkage::Ward.merge(Some(EdgeState::point(1.0)), None, ctx(1, 1, 1, 0.5));
    }

    #[test]
    fn ward_matches_variance_identity() {
        // Four 1-d points: A={0}, B={2}, C={10}. Squared distances.
        // Ward distance between singletons is half... we use the LW update
        // convention on squared euclidean: d(A∪B, C) from the formula.
        let w_ac = 100.0; // (10-0)^2
        let w_bc = 64.0; // (10-2)^2
        let w_ab = 4.0; // (2-0)^2
        let e = Linkage::Ward
            .merge(
                Some(EdgeState::point(w_ac)),
                Some(EdgeState::point(w_bc)),
                ctx(1, 1, 1, w_ab),
            )
            .unwrap();
        // centroid of A∪B = 1; ward cost of merging {0,2} with {10}:
        // (|AB|*|C|/(|AB|+|C|)) * ||mu_AB - mu_C||^2 * (|AB|+|C|)/(|AB|*|C|)
        // With the LW convention the value is (2*100 + 2*64 - 1*4)/3.
        assert!((e.weight - (2.0 * 100.0 + 2.0 * 64.0 - 4.0) / 3.0).abs() < 1e-12);
    }

    #[test]
    fn centroid_matches_geometry() {
        // 1-d points A={0}, B={2}, C={5}; squared distances.
        // Centroid of A∪B is 1 → squared distance to C = 16.
        let e = Linkage::Centroid
            .merge(
                Some(EdgeState::point(25.0)),
                Some(EdgeState::point(9.0)),
                ctx(1, 1, 1, 4.0),
            )
            .unwrap();
        assert!((e.weight - 16.0).abs() < 1e-12);
    }

    #[test]
    fn reducibility_flags() {
        assert!(Linkage::Single.is_reducible());
        assert!(Linkage::Ward.is_reducible());
        assert!(!Linkage::Centroid.is_reducible());
        assert!(!Linkage::Ward.supports_sparse());
        assert!(Linkage::Average.supports_sparse());
    }

    #[test]
    fn reducibility_inequality_random() {
        // Sampled check of W(A∪B,C) >= min(W(A,C), W(B,C)) for reducible
        // linkages with consistent inputs (pair weight <= both parents,
        // which HAC/RAC guarantee when A,B are nearest neighbors).
        let mut state = 0x9e3779b97f4a7c15u64;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state >> 11) as f64 / (1u64 << 53) as f64
        };
        for _ in 0..1000 {
            let w_ac = 1.0 + next() * 9.0;
            let w_bc = 1.0 + next() * 9.0;
            let pw = next() * w_ac.min(w_bc);
            let (ca, cb) = (1 + (next() * 4.0) as u64, 1 + (next() * 4.0) as u64);
            for l in [
                Linkage::Single,
                Linkage::Complete,
                Linkage::Average,
                Linkage::WeightedAverage,
                Linkage::Ward,
            ] {
                let e = l
                    .merge(
                        Some(EdgeState::new(w_ac, ca)),
                        Some(EdgeState::new(w_bc, cb)),
                        ctx(ca, cb, 2, pw),
                    )
                    .unwrap();
                assert!(
                    e.weight >= w_ac.min(w_bc) - 1e-9,
                    "{l:?}: {} < min({w_ac}, {w_bc})",
                    e.weight
                );
            }
        }
    }

    #[test]
    fn from_pairs_matches_definitions() {
        let pairs = [3.0, 1.0, 2.0];
        assert_eq!(
            Linkage::Single.from_pairs(pairs).unwrap().weight,
            1.0
        );
        assert_eq!(
            Linkage::Complete.from_pairs(pairs).unwrap().weight,
            3.0
        );
        assert!((Linkage::Average.from_pairs(pairs).unwrap().weight - 2.0).abs() < 1e-12);
        assert!(Linkage::Single.from_pairs(std::iter::empty()).is_none());
    }

    #[test]
    fn average_update_matches_definition_on_complete_graph() {
        // Points a0,a1 in A; b0 in B; c0,c1,c2 in C with arbitrary pairwise
        // dissimilarities. Update formula must equal the from-scratch mean.
        let a_c = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 2x3 pairs
        let b_c = [10.0, 11.0, 12.0]; // 1x3 pairs
        let ac = Linkage::Average.from_pairs(a_c).unwrap();
        let bc = Linkage::Average.from_pairs(b_c).unwrap();
        let merged = Linkage::Average
            .merge(Some(ac), Some(bc), ctx(2, 1, 3, 0.0))
            .unwrap();
        let direct = Linkage::Average
            .from_pairs(a_c.iter().chain(b_c.iter()).copied())
            .unwrap();
        assert!((merged.weight - direct.weight).abs() < 1e-12);
        assert_eq!(merged.count, direct.count);
    }
}
