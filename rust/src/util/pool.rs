//! A persistent scoped thread pool (offline `rayon`-core substitute).
//!
//! The BSP engines run many short parallel phases per round; spawning OS
//! threads per phase (as `util::parallel` does) costs more than the phase
//! itself at realistic shard counts. [`Pool`] keeps `threads` workers alive
//! for the lifetime of an engine run and hands them borrowed closures.
//!
//! Safety model: [`Pool::par_map_indexed`] erases the closure's lifetime to
//! send it to the workers, then **blocks until every chunk completes**
//! before returning, so the borrowed environment strictly outlives all
//! worker access (the classic scoped-pool argument). Worker panics are
//! captured and re-raised on the caller thread.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<(VecDeque<Job>, bool /* shutdown */)>,
    cv: Condvar,
}

/// Fixed-size persistent worker pool.
pub struct Pool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
}

impl Pool {
    /// Spawn `threads` workers (min 1).
    pub fn new(threads: usize) -> Pool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new((VecDeque::new(), false)),
            cv: Condvar::new(),
        });
        let workers = (0..threads)
            .map(|_| {
                let shared = shared.clone();
                std::thread::spawn(move || loop {
                    let job = {
                        let mut guard = shared.queue.lock().unwrap();
                        loop {
                            if let Some(job) = guard.0.pop_front() {
                                break Some(job);
                            }
                            if guard.1 {
                                break None;
                            }
                            guard = shared.cv.wait(guard).unwrap();
                        }
                    };
                    match job {
                        Some(job) => job(),
                        None => return,
                    }
                })
            })
            .collect();
        Pool {
            shared,
            workers,
            threads,
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Parallel indexed map: results in index order. The closure may borrow
    /// from the caller's stack; see the module-level safety argument.
    pub fn par_map_indexed<R: Send>(&self, n: usize, f: impl Fn(usize) -> R + Sync) -> Vec<R> {
        if n == 0 {
            return Vec::new();
        }
        // Small inputs: run inline, skip dispatch overhead entirely.
        if n == 1 || self.threads == 1 {
            return (0..n).map(f).collect();
        }

        let mut out: Vec<Option<R>> = Vec::with_capacity(n);
        out.resize_with(n, || None);

        // Work-stealing over fixed-size chunks via a shared cursor.
        let chunk = n.div_ceil(self.threads * 4).max(1);
        let n_chunks = n.div_ceil(chunk);
        let cursor = AtomicUsize::new(0);
        let runners = self.threads.min(n_chunks);
        // The latch counts RUNNERS (dispatched jobs + the caller), each
        // signalling exactly once on exit: after `wait` returns no thread
        // can still touch the borrowed context below.
        let latch = Latch::new(runners + 1);

        let ctx = Ctx {
            out: out.as_mut_ptr(),
            f: &f,
            cursor: &cursor,
            latch: &latch,
            n,
            chunk,
            n_chunks,
        };
        // Type+lifetime erasure: the queued job captures only a raw
        // pointer and a monomorphic thunk (both 'static types). Workers
        // dereference `ctx` strictly before signalling the latch, and we
        // block on the latch before `ctx`/`f`/`out` leave scope.
        let ctx_erased = SendPtr(&ctx as *const Ctx<'_, R> as *mut ());
        let thunk: fn(*const ()) = run_chunks_thunk::<R>;
        {
            let mut guard = self.shared.queue.lock().unwrap();
            for _ in 0..runners {
                guard.0.push_back(Box::new(move || {
                    // Bind the wrapper whole so the Send impl applies
                    // (field-precise capture would grab the raw pointer).
                    let ptr = ctx_erased;
                    thunk(ptr.0 as *const ())
                }));
            }
        }
        self.shared.cv.notify_all();

        // The caller participates too (keeps 1-thread pools correct and
        // cuts latency on small phases).
        run_chunks(&ctx);
        latch.wait();

        out.into_iter().map(|o| o.expect("chunk filled")).collect()
    }

    /// Parallel map over a slice.
    pub fn par_map<T: Sync, R: Send>(&self, items: &[T], f: impl Fn(&T) -> R + Sync) -> Vec<R> {
        self.par_map_indexed(items.len(), |i| f(&items[i]))
    }

    /// Parallel filter-map over `0..n`, order preserved.
    pub fn par_filter_map_indexed<R: Send>(
        &self,
        n: usize,
        f: impl Fn(usize) -> Option<R> + Sync,
    ) -> Vec<R> {
        self.par_map_indexed(n, f).into_iter().flatten().collect()
    }
}

/// Parallel-map context handed to workers through a type-erased pointer.
/// Validity is enforced by the latch protocol in `par_map_indexed`.
struct Ctx<'a, R> {
    out: *mut Option<R>,
    f: &'a (dyn Fn(usize) -> R + Sync + 'a),
    cursor: &'a AtomicUsize,
    latch: &'a Latch,
    n: usize,
    chunk: usize,
    n_chunks: usize,
}

fn run_chunks_thunk<R: Send>(p: *const ()) {
    // SAFETY: `p` was produced from a live `Ctx<R>` whose owner blocks on
    // the latch until this call signals completion; the reference created
    // here does not escape the call.
    run_chunks(unsafe { &*(p as *const Ctx<'_, R>) })
}

/// The chunk loop shared by workers and the caller thread. Signals the
/// latch exactly once, on exit.
fn run_chunks<R: Send>(ctx: &Ctx<'_, R>) {
    let (f, cursor, latch) = (ctx.f, ctx.cursor, ctx.latch);
    let mut panicked = false;
    loop {
        let c = cursor.fetch_add(1, Ordering::Relaxed);
        if c >= ctx.n_chunks {
            break;
        }
        let lo = c * ctx.chunk;
        let hi = (lo + ctx.chunk).min(ctx.n);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            for i in lo..hi {
                // SAFETY: each index is written by exactly one chunk owner.
                unsafe { ctx.out.add(i).write(Some(f(i))) };
            }
        }));
        panicked |= result.is_err();
    }
    latch.done(panicked);
}

/// Countdown latch with panic flag.
struct Latch {
    state: Mutex<(usize, bool)>,
    cv: Condvar,
}

impl Latch {
    fn new(count: usize) -> Latch {
        Latch {
            state: Mutex::new((count, false)),
            cv: Condvar::new(),
        }
    }

    fn done(&self, panicked: bool) {
        let mut guard = self.state.lock().unwrap();
        guard.0 -= 1;
        guard.1 |= panicked;
        if guard.0 == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut guard = self.state.lock().unwrap();
        while guard.0 > 0 {
            guard = self.cv.wait(guard).unwrap();
        }
        if guard.1 {
            panic!("pool worker panicked");
        }
    }
}

/// Raw pointer wrapper that asserts cross-thread sendability for
/// disjoint-write patterns: the holder must guarantee that concurrent
/// users never touch the same element (as `par_map_indexed` does with
/// per-chunk output slots, and `store::NeighborStore::par_apply_round`
/// does with owner-sharded rows).
pub(crate) struct SendPtr<T>(pub(crate) *mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        SendPtr(self.0)
    }
}
impl<T> Copy for SendPtr<T> {}

impl Drop for Pool {
    fn drop(&mut self) {
        self.shared.queue.lock().unwrap().1 = true;
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_matches_sequential() {
        let pool = Pool::new(4);
        for n in [0usize, 1, 7, 100, 1000] {
            let got = pool.par_map_indexed(n, |i| i * 3);
            assert_eq!(got, (0..n).map(|i| i * 3).collect::<Vec<_>>());
        }
    }

    #[test]
    fn borrows_environment() {
        let pool = Pool::new(3);
        let data: Vec<u64> = (0..500).collect();
        let sum: u64 = pool.par_map_indexed(500, |i| data[i] * 2).iter().sum();
        assert_eq!(sum, 2 * (499 * 500 / 2));
    }

    #[test]
    fn reusable_across_many_phases() {
        let pool = Pool::new(4);
        for phase in 0..200 {
            let v = pool.par_map_indexed(37, |i| i + phase);
            assert_eq!(v[0], phase);
        }
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = Pool::new(1);
        let v = pool.par_map_indexed(10, |i| i);
        assert_eq!(v.len(), 10);
    }

    #[test]
    fn filter_map_preserves_order() {
        let pool = Pool::new(4);
        let v = pool.par_filter_map_indexed(100, |i| (i % 7 == 0).then_some(i));
        assert_eq!(v, (0..100).filter(|i| i % 7 == 0).collect::<Vec<_>>());
    }

    #[test]
    fn propagates_panics() {
        let pool = Pool::new(2);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.par_map_indexed(64, |i| {
                if i == 33 {
                    panic!("boom");
                }
                i
            })
        }));
        assert!(result.is_err());
        // Pool still usable afterwards.
        assert_eq!(pool.par_map_indexed(3, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn panicking_phases_leave_pool_reusable_at_all_thread_counts() {
        // The latch protocol must count down even when every chunk
        // panics; a missed `done` would leave `wait` blocked forever and
        // deadlock the *next* phase. Stress it across the inline path
        // (threads=1), the minimal dispatch path (2), and a wide pool
        // (8), with panics landing in different chunks each phase.
        for threads in [1usize, 2, 8] {
            let pool = Pool::new(threads);
            for phase in 0..25 {
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    pool.par_map_indexed(64, |i| {
                        if i % 8 == phase % 8 {
                            panic!("boom in phase {phase}");
                        }
                        i
                    })
                }));
                assert!(result.is_err(), "threads={threads} phase={phase}");
                // The very next phase must run to completion on the same
                // workers — no deadlocked latch, no dead threads.
                let v = pool.par_map_indexed(16, |i| i * 2);
                assert_eq!(
                    v,
                    (0..16).map(|i| i * 2).collect::<Vec<_>>(),
                    "pool unusable after panic (threads={threads} phase={phase})"
                );
            }
        }
    }

    #[test]
    fn uses_multiple_threads() {
        let pool = Pool::new(4);
        let ids = pool.par_map_indexed(16, |_| {
            std::thread::sleep(std::time::Duration::from_millis(10));
            std::thread::current().id()
        });
        let distinct: std::collections::HashSet<_> = ids.into_iter().collect();
        assert!(distinct.len() > 1);
    }
}
