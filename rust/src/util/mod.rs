//! From-scratch substrate utilities.
//!
//! This environment builds fully offline against a small vendored crate
//! set (see `.cargo/config.toml`), so the usual ecosystem crates (rand,
//! rayon, serde, clap, criterion, proptest) are unavailable. Everything
//! they would have provided is implemented here from first principles:
//!
//! * [`rng`] — xoshiro256++ PRNG with normal / zipf / gamma / dirichlet
//!   samplers (replaces `rand` + `rand_distr`).
//! * [`parallel`] — deterministic scoped-thread fork/join helpers
//!   (replaces `rayon` for the coordinator's data-parallel phases).
//! * [`json`] — a minimal JSON value, parser and writer (replaces
//!   `serde_json`; parses `artifacts/manifest.json`, emits metrics).
//! * [`bench`] — timing-loop helpers for the `cargo bench` binaries
//!   (replaces `criterion`).
//! * [`prop`] — a tiny seeded property-testing harness (replaces
//!   `proptest`; on failure it reports the reproducing seed).

pub mod bench;
pub mod json;
pub mod parallel;
pub mod pool;
pub mod prop;
pub mod rng;
