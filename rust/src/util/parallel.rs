//! Deterministic fork/join data parallelism on scoped std threads
//! (offline `rayon` substitute).
//!
//! The coordinator's phases are embarrassingly parallel over clusters or
//! shards, so simple contiguous range splitting suffices. Results are
//! returned in input order regardless of thread count, keeping every
//! engine bit-for-bit reproducible across parallelism settings.

/// Map `0..n` in parallel over at most `threads` workers; results are in
/// index order. `f` must be `Sync` (read-only shared captures).
pub fn par_map_indexed<R: Send>(
    threads: usize,
    n: usize,
    f: impl Fn(usize) -> R + Sync,
) -> Vec<R> {
    let threads = effective_threads(threads, n);
    if threads <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<R>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    let chunk = n.div_ceil(threads);
    let slots = out.as_mut_slice();
    std::thread::scope(|scope| {
        // Hand each worker a disjoint &mut of the output.
        let mut rest = slots;
        let mut start = 0usize;
        let mut handles = Vec::with_capacity(threads);
        for _ in 0..threads {
            let take = chunk.min(rest.len());
            if take == 0 {
                break;
            }
            let (mine, tail) = rest.split_at_mut(take);
            rest = tail;
            let base = start;
            start += take;
            let f = &f;
            handles.push(scope.spawn(move || {
                for (i, slot) in mine.iter_mut().enumerate() {
                    *slot = Some(f(base + i));
                }
            }));
        }
        for h in handles {
            h.join().expect("worker panicked");
        }
    });
    out.into_iter().map(|o| o.expect("slot filled")).collect()
}

/// Parallel map over a slice, preserving order.
pub fn par_map<T: Sync, R: Send>(
    threads: usize,
    items: &[T],
    f: impl Fn(&T) -> R + Sync,
) -> Vec<R> {
    par_map_indexed(threads, items.len(), |i| f(&items[i]))
}

/// Parallel filter-map over `0..n`, preserving index order of survivors.
pub fn par_filter_map_indexed<R: Send>(
    threads: usize,
    n: usize,
    f: impl Fn(usize) -> Option<R> + Sync,
) -> Vec<R> {
    par_map_indexed(threads, n, f).into_iter().flatten().collect()
}

/// Run one closure per item of `items`, each receiving `&mut` access to
/// exactly its own element (disjoint mutation — the per-shard apply
/// pattern).
pub fn par_for_each_mut<T: Send>(
    threads: usize,
    items: &mut [T],
    f: impl Fn(usize, &mut T) + Sync,
) {
    let n = items.len();
    let threads = effective_threads(threads, n);
    if threads <= 1 {
        for (i, item) in items.iter_mut().enumerate() {
            f(i, item);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        let mut rest = items;
        let mut base = 0usize;
        for _ in 0..threads {
            let take = chunk.min(rest.len());
            if take == 0 {
                break;
            }
            let (mine, tail) = rest.split_at_mut(take);
            rest = tail;
            let f = &f;
            let start = base;
            base += take;
            scope.spawn(move || {
                for (i, item) in mine.iter_mut().enumerate() {
                    f(start + i, item);
                }
            });
        }
    });
}

/// Default worker count: the machine's logical cores. Cached in a
/// `OnceLock` — every engine construction queries this, and
/// `available_parallelism` is a syscall on most platforms, so the first
/// call pays it once and the rest are a load.
pub fn default_threads() -> usize {
    static DEFAULT: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *DEFAULT.get_or_init(|| {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    })
}

fn effective_threads(threads: usize, n: usize) -> usize {
    threads.max(1).min(n.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_indexed_order_is_stable() {
        for t in [1, 2, 3, 8, 64] {
            let out = par_map_indexed(t, 1000, |i| i * i);
            assert_eq!(out, (0..1000).map(|i| i * i).collect::<Vec<_>>(), "t={t}");
        }
    }

    #[test]
    fn map_over_slice() {
        let xs = vec![1, 2, 3, 4, 5];
        assert_eq!(par_map(4, &xs, |x| x * 10), vec![10, 20, 30, 40, 50]);
    }

    #[test]
    fn filter_map_keeps_order() {
        let out = par_filter_map_indexed(4, 100, |i| (i % 3 == 0).then_some(i));
        assert_eq!(out, (0..100).filter(|i| i % 3 == 0).collect::<Vec<_>>());
    }

    #[test]
    fn for_each_mut_touches_every_item_once() {
        let mut xs = vec![0usize; 257];
        let calls = AtomicUsize::new(0);
        par_for_each_mut(8, &mut xs, |i, x| {
            *x = i + 1;
            calls.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(calls.load(Ordering::Relaxed), 257);
        assert!(xs.iter().enumerate().all(|(i, &x)| x == i + 1));
    }

    #[test]
    fn empty_and_tiny_inputs() {
        assert!(par_map_indexed(8, 0, |i| i).is_empty());
        assert_eq!(par_map_indexed(8, 1, |i| i), vec![0]);
    }

    #[test]
    fn actually_uses_threads() {
        // With 4 workers on 4 chunks, max observed concurrency > 1 —
        // verified indirectly via distinct thread ids.
        let ids = par_map_indexed(4, 4, |_| {
            std::thread::sleep(std::time::Duration::from_millis(20));
            std::thread::current().id()
        });
        let distinct: std::collections::HashSet<_> = ids.into_iter().collect();
        assert!(distinct.len() > 1);
    }
}
