//! Tiny seeded property-testing harness (offline `proptest` substitute).
//!
//! [`for_all_seeds`] drives a property over many deterministic RNG seeds
//! and, on failure, panics with the reproducing seed so the case can be
//! replayed with `check_seed`. No shrinking — generators in this crate are
//! parameterised by size, so re-running at a smaller size serves the same
//! purpose.

use super::rng::Rng;

/// Run `prop` for `cases` seeds derived from `base_seed`. The property
/// receives a fresh deterministic [`Rng`] per case and should panic (e.g.
/// via `assert!`) on violation.
pub fn for_all_seeds(base_seed: u64, cases: u64, prop: impl Fn(&mut Rng)) {
    for case in 0..cases {
        let seed = base_seed ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::seed_from(seed);
            prop(&mut rng);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property failed on case {case} (reproduce with seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Replay a single failing seed reported by [`for_all_seeds`].
pub fn check_seed(seed: u64, prop: impl Fn(&mut Rng)) {
    let mut rng = Rng::seed_from(seed);
    prop(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_quietly() {
        for_all_seeds(1, 50, |rng| {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    fn reports_reproducing_seed() {
        let err = std::panic::catch_unwind(|| {
            for_all_seeds(2, 100, |rng| {
                // Fails for roughly half the seeds.
                assert!(rng.f64() < 0.5, "too big");
            });
        })
        .unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("reproduce with seed"), "{msg}");
        // Extract and replay the seed: must fail again.
        let seed_hex = msg
            .split("seed ")
            .nth(1)
            .unwrap()
            .split(')')
            .next()
            .unwrap();
        let seed = u64::from_str_radix(seed_hex.trim_start_matches("0x"), 16).unwrap();
        assert!(std::panic::catch_unwind(|| {
            check_seed(seed, |rng| {
                assert!(rng.f64() < 0.5, "too big");
            })
        })
        .is_err());
    }
}
