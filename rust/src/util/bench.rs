//! Timing-loop helpers for the `cargo bench` binaries (offline `criterion`
//! substitute).
//!
//! Each bench target under `rust/benches/` is a plain binary
//! (`harness = false`) that uses [`time_fn`] / [`Sampler`] to produce
//! median/min/mean timings with warmup, and prints paper-style tables.

use std::time::{Duration, Instant};

/// Summary statistics over repeated timed runs.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub samples: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
    pub max: Duration,
}

impl Timing {
    pub fn from_samples(mut xs: Vec<Duration>) -> Timing {
        assert!(!xs.is_empty());
        xs.sort();
        let sum: Duration = xs.iter().sum();
        Timing {
            samples: xs.len(),
            min: xs[0],
            median: xs[xs.len() / 2],
            mean: sum / xs.len() as u32,
            max: *xs.last().unwrap(),
        }
    }
}

impl std::fmt::Display for Timing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "median {:>10.3?}  mean {:>10.3?}  min {:>10.3?}  (n={})",
            self.median, self.mean, self.min, self.samples
        )
    }
}

/// Time `f` with `warmup` discarded runs followed by `samples` measured
/// runs. The closure's return value is passed through a black box so the
/// optimizer cannot elide the work.
pub fn time_fn<R>(warmup: usize, samples: usize, mut f: impl FnMut() -> R) -> Timing {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut xs = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        black_box(f());
        xs.push(t.elapsed());
    }
    Timing::from_samples(xs)
}

/// Adaptive sampler: keeps running `f` until `budget` wall time is spent
/// (at least `min_samples` runs). Good default for benches whose cost
/// varies by orders of magnitude across parameter sweeps.
pub fn time_budget<R>(
    budget: Duration,
    min_samples: usize,
    mut f: impl FnMut() -> R,
) -> Timing {
    black_box(f()); // warmup
    let start = Instant::now();
    let mut xs = Vec::new();
    while xs.len() < min_samples || start.elapsed() < budget {
        let t = Instant::now();
        black_box(f());
        xs.push(t.elapsed());
        if xs.len() > 10_000 {
            break;
        }
    }
    Timing::from_samples(xs)
}

/// Prevent the optimizer from discarding a value (stable-Rust black box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Right-aligned fixed-width table printer for paper-style outputs.
pub struct Table {
    widths: Vec<usize>,
}

impl Table {
    pub fn new(headers: &[&str], widths: &[usize]) -> Table {
        let t = Table {
            widths: widths.to_vec(),
        };
        t.row(headers);
        let rule: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        t.row(&rule.iter().map(|s| s.as_str()).collect::<Vec<_>>());
        t
    }

    pub fn row(&self, cells: &[&str]) {
        let mut line = String::new();
        for (i, c) in cells.iter().enumerate() {
            let w = self.widths.get(i).copied().unwrap_or(12);
            line.push_str(&format!("{c:>w$} "));
        }
        println!("{}", line.trim_end());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_stats_ordering() {
        let t = Timing::from_samples(vec![
            Duration::from_micros(5),
            Duration::from_micros(1),
            Duration::from_micros(3),
        ]);
        assert_eq!(t.min, Duration::from_micros(1));
        assert_eq!(t.median, Duration::from_micros(3));
        assert_eq!(t.max, Duration::from_micros(5));
        assert_eq!(t.mean, Duration::from_micros(3));
    }

    #[test]
    fn time_fn_runs_expected_count() {
        let mut calls = 0;
        let t = time_fn(2, 5, || {
            calls += 1;
            calls
        });
        assert_eq!(calls, 7);
        assert_eq!(t.samples, 5);
    }

    #[test]
    fn time_budget_hits_min_samples() {
        let t = time_budget(Duration::ZERO, 3, || 1 + 1);
        assert!(t.samples >= 3);
    }
}
