//! Deterministic PRNG and distribution samplers (offline `rand` substitute).
//!
//! Core generator: **xoshiro256++** seeded through SplitMix64 — fast,
//! well-tested statistical quality, trivially reproducible across runs and
//! thread counts (every generator site owns its own seeded instance).

/// xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the Box–Muller transform.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 so that small/sequential seeds decorrelate.
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = move || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng {
            s,
            spare_normal: None,
        }
    }

    /// Derive an independent stream (for per-thread / per-shard use).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::seed_from(self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (Lemire's unbiased method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn bool_with(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u = self.f64();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.f64();
            let r = (-2.0 * u.ln()).sqrt();
            let (s, c) = (std::f64::consts::TAU * v).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// Normal with given mean / standard deviation.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Zipf-distributed integer in `[1, n]` with exponent `s > 1`, via
    /// rejection from the continuous envelope `x^{-s}` (Hörmann-style).
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        assert!(n >= 1 && s > 1.0);
        // H(x) = (x^{1-s} - 1) / (1 - s) is the antiderivative of x^{-s}
        // (shifted so H(1) = 0); H is increasing, so inversion sampling on
        // [0.5, n + 0.5] plus a per-bucket rejection yields the exact pmf.
        let h = |x: f64| ((1.0 - s) * x.ln()).exp_m1() / (1.0 - s);
        let h_inv = |y: f64| (1.0 + (1.0 - s) * y).powf(1.0 / (1.0 - s));
        let (lo, hi) = (h(0.5), h(n as f64 + 0.5));
        loop {
            let u = lo + self.f64() * (hi - lo);
            let k = h_inv(u).round().clamp(1.0, n as f64) as u64;
            // Bucket mass under the envelope vs the true pmf value; for the
            // convex decreasing x^{-s} the envelope dominates (midpoint
            // rule), so this is a valid rejection step.
            let hk = h(k as f64 + 0.5) - h(k as f64 - 0.5);
            let pk = (k as f64).powf(-s);
            if self.f64() * hk <= pk {
                return k;
            }
        }
    }

    /// Gamma(shape k, scale 1) via Marsaglia–Tsang (k >= 0.01).
    pub fn gamma(&mut self, k: f64) -> f64 {
        assert!(k > 0.0);
        if k < 1.0 {
            // Boost: Gamma(k) = Gamma(k+1) * U^{1/k}.
            let g = self.gamma(k + 1.0);
            return g * self.f64().max(f64::MIN_POSITIVE).powf(1.0 / k);
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Dirichlet sample over the given concentration parameters.
    pub fn dirichlet(&mut self, alphas: &[f64]) -> Vec<f64> {
        let gs: Vec<f64> = alphas.iter().map(|&a| self.gamma(a)).collect();
        let sum: f64 = gs.iter().sum();
        gs.into_iter().map(|g| g / sum.max(f64::MIN_POSITIVE)).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(1);
        let mut c = Rng::seed_from(2);
        let (xa, xb, xc) = (a.next_u64(), b.next_u64(), c.next_u64());
        assert_eq!(xa, xb);
        assert_ne!(xa, xc);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::seed_from(4);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn zipf_is_heavy_headed() {
        let mut r = Rng::seed_from(6);
        let n = 20_000;
        let mut ones = 0;
        for _ in 0..n {
            let k = r.zipf(100, 1.5);
            assert!((1..=100).contains(&k));
            if k == 1 {
                ones += 1;
            }
        }
        // P[k=1] ≈ 1/ζ(1.5 truncated) ≈ 0.38 for n=100.
        assert!(ones as f64 / n as f64 > 0.25, "{ones}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::seed_from(7);
        for k in [0.5, 1.0, 3.0, 10.0] {
            let n = 40_000;
            let mean = (0..n).map(|_| r.gamma(k)).sum::<f64>() / n as f64;
            assert!((mean - k).abs() < 0.1 * k.max(1.0), "k={k} mean={mean}");
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::seed_from(8);
        let v = r.dirichlet(&[1.0, 0.3, 0.1]);
        assert_eq!(v.len(), 3);
        assert!((v.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(v.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::seed_from(10);
        let mut a = r.fork(0);
        let mut b = r.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
