//! Minimal JSON value, recursive-descent parser and writer
//! (offline `serde_json` substitute).
//!
//! Used to read `artifacts/manifest.json` (the AOT variant index written
//! by `python/compile/aot.py`) and to emit metrics / bench reports.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects preserve key order via `BTreeMap` (sorted), which
/// is fine for our manifest/metrics uses and keeps output deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Numeric access for index-like fields (manifest shapes, bench
    /// report counters). Only a non-negative integral value that fits in
    /// `usize` qualifies: negative, NaN, infinite, fractional, and
    /// oversized numbers all return `None` instead of being silently
    /// coerced (a bare `as usize` maps NaN and negatives to 0 — a valid
    /// index pointing at the wrong data).
    pub fn as_usize(&self) -> Option<usize> {
        match self.as_f64() {
            // `fract()` is NaN for NaN/±inf inputs, so the `== 0.0`
            // comparison rejects those too. The upper bound is exclusive:
            // `usize::MAX as f64` rounds up to 2^64, which `as` would
            // saturate rather than represent.
            Some(x) if x >= 0.0 && x.fract() == 0.0 && x < usize::MAX as f64 => Some(x as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }
}

/// Convenience constructors for building metric/report objects.
impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build a `Json::Obj` from `(key, value)` pairs.
pub fn obj(pairs: impl IntoIterator<Item = (&'static str, Json)>) -> Json {
    Json::Obj(
        pairs
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    )
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {} (found {:?})",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.pos)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or("bad escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                                16,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            self.pos += 4;
                            // Surrogate pairs unsupported (not needed for
                            // our ASCII manifests); map lone surrogates to
                            // the replacement character.
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(format!("bad escape \\{}", esc as char)),
                    }
                }
                Some(c) => {
                    // Consume one UTF-8 scalar.
                    let len = utf8_len(c);
                    let chunk = self
                        .bytes
                        .get(self.pos..self.pos + len)
                        .ok_or("truncated utf8")?;
                    out.push_str(std::str::from_utf8(chunk).map_err(|e| e.to_string())?);
                    self.pos += len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected , or ] (found {other:?})")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("expected , or }} (found {other:?})")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(items) => {
                write!(f, "[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(map) => {
                write!(f, "{{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{
            "dist_l2_m256_n256_d64": {
                "kind": "distance", "metric": "l2",
                "m": 256, "n": 256, "d": 64,
                "file": "dist_l2_m256_n256_d64.hlo.txt",
                "inputs": [[256, 64], [256, 64]]
            }
        }"#;
        let v = Json::parse(text).unwrap();
        let entry = v.get("dist_l2_m256_n256_d64").unwrap();
        assert_eq!(entry.get("metric").unwrap().as_str(), Some("l2"));
        assert_eq!(entry.get("m").unwrap().as_usize(), Some(256));
        let inputs = entry.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(inputs[0].as_arr().unwrap()[1].as_usize(), Some(64));
    }

    #[test]
    fn roundtrip() {
        let v = obj([
            ("name", "rac".into()),
            ("rounds", 42usize.into()),
            ("alpha", 0.333.into()),
            ("ok", true.into()),
            ("series", vec![1.0, 2.5, 3.0].into()),
        ]);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        let text = v.to_string();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,2,").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo → wörld\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → wörld"));
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(Json::parse("42").unwrap().as_usize(), Some(42));
    }

    #[test]
    fn as_usize_accepts_only_non_negative_integers() {
        assert_eq!(Json::Num(0.0).as_usize(), Some(0));
        assert_eq!(Json::Num(-0.0).as_usize(), Some(0));
        assert_eq!(Json::Num(42.0).as_usize(), Some(42));
        assert_eq!(Json::Num(2.0_f64.powi(52)).as_usize(), Some(1 << 52));

        // Each of these used to coerce to a "valid" index via `as usize`.
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(-0.5).as_usize(), None);
        assert_eq!(Json::Num(1.5).as_usize(), None);
        assert_eq!(Json::Num(f64::NAN).as_usize(), None);
        assert_eq!(Json::Num(f64::INFINITY).as_usize(), None);
        assert_eq!(Json::Num(f64::NEG_INFINITY).as_usize(), None);
        assert_eq!(Json::Num(f64::MAX).as_usize(), None);
        assert_eq!(Json::Num(2.0_f64.powi(64)).as_usize(), None);
        assert_eq!(Json::Str("3".into()).as_usize(), None);
        assert_eq!(Json::Null.as_usize(), None);
    }
}
