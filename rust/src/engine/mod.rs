//! `engine` — the shared bulk-synchronous round driver behind every
//! shared-memory engine in the crate.
//!
//! Before this module existed, [`crate::rac::RacEngine`],
//! [`crate::rac::baseline::HashRacEngine`] and
//! [`crate::approx::ApproxEngine`] each carried a private copy of the same
//! loop: initial NN scan, phase-1 pair selection, phase-2 union
//! compute + apply, phase-3 rescan, round metrics, termination. The copies
//! differed along exactly two axes, so those are the two parameters here:
//!
//! * **Store** ([`EngineStore`]) — where cluster adjacency lives and how a
//!   merge round is applied to it. Two implementations: the flat
//!   arena-backed [`NeighborStore`] (lock-free owner-sharded parallel
//!   apply + compaction) and the hashmap [`crate::rac::baseline::HashStore`]
//!   (the PR-1 representation, serial apply — kept as the differential
//!   oracle and perf baseline).
//! * **Selector** ([`PairSelector`]) — how phase 1 picks this round's
//!   merge pairs. Two implementations: [`RnnSelector`] (exact reciprocal
//!   nearest neighbors — the paper's Algorithm 2 condition, `O(active)`
//!   pointer checks) and [`GoodSelector`] (TeraHAC-style (1+ε)-good merge
//!   matching from [`crate::approx::good`], `O(edges)` row scans).
//!
//! The three engines are the three useful points of that 2×2 grid:
//! `RacEngine` = flat × RNN, `HashRacEngine` = hashmap × RNN,
//! `ApproxEngine` = flat × good. The ε = 0 bitwise anchor
//! (`Approx(0) == Rac`, `rust/tests/approx_quality.rs`) is therefore a
//! property of two *selectors* over literally shared phase-2/3 code, not of
//! two mirrored loops that must be edited in lockstep.
//!
//! ## Determinism contract
//!
//! The driver inherits and centralises the engines' bitwise-reproducibility
//! requirements: selectors return pairs in ascending-leader order, union
//! maps are computed read-only in pair order, the store applies each row's
//! patches in ascending union order for every thread count, and phase-3
//! rescans go through the shared [`crate::rac::logic::scan_nn`]
//! `(weight, id)` total order. Dendrograms are identical bit for bit
//! across stores, selectors-at-ε=0, and thread counts
//! (`rust/tests/store_equivalence.rs`).
//!
//! ## Dispatch
//!
//! Both parameters are generics, never trait objects: each engine
//! monomorphises its own copy of [`RoundDriver::run`], so the refactor adds
//! zero indirect calls to the inner loop. `BENCH_hot_paths.json` entries
//! are tagged with [`DRIVER_REV`] so the perf trajectory can pin this
//! (flat-store medians must not regress against pre-driver datapoints).
//!
//! Below the driver, the two hot row scans — the `(weight, id)`-min NN
//! scan and [`GoodSelector`]'s eligibility sweep — lower to the runtime-
//! dispatched SIMD kernels in [`crate::store::scan`] whenever the store
//! hands out flat [`RowRef`] rows (so all flat-store engines, shared-
//! memory and distributed, get them with no driver changes); the hashmap
//! oracle keeps the scalar fold. `RAC_FORCE_SCALAR` (env), the
//! `force_scalar` config key, or `--force-scalar` pin the scalar
//! fallback; results are bitwise identical either way, so the selection
//! is invisible to everything above this paragraph.
//!
//! The distributed engines ([`crate::dist`]) run the same three phases
//! serially with batched cross-shard traffic accounting woven through each
//! phase; they share the phase-1 *selection logic* with this driver (both
//! of `dist`'s engines reuse [`crate::approx::good`] / the reciprocal-NN
//! condition) but keep their own accounting loop — see `dist`'s docs.

use std::time::Instant;

use crate::dendrogram::{Dendrogram, Merge};
use crate::linkage::{EdgeState, Linkage, Weight};
use crate::metrics::{RoundMetrics, RunMetrics};
use crate::rac::logic::{compute_union_map, scan_nn, PairView};
use crate::rac::NO_NN;
use crate::store::{NeighborStore, NeighborsRef, RowRef, UnionRow};
use crate::trace::{EventKind, Phase as TracePhase, TraceSink, COORD};
use crate::util::parallel::default_threads;
use crate::util::pool::Pool;

use crate::approx::good;
use crate::approx::quality::MergeBound;

pub use crate::approx::good::MergePair;

/// Revision tag of the driver core, stamped into bench reports so the
/// perf trajectory can attribute datapoints to engine-core rewires.
pub const DRIVER_REV: &str = "round_driver/v1";

/// Cluster-adjacency backend the driver runs over.
///
/// Implementations must mirror each other observationally: `row` exposes
/// the same live edge set, and `apply_round` must be equivalent to the
/// serial patch → install → clear sequence per union in ascending union
/// order (plus any store-internal housekeeping such as compaction). That
/// equivalence is what `rust/tests/store_equivalence.rs` pins.
pub trait EngineStore: Sync {
    /// Read-only view of one cluster's adjacency row.
    type Row<'a>: NeighborsRef
    where
        Self: 'a;

    /// The row of cluster `c`.
    fn row(&self, c: u32) -> Self::Row<'_>;

    /// Apply one merge round: for each `(leader, union_map)` in `unions`
    /// (ascending-leader order), patch every target `t` with
    /// `patch_target(t)` true, install the union row under the leader, and
    /// retire `partner_of(leader)`'s row.
    fn apply_round(
        &mut self,
        pool: &Pool,
        unions: &[UnionRow],
        partner_of: impl Fn(u32) -> u32 + Sync,
        patch_target: impl Fn(u32) -> bool + Sync,
    );
}

impl EngineStore for NeighborStore {
    type Row<'a>
        = RowRef<'a>
    where
        Self: 'a;

    #[inline]
    fn row(&self, c: u32) -> RowRef<'_> {
        NeighborStore::row(self, c)
    }

    fn apply_round(
        &mut self,
        pool: &Pool,
        unions: &[UnionRow],
        partner_of: impl Fn(u32) -> u32 + Sync,
        patch_target: impl Fn(u32) -> bool + Sync,
    ) {
        self.par_apply_round(pool, unions, partner_of, patch_target);
        // Same per-round compaction point as the pre-driver engines; the
        // trigger reads only live/dead counts, so layouts stay bit-for-bit
        // reproducible across thread counts (store module docs).
        self.maybe_compact();
    }
}

/// The per-cluster state every engine keeps between rounds. Selectors read
/// the NN caches and fill the selection arrays; the driver owns everything
/// else.
pub struct RoundState {
    pub n: usize,
    /// `active[c]`: cluster `c` has not been retired by a merge.
    pub active: Vec<bool>,
    /// Live cluster ids, ascending; compacted once per round so per-round
    /// phases cost `O(active)`, not `O(n)`.
    pub active_ids: Vec<u32>,
    pub size: Vec<u64>,
    /// Cached nearest-neighbor id (the weight is always the true row
    /// minimum; the id may be a stale tie — see [`crate::approx::good`]).
    pub nn: Vec<u32>,
    pub nn_weight: Vec<Weight>,
    /// Selected for a merge this round. Invariant at phase-1 entry: false
    /// for every live cluster (the driver clears pair endpoints at the end
    /// of each round; stale `true` on long-retired clusters is never read).
    pub matched: Vec<bool>,
    /// This round's merge partner (valid only while `matched`).
    pub partner: Vec<u32>,
    /// This round's merge weight (valid only while `matched`).
    pub pair_weight: Vec<Weight>,
}

impl RoundState {
    pub fn new(n: usize) -> RoundState {
        RoundState {
            n,
            active: vec![true; n],
            active_ids: (0..n as u32).collect(),
            size: vec![1; n],
            nn: vec![NO_NN; n],
            nn_weight: vec![Weight::INFINITY; n],
            matched: vec![false; n],
            partner: vec![NO_NN; n],
            pair_weight: vec![0.0; n],
        }
    }
}

/// Phase-1 strategy: pick this round's merge pairs.
///
/// Contract: returns pairs in **ascending-leader order** with
/// `leader < partner`, pairwise disjoint; for every returned pair, sets
/// `matched`/`partner`/`pair_weight` on **both** endpoints. Must not touch
/// any other driver state. Selection must be a pure function of the
/// visible state (no thread-count or visit-order dependence) — the
/// bitwise-reproducibility contract.
pub trait PairSelector<S: EngineStore> {
    fn select(
        &mut self,
        pool: &Pool,
        store: &S,
        state: &mut RoundState,
        rm: &mut RoundMetrics,
    ) -> Vec<MergePair>;
}

/// Exact phase 1: merge the reciprocal-nearest-neighbor pairs
/// (`nn[nn[c]] == c`), the paper's Algorithm 2 condition. `O(active)`
/// pointer checks, parallelised over the pool.
pub struct RnnSelector;

impl<S: EngineStore> PairSelector<S> for RnnSelector {
    fn select(
        &mut self,
        pool: &Pool,
        _store: &S,
        state: &mut RoundState,
        _rm: &mut RoundMetrics,
    ) -> Vec<MergePair> {
        let nn = &state.nn;
        let flags = pool.par_map(&state.active_ids, |&c| {
            let c = c as usize;
            nn[c] != NO_NN && nn[nn[c] as usize] == c as u32
        });
        let mut pairs = Vec::new();
        for (idx, flag) in flags.into_iter().enumerate() {
            if !flag {
                continue;
            }
            let c = state.active_ids[idx] as usize;
            let p = state.nn[c];
            state.matched[c] = true;
            state.partner[c] = p;
            state.pair_weight[c] = state.nn_weight[c];
            if (c as u32) < p {
                pairs.push(MergePair {
                    leader: c as u32,
                    partner: p,
                    weight: state.nn_weight[c],
                });
            }
        }
        pairs
    }
}

/// Which edges a [`GoodSelector`] may even consider: the driver's
/// edge-eligibility mask. The default [`FullScope`] admits everything;
/// the batched distributed engine restricts selection to edges whose
/// endpoints share a virtual shard (`crate::dist::VShardScope`), which is
/// what lets a per-shard driver instance drain its subgraph's good merges
/// without any cross-shard coordination. Scopes must be pure functions of
/// the endpoint ids (no round state), so selection stays a pure function
/// of the visible state — the bitwise-reproducibility contract.
pub trait EdgeScope: Sync {
    /// May the edge `(a, b)` (`a < b`) be selected?
    fn admits(&self, a: u32, b: u32) -> bool;
}

/// The trivial scope: every edge is eligible (the shared-memory engines).
#[derive(Debug, Clone, Copy, Default)]
pub struct FullScope;

impl EdgeScope for FullScope {
    #[inline]
    fn admits(&self, _a: u32, _b: u32) -> bool {
        true
    }
}

/// Approximate phase 1: TeraHAC-style (1+ε)-good merges. Every active
/// cluster scans its row for edges both endpoints accept
/// ([`good::accepts`] — candidates oriented `a < b` so each edge is tested
/// once, from its lower endpoint), then a maximal conflict-free set is
/// chosen deterministically ([`good::select_matching`]). At ε = 0 the
/// criterion degenerates to the reciprocal-NN pointer condition, so this
/// selector is bitwise-interchangeable with [`RnnSelector`] (the crate's
/// correctness anchor).
///
/// The `E` parameter is the edge-eligibility mask ([`EdgeScope`]): with
/// the default [`FullScope`] this is the PR-3/4 selector unchanged; with
/// a restrictive scope the selector only ever matches in-scope edges —
/// the building block of the subgraph-batched distributed engine, which
/// runs the driver loop per shard over a shard-local scope.
pub struct GoodSelector<E: EdgeScope = FullScope> {
    epsilon: f64,
    scope: E,
}

impl GoodSelector {
    /// `epsilon` must be finite and `>= 0` (callers guard; see
    /// [`crate::approx::ApproxEngine::new`]).
    pub fn new(epsilon: f64) -> GoodSelector {
        GoodSelector::scoped(epsilon, FullScope)
    }
}

impl<E: EdgeScope> GoodSelector<E> {
    /// A selector restricted to the edges `scope` admits.
    pub fn scoped(epsilon: f64, scope: E) -> GoodSelector<E> {
        debug_assert!(epsilon >= 0.0 && epsilon.is_finite());
        GoodSelector { epsilon, scope }
    }
}

impl<S: EngineStore, E: EdgeScope> PairSelector<S> for GoodSelector<E> {
    fn select(
        &mut self,
        pool: &Pool,
        store: &S,
        state: &mut RoundState,
        rm: &mut RoundMetrics,
    ) -> Vec<MergePair> {
        let eps = self.epsilon;
        let scans: Vec<(Vec<(Weight, u32)>, usize)> = {
            let nn = &state.nn;
            let nn_weight = &state.nn_weight;
            let scope = &self.scope;
            pool.par_map(&state.active_ids, |&a| {
                good::scan_row_candidates_scoped(store.row(a), a, eps, nn_weight, nn, |x, y| {
                    scope.admits(x, y)
                })
            })
        };
        let mut candidates: Vec<good::Candidate> = Vec::new();
        for (&a, (row_cands, scanned)) in state.active_ids.iter().zip(scans) {
            rm.eligibility_scan_entries += scanned;
            candidates.extend(row_cands.into_iter().map(|(w, b)| (w, a, b)));
        }
        let pairs = good::select_matching(candidates, &mut state.matched);
        for p in &pairs {
            state.partner[p.leader as usize] = p.partner;
            state.partner[p.partner as usize] = p.leader;
            state.pair_weight[p.leader as usize] = p.weight;
            state.pair_weight[p.partner as usize] = p.weight;
        }
        pairs
    }
}

/// What a finished driver run reports. Engine wrappers adapt this to
/// their public result types ([`crate::rac::RacResult`],
/// [`crate::approx::ApproxResult`]).
#[derive(Debug)]
pub struct DriverResult {
    pub dendrogram: Dendrogram,
    pub metrics: RunMetrics,
    /// Per merge, in recording order: `(weight, visible minimum)` at merge
    /// time — the approximate engines' quality trace. Recorded for every
    /// selector (for [`RnnSelector`] the ratio is identically 1); exact
    /// wrappers simply drop it.
    pub bounds: Vec<MergeBound>,
}

/// The shared round loop. Owns all driver state; phase 1 is delegated to a
/// [`PairSelector`], storage and round application to an [`EngineStore`].
pub struct RoundDriver<S: EngineStore> {
    linkage: Linkage,
    store: S,
    state: RoundState,
    threads: usize,
    max_rounds: usize,
    /// Where span/instant events go; the default disabled sink makes
    /// every emission site a single branch (pinned in `hot_paths`).
    sink: TraceSink,
    engine_name: &'static str,
}

impl<S: EngineStore> RoundDriver<S> {
    /// Build a driver over `n` singleton clusters backed by `store`.
    pub fn new(store: S, n: usize, linkage: Linkage) -> RoundDriver<S> {
        RoundDriver {
            linkage,
            store,
            state: RoundState::new(n),
            threads: default_threads(),
            // Safety valve for non-reducible linkages (same cap as the
            // pre-driver engines).
            max_rounds: 4 * n + 64,
            sink: TraceSink::disabled(),
            engine_name: "rac",
        }
    }

    /// Limit the worker-thread count (the paper's CPUs knob, Fig 3c).
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
    }

    /// Override the round safety cap.
    pub fn set_max_rounds(&mut self, max_rounds: usize) {
        self.max_rounds = max_rounds;
    }

    /// Stream run/round/phase events into `sink`, stamped `engine`.
    /// Tracing is purely observational: it never touches driver state,
    /// so traced runs stay bitwise identical to untraced ones
    /// (`rust/tests/trace_invariance.rs`).
    pub fn set_trace(&mut self, sink: TraceSink, engine: &'static str) {
        self.sink = sink;
        self.engine_name = engine;
    }

    /// Run to completion: init NN scan, then rounds of select → merge →
    /// rescan until no pair is selected (or the safety cap trips).
    pub fn run<P: PairSelector<S>>(mut self, selector: &mut P) -> DriverResult {
        // One persistent worker pool for the whole run: phases are short
        // and frequent, so per-phase thread spawning would dominate.
        let pool = Pool::new(self.threads);
        let t0 = Instant::now();
        let mut tb = self.sink.buf(self.engine_name, COORD, 0);
        let run_start = tb.now();
        let n = self.state.n;
        let mut merges: Vec<Merge> = Vec::with_capacity(n.saturating_sub(1));
        let mut bounds: Vec<MergeBound> = Vec::with_capacity(n.saturating_sub(1));
        let mut metrics = RunMetrics::default();

        // Initial NN cache for every cluster.
        let init: Vec<(u32, Weight)> = {
            let store = &self.store;
            pool.par_map_indexed(n, |c| scan_nn(store.row(c as u32)))
        };
        for (c, (nn, w)) in init.into_iter().enumerate() {
            self.state.nn[c] = nn;
            self.state.nn_weight[c] = w;
        }

        let mut n_active = n;
        for round in 0..self.max_rounds {
            tb.set_round(round);
            let round_start = tb.now();
            let mut rm = RoundMetrics {
                round,
                clusters: n_active,
                ..Default::default()
            };

            // ---- Phase 1: select this round's merge pairs ---------------
            let t = Instant::now();
            let find_start = tb.now();
            let pairs = selector.select(&pool, &self.store, &mut self.state, &mut rm);
            rm.t_find = t.elapsed();
            tb.span(find_start, EventKind::Phase(TracePhase::Find));
            rm.merges = pairs.len();

            if pairs.is_empty() {
                tb.span(round_start, EventKind::Round);
                metrics.rounds.push(rm);
                break;
            }

            // ---- Phase 2: update cluster dissimilarities ----------------
            // Compute every leader's union map in parallel (read-only over
            // shared state; pair–pair dissimilarities are computed twice,
            // once by each leader — the paper's contention-free choice)...
            let t = Instant::now();
            let merge_start = tb.now();
            let unions: Vec<UnionRow> = {
                let store = &self.store;
                let state = &self.state;
                let linkage = self.linkage;
                pool.par_map(&pairs, |pr| {
                    (pr.leader, union_map(linkage, store, state, pr.leader))
                })
            };

            for pr in &pairs {
                merges.push(Merge {
                    a: pr.leader,
                    b: pr.partner,
                    weight: pr.weight,
                });
                bounds.push(MergeBound {
                    weight: pr.weight,
                    visible_min: self.state.nn_weight[pr.leader as usize]
                        .min(self.state.nn_weight[pr.partner as usize]),
                });
            }
            // ...then apply through the store (for the flat arena this is
            // the lock-free owner-sharded parallel pass).
            {
                let partner = &self.state.partner;
                let matched = &self.state.matched;
                self.store.apply_round(
                    &pool,
                    &unions,
                    |l| partner[l as usize],
                    |t| !matched[t as usize],
                );
            }
            for pr in &pairs {
                self.state.size[pr.leader as usize] += self.state.size[pr.partner as usize];
                self.state.active[pr.partner as usize] = false;
            }
            n_active -= rm.merges;
            {
                let active = &self.state.active;
                self.state.active_ids.retain(|&c| active[c as usize]);
            }
            rm.t_merge = t.elapsed();
            tb.span(merge_start, EventKind::Phase(TracePhase::Merge));

            // ---- Phase 3: update nearest neighbors ----------------------
            // Only a cluster that merged, or whose cached NN merged, can
            // see its row minimum change (reducibility: patches never
            // lower a row's minimum) — the paper's rescan condition.
            let t = Instant::now();
            let update_start = tb.now();
            let updates: Vec<(u32, u32, Weight, usize)> = {
                let st = &self.state;
                let store = &self.store;
                let ids = &self.state.active_ids;
                pool.par_filter_map_indexed(ids.len(), |idx| {
                    let c = ids[idx];
                    let needs_rescan = st.matched[c as usize]
                        || (st.nn[c as usize] != NO_NN
                            && st.matched[st.nn[c as usize] as usize]);
                    needs_rescan.then(|| {
                        let row = store.row(c);
                        let (nn, w) = scan_nn(row);
                        (c, nn, w, row.live_len())
                    })
                })
            };
            rm.nn_updates = updates.len();
            for (c, nn, w, scanned) in updates {
                self.state.nn[c as usize] = nn;
                self.state.nn_weight[c as usize] = w;
                rm.nn_scan_entries += scanned;
            }
            // Clear this round's selection so the phase-1 invariant holds
            // next round (retired partners' stale flags are unreachable —
            // no live `nn` points at them).
            for pr in &pairs {
                self.state.matched[pr.leader as usize] = false;
                self.state.matched[pr.partner as usize] = false;
            }
            rm.t_update_nn = t.elapsed();
            tb.span(update_start, EventKind::Phase(TracePhase::UpdateNn));
            tb.span(round_start, EventKind::Round);
            metrics.rounds.push(rm);

            if n_active <= 1 {
                break;
            }
        }

        metrics.total_time = t0.elapsed();
        tb.span(run_start, EventKind::Run);
        self.sink.absorb(tb);
        DriverResult {
            dendrogram: Dendrogram::new(n, merges),
            metrics,
            bounds,
        }
    }
}

/// Neighbor map of the union `L ∪ partner(L)` — the single call site of
/// the engine-agnostic [`compute_union_map`] for every driver-backed
/// engine, so the arithmetic (and its floating-point rounding) is bitwise
/// identical across stores and selectors.
fn union_map<S: EngineStore>(
    linkage: Linkage,
    store: &S,
    st: &RoundState,
    l: u32,
) -> Vec<(u32, EdgeState)> {
    let p = st.partner[l as usize];
    compute_union_map(
        linkage,
        l,
        p,
        st.pair_weight[l as usize],
        st.size[l as usize],
        st.size[p as usize],
        store.row(l),
        store.row(p),
        |x| PairView {
            merging: st.matched[x as usize],
            partner: st.partner[x as usize],
            size: st.size[x as usize],
            pair_weight: st.pair_weight[x as usize],
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use crate::rac::baseline::HashStore;

    fn tiny_graph() -> Graph {
        Graph::from_edges(
            6,
            [
                (0, 1, 1.0),
                (2, 3, 1.5),
                (1, 3, 10.0),
                (3, 4, 2.0),
                (4, 5, 7.0),
            ],
        )
    }

    fn run<S: EngineStore, P: PairSelector<S>>(
        store: S,
        n: usize,
        selector: &mut P,
        threads: usize,
    ) -> DriverResult {
        let mut d = RoundDriver::new(store, n, Linkage::Average);
        d.set_threads(threads);
        d.run(selector)
    }

    #[test]
    fn both_stores_agree_bitwise_under_both_selectors() {
        let g = tiny_graph();
        for threads in [1usize, 3] {
            let flat_rnn = run(NeighborStore::from_graph(&g), 6, &mut RnnSelector, threads);
            let hash_rnn = run(HashStore::from_graph(&g), 6, &mut RnnSelector, threads);
            let flat_good = run(
                NeighborStore::from_graph(&g),
                6,
                &mut GoodSelector::new(0.0),
                threads,
            );
            let hash_good = run(
                HashStore::from_graph(&g),
                6,
                &mut GoodSelector::new(0.0),
                threads,
            );
            let want = flat_rnn.dendrogram.bitwise_merges();
            assert_eq!(want.len(), 5);
            for (name, r) in [
                ("hash×rnn", &hash_rnn),
                ("flat×good", &flat_good),
                ("hash×good", &hash_good),
            ] {
                assert_eq!(want, r.dendrogram.bitwise_merges(), "{name} t={threads}");
            }
        }
    }

    #[test]
    fn bounds_are_recorded_for_every_selector() {
        let g = tiny_graph();
        let exact = run(NeighborStore::from_graph(&g), 6, &mut RnnSelector, 1);
        assert_eq!(exact.bounds.len(), exact.dendrogram.merges().len());
        assert_eq!(crate::approx::quality::merge_quality_ratio(&exact.bounds), 1.0);
        let good = run(
            NeighborStore::from_graph(&g),
            6,
            &mut GoodSelector::new(0.5),
            1,
        );
        assert_eq!(good.bounds.len(), good.dendrogram.merges().len());
        assert!(crate::approx::quality::merge_quality_ratio(&good.bounds) <= 1.5 + 1e-12);
    }

    #[test]
    fn empty_and_singleton_inputs() {
        for n in [0usize, 1] {
            let g = Graph::from_edges(n, []);
            let r = run(NeighborStore::from_graph(&g), n, &mut RnnSelector, 2);
            assert!(r.dendrogram.merges().is_empty());
            assert!(r.bounds.is_empty());
        }
    }

    #[test]
    fn max_rounds_zero_runs_nothing() {
        let g = tiny_graph();
        let mut d = RoundDriver::new(NeighborStore::from_graph(&g), 6, Linkage::Average);
        d.set_max_rounds(0);
        let r = d.run(&mut RnnSelector);
        assert!(r.dendrogram.merges().is_empty());
        assert!(r.metrics.rounds.is_empty());
    }

    #[test]
    fn eligibility_scans_accounted_only_by_good_selector() {
        let g = tiny_graph();
        let exact = run(NeighborStore::from_graph(&g), 6, &mut RnnSelector, 1);
        assert!(exact
            .metrics
            .rounds
            .iter()
            .all(|r| r.eligibility_scan_entries == 0));
        let good = run(
            NeighborStore::from_graph(&g),
            6,
            &mut GoodSelector::new(0.1),
            1,
        );
        assert!(good.metrics.rounds[0].eligibility_scan_entries > 0);
    }

    /// A scope splitting the ids into halves: the driver drains each
    /// half's good merges but never crosses the boundary.
    struct Halves {
        split: u32,
    }

    impl EdgeScope for Halves {
        fn admits(&self, a: u32, b: u32) -> bool {
            (a < self.split) == (b < self.split)
        }
    }

    #[test]
    fn scoped_selector_never_crosses_the_scope_boundary() {
        let g = tiny_graph();
        for eps in [0.0, 0.5] {
            let r = run(
                NeighborStore::from_graph(&g),
                6,
                &mut GoodSelector::scoped(eps, Halves { split: 3 }),
                1,
            );
            // The driver drains only in-scope good merges and stops at
            // the scoped fixed point: (0, 1) is always in scope and
            // reciprocal, the bridges (1,3)/(2,3) are masked, and
            // cluster 2 (whose ONLY edge is the masked bridge) can never
            // merge. Note the fixed point may strand more than the
            // bridge endpoints — a cluster whose visible minimum lies
            // out of scope rejects in-scope edges above its band — which
            // is exactly why the batched distributed engine falls back
            // to a global sync when local merges dry up.
            assert!(!r.dendrogram.merges().is_empty(), "eps={eps}");
            for m in r.dendrogram.merges() {
                assert_eq!(
                    m.a < 3,
                    m.b < 3,
                    "eps={eps}: merge ({}, {}) crossed the scope",
                    m.a,
                    m.b
                );
                assert!(m.a != 2 && m.b != 2, "eps={eps}: the masked cluster merged");
            }
            // The band audit applies to the scoped run unchanged.
            assert!(crate::approx::quality::merge_quality_ratio(&r.bounds) <= 1.0 + eps + 1e-12);
        }
    }

    #[test]
    fn full_scope_is_the_unscoped_selector_bitwise() {
        let g = tiny_graph();
        for eps in [0.0, 0.3] {
            let plain = run(
                NeighborStore::from_graph(&g),
                6,
                &mut GoodSelector::new(eps),
                2,
            );
            let scoped = run(
                NeighborStore::from_graph(&g),
                6,
                &mut GoodSelector::scoped(eps, FullScope),
                2,
            );
            assert_eq!(
                plain.dendrogram.bitwise_merges(),
                scoped.dendrogram.bitwise_merges(),
                "eps={eps}"
            );
        }
    }
}
