//! Constructions from the paper's theory section (§4).

use crate::graph::Graph;
use crate::linkage::Weight;
use crate::util::rng::Rng;

/// §4.2.2 "Single Linkage, 1-dimensional grid": `n` iid-uniform points on
/// [0,1], relabelled in increasing order, connected as a path graph with
/// consecutive-gap weights. Under single linkage each round merges ≥ 1/3 of
/// clusters in expectation (α = 1/3 in Theorem 6).
pub fn grid1d_graph(n: usize, seed: u64) -> Graph {
    assert!(n >= 2);
    let mut rng = Rng::seed_from(seed);
    let mut xs: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
    xs.sort_by(|a, b| a.total_cmp(b));
    Graph::from_edges(
        n,
        (0..n - 1).map(|i| (i as u32, (i + 1) as u32, xs[i + 1] - xs[i])),
    )
}

/// Theorem 4 adversarial instance: `P_k = (k+1) + ε(k+1)²` for
/// `k = 0..2^levels - 1` with `ε = 2^{-4·levels}`, as a complete graph of
/// 1-d distances.
///
/// Under **average** linkage HAC builds the natural complete binary tree
/// (height = `levels`), yet RAC needs Ω(2^levels) rounds because only one
/// reciprocal pair exists among the remaining singletons in any round.
///
/// Weight arithmetic needs ≈ 4·levels bits of relative precision; with f64
/// this is exact for `levels <= 12` (asserted).
pub fn adversarial_thm4(levels: u32) -> Graph {
    assert!(levels >= 1 && levels <= 12, "f64 precision bound");
    let n = 1usize << levels;
    let eps = (2.0f64).powi(-(4 * levels as i32));
    let pts: Vec<f64> = (0..n)
        .map(|k| {
            let k1 = (k + 1) as f64;
            k1 + eps * k1 * k1
        })
        .collect();
    let mut m = vec![0.0 as Weight; n * n];
    for i in 0..n {
        for j in 0..n {
            m[i * n + j] = (pts[i] - pts[j]).abs();
        }
    }
    Graph::from_dense(n, &m)
}

/// Theorem 5 stable cluster tree: a perfect binary hierarchy over
/// `2^depth` leaves whose pairwise dissimilarity is `base^(level of the
/// LCA)` plus a tiny tie-breaking jitter.
///
/// With `base >= 4` the tree satisfies Definition 1 (stability) for
/// average linkage by a wide margin, so RAC must finish in exactly
/// `depth` rounds. Returned as a complete graph.
pub fn stable_hierarchy(depth: u32, base: f64, seed: u64) -> Graph {
    assert!(depth >= 1 && depth <= 14);
    assert!(base >= 2.5, "need separation for stability");
    let n = 1usize << depth;
    let mut rng = Rng::seed_from(seed);
    let mut m = vec![0.0 as Weight; n * n];
    for i in 0..n {
        for j in (i + 1)..n {
            // Level of the lowest common ancestor of leaves i, j in the
            // perfect binary tree = position of highest differing bit + 1.
            let lca = 64 - ((i ^ j) as u64).leading_zeros();
            let w = base.powi(lca as i32) * (1.0 + rng.range_f64(-0.01, 0.01));
            m[i * n + j] = w;
            m[j * n + i] = w;
        }
    }
    Graph::from_dense(n, &m)
}

/// §4.2.2 bounded-degree probabilistic graph: a random (near-)`k`-regular
/// graph whose edge weights are a random permutation of `1..=m` (random
/// ranks). Theorem 6 applies with α = 1/(4k) under single linkage.
///
/// Built by the pairing/configuration heuristic with rejection of
/// duplicates and self-loops; the result has max degree ≤ `k` (some
/// vertices may fall short by a few edges — degree *bounded*, as the
/// theorem requires).
pub fn random_regular_graph(n: usize, k: usize, seed: u64) -> Graph {
    assert!(n >= 4 && k >= 2 && k < n);
    let mut rng = Rng::seed_from(seed);
    let mut degree = vec![0usize; n];
    let mut edges: std::collections::HashSet<(u32, u32)> = Default::default();
    // Randomised sweep: propose edges between under-full vertices.
    let mut attempts = 0usize;
    let target = n * k / 2;
    while edges.len() < target && attempts < 50 * target {
        attempts += 1;
        let u = rng.below(n);
        let v = rng.below(n);
        if u == v || degree[u] >= k || degree[v] >= k {
            continue;
        }
        let key = (u.min(v) as u32, u.max(v) as u32);
        if edges.insert(key) {
            degree[u] += 1;
            degree[v] += 1;
        }
    }
    // Random ranks as weights (sorted uniformly at random, per the model).
    let mut ranks: Vec<u64> = (1..=edges.len() as u64).collect();
    rng.shuffle(&mut ranks);
    let mut list: Vec<(u32, u32)> = edges.into_iter().collect();
    list.sort_unstable();
    Graph::from_edges(
        n,
        list.into_iter()
            .zip(ranks)
            .map(|((u, v), r)| (u, v, r as Weight)),
    )
}

/// Random sparse property-test graph: a random tree over most nodes
/// (keeps the graph connected enough that runs produce long merge
/// sequences) plus random extra edges, with occasional isolated tail
/// nodes. The shape the differential suites
/// (`rust/tests/store_equivalence.rs`, `rust/tests/approx_quality.rs`)
/// throw at every engine; lives here so the suites share one generator.
pub fn random_sparse_graph(rng: &mut Rng) -> Graph {
    let n = rng.range_usize(2, 140);
    let mut edges: Vec<(u32, u32, Weight)> = Vec::new();
    for v in 1..n {
        // ~1 node in 12 stays detached from the tree.
        if rng.bool_with(1.0 / 12.0) {
            continue;
        }
        let u = rng.below(v) as u32;
        edges.push((u, v as u32, rng.range_f64(0.1, 100.0)));
    }
    let extra = rng.range_usize(0, 3 * n);
    for _ in 0..extra {
        let u = rng.below(n) as u32;
        let v = rng.below(n) as u32;
        if u != v {
            edges.push((u.min(v), u.max(v), rng.range_f64(0.1, 100.0)));
        }
    }
    Graph::from_edges(n, edges)
}

/// Like [`random_sparse_graph`] but with weights quantised to a handful
/// of integer values — exact weight ties everywhere. This is the regime
/// the ε-good boundary rule exists for: the engines' NN caches go stale
/// on tie *ids* (a patch can add an equal-weight edge toward a lower id
/// without triggering a rescan), and the exact engine still merges along
/// its cached pointer. Continuous weights never exercise this
/// (see `crate::approx::good`'s docs).
pub fn random_tied_graph(rng: &mut Rng) -> Graph {
    let n = rng.range_usize(2, 120);
    let mut edges: Vec<(u32, u32, Weight)> = Vec::new();
    for v in 1..n {
        if rng.bool_with(1.0 / 12.0) {
            continue;
        }
        let u = rng.below(v) as u32;
        edges.push((u, v as u32, (1 + rng.below(5)) as Weight));
    }
    for _ in 0..rng.range_usize(0, 3 * n) {
        let u = rng.below(n) as u32;
        let v = rng.below(n) as u32;
        if u != v {
            edges.push((u.min(v), u.max(v), (1 + rng.below(5)) as Weight));
        }
    }
    Graph::from_edges(n, edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn property_graphs_are_valid_and_sized() {
        let mut rng = Rng::seed_from(0x9E0);
        for _ in 0..20 {
            let g = random_sparse_graph(&mut rng);
            g.validate().unwrap();
            assert!((2..140).contains(&g.n()));
            let t = random_tied_graph(&mut rng);
            t.validate().unwrap();
            // Quantised weights: every edge is one of 1..=5.
            for u in 0..t.n() as u32 {
                for (_, w) in t.neighbors(u) {
                    assert!((1.0..=5.0).contains(&w) && w.fract() == 0.0);
                }
            }
        }
    }

    #[test]
    fn grid1d_is_path() {
        let g = grid1d_graph(100, 3);
        assert_eq!(g.n(), 100);
        assert_eq!(g.m(), 99);
        assert_eq!(g.degree(0), 1);
        assert_eq!(g.degree(50), 2);
        g.validate().unwrap();
        // Gaps are positive.
        for u in 0..100u32 {
            for (_, w) in g.neighbors(u) {
                assert!(w > 0.0);
            }
        }
    }

    #[test]
    fn adversarial_structure() {
        let g = adversarial_thm4(4);
        assert_eq!(g.n(), 16);
        assert_eq!(g.m(), 16 * 15 / 2);
        g.validate().unwrap();
        // Consecutive gaps strictly increase (the ε(k+1)² term).
        let mut prev = 0.0;
        for k in 0..15u32 {
            let w = g.weight(k, k + 1).unwrap();
            assert!(w > prev, "gap {k} not increasing");
            prev = w;
        }
    }

    #[test]
    fn adversarial_eps_resolves_in_f64() {
        let g = adversarial_thm4(12);
        // Smallest ε-difference between adjacent gaps must be nonzero.
        let w0 = g.weight(0, 1).unwrap();
        let w1 = g.weight(1, 2).unwrap();
        assert!(w1 - w0 > 0.0);
    }

    #[test]
    fn stable_hierarchy_levels() {
        let g = stable_hierarchy(3, 4.0, 5);
        assert_eq!(g.n(), 8);
        g.validate().unwrap();
        // Sibling leaves (LCA level 1) much closer than cousins (level 2+).
        let sib = g.weight(0, 1).unwrap();
        let cousin = g.weight(0, 2).unwrap();
        let far = g.weight(0, 7).unwrap();
        assert!(sib < cousin && cousin < far);
        assert!(cousin / sib > 3.0);
    }

    #[test]
    fn regular_graph_degree_bounded() {
        let g = random_regular_graph(200, 8, 11);
        g.validate().unwrap();
        assert!(g.max_degree() <= 8);
        // Near-regular: mean degree close to k.
        assert!(g.mean_degree() > 6.0, "mean degree {}", g.mean_degree());
    }

    #[test]
    fn regular_graph_weights_are_distinct_ranks() {
        let g = random_regular_graph(50, 4, 2);
        let mut seen = std::collections::HashSet::new();
        for u in 0..50u32 {
            for (v, w) in g.neighbors(u) {
                if u < v {
                    assert!(seen.insert(w as u64), "duplicate rank {w}");
                }
            }
        }
    }
}
