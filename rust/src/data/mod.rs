//! Synthetic dataset and graph generators.
//!
//! The paper's datasets (SIFT1B/1M/200K, WEB88M, News20, RCV1) are either
//! proprietary or hardware-gated at their published scale; per DESIGN.md §1
//! each is substituted with a generator that preserves the properties RAC's
//! behaviour depends on: metric space, bounded-degree kNN structure, and
//! hierarchical clusterability.
//!
//! * [`vectors`] — Gaussian-mixture "SIFT-like" dense vectors and Zipfian
//!   topic-model "web/doc-like" vectors.
//! * [`theory`] — the constructions from §4: the 1-d grid (α ≥ 1/3), the
//!   Theorem-4 adversarial sequence (Ω(n) rounds at height log n), stable
//!   cluster hierarchies (Theorem 5), and bounded-degree random graphs with
//!   randomly-ranked edges (§4.2.2).

pub mod theory;
pub mod vectors;

pub use theory::{
    adversarial_thm4, grid1d_graph, random_regular_graph, random_sparse_graph, random_tied_graph,
    stable_hierarchy,
};
pub use vectors::{gaussian_mixture, gaussian_mixture_labeled, topic_docs, Dataset, Metric};
