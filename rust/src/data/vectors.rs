//! Vector dataset generators: the SIFT-like and web/doc-like substitutes.

use crate::util::rng::Rng;

/// Dissimilarity metric attached to a dataset (paper Table 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Metric {
    /// Squared euclidean distance (SIFT datasets).
    L2,
    /// Cosine dissimilarity `1 - cos` (WEB88M, News20, RCV1).
    Cosine,
}

impl Metric {
    pub fn name(self) -> &'static str {
        match self {
            Metric::L2 => "l2",
            Metric::Cosine => "cosine",
        }
    }

    /// Exact dissimilarity between two feature rows (the pure-Rust oracle
    /// behind [`Dataset::dissimilarity`]; taking the slices directly lets
    /// callers hoist one row's slice out of an inner loop).
    pub fn dissimilarity(self, a: &[f32], b: &[f32]) -> f64 {
        match self {
            Metric::L2 => a
                .iter()
                .zip(b)
                .map(|(&x, &y)| {
                    let d = x as f64 - y as f64;
                    d * d
                })
                .sum(),
            Metric::Cosine => {
                let (mut dot, mut na, mut nb) = (0f64, 0f64, 0f64);
                for (&x, &y) in a.iter().zip(b) {
                    dot += x as f64 * y as f64;
                    na += x as f64 * x as f64;
                    nb += y as f64 * y as f64;
                }
                1.0 - dot / (na.sqrt().max(1e-12) * nb.sqrt().max(1e-12))
            }
        }
    }

    /// Dissimilarity if it is `< bound`, else `None`. For L2 the
    /// accumulation bails out as soon as the partial sum reaches `bound`
    /// (terms are non-negative, so the full sum could only be larger) —
    /// the ε-ball builder's early exit. Identical accumulation order to
    /// [`Metric::dissimilarity`], so any returned value is bitwise the
    /// same. Cosine has no monotone prefix, so it computes fully and
    /// compares at the end.
    pub fn dissimilarity_within(self, a: &[f32], b: &[f32], bound: f64) -> Option<f64> {
        match self {
            Metric::L2 => {
                let mut acc = 0.0f64;
                for (&x, &y) in a.iter().zip(b) {
                    let d = x as f64 - y as f64;
                    acc += d * d;
                    if acc >= bound {
                        return None;
                    }
                }
                Some(acc)
            }
            Metric::Cosine => {
                let w = self.dissimilarity(a, b);
                (w < bound).then_some(w)
            }
        }
    }
}

impl std::str::FromStr for Metric {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "l2" => Ok(Metric::L2),
            "cosine" => Ok(Metric::Cosine),
            other => Err(format!("unknown metric {other:?} (expected l2|cosine)")),
        }
    }
}

/// A dense row-major vector dataset.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub n: usize,
    pub d: usize,
    pub metric: Metric,
    /// Row-major `n × d`, f32 to match the AOT kernel interface.
    pub rows: Vec<f32>,
}

impl Dataset {
    pub fn row(&self, i: usize) -> &[f32] {
        &self.rows[i * self.d..(i + 1) * self.d]
    }

    /// Exact dissimilarity between two rows (pure-Rust oracle used by the
    /// kNN fallback path and by tests of the XLA path).
    pub fn dissimilarity(&self, i: usize, j: usize) -> f64 {
        self.metric.dissimilarity(self.row(i), self.row(j))
    }
}

/// SIFT-like dataset: a Gaussian mixture in `d` dimensions.
///
/// `n_clusters` centers drawn around `sqrt(n_clusters)` super-centers (so
/// the hierarchy has coarse and fine structure, mirroring SIFT's merge
/// profile in paper Fig 2c/d); each point is a center plus isotropic noise
/// with per-cluster `spread`; a `noise_frac` fraction of points is
/// background uniform noise (SIFT's outlier tail).
pub fn gaussian_mixture(
    n: usize,
    d: usize,
    n_clusters: usize,
    spread: f64,
    noise_frac: f64,
    seed: u64,
) -> Dataset {
    gaussian_mixture_labeled(n, d, n_clusters, spread, noise_frac, seed).0
}

/// [`gaussian_mixture`] plus ground-truth labels: the generating component
/// per point, with `n_clusters` reserved for background-noise points. Used
/// by the end-to-end example to score flat cuts (purity) against truth.
pub fn gaussian_mixture_labeled(
    n: usize,
    d: usize,
    n_clusters: usize,
    spread: f64,
    noise_frac: f64,
    seed: u64,
) -> (Dataset, Vec<u32>) {
    assert!(n_clusters >= 1);
    let mut rng = Rng::seed_from(seed);
    let n_super = (n_clusters as f64).sqrt().ceil() as usize;
    let sup: Vec<Vec<f32>> = (0..n_super)
        .map(|_| (0..d).map(|_| rng.range_f64(-10.0, 10.0) as f32).collect())
        .collect();
    let centers: Vec<Vec<f32>> = (0..n_clusters)
        .map(|_| {
            let s = &sup[rng.below(n_super)];
            s.iter()
                .map(|&v| v + rng.normal_with(0.0, 2.0) as f32)
                .collect()
        })
        .collect();
    let mut rows = Vec::with_capacity(n * d);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        if rng.bool_with(noise_frac) {
            labels.push(n_clusters as u32);
            for _ in 0..d {
                rows.push(rng.range_f64(-12.0, 12.0) as f32);
            }
        } else {
            let ci = rng.below(n_clusters);
            labels.push(ci as u32);
            let c = &centers[ci];
            for &v in c {
                rows.push(v + rng.normal_with(0.0, spread) as f32);
            }
        }
    }
    (
        Dataset {
            n,
            d,
            metric: Metric::L2,
            rows,
        },
        labels,
    )
}

/// Web/doc-like dataset: Zipfian topic mixtures (substitute for WEB88M /
/// News20 / RCV1 bag-of-words features, clustered under cosine).
///
/// Each document draws a dominant topic from a Zipf distribution over
/// `n_topics`, blends it with two Dirichlet-weighted secondary topics, and
/// adds sparse positive noise — producing the high-dimensional,
/// non-negative, cluster-structured geometry of tf-idf features.
pub fn topic_docs(n: usize, d: usize, n_topics: usize, seed: u64) -> Dataset {
    assert!(n_topics >= 2);
    let mut rng = Rng::seed_from(seed);
    // Topic base vectors: sparse non-negative profiles.
    let topics: Vec<Vec<f32>> = (0..n_topics)
        .map(|_| {
            (0..d)
                .map(|_| {
                    if rng.bool_with(0.15) {
                        rng.range_f64(0.5, 2.0) as f32
                    } else {
                        0.0
                    }
                })
                .collect()
        })
        .collect();
    let mut rows = Vec::with_capacity(n * d);
    for _ in 0..n {
        let main = (rng.zipf(n_topics as u64, 1.1) as usize - 1).min(n_topics - 1);
        let others = [rng.below(n_topics), rng.below(n_topics)];
        let mix = rng.dirichlet(&[1.0, 0.3, 0.1]);
        for j in 0..d {
            let mut v = mix[0] as f32 * topics[main][j]
                + mix[1] as f32 * topics[others[0]][j]
                + mix[2] as f32 * topics[others[1]][j];
            // Dense ZERO-MEAN per-document noise (LSA/embedding-like).
            // Two generator artifacts to avoid, neither of which real
            // corpora exhibit: (a) near-duplicate head-topic documents,
            // whose tied distances serialise RAC merges through the id
            // tie-break; (b) a shared positive noise direction, which
            // creates a cosine "hub" document that is everyone's nearest
            // neighbor — reciprocal pairs then collapse to one per round.
            v += rng.normal_with(0.0, 0.15) as f32;
            if rng.bool_with(0.02) {
                v += rng.range_f64(0.0, 0.5) as f32;
            }
            rows.push(v);
        }
    }
    Dataset {
        n,
        d,
        metric: Metric::Cosine,
        rows,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixture_shape_and_determinism() {
        let a = gaussian_mixture(100, 16, 5, 0.5, 0.05, 42);
        let b = gaussian_mixture(100, 16, 5, 0.5, 0.05, 42);
        assert_eq!(a.rows.len(), 100 * 16);
        assert_eq!(a.rows, b.rows);
        let c = gaussian_mixture(100, 16, 5, 0.5, 0.05, 43);
        assert_ne!(a.rows, c.rows);
    }

    #[test]
    fn mixture_is_clustered() {
        // There must exist tight pairs (same center) at spread 0.1.
        let ds = gaussian_mixture(200, 8, 4, 0.1, 0.0, 7);
        let mut near = 0usize;
        for i in 0..50 {
            for j in (i + 1)..50 {
                if ds.dissimilarity(i, j) < 1.0 {
                    near += 1;
                }
            }
        }
        assert!(near > 0, "no tight pairs at all — not clustered");
    }

    #[test]
    fn docs_shape_and_metric() {
        let ds = topic_docs(50, 64, 10, 1);
        assert_eq!(ds.metric, Metric::Cosine);
        assert_eq!(ds.rows.len(), 50 * 64);
        // Mostly-positive tf-idf-like profile with zero-mean jitter (the
        // jitter is what keeps documents distinct; see generator docs).
        let positive = ds.rows.iter().filter(|&&v| v > 0.0).count();
        assert!(positive * 2 > ds.rows.len(), "{positive}");
    }

    #[test]
    fn l2_dissimilarity_exact() {
        let ds = Dataset {
            n: 2,
            d: 2,
            metric: Metric::L2,
            rows: vec![0.0, 0.0, 3.0, 4.0],
        };
        assert!((ds.dissimilarity(0, 1) - 25.0).abs() < 1e-9);
        assert_eq!(ds.dissimilarity(0, 0), 0.0);
    }

    #[test]
    fn cosine_dissimilarity_exact() {
        let ds = Dataset {
            n: 3,
            d: 2,
            metric: Metric::Cosine,
            rows: vec![1.0, 0.0, 0.0, 1.0, 2.0, 0.0],
        };
        assert!((ds.dissimilarity(0, 1) - 1.0).abs() < 1e-6); // orthogonal
        assert!(ds.dissimilarity(0, 2).abs() < 1e-6); // parallel
    }

    #[test]
    fn dissimilarity_within_agrees_with_full_computation() {
        let a: Vec<f32> = vec![0.5, -1.0, 2.0, 0.0];
        let b: Vec<f32> = vec![1.5, 1.0, -0.5, 0.25];
        for metric in [Metric::L2, Metric::Cosine] {
            let full = metric.dissimilarity(&a, &b);
            // Bound above the value: bitwise the same result.
            assert_eq!(metric.dissimilarity_within(&a, &b, full + 1.0), Some(full));
            // Bound at or below the value: excluded (strict `<`).
            assert_eq!(metric.dissimilarity_within(&a, &b, full), None);
            assert_eq!(metric.dissimilarity_within(&a, &b, full / 2.0), None);
        }
    }

    #[test]
    fn metric_fromstr() {
        assert_eq!("l2".parse::<Metric>().unwrap(), Metric::L2);
        assert_eq!("cosine".parse::<Metric>().unwrap(), Metric::Cosine);
        assert!("manhattan".parse::<Metric>().is_err());
    }
}
