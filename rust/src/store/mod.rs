//! Flat arena-backed neighbor store — the cluster-adjacency representation
//! shared by every engine: the [`crate::engine::RoundDriver`]-backed
//! shared-memory engines ([`crate::rac::RacEngine`],
//! [`crate::approx::ApproxEngine`]) use it as their
//! [`crate::engine::EngineStore`] backend, and the distributed engines
//! ([`crate::dist`]) run the same representation under their accounting
//! loop.
//!
//! The PR-1 engines kept one `FxHashMap<u32, EdgeState>` per cluster, so
//! every hot-path operation (NN scans, union folds, per-round patches) was
//! a chain of pointer-chasing hash probes over thousands of tiny heap
//! allocations. TeraHAC and ParChain both attribute their scalability
//! headroom to flat, cache-friendly cluster/edge state; this module is
//! that layout:
//!
//! * One shared **arena** (`Vec<Entry>`) holds every `(neighbor id,
//!   EdgeState)` entry of every live cluster. Each cluster owns one
//!   contiguous run described by a [`Row`] (`off/len/cap/dead`), so NN
//!   scans and union folds are linear passes over contiguous memory.
//! * **Tombstones** — deletions overwrite the entry id with
//!   [`TOMBSTONE`] in place; readers skip them. A row's patch in a merge
//!   round never grows it (see below), so rows are never relocated on the
//!   engines' hot path.
//! * **Amortised append-with-doubling** — [`NeighborStore::push`] appends
//!   into spare row capacity, relocating the row to the arena tail with
//!   doubled capacity (and dropping its tombstones) when full. This is
//!   the store's *incremental* mutation API (graph construction, future
//!   dynamic workloads); the engines' merge loop never needs it, because
//!   patches are in-place and unions install whole rows.
//! * **Periodic compaction** keyed off the live/dead ratio — see
//!   [`NeighborStore::maybe_compact`] for the exact policy.
//!
//! ## Why merge-round patches never grow a row
//!
//! When a pair `(L, P)` merges, every non-merging neighbor `T` of the
//! union is patched: `T`'s edge to the retired partner `P` is removed and
//! the edge to the surviving leader `L` is upserted. Because adjacency is
//! symmetric, `T` appearing in the union map means `T`'s row already
//! holds an entry for `L` or for `P` (or both), so the patch is always an
//! in-place overwrite: update `L`'s slot and tombstone `P`'s, or rewrite
//! `P`'s slot as the new `L` entry. This is what makes the owner-sharded
//! parallel apply ([`NeighborStore::par_apply_round`]) lock-free: no
//! patch ever needs to relocate a row, so workers only ever write memory
//! owned by their shard.
//!
//! ## Compaction policy
//!
//! The store tracks the number of live entries; everything else in the
//! arena is dead space (tombstones, abandoned rows of retired clusters,
//! unused row capacity). After each merge round the engines call
//! [`NeighborStore::maybe_compact`], which rebuilds the arena iff
//!
//! * the arena holds at least [`COMPACT_MIN_ARENA`] entries (tiny runs
//!   never pay the copy), and
//! * dead entries strictly outnumber live ones (utilisation < 50%).
//!
//! Compaction copies every row's live entries (preserving their order) to
//! a fresh arena with zero slack, so its cost is `O(live)` and the
//! amortised overhead over a full clustering run is a constant factor of
//! the total merge work. The trigger depends only on the live/total
//! counts — which are identical across thread counts — so compaction
//! points, and therefore row layouts, are bit-for-bit reproducible for
//! any parallelism setting.
//!
//! ## Lane padding and the scan kernels
//!
//! Every allocation site ([`NeighborStore::from_graph`], `push`
//! relocation, [`NeighborStore::install_row`],
//! [`NeighborStore::maybe_compact`], the parallel apply's reserved
//! ranges) rounds a row's reserved capacity up to a multiple of
//! [`scan::LANES`], filling the slack with [`Entry::VACANT`] slots. The
//! invariant — slots `[off + len, off + cap)` are always `VACANT`, and
//! `cap % LANES == 0` — lets [`RowRef`] hand its whole padded span to the
//! vectorized row-scan kernels in [`scan`] with no scalar tail loop:
//! vacant slots carry `id == TOMBSTONE` and are masked exactly like
//! deletions. The hot scans ([`NeighborsRef::nn_min`],
//! [`NeighborsRef::for_each_band`]) dispatch to those kernels on the flat
//! store and fall back to a scalar fold on every other backend; both
//! paths are bitwise identical by the kernel contract ([`scan`]'s module
//! docs).
//!
//! ## Determinism contract
//!
//! The engines require dendrograms that are bitwise identical across
//! backends and thread counts. The store contributes: identical entry
//! values regardless of layout (all union-fold arithmetic in
//! [`crate::rac::logic`] reduces edges in a canonical slot order, never
//! in row-iteration order), and per-row patch sequences that are ordered
//! by ascending union index regardless of how rows are sharded over
//! workers.

pub mod scan;

use crate::graph::Graph;
use crate::linkage::{EdgeState, Weight};
use crate::util::pool::{Pool, SendPtr};

use scan::padded_len;

/// Entry id marking a deleted slot (also padding in reserved-but-unwritten
/// arena space). Cluster ids must therefore be `< u32::MAX`, which the
/// engines already require (`u32::MAX` is their `NO_NN` sentinel).
pub const TOMBSTONE: u32 = u32::MAX;

/// One computed merge: `(leader id, neighbor map of the union)` — the
/// unit the round-apply paths consume.
pub type UnionRow = (u32, Vec<(u32, EdgeState)>);

/// Rebuild threshold: arenas smaller than this never compact.
pub const COMPACT_MIN_ARENA: usize = 1 << 12;

/// One adjacency slot: a neighbor id (or [`TOMBSTONE`]) plus edge state.
/// `repr(C)` pins the field layout the raw-slice scan kernels
/// ([`scan`]) assume.
#[repr(C)]
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Entry {
    pub id: u32,
    pub edge: EdgeState,
}

impl Entry {
    /// Reserved-but-empty slot — also the lane-padding filler past a
    /// row's `len`. Its `(+inf, u32::MAX)` encoding is exactly what the
    /// scan kernels mask dead lanes to, so padded spans scan like the
    /// unpadded row.
    pub const VACANT: Entry = Entry {
        id: TOMBSTONE,
        edge: EdgeState {
            weight: Weight::INFINITY,
            count: 0,
        },
    };
}

/// Per-cluster descriptor of a contiguous arena run.
#[derive(Debug, Clone, Copy, Default)]
struct Row {
    /// First arena slot of the run.
    off: usize,
    /// Occupied slots (live entries + tombstones), `<= cap`.
    len: u32,
    /// Reserved slots.
    cap: u32,
    /// Tombstones among the first `len` slots.
    dead: u32,
}

impl Row {
    #[inline]
    fn live(&self) -> usize {
        (self.len - self.dead) as usize
    }
}

/// Read-only view of one cluster's adjacency row.
///
/// `Copy`, so it is passed by value into the engine-shared scan/fold
/// routines (see [`NeighborsRef`]).
#[derive(Clone, Copy)]
pub struct RowRef<'a> {
    entries: &'a [Entry],
    live: usize,
}

impl<'a> RowRef<'a> {
    /// Live `(neighbor id, edge)` pairs in row-storage order.
    pub fn iter(self) -> impl Iterator<Item = (u32, EdgeState)> + 'a {
        let entries: &'a [Entry] = self.entries;
        entries
            .iter()
            .filter(|e| e.id != TOMBSTONE)
            .map(|e| (e.id, e.edge))
    }

    /// Number of live entries.
    pub fn live_len(self) -> usize {
        self.live
    }

    pub fn is_empty(self) -> bool {
        self.live == 0
    }

    /// Edge toward `id`, if present (linear scan — rows are small and
    /// contiguous, which beats hashing at kNN-scale degrees).
    pub fn get(self, id: u32) -> Option<EdgeState> {
        self.entries
            .iter()
            .find(|e| e.id == id)
            .map(|e| e.edge)
    }

    /// The raw contiguous slot span backing this row — live entries,
    /// tombstones, and the trailing [`Entry::VACANT`] lane padding. What
    /// the vectorized kernels in [`scan`] consume; dead slots must be
    /// masked by `id == TOMBSTONE` (their stored weight is stale).
    pub fn entries(self) -> &'a [Entry] {
        self.entries
    }
}

/// Read-only neighbor view the engine-shared logic
/// ([`crate::rac::logic`]) and the driver's selectors
/// ([`crate::engine`]) fold over. Implemented by the flat store's
/// [`RowRef`] and — for the differential oracle
/// ([`crate::rac::baseline::HashStore`]) — by
/// `&FxHashMap<u32, EdgeState>`.
///
/// Implementations MUST visit each live neighbor exactly once; visit
/// *order* is explicitly unspecified, and all arithmetic layered on top
/// is required to be independent of it (see the determinism notes in
/// [`crate::rac::logic`]).
pub trait NeighborsRef: Copy {
    /// Visit every live `(neighbor id, edge)` entry.
    fn for_each_edge(self, f: impl FnMut(u32, EdgeState));

    /// Number of live entries.
    fn live_len(self) -> usize;

    /// `(weight, id)` lex-min live entry — `(NO_NN, +inf)` when empty.
    /// The default is the scalar reference fold; [`RowRef`] overrides it
    /// with the dispatched row kernel ([`scan::scan_nn_entries`]), which
    /// is bitwise identical by the kernel contract.
    fn nn_min(self) -> (u32, Weight) {
        let mut best_id = scan::NO_NN;
        let mut best_w = Weight::INFINITY;
        self.for_each_edge(|v, e| {
            if scan::nn_better(e.weight, v, best_w, best_id) {
                best_w = e.weight;
                best_id = v;
            }
        });
        (best_id, best_w)
    }

    /// Visit every live entry with `id > a` inside the ε-good band
    /// ([`scan::band_accepts`]`(w, id, thr, nn_a)`). The default is the
    /// scalar filter over [`Self::for_each_edge`]; [`RowRef`] overrides
    /// it with the dispatched band kernel ([`scan::scan_band_entries`]).
    fn for_each_band(self, a: u32, thr: Weight, nn_a: u32, mut f: impl FnMut(u32, Weight)) {
        self.for_each_edge(|b, e| {
            if b > a && scan::band_accepts(e.weight, b, thr, nn_a) {
                f(b, e.weight);
            }
        });
    }
}

impl NeighborsRef for RowRef<'_> {
    #[inline]
    fn for_each_edge(self, mut f: impl FnMut(u32, EdgeState)) {
        for e in self.entries {
            if e.id != TOMBSTONE {
                f(e.id, e.edge);
            }
        }
    }

    #[inline]
    fn live_len(self) -> usize {
        self.live
    }

    #[inline]
    fn nn_min(self) -> (u32, Weight) {
        scan::scan_nn_entries(self.entries)
    }

    #[inline]
    fn for_each_band(self, a: u32, thr: Weight, nn_a: u32, f: impl FnMut(u32, Weight)) {
        scan::scan_band_entries(self.entries, a, thr, nn_a, f);
    }
}

impl NeighborsRef for &rustc_hash::FxHashMap<u32, EdgeState> {
    #[inline]
    fn for_each_edge(self, mut f: impl FnMut(u32, EdgeState)) {
        for (&v, &e) in self {
            f(v, e);
        }
    }

    #[inline]
    fn live_len(self) -> usize {
        self.len()
    }
}

/// The arena-backed adjacency store. See the module docs for layout and
/// policy.
pub struct NeighborStore {
    arena: Vec<Entry>,
    rows: Vec<Row>,
    /// Live entries across all rows; `arena.len() - live` is dead space.
    live: usize,
}

impl NeighborStore {
    /// Empty store with `n` zero-capacity rows.
    pub fn new(n: usize) -> NeighborStore {
        NeighborStore {
            arena: Vec::new(),
            rows: vec![Row::default(); n],
            live: 0,
        }
    }

    /// Build from a graph, pre-sizing every row from the CSR degrees
    /// (rounded up to the lane multiple) — one arena allocation, no
    /// per-insert growth.
    pub fn from_graph(g: &Graph) -> NeighborStore {
        let n = g.n();
        let total = 2 * g.m();
        let mut arena = Vec::with_capacity(total + n * (scan::LANES - 1));
        let mut rows = Vec::with_capacity(n);
        for u in 0..n as u32 {
            let off = arena.len();
            for (v, w) in g.neighbors(u) {
                arena.push(Entry {
                    id: v,
                    edge: EdgeState::point(w),
                });
            }
            let len = (arena.len() - off) as u32;
            let cap = padded_len(len as usize) as u32;
            arena.resize(off + cap as usize, Entry::VACANT);
            rows.push(Row {
                off,
                len,
                cap,
                dead: 0,
            });
        }
        NeighborStore {
            arena,
            rows,
            live: total,
        }
    }

    /// Number of rows (clusters, live or retired).
    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Live entries across all rows.
    pub fn live_entries(&self) -> usize {
        self.live
    }

    /// Dead arena slots (tombstones + abandoned rows + slack capacity).
    pub fn dead_entries(&self) -> usize {
        self.arena.len() - self.live
    }

    /// Total arena length in slots.
    pub fn arena_len(&self) -> usize {
        self.arena.len()
    }

    /// Read-only view of cluster `c`'s row. The span covers the occupied
    /// slots rounded up to the lane multiple — never past `cap` — so the
    /// scan kernels can consume it whole; the extra slots are `VACANT`
    /// by the padding invariant (module docs).
    #[inline]
    pub fn row(&self, c: u32) -> RowRef<'_> {
        let r = &self.rows[c as usize];
        let span = padded_len(r.len as usize).min(r.cap as usize);
        RowRef {
            entries: &self.arena[r.off..r.off + span],
            live: r.live(),
        }
    }

    /// Append `(id, edge)` to row `c` (caller guarantees `id` is not
    /// already present). Amortised O(1): uses spare capacity when
    /// available, otherwise relocates the row to the arena tail with
    /// doubled capacity, dropping its tombstones.
    pub fn push(&mut self, c: u32, id: u32, edge: EdgeState) {
        debug_assert_ne!(id, TOMBSTONE, "TOMBSTONE is not a valid neighbor id");
        let row = self.rows[c as usize];
        if row.len < row.cap {
            self.arena[row.off + row.len as usize] = Entry { id, edge };
            self.rows[c as usize].len += 1;
        } else {
            let new_cap = padded_len((row.cap as usize * 2).max(4));
            let live: Vec<Entry> = self.arena[row.off..row.off + row.len as usize]
                .iter()
                .copied()
                .filter(|e| e.id != TOMBSTONE)
                .collect();
            let new_off = self.arena.len();
            self.arena.resize(new_off + new_cap, Entry::VACANT);
            self.arena[new_off..new_off + live.len()].copy_from_slice(&live);
            self.arena[new_off + live.len()] = Entry { id, edge };
            self.rows[c as usize] = Row {
                off: new_off,
                len: live.len() as u32 + 1,
                cap: new_cap as u32,
                dead: 0,
            };
        }
        self.live += 1;
    }

    /// Tombstone row `c`'s entry for `id` (no-op when absent).
    pub fn remove(&mut self, c: u32, id: u32) {
        let row = self.rows[c as usize];
        let span = &mut self.arena[row.off..row.off + row.len as usize];
        if let Some(e) = span.iter_mut().find(|e| e.id == id) {
            e.id = TOMBSTONE;
            self.rows[c as usize].dead += 1;
            self.live -= 1;
        }
    }

    /// Merge-round patch of non-merging neighbor `t`: drop `t`'s edge to
    /// the retired partner `p`, upsert the edge to the surviving leader
    /// `l`. In-place by the symmetry argument in the module docs.
    pub fn patch(&mut self, t: u32, l: u32, p: u32, e: EdgeState) {
        let row = self.rows[t as usize];
        let span = &mut self.arena[row.off..row.off + row.len as usize];
        let delta = patch_span(span, &mut self.rows[t as usize].dead, l, p, e);
        self.live = (self.live as isize + delta) as usize;
    }

    /// Replace row `c` with `entries`, written contiguously at the arena
    /// tail (lane-padded); the old run becomes dead space.
    pub fn install_row(&mut self, c: u32, entries: &[(u32, EdgeState)]) {
        let off = self.arena.len();
        self.arena.extend(
            entries
                .iter()
                .map(|&(id, edge)| Entry { id, edge }),
        );
        let cap = padded_len(entries.len()) as u32;
        self.arena.resize(off + cap as usize, Entry::VACANT);
        let old = self.rows[c as usize];
        self.live = self.live - old.live() + entries.len();
        self.rows[c as usize] = Row {
            off,
            len: entries.len() as u32,
            cap,
            dead: 0,
        };
    }

    /// Retire row `c`: zero its descriptor, abandoning its arena run.
    pub fn clear_row(&mut self, c: u32) {
        let old = self.rows[c as usize];
        self.live -= old.live();
        self.rows[c as usize] = Row {
            off: old.off,
            len: 0,
            cap: 0,
            dead: 0,
        };
    }

    /// Compact iff utilisation dropped below 50% (see module docs for the
    /// full policy). Returns whether a rebuild happened.
    pub fn maybe_compact(&mut self) -> bool {
        let dead = self.arena.len() - self.live;
        if self.arena.len() < COMPACT_MIN_ARENA || dead <= self.live {
            return false;
        }
        let mut arena = Vec::with_capacity(self.live + self.rows.len() * (scan::LANES - 1));
        for row in &mut self.rows {
            let off = arena.len();
            for e in &self.arena[row.off..row.off + row.len as usize] {
                if e.id != TOMBSTONE {
                    arena.push(*e);
                }
            }
            let len = (arena.len() - off) as u32;
            let cap = padded_len(len as usize) as u32;
            arena.resize(off + cap as usize, Entry::VACANT);
            *row = Row {
                off,
                len,
                cap,
                dead: 0,
            };
        }
        self.arena = arena;
        true
    }

    /// Apply one merge round in parallel, owner-sharded over `pool`'s
    /// workers with no locks: worker `w` (of `S = pool.threads()` shards)
    /// exclusively handles every row whose cluster id satisfies
    /// `id % S == w` — patches to its non-merging targets, union-row
    /// installs for its leaders, clears for its retired partners. Rows
    /// are disjoint across shards and union rows are written into ranges
    /// reserved up front, so no two workers ever touch the same memory.
    ///
    /// `unions` is the round's merge list in ascending-leader order: for
    /// each `(leader, union_map)`, `partner_of(leader)` names the retired
    /// partner and `patch_target(t)` says whether target `t` is a
    /// non-merging survivor to patch (merging targets are installed by
    /// their own union entry instead).
    ///
    /// Results are bit-for-bit identical for every shard count: each row
    /// receives its patches in ascending union order regardless of `S`,
    /// and every write is a pure function of that row's prior state.
    pub fn par_apply_round(
        &mut self,
        pool: &Pool,
        unions: &[UnionRow],
        partner_of: impl Fn(u32) -> u32 + Sync,
        patch_target: impl Fn(u32) -> bool + Sync,
    ) {
        if unions.is_empty() {
            return;
        }
        let shards = pool.threads();
        if shards == 1 {
            // Single shard: the serial path, no bucketing overhead.
            for (l, map) in unions {
                let p = partner_of(*l);
                for &(t, e) in map {
                    if patch_target(t) {
                        self.patch(t, *l, p, e);
                    }
                }
                self.install_row(*l, map);
                self.clear_row(p);
            }
            return;
        }

        // Reserve contiguous fresh ranges for every union row up front so
        // the arena never reallocates while workers hold pointers into it,
        // and bucket every operation by owner shard in the same O(total)
        // pass — each worker then walks only its own work list instead of
        // rescanning every union (which would put an O(total) floor under
        // every worker regardless of shard count). Bucket order is
        // ascending union index, so each row still receives its patches in
        // exactly the serial order. Ranges are lane-padded exactly like
        // the serial install_row path, so arena layout stays identical
        // across shard counts.
        let total: usize = unions.iter().map(|(_, m)| padded_len(m.len())).sum();
        let base = self.arena.len();
        self.arena.resize(base + total, Entry::VACANT);
        let mut offs = Vec::with_capacity(unions.len());
        let mut partners = Vec::with_capacity(unions.len());
        // (union idx, entry idx) per shard for patches; union idx per
        // shard for installs/clears.
        let mut patch_work: Vec<Vec<(u32, u32)>> = vec![Vec::new(); shards];
        let mut install_work: Vec<Vec<u32>> = vec![Vec::new(); shards];
        let mut clear_work: Vec<Vec<u32>> = vec![Vec::new(); shards];
        let mut off = base;
        for (i, (l, map)) in unions.iter().enumerate() {
            let p = partner_of(*l);
            offs.push(off);
            partners.push(p);
            off += padded_len(map.len());
            for (j, &(t, _)) in map.iter().enumerate() {
                if patch_target(t) {
                    patch_work[t as usize % shards].push((i as u32, j as u32));
                }
            }
            install_work[*l as usize % shards].push(i as u32);
            clear_work[p as usize % shards].push(i as u32);
        }

        let arena = SendPtr(self.arena.as_mut_ptr());
        let rows = SendPtr(self.rows.as_mut_ptr());
        let deltas: Vec<isize> = pool.par_map_indexed(shards, |w| {
            let mut delta = 0isize;
            // Patches first, installs/clears after: patches touch only
            // non-merging rows, installs/clears only merging rows, so the
            // two groups are independent; within a row, bucket order keeps
            // patches in ascending union order (bit-for-bit the serial
            // sequence).
            for &(i, j) in &patch_work[w] {
                let (l, map) = &unions[i as usize];
                let (t, e) = map[j as usize];
                // SAFETY: row `t` (descriptor and arena span) is written
                // only by shard `t % S`; spans of distinct rows never
                // overlap; the arena is not resized while workers run.
                let row = unsafe { &mut *rows.0.add(t as usize) };
                let span = unsafe {
                    std::slice::from_raw_parts_mut(arena.0.add(row.off), row.len as usize)
                };
                delta += patch_span(span, &mut row.dead, *l, partners[i as usize], e);
            }
            for &i in &install_work[w] {
                let (l, map) = &unions[i as usize];
                // SAFETY: as above — row `l` belongs to this shard, and
                // its reserved range [offs[i], offs[i]+len) is written by
                // no one else.
                let row = unsafe { &mut *rows.0.add(*l as usize) };
                delta += map.len() as isize - row.live() as isize;
                for (k, &(id, edge)) in map.iter().enumerate() {
                    unsafe { arena.0.add(offs[i as usize] + k).write(Entry { id, edge }) };
                }
                *row = Row {
                    off: offs[i as usize],
                    len: map.len() as u32,
                    cap: padded_len(map.len()) as u32,
                    dead: 0,
                };
            }
            for &i in &clear_work[w] {
                let p = partners[i as usize];
                // SAFETY: as above — row `p` belongs to this shard.
                let row = unsafe { &mut *rows.0.add(p as usize) };
                delta -= row.live() as isize;
                *row = Row {
                    off: row.off,
                    len: 0,
                    cap: 0,
                    dead: 0,
                };
            }
            delta
        });
        self.live = (self.live as isize + deltas.iter().sum::<isize>()) as usize;
    }
}

/// The single implementation of merge-round patch slot logic (shared by
/// the serial [`NeighborStore::patch`] and the owner-sharded parallel
/// apply): upsert the leader edge, retire the partner edge, reusing the
/// partner's slot when the leader had none. Returns the live-entry delta.
fn patch_span(span: &mut [Entry], row_dead: &mut u32, l: u32, p: u32, e: EdgeState) -> isize {
    let (mut slot_l, mut slot_p, mut slot_tomb) = (None, None, None);
    for (i, en) in span.iter().enumerate() {
        if en.id == l {
            slot_l = Some(i);
            if slot_p.is_some() {
                break;
            }
        } else if en.id == p {
            slot_p = Some(i);
            if slot_l.is_some() {
                break;
            }
        } else if en.id == TOMBSTONE && slot_tomb.is_none() {
            slot_tomb = Some(i);
        }
    }
    match (slot_l, slot_p) {
        (Some(i), Some(j)) => {
            span[i].edge = e;
            span[j].id = TOMBSTONE;
            *row_dead += 1;
            -1
        }
        (Some(i), None) => {
            span[i].edge = e;
            0
        }
        (None, Some(j)) => {
            span[j] = Entry { id: l, edge: e };
            0
        }
        (None, None) => {
            // Symmetry guarantees l or p is present (module docs); keep
            // the operation total by claiming a tombstone slot if the
            // invariant is ever violated upstream.
            let i = slot_tomb.expect("neighbor row lost symmetry: no slot for union edge");
            span[i] = Entry { id: l, edge: e };
            *row_dead -= 1;
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    fn es(w: Weight) -> EdgeState {
        EdgeState::point(w)
    }

    fn row_vec(s: &NeighborStore, c: u32) -> Vec<(u32, Weight)> {
        s.row(c).iter().map(|(v, e)| (v, e.weight)).collect()
    }

    fn diamond() -> Graph {
        Graph::from_edges(
            4,
            [
                (0, 1, 1.0),
                (1, 2, 2.0),
                (2, 3, 3.0),
                (3, 0, 4.0),
                (0, 2, 5.0),
            ],
        )
    }

    #[test]
    fn from_graph_mirrors_csr() {
        let g = diamond();
        let s = NeighborStore::from_graph(&g);
        assert_eq!(s.n_rows(), 4);
        assert_eq!(s.live_entries(), 2 * g.m());
        // The only dead space is the per-row lane padding.
        let pad: usize = (0..4u32)
            .map(|u| padded_len(g.degree(u)) - g.degree(u))
            .sum();
        assert_eq!(s.dead_entries(), pad);
        for u in 0..4u32 {
            let want: Vec<(u32, Weight)> = g.neighbors(u).collect();
            assert_eq!(row_vec(&s, u), want, "row {u}");
            assert_eq!(s.row(u).live_len(), g.degree(u));
        }
        assert_eq!(s.row(0).get(2), Some(es(5.0)));
        assert_eq!(s.row(0).get(9), None);
    }

    #[test]
    fn push_grows_with_relocation() {
        let mut s = NeighborStore::new(2);
        for i in 0..10u32 {
            s.push(0, i + 2, es(i as Weight));
        }
        assert_eq!(s.row(0).live_len(), 10);
        assert_eq!(
            row_vec(&s, 0),
            (0..10u32).map(|i| (i + 2, i as Weight)).collect::<Vec<_>>()
        );
        // Row 1 untouched.
        assert!(s.row(1).is_empty());
        // Relocations abandoned old runs: arena holds dead space now.
        assert!(s.dead_entries() > 0);
        assert_eq!(s.live_entries(), 10);
    }

    #[test]
    fn remove_tombstones_in_place() {
        let g = diamond();
        let mut s = NeighborStore::from_graph(&g);
        s.remove(0, 2);
        assert_eq!(row_vec(&s, 0), vec![(1, 1.0), (3, 4.0)]);
        assert_eq!(s.row(0).live_len(), 2);
        assert_eq!(s.live_entries(), 2 * g.m() - 1);
        // Removing a missing id is a no-op.
        s.remove(0, 99);
        assert_eq!(s.row(0).live_len(), 2);
        // Relocation after tombstoning drops the tombstone.
        s.push(0, 5, es(9.0));
        s.push(0, 6, es(10.0));
        assert_eq!(row_vec(&s, 0), vec![(1, 1.0), (3, 4.0), (5, 9.0), (6, 10.0)]);
    }

    #[test]
    fn patch_reuses_partner_slot() {
        // Row 0 has an edge to p=3 but none to l=2: the patch must land in
        // p's slot without growing the row.
        let mut s = NeighborStore::new(1);
        s.push(0, 1, es(1.0));
        s.push(0, 3, es(4.0));
        let cap_before = s.arena_len();
        s.patch(0, 2, 3, es(7.5));
        assert_eq!(row_vec(&s, 0), vec![(1, 1.0), (2, 7.5)]);
        assert_eq!(s.arena_len(), cap_before, "patch must not allocate");
    }

    #[test]
    fn patch_overwrites_leader_and_retires_partner() {
        let mut s = NeighborStore::new(1);
        s.push(0, 2, es(1.0));
        s.push(0, 3, es(4.0));
        s.patch(0, 2, 3, es(2.5));
        assert_eq!(row_vec(&s, 0), vec![(2, 2.5)]);
        assert_eq!(s.row(0).live_len(), 1);
        // Leader present, partner absent: plain overwrite.
        s.patch(0, 2, 9, es(6.0));
        assert_eq!(row_vec(&s, 0), vec![(2, 6.0)]);
    }

    #[test]
    fn install_and_clear_rows() {
        let g = diamond();
        let mut s = NeighborStore::from_graph(&g);
        s.install_row(0, &[(2, es(1.5)), (3, es(2.5))]);
        assert_eq!(row_vec(&s, 0), vec![(2, 1.5), (3, 2.5)]);
        s.clear_row(1);
        assert!(s.row(1).is_empty());
        assert_eq!(s.live_entries(), 2 + 3 + 2); // rows 0,2,3
        assert!(s.dead_entries() > 0);
    }

    #[test]
    fn compaction_preserves_rows_and_reclaims_space() {
        let mut s = NeighborStore::new(8);
        // Grow rows well past the compaction minimum, then churn.
        let per_row = COMPACT_MIN_ARENA / 4;
        for c in 0..8u32 {
            for i in 0..per_row as u32 {
                s.push(c, 8 + i, es((c as Weight) + i as Weight));
            }
        }
        for c in 4..8u32 {
            s.clear_row(c);
        }
        let want: Vec<Vec<(u32, Weight)>> = (0..8u32).map(|c| row_vec(&s, c)).collect();
        assert!(s.dead_entries() > s.live_entries());
        assert!(s.maybe_compact());
        // Post-compact the only dead space is per-row lane padding.
        assert!(s.dead_entries() < s.n_rows() * scan::LANES);
        assert!(s.arena_len() - s.live_entries() == s.dead_entries());
        for c in 0..8u32 {
            assert_eq!(row_vec(&s, c), want[c as usize], "row {c} changed");
        }
        // Already compact: second call is a no-op.
        assert!(!s.maybe_compact());
    }

    #[test]
    fn small_arenas_never_compact() {
        let g = diamond();
        let mut s = NeighborStore::from_graph(&g);
        s.clear_row(0);
        s.clear_row(1);
        s.clear_row(2);
        assert!(s.dead_entries() > s.live_entries());
        assert!(!s.maybe_compact(), "below COMPACT_MIN_ARENA");
    }

    /// The parallel owner-sharded apply must produce exactly the serial
    /// patch/install/clear sequence, for every shard count.
    #[test]
    fn par_apply_round_matches_serial() {
        // Clusters 0..8; pairs (0,1) and (2,3) merge; 4..8 survive.
        let edges: Vec<(u32, u32, Weight)> = vec![
            (0, 1, 1.0),
            (2, 3, 1.5),
            (0, 4, 5.0),
            (1, 5, 6.0),
            (2, 5, 7.0),
            (3, 6, 8.0),
            (0, 2, 9.0), // cross-pair edge
            (4, 5, 11.0),
            (5, 6, 12.0),
            (6, 7, 13.0),
        ];
        let g = Graph::from_edges(8, edges);
        let merging = [true, true, true, true, false, false, false, false];
        // Hand-built union maps (values don't matter for layout logic).
        let unions: Vec<UnionRow> = vec![
            (0, vec![(4, es(5.0)), (5, es(6.0)), (2, es(9.0))]),
            (2, vec![(5, es(7.0)), (6, es(8.0)), (0, es(9.0))]),
        ];
        let partner = |l: u32| l + 1;

        let mut serial = NeighborStore::from_graph(&g);
        for (l, map) in &unions {
            let p = partner(*l);
            for &(t, e) in map {
                if !merging[t as usize] {
                    serial.patch(t, *l, p, e);
                }
            }
            serial.install_row(*l, map);
            serial.clear_row(p);
        }

        for threads in [1usize, 2, 3, 8] {
            let pool = Pool::new(threads);
            let mut par = NeighborStore::from_graph(&g);
            par.par_apply_round(&pool, &unions, partner, |t| !merging[t as usize]);
            assert_eq!(par.live_entries(), serial.live_entries(), "t={threads}");
            assert_eq!(par.arena_len(), serial.arena_len(), "t={threads}");
            for c in 0..8u32 {
                assert_eq!(row_vec(&par, c), row_vec(&serial, c), "row {c}, t={threads}");
            }
        }
    }

    /// Every mutation path must preserve the lane-padding invariant the
    /// scan kernels rely on: row capacity is a multiple of
    /// [`scan::LANES`], the padded span fits inside it, and every slot in
    /// `[off + len, off + cap)` is `VACANT`.
    #[test]
    fn rows_stay_lane_padded() {
        fn check(s: &NeighborStore, when: &str) {
            for (c, r) in s.rows.iter().enumerate() {
                assert_eq!(r.cap as usize % scan::LANES, 0, "{when}: row {c} cap {}", r.cap);
                assert!(r.len <= r.cap, "{when}: row {c} len {} > cap {}", r.len, r.cap);
                for (i, e) in s.arena[r.off + r.len as usize..r.off + r.cap as usize]
                    .iter()
                    .enumerate()
                {
                    assert_eq!(
                        *e,
                        Entry::VACANT,
                        "{when}: row {c} slack slot {i} not vacant"
                    );
                }
            }
        }

        let g = diamond();
        let mut s = NeighborStore::from_graph(&g);
        check(&s, "from_graph");
        // Spare-capacity pushes, then enough to force a relocation.
        for i in 0..9u32 {
            s.push(0, 10 + i, es(i as Weight));
        }
        check(&s, "push/relocate");
        s.remove(0, 10);
        s.remove(0, 2);
        check(&s, "remove");
        s.patch(1, 5, 2, es(0.5));
        check(&s, "patch");
        s.install_row(3, &[(0, es(1.0)), (5, es(2.0)), (6, es(3.0))]);
        s.clear_row(2);
        check(&s, "install/clear");

        // The parallel apply's reserved ranges pad the same way.
        let g2 = Graph::from_edges(
            6,
            [
                (0, 1, 1.0),
                (0, 2, 3.0),
                (1, 3, 4.0),
                (2, 3, 2.0),
                (2, 4, 5.0),
                (3, 5, 6.0),
            ],
        );
        let unions: Vec<UnionRow> = vec![(0, vec![(2, es(3.0)), (3, es(4.0))])];
        for threads in [1usize, 3] {
            let pool = Pool::new(threads);
            let mut par = NeighborStore::from_graph(&g2);
            par.par_apply_round(&pool, &unions, |l| l + 1, |t| t > 1);
            check(&par, "par_apply_round");
        }

        // Compaction rebuilds padded.
        let mut big = NeighborStore::new(4);
        for c in 0..4u32 {
            for i in 0..(COMPACT_MIN_ARENA / 2) as u32 {
                big.push(c, 4 + i, es(i as Weight));
            }
        }
        big.clear_row(0);
        big.clear_row(1);
        assert!(big.maybe_compact());
        check(&big, "maybe_compact");
    }

    #[test]
    fn neighbors_ref_impls_agree() {
        use rustc_hash::FxHashMap;
        let g = diamond();
        let s = NeighborStore::from_graph(&g);
        let map: FxHashMap<u32, EdgeState> =
            g.neighbors(0).map(|(v, w)| (v, es(w))).collect();
        let mut from_row: Vec<(u32, Weight)> = Vec::new();
        s.row(0).for_each_edge(|v, e| from_row.push((v, e.weight)));
        let mut from_map: Vec<(u32, Weight)> = Vec::new();
        (&map).for_each_edge(|v, e| from_map.push((v, e.weight)));
        from_map.sort_unstable_by_key(|&(v, _)| v);
        assert_eq!(from_row, from_map);
        assert_eq!(s.row(0).live_len(), (&map).live_len());
    }
}
