//! Vectorized row-scan kernels for the two hot linear passes, behind
//! one-time runtime dispatch with a bitwise-pinned scalar fallback.
//!
//! Every round of every engine is dominated by two contiguous-row scans
//! over the flat arena ([`crate::store::NeighborStore`]):
//!
//! * the exact `(weight, id)`-min NN scan ([`crate::rac::logic::scan_nn`],
//!   driven per-cluster by [`crate::engine::RoundDriver`] and both
//!   distributed engines), lowered here as [`scan_nn_entries`], and
//! * the ε-good eligibility sweep
//!   ([`crate::approx::good::scan_row_candidates`]), whose per-row band
//!   test `w < thr || (w == thr && id == nn)` is lowered as
//!   [`scan_band_entries`].
//!
//! Both kernels operate on the raw contiguous [`Entry`] slice of a row
//! (see `RowRef::entries`), including its tombstoned and vacant padding
//! slots: any slot whose id is [`TOMBSTONE`] is masked by treating it as
//! `(+inf, u32::MAX)` *before* any weight or band comparison — tombstones
//! keep their stale weight in the arena, so the mask must come first.
//!
//! ## Dispatch
//!
//! Kernel selection happens once per process (first scan) and is cached
//! in an atomic:
//!
//! * `x86_64` with AVX2 detected at runtime → [`Kernel::Avx2`]
//!   (4 × f64 lanes);
//! * `aarch64` with NEON detected at runtime → [`Kernel::Neon`]
//!   (2 × f64 lanes);
//! * everything else → [`Kernel::Scalar`], the always-compiled fallback.
//!
//! The scalar path can be forced for differential testing via the
//! `RAC_FORCE_SCALAR` environment variable (any value other than empty /
//! `0` / `false` / `off` / `no`), the `force_scalar` config key /
//! `--force-scalar` CLI flag (see [`crate::config::RunConfig`]), or
//! programmatically via [`force_scalar`] (process-wide) or a scoped
//! [`KernelPin`] (restores the entry dispatch on drop — how the config
//! key keeps its pin from leaking past the run that asked for it).
//!
//! ## Why the packed compare preserves the tie-break (bitwise contract)
//!
//! The crate-wide total order for NN selection is `(weight, id)` lex-min
//! under IEEE `<` / `==` (see [`nn_better`]): strictly smaller weight
//! wins, equal weight falls back to smaller id. Because live ids within a
//! row are unique, this is a *strict total order on live entries* — it
//! has a unique minimum, and that minimum is independent of visit order:
//!
//! * NaN weights never win (`<` and `==` are both false), in any lane or
//!   scalar step, so they are skipped identically on every path;
//! * `-0.0 == +0.0` ties fall through to the id compare, which is exact
//!   integer arithmetic;
//! * masked lanes carry `(+inf, u32::MAX)` — the accumulator's initial
//!   value — and therefore never displace a live candidate (equal weight,
//!   id not smaller) and never survive a live candidate with finite
//!   weight or smaller id.
//!
//! A lane-partitioned reduction (4 running minima folded at the end) thus
//! lands on exactly the entry the scalar left-to-right fold lands on, and
//! copies its weight bits verbatim — results are bitwise identical to the
//! scalar path, which is the determinism contract every differential
//! suite (`store_equivalence`, `approx_quality`, `dist_*`,
//! `trace_invariance`) pins. `tests/simd_scan.rs` property-tests this
//! equality over every row length and remainder shape, and end-to-end
//! over full dendrograms for all five engines.
//!
//! The eligibility band is a pure per-lane predicate (no cross-lane
//! state), so its SIMD form only has to visit accepted entries in storage
//! order — a movemask over the packed predicate does exactly that.

use crate::linkage::Weight;
use crate::store::{Entry, TOMBSTONE};
use std::cmp::Ordering;
use std::sync::atomic::{AtomicU8, Ordering as AtomicOrd};

/// "No nearest neighbor" sentinel shared by every engine (isolated or
/// retired clusters). Identical to [`TOMBSTONE`] by design: cluster ids
/// must stay `< u32::MAX` either way, and the NN scan's accumulator can
/// start at `(NO_NN, +inf)` — the same encoding masked lanes carry.
pub const NO_NN: u32 = u32::MAX;

/// Widest SIMD lane count across supported targets (AVX2: 4 × f64).
/// Arena rows reserve capacity in multiples of this so vector kernels
/// never read past a row's reserved span.
pub const LANES: usize = 4;

/// `len` rounded up to a multiple of [`LANES`] (0 stays 0).
#[inline]
pub fn padded_len(len: usize) -> usize {
    len.div_ceil(LANES) * LANES
}

/// The crate-wide NN total order: does candidate `(w, id)` beat the
/// current best `(best_w, best_id)`? Strictly smaller weight wins; equal
/// weight falls back to strictly smaller id. IEEE semantics — a NaN
/// weight never beats anything (both compares are false), so NaNs are
/// skipped identically on the scalar and vector paths.
#[inline]
pub fn nn_better(w: Weight, id: u32, best_w: Weight, best_id: u32) -> bool {
    w < best_w || (w == best_w && id < best_id)
}

/// The ε-good eligibility band from one endpoint's perspective: accept a
/// partner at weight `w` iff `w` is strictly inside the threshold, or
/// exactly on the boundary *and* the partner is the cached NN pointer
/// (`nn_a`) — the boundary case keeps exactness at ε = 0 (see
/// [`crate::approx::good`]).
#[inline]
pub fn band_accepts(w: Weight, b: u32, thr: Weight, nn_a: u32) -> bool {
    w < thr || (w == thr && b == nn_a)
}

/// Total order on `(weight, lo_id, hi_id)` triples: weight by
/// `total_cmp`, then both ids ascending. The single shared comparator for
/// every sort that must break weight ties deterministically
/// ([`crate::hac::mst`], [`crate::hac::naive`]'s global heap,
/// [`crate::approx`]'s candidate ranking).
#[inline]
pub fn cmp_weight_pair(a: &(Weight, u32, u32), b: &(Weight, u32, u32)) -> Ordering {
    a.0.total_cmp(&b.0)
        .then(a.1.cmp(&b.1))
        .then(a.2.cmp(&b.2))
}

/// One row-scan kernel implementation. `Scalar` is always compiled; the
/// vector variants exist only on their target architecture and are only
/// ever *selected* after runtime feature detection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kernel {
    /// Portable scalar loop — the reference semantics.
    Scalar,
    /// 4 × f64 AVX2 kernel (`x86_64` only).
    #[cfg(target_arch = "x86_64")]
    Avx2,
    /// 2 × f64 NEON kernel (`aarch64` only).
    #[cfg(target_arch = "aarch64")]
    Neon,
}

impl Kernel {
    /// Stable name for logs / bench artifacts.
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => "avx2",
            #[cfg(target_arch = "aarch64")]
            Kernel::Neon => "neon",
        }
    }
}

/// Cached dispatch decision: 0 = undecided, otherwise `encode(kernel)`.
static ACTIVE: AtomicU8 = AtomicU8::new(0);

fn encode(k: Kernel) -> u8 {
    match k {
        Kernel::Scalar => 1,
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => 2,
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => 3,
    }
}

fn decode(v: u8) -> Kernel {
    match v {
        #[cfg(target_arch = "x86_64")]
        2 => Kernel::Avx2,
        #[cfg(target_arch = "aarch64")]
        3 => Kernel::Neon,
        _ => Kernel::Scalar,
    }
}

/// Best kernel this machine supports (runtime feature detection; does not
/// consult the force-scalar override).
pub fn detect() -> Kernel {
    #[cfg(target_arch = "x86_64")]
    {
        if std::is_x86_feature_detected!("avx2") {
            return Kernel::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Kernel::Neon;
        }
    }
    Kernel::Scalar
}

/// Every kernel runnable on this machine (always starts with `Scalar`) —
/// what the differential tests iterate over.
pub fn available() -> Vec<Kernel> {
    let mut v = vec![Kernel::Scalar];
    let best = detect();
    if best != Kernel::Scalar {
        v.push(best);
    }
    v
}

/// Does this `RAC_FORCE_SCALAR` value request the scalar fallback?
/// Empty / `0` / `false` / `off` / `no` (case-insensitive) mean "no";
/// anything else (including `1`) means "yes".
pub fn env_wants_scalar(value: &str) -> bool {
    !matches!(
        value.trim().to_ascii_lowercase().as_str(),
        "" | "0" | "false" | "off" | "no"
    )
}

fn env_forces_scalar() -> bool {
    std::env::var("RAC_FORCE_SCALAR")
        .map(|v| env_wants_scalar(&v))
        .unwrap_or(false)
}

/// The kernel scans dispatch to. Decided once per process — environment
/// override first, then feature detection — and cached; a concurrent
/// first call computes the same value, so the race is benign.
pub fn active() -> Kernel {
    let v = ACTIVE.load(AtomicOrd::Relaxed);
    if v != 0 {
        return decode(v);
    }
    let k = if env_forces_scalar() {
        Kernel::Scalar
    } else {
        detect()
    };
    let _ = ACTIVE.compare_exchange(0, encode(k), AtomicOrd::Relaxed, AtomicOrd::Relaxed);
    decode(ACTIVE.load(AtomicOrd::Relaxed))
}

/// Process-wide override: `true` pins the scalar fallback, `false`
/// restores the *detected* kernel — note that the latter ignores an
/// `RAC_FORCE_SCALAR` environment pin, so prefer a scoped [`KernelPin`]
/// anywhere the surrounding dispatch should survive (the config/CLI
/// plumbing, tests, bench cells). Safe to flip at any point because both
/// settings produce bitwise-identical results.
pub fn force_scalar(on: bool) {
    let k = if on { Kernel::Scalar } else { detect() };
    ACTIVE.store(encode(k), AtomicOrd::Relaxed);
}

/// RAII dispatch pin: forces `kernel` active until the guard drops, then
/// restores whatever dispatch was active on entry — the environment-aware
/// decision, not raw detection, so an `RAC_FORCE_SCALAR` pin survives a
/// scoped override. This is what the config-level `force_scalar` plumbing
/// holds for the duration of a run, so one pinned run in a process does
/// not leak its dispatch into later runs. The underlying state is still
/// process-global: overlapping pins from concurrent runs race (benignly —
/// every kernel is bitwise identical), and the last guard to drop wins.
#[must_use = "the pin is released when this guard is dropped"]
pub struct KernelPin {
    prev: Kernel,
}

impl KernelPin {
    /// Pin `kernel` as the active dispatch until the guard drops.
    pub fn pin(kernel: Kernel) -> KernelPin {
        let prev = active();
        ACTIVE.store(encode(kernel), AtomicOrd::Relaxed);
        KernelPin { prev }
    }

    /// Pin the scalar fallback until the guard drops.
    pub fn scalar() -> KernelPin {
        Self::pin(Kernel::Scalar)
    }
}

impl Drop for KernelPin {
    fn drop(&mut self) {
        ACTIVE.store(encode(self.prev), AtomicOrd::Relaxed);
    }
}

/// `(weight, id)` lex-min over a raw row span, dispatching to the active
/// kernel. Returns `(NO_NN, +inf)` for rows with no live entries. Slots
/// with `id == TOMBSTONE` (deletions and vacant padding) are masked as
/// `(+inf, u32::MAX)` — never by their stale stored weight.
#[inline]
pub fn scan_nn_entries(entries: &[Entry]) -> (u32, Weight) {
    scan_nn_with(active(), entries)
}

/// [`scan_nn_entries`] pinned to a specific kernel (differential tests,
/// bench cells). Panics if `kernel` is a vector variant the current
/// machine does not support.
pub fn scan_nn_with(kernel: Kernel, entries: &[Entry]) -> (u32, Weight) {
    match kernel {
        Kernel::Scalar => scan_nn_scalar(entries),
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => {
            assert!(std::is_x86_feature_detected!("avx2"), "AVX2 not available");
            // SAFETY: AVX2 support was just verified at runtime.
            unsafe { scan_nn_avx2(entries) }
        }
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => {
            assert!(
                std::arch::is_aarch64_feature_detected!("neon"),
                "NEON not available"
            );
            // SAFETY: NEON support was just verified at runtime.
            unsafe { scan_nn_neon(entries) }
        }
    }
}

/// ε-good eligibility sweep over a raw row span, dispatching to the
/// active kernel: visit every live entry with `id > a` whose weight
/// passes [`band_accepts`]`(w, id, thr, nn_a)`, in storage order.
/// Tombstoned / vacant slots are masked *before* the band test — a vacant
/// slot is `(+inf, u32::MAX)`, which would otherwise sit exactly on the
/// boundary of an isolated cluster's band (`thr = +inf`,
/// `nn_a = u32::MAX`).
#[inline]
pub fn scan_band_entries(
    entries: &[Entry],
    a: u32,
    thr: Weight,
    nn_a: u32,
    mut f: impl FnMut(u32, Weight),
) {
    scan_band_with(active(), entries, a, thr, nn_a, &mut f);
}

/// [`scan_band_entries`] pinned to a specific kernel (differential tests,
/// bench cells). Panics if `kernel` is a vector variant the current
/// machine does not support.
pub fn scan_band_with(
    kernel: Kernel,
    entries: &[Entry],
    a: u32,
    thr: Weight,
    nn_a: u32,
    f: &mut impl FnMut(u32, Weight),
) {
    match kernel {
        Kernel::Scalar => scan_band_scalar(entries, a, thr, nn_a, f),
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => {
            assert!(std::is_x86_feature_detected!("avx2"), "AVX2 not available");
            // SAFETY: AVX2 support was just verified at runtime.
            unsafe { scan_band_avx2(entries, a, thr, nn_a, f) }
        }
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => {
            assert!(
                std::arch::is_aarch64_feature_detected!("neon"),
                "NEON not available"
            );
            // SAFETY: NEON support was just verified at runtime.
            unsafe { scan_band_neon(entries, a, thr, nn_a, f) }
        }
    }
}

fn scan_nn_scalar(entries: &[Entry]) -> (u32, Weight) {
    let mut best_id = NO_NN;
    let mut best_w = Weight::INFINITY;
    for e in entries {
        if e.id != TOMBSTONE && nn_better(e.edge.weight, e.id, best_w, best_id) {
            best_w = e.edge.weight;
            best_id = e.id;
        }
    }
    (best_id, best_w)
}

fn scan_band_scalar(
    entries: &[Entry],
    a: u32,
    thr: Weight,
    nn_a: u32,
    f: &mut impl FnMut(u32, Weight),
) {
    for e in entries {
        if e.id != TOMBSTONE && e.id > a && band_accepts(e.edge.weight, e.id, thr, nn_a) {
            f(e.id, e.edge.weight);
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn scan_nn_avx2(entries: &[Entry]) -> (u32, Weight) {
    use std::arch::x86_64::*;
    let inf = _mm256_set1_pd(f64::INFINITY);
    let tomb = _mm256_set1_epi64x(TOMBSTONE as i64);
    let mut best_w = inf;
    let mut best_id = tomb; // TOMBSTONE == NO_NN: the scalar accumulator init
    let mut chunks = entries.chunks_exact(LANES);
    for c in chunks.by_ref() {
        // Ids zero-extend to i64, so signed 64-bit compares are exact.
        let id = _mm256_set_epi64x(
            c[3].id as i64,
            c[2].id as i64,
            c[1].id as i64,
            c[0].id as i64,
        );
        let w = _mm256_set_pd(
            c[3].edge.weight,
            c[2].edge.weight,
            c[1].edge.weight,
            c[0].edge.weight,
        );
        // Mask dead slots (deleted or vacant) to (+inf, u32::MAX) BEFORE
        // comparing — tombstones keep their stale weight in the arena.
        let dead = _mm256_cmpeq_epi64(id, tomb);
        let w = _mm256_blendv_pd(w, inf, _mm256_castsi256_pd(dead));
        // Packed (weight, id) lex-min: take = w < best || (w == best && id < best_id).
        // Ordered-quiet compares are false on NaN, matching scalar `<`/`==`.
        let lt = _mm256_cmp_pd(w, best_w, _CMP_LT_OQ);
        let eq = _mm256_cmp_pd(w, best_w, _CMP_EQ_OQ);
        let id_lt = _mm256_castsi256_pd(_mm256_cmpgt_epi64(best_id, id));
        let take = _mm256_or_pd(lt, _mm256_and_pd(eq, id_lt));
        best_w = _mm256_blendv_pd(best_w, w, take);
        best_id = _mm256_castpd_si256(_mm256_blendv_pd(
            _mm256_castsi256_pd(best_id),
            _mm256_castsi256_pd(id),
            take,
        ));
    }
    let mut ws = [0.0f64; LANES];
    let mut ids = [0i64; LANES];
    _mm256_storeu_pd(ws.as_mut_ptr(), best_w);
    _mm256_storeu_si256(ids.as_mut_ptr() as *mut __m256i, best_id);
    // Fold the per-lane minima with the same total order; masked lanes
    // hold (+inf, NO_NN) and thus never displace a live winner.
    let mut out_id = NO_NN;
    let mut out_w = Weight::INFINITY;
    for (&w, &id) in ws.iter().zip(ids.iter()) {
        let id = id as u32;
        if nn_better(w, id, out_w, out_id) {
            out_w = w;
            out_id = id;
        }
    }
    for e in chunks.remainder() {
        if e.id != TOMBSTONE && nn_better(e.edge.weight, e.id, out_w, out_id) {
            out_w = e.edge.weight;
            out_id = e.id;
        }
    }
    (out_id, out_w)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn scan_band_avx2(
    entries: &[Entry],
    a: u32,
    thr: Weight,
    nn_a: u32,
    f: &mut impl FnMut(u32, Weight),
) {
    use std::arch::x86_64::*;
    let tomb = _mm256_set1_epi64x(TOMBSTONE as i64);
    let av = _mm256_set1_epi64x(a as i64);
    let thrv = _mm256_set1_pd(thr);
    let nnv = _mm256_set1_epi64x(nn_a as i64);
    let mut chunks = entries.chunks_exact(LANES);
    for c in chunks.by_ref() {
        let id = _mm256_set_epi64x(
            c[3].id as i64,
            c[2].id as i64,
            c[1].id as i64,
            c[0].id as i64,
        );
        let w = _mm256_set_pd(
            c[3].edge.weight,
            c[2].edge.weight,
            c[1].edge.weight,
            c[0].edge.weight,
        );
        let dead = _mm256_cmpeq_epi64(id, tomb);
        let gt = _mm256_cmpgt_epi64(id, av);
        let wlt = _mm256_cmp_pd(w, thrv, _CMP_LT_OQ);
        let weq = _mm256_cmp_pd(w, thrv, _CMP_EQ_OQ);
        let id_is_nn = _mm256_castsi256_pd(_mm256_cmpeq_epi64(id, nnv));
        let accept = _mm256_or_pd(wlt, _mm256_and_pd(weq, id_is_nn));
        // The dead mask must gate the band test: a vacant slot decodes as
        // (+inf, u32::MAX), which an isolated cluster's band (thr = +inf,
        // nn = u32::MAX) would otherwise accept on the boundary.
        let live_gt = _mm256_andnot_si256(dead, gt);
        let take = _mm256_and_pd(_mm256_castsi256_pd(live_gt), accept);
        let bits = _mm256_movemask_pd(take);
        if bits != 0 {
            for (lane, e) in c.iter().enumerate() {
                if bits & (1 << lane) != 0 {
                    f(e.id, e.edge.weight);
                }
            }
        }
    }
    for e in chunks.remainder() {
        if e.id != TOMBSTONE && e.id > a && band_accepts(e.edge.weight, e.id, thr, nn_a) {
            f(e.id, e.edge.weight);
        }
    }
}

#[cfg(target_arch = "aarch64")]
const NEON_LANES: usize = 2;

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn scan_nn_neon(entries: &[Entry]) -> (u32, Weight) {
    use std::arch::aarch64::*;
    let inf = vdupq_n_f64(f64::INFINITY);
    let tomb = vdupq_n_u64(TOMBSTONE as u64);
    let mut best_w = inf;
    let mut best_id = tomb; // TOMBSTONE == NO_NN: the scalar accumulator init
    let mut chunks = entries.chunks_exact(NEON_LANES);
    for c in chunks.by_ref() {
        let ids = [c[0].id as u64, c[1].id as u64];
        let wsv = [c[0].edge.weight, c[1].edge.weight];
        let id = vld1q_u64(ids.as_ptr());
        let w = vld1q_f64(wsv.as_ptr());
        // Mask dead slots to (+inf, u32::MAX) before comparing.
        let dead = vceqq_u64(id, tomb);
        let w = vbslq_f64(dead, inf, w);
        // Packed (weight, id) lex-min; float compares are false on NaN.
        let lt = vcltq_f64(w, best_w);
        let eq = vceqq_f64(w, best_w);
        let id_lt = vcltq_u64(id, best_id);
        let take = vorrq_u64(lt, vandq_u64(eq, id_lt));
        best_w = vbslq_f64(take, w, best_w);
        best_id = vbslq_u64(take, id, best_id);
    }
    let ws = [vgetq_lane_f64::<0>(best_w), vgetq_lane_f64::<1>(best_w)];
    let ids = [
        vgetq_lane_u64::<0>(best_id) as u32,
        vgetq_lane_u64::<1>(best_id) as u32,
    ];
    let mut out_id = NO_NN;
    let mut out_w = Weight::INFINITY;
    for (&w, &id) in ws.iter().zip(ids.iter()) {
        if nn_better(w, id, out_w, out_id) {
            out_w = w;
            out_id = id;
        }
    }
    for e in chunks.remainder() {
        if e.id != TOMBSTONE && nn_better(e.edge.weight, e.id, out_w, out_id) {
            out_w = e.edge.weight;
            out_id = e.id;
        }
    }
    (out_id, out_w)
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn scan_band_neon(
    entries: &[Entry],
    a: u32,
    thr: Weight,
    nn_a: u32,
    f: &mut impl FnMut(u32, Weight),
) {
    use std::arch::aarch64::*;
    let tomb = vdupq_n_u64(TOMBSTONE as u64);
    let av = vdupq_n_u64(a as u64);
    let thrv = vdupq_n_f64(thr);
    let nnv = vdupq_n_u64(nn_a as u64);
    let mut chunks = entries.chunks_exact(NEON_LANES);
    for c in chunks.by_ref() {
        let ids = [c[0].id as u64, c[1].id as u64];
        let wsv = [c[0].edge.weight, c[1].edge.weight];
        let id = vld1q_u64(ids.as_ptr());
        let w = vld1q_f64(wsv.as_ptr());
        let dead = vceqq_u64(id, tomb);
        let gt = vcgtq_u64(id, av);
        let wlt = vcltq_f64(w, thrv);
        let weq = vceqq_f64(w, thrv);
        let id_is_nn = vceqq_u64(id, nnv);
        let accept = vorrq_u64(wlt, vandq_u64(weq, id_is_nn));
        // Dead mask gates the band test (vacant slots decode as the
        // isolated-cluster boundary case — see the AVX2 kernel).
        let take = vandq_u64(vbicq_u64(gt, dead), accept);
        if vgetq_lane_u64::<0>(take) != 0 {
            f(c[0].id, c[0].edge.weight);
        }
        if vgetq_lane_u64::<1>(take) != 0 {
            f(c[1].id, c[1].edge.weight);
        }
    }
    for e in chunks.remainder() {
        if e.id != TOMBSTONE && e.id > a && band_accepts(e.edge.weight, e.id, thr, nn_a) {
            f(e.id, e.edge.weight);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linkage::EdgeState;

    fn entry(id: u32, w: Weight) -> Entry {
        Entry {
            id,
            edge: EdgeState { weight: w, count: 1 },
        }
    }

    #[test]
    fn padded_len_rounds_up_to_lanes() {
        assert_eq!(padded_len(0), 0);
        for len in 1..=3 * LANES {
            let p = padded_len(len);
            assert!(p >= len);
            assert_eq!(p % LANES, 0);
            assert!(p - len < LANES);
        }
    }

    #[test]
    fn env_values_parse_like_booleans() {
        for off in ["", "0", "false", "FALSE", "off", "no", " Off "] {
            assert!(!env_wants_scalar(off), "{off:?} should not force scalar");
        }
        for on in ["1", "true", "yes", "on", "anything"] {
            assert!(env_wants_scalar(on), "{on:?} should force scalar");
        }
    }

    #[test]
    fn nn_better_is_lex_min_and_nan_never_wins() {
        assert!(nn_better(1.0, 9, 2.0, 0));
        assert!(nn_better(1.0, 3, 1.0, 5));
        assert!(!nn_better(1.0, 5, 1.0, 3));
        assert!(!nn_better(2.0, 0, 1.0, 9));
        assert!(!nn_better(f64::NAN, 0, f64::INFINITY, NO_NN));
        // -0.0 == +0.0: the tie falls through to the id compare.
        assert!(nn_better(-0.0, 1, 0.0, 2));
        assert!(!nn_better(-0.0, 2, 0.0, 1));
    }

    #[test]
    fn cmp_weight_pair_totally_orders_ties() {
        let mut v = [(1.0, 4, 0), (1.0, 2, 9), (0.5, 7, 7), (1.0, 2, 3)];
        v.sort_unstable_by(cmp_weight_pair);
        assert_eq!(v, [(0.5, 7, 7), (1.0, 2, 3), (1.0, 2, 9), (1.0, 4, 0)]);
    }

    #[test]
    fn scalar_nn_masks_stale_tombstone_weights() {
        // The tombstone carries a tempting stale weight; it must lose.
        let row = [entry(TOMBSTONE, 0.125), entry(7, 2.0), entry(3, 2.0)];
        assert_eq!(scan_nn_scalar(&row), (3, 2.0));
        assert_eq!(scan_nn_scalar(&[]), (NO_NN, Weight::INFINITY));
    }

    #[test]
    fn scalar_band_rejects_vacant_padding_on_isolated_boundary() {
        // Isolated cluster: thr = +inf, nn = u32::MAX. A vacant slot
        // (+inf, u32::MAX) sits exactly on that boundary and must still
        // be rejected by the dead mask.
        let row = [Entry::VACANT, Entry::VACANT, entry(TOMBSTONE, 1.0)];
        let mut hits = Vec::new();
        scan_band_scalar(&row, 0, Weight::INFINITY, NO_NN, &mut |b, w| {
            hits.push((b, w));
        });
        assert!(hits.is_empty());
    }

    #[test]
    fn scalar_band_visits_in_storage_order_with_boundary() {
        let row = [
            entry(5, 1.0),
            entry(2, 3.0), // not > a for a = 4
            entry(9, 2.0), // boundary, is the NN pointer
            entry(8, 2.0), // boundary, not the NN pointer
            entry(TOMBSTONE, 0.0),
        ];
        let mut hits = Vec::new();
        scan_band_scalar(&row, 4, 2.0, 9, &mut |b, w| hits.push((b, w)));
        assert_eq!(hits, vec![(5, 1.0), (9, 2.0)]);
    }

    #[test]
    fn kernel_pin_restores_entry_dispatch() {
        let entry = active();
        {
            let _pin = KernelPin::scalar();
            assert_eq!(active(), Kernel::Scalar);
            {
                let _inner = KernelPin::pin(detect());
                assert_eq!(active(), detect());
            }
            // Nested pins unwind to the enclosing pin, not detection.
            assert_eq!(active(), Kernel::Scalar);
        }
        assert_eq!(active(), entry);
    }

    #[test]
    fn detected_kernel_is_listed_and_named() {
        let kernels = available();
        assert_eq!(kernels[0], Kernel::Scalar);
        assert!(kernels.contains(&detect()));
        for k in kernels {
            assert!(!k.name().is_empty());
        }
    }
}
