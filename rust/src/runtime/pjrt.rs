//! PJRT-backed [`KernelRuntime`] (built with the `xla` feature): compiles
//! the AOT HLO artifacts on the PJRT CPU client and executes them.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

use super::{Manifest, VariantMeta};

/// A compiled-and-loaded kernel set on the PJRT CPU client.
///
/// Executables are compiled lazily (first use) and cached per variant.
/// `execute` takes `&self`; the interior mutex only guards the compile
/// cache, never execution.
pub struct KernelRuntime {
    artifacts_dir: PathBuf,
    manifest: Manifest,
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl KernelRuntime {
    /// Open the artifacts directory and start a PJRT CPU client.
    pub fn open(artifacts_dir: impl Into<PathBuf>) -> Result<KernelRuntime> {
        let artifacts_dir = artifacts_dir.into();
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(KernelRuntime {
            artifacts_dir,
            manifest,
            client,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn executable(&self, meta: &VariantMeta) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(&meta.name) {
            return Ok(exe.clone());
        }
        let path = self.artifacts_dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("loading {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", meta.name))?,
        );
        self.cache
            .lock()
            .unwrap()
            .insert(meta.name.clone(), exe.clone());
        Ok(exe)
    }

    fn literal(rows: &[f32], n_rows: usize, d: usize) -> Result<xla::Literal> {
        if rows.len() != n_rows * d {
            bail!("literal shape mismatch: {} != {n_rows}x{d}", rows.len());
        }
        xla::Literal::vec1(rows)
            .reshape(&[n_rows as i64, d as i64])
            .map_err(|e| anyhow!("reshape: {e:?}"))
    }

    /// Execute a `distance` variant on one `(x, y)` tile pair; returns the
    /// row-major `m × n` dissimilarity tile.
    pub fn distance_block(&self, meta: &VariantMeta, x: &[f32], y: &[f32]) -> Result<Vec<f32>> {
        assert_eq!(meta.kind, "distance");
        let exe = self.executable(meta)?;
        let lx = Self::literal(x, meta.m, meta.d)?;
        let ly = Self::literal(y, meta.n, meta.d)?;
        let result = exe
            .execute::<xla::Literal>(&[lx, ly])
            .map_err(|e| anyhow!("execute {}: {e:?}", meta.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow!("to_tuple1: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }

    /// Execute a `knn` variant on one `(x, y)` tile pair; returns per-row
    /// `(distances [m×k], indices [m×k])`, ascending by distance, indices
    /// local to the y tile.
    pub fn knn_block(
        &self,
        meta: &VariantMeta,
        x: &[f32],
        y: &[f32],
    ) -> Result<(Vec<f32>, Vec<i32>)> {
        assert_eq!(meta.kind, "knn");
        let exe = self.executable(meta)?;
        let lx = Self::literal(x, meta.m, meta.d)?;
        let ly = Self::literal(y, meta.n, meta.d)?;
        let result = exe
            .execute::<xla::Literal>(&[lx, ly])
            .map_err(|e| anyhow!("execute {}: {e:?}", meta.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let (vals, idx) = result
            .to_tuple2()
            .map_err(|e| anyhow!("to_tuple2: {e:?}"))?;
        Ok((
            vals.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?,
            idx.to_vec::<i32>().map_err(|e| anyhow!("to_vec: {e:?}"))?,
        ))
    }
}
