//! Offline stub for the PJRT kernel runtime (default build, no `xla`
//! feature). Keeps the full [`KernelRuntime`] API available so the XLA
//! consumers compile unchanged; `open` always fails with an explanatory
//! error, which the parity tests and `Backend::Xla` callers treat as
//! "artifacts unavailable — skip or fall back to native".

use std::path::PathBuf;

use anyhow::{bail, Result};

use super::{Manifest, VariantMeta};

/// API-compatible stand-in for the PJRT runtime.
pub struct KernelRuntime {
    manifest: Manifest,
}

impl KernelRuntime {
    /// Always fails: the offline build carries no PJRT client.
    pub fn open(artifacts_dir: impl Into<PathBuf>) -> Result<KernelRuntime> {
        let artifacts_dir: PathBuf = artifacts_dir.into();
        bail!(
            "rac-hac was built without the `xla` feature; AOT artifacts at \
             {artifacts_dir:?} cannot be executed (rebuild with `--features xla` \
             and the xla-rs crate available, or use Backend::Native)"
        );
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        "unavailable (built without the `xla` feature)".to_string()
    }

    /// Unreachable in practice (`open` never succeeds); kept for API parity.
    pub fn distance_block(&self, _meta: &VariantMeta, _x: &[f32], _y: &[f32]) -> Result<Vec<f32>> {
        bail!("distance kernels require the `xla` feature")
    }

    /// Unreachable in practice (`open` never succeeds); kept for API parity.
    pub fn knn_block(
        &self,
        _meta: &VariantMeta,
        _x: &[f32],
        _y: &[f32],
    ) -> Result<(Vec<f32>, Vec<i32>)> {
        bail!("knn kernels require the `xla` feature")
    }
}
