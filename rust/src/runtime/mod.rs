//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas kernels.
//!
//! This is the only place the crate touches XLA. At build time,
//! `python/compile/aot.py` lowers every kernel variant to **HLO text**
//! (`artifacts/<name>.hlo.txt`; text because jax ≥ 0.5 emits 64-bit
//! instruction ids that xla_extension 0.5.1's proto path rejects) plus
//! `artifacts/manifest.json` describing the static shapes. At run time
//! this module compiles each needed variant once on the PJRT CPU client
//! and executes it from the graph-construction hot path — Python is never
//! on the clustering path.
//!
//! The PJRT-backed [`KernelRuntime`] needs the `xla` crate (xla-rs) and
//! libxla_extension, which the offline vendored build does not carry, so
//! it is gated behind the `xla` cargo feature. The default build exports a
//! stub with the same API whose [`KernelRuntime::open`] fails gracefully;
//! every XLA consumer (the parity tests, `rac kernels`, `Backend::Xla`)
//! already treats an `open` failure as "skip / fall back to native".

#[cfg(feature = "xla")]
mod pjrt;
#[cfg(feature = "xla")]
pub use pjrt::KernelRuntime;

#[cfg(not(feature = "xla"))]
mod stub;
#[cfg(not(feature = "xla"))]
pub use stub::KernelRuntime;

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::data::Metric;
use crate::util::json::Json;

/// One AOT kernel variant as described by the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct VariantMeta {
    pub name: String,
    /// "distance" (full m×n tile) or "knn" (fused per-row top-k).
    pub kind: String,
    pub metric: Metric,
    /// Static tile shapes: x is `[m, d]`, y is `[n, d]`.
    pub m: usize,
    pub n: usize,
    pub d: usize,
    /// Top-k width (knn variants only).
    pub k: Option<usize>,
    /// HLO text file, relative to the artifacts dir.
    pub file: String,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub variants: Vec<VariantMeta>,
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Manifest> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let root = Json::parse(text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let obj = root.as_obj().ok_or_else(|| anyhow!("manifest not an object"))?;
        let mut variants = Vec::new();
        for (name, entry) in obj {
            let get_usize = |k: &str| -> Result<usize> {
                entry
                    .get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("variant {name}: missing field {k}"))
            };
            let get_str = |k: &str| -> Result<String> {
                entry
                    .get(k)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .ok_or_else(|| anyhow!("variant {name}: missing field {k}"))
            };
            variants.push(VariantMeta {
                name: name.clone(),
                kind: get_str("kind")?,
                metric: get_str("metric")?
                    .parse()
                    .map_err(|e: String| anyhow!(e))?,
                m: get_usize("m")?,
                n: get_usize("n")?,
                d: get_usize("d")?,
                k: entry.get("k").and_then(Json::as_usize),
                file: get_str("file")?,
            });
        }
        Ok(Manifest { variants })
    }

    /// Pick the variant for a `(kind, metric, d)` request, if any.
    pub fn find(&self, kind: &str, metric: Metric, d: usize) -> Option<&VariantMeta> {
        self.variants
            .iter()
            .find(|v| v.kind == kind && v.metric == metric && v.d == d)
    }

    /// Feature dimensions the AOT set supports for a kind/metric.
    pub fn supported_dims(&self, kind: &str, metric: Metric) -> Vec<usize> {
        let mut dims: Vec<usize> = self
            .variants
            .iter()
            .filter(|v| v.kind == kind && v.metric == metric)
            .map(|v| v.d)
            .collect();
        dims.sort_unstable();
        dims.dedup();
        dims
    }
}

/// Default artifacts location: `$RAC_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("RAC_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let text = r#"{
            "dist_l2_m256_n256_d64": {
                "kind": "distance", "metric": "l2", "m": 256, "n": 256,
                "d": 64, "file": "dist_l2_m256_n256_d64.hlo.txt",
                "inputs": [[256, 64], [256, 64]]
            },
            "knn_cos_m256_n1024_d128_k32": {
                "kind": "knn", "metric": "cosine", "m": 256, "n": 1024,
                "d": 128, "k": 32, "file": "knn_cos_m256_n1024_d128_k32.hlo.txt",
                "inputs": [[256, 128], [1024, 128]]
            }
        }"#;
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.variants.len(), 2);
        let v = m.find("distance", Metric::L2, 64).unwrap();
        assert_eq!(v.m, 256);
        assert_eq!(v.k, None);
        let v = m.find("knn", Metric::Cosine, 128).unwrap();
        assert_eq!(v.k, Some(32));
        assert!(m.find("knn", Metric::L2, 64).is_none());
        assert_eq!(m.supported_dims("knn", Metric::Cosine), vec![128]);
    }

    #[test]
    fn manifest_rejects_malformed() {
        assert!(Manifest::parse("[]").is_err());
        assert!(Manifest::parse(r#"{"x": {"kind": "distance"}}"#).is_err());
    }
}
