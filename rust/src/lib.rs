//! # rac-hac
//!
//! A distributed implementation of **Reciprocal Agglomerative Clustering
//! (RAC)** — exact Hierarchical Agglomerative Clustering that merges all
//! reciprocal-nearest-neighbor cluster pairs in parallel rounds — as
//! described in *"Scaling Hierarchical Agglomerative Clustering to
//! Billion-sized Datasets"* (Sumengen et al., 2021).
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//!
//! * [`runtime`] loads AOT-compiled XLA artifacts (JAX + Pallas pairwise
//!   dissimilarity kernels, lowered to HLO text at build time) and executes
//!   them on the PJRT CPU client; Python never runs at clustering time.
//! * [`knn`] streams dataset tiles through those kernels to build the
//!   kNN / ε-ball dissimilarity graphs the paper clusters.
//! * [`rac`] is the paper's contribution: the round-based
//!   reciprocal-nearest-neighbor merge engine; [`dist`] runs the same
//!   phases sharded across simulated machines with batched cross-shard
//!   messaging; [`hac`] holds the exact sequential baselines the engine is
//!   verified against.
//!
//! Quick start (see `examples/quickstart.rs` for the runnable version):
//!
//! ```no_run
//! // (no_run: cargo does not apply the workspace rpath flags to doctest
//! // binaries, so they cannot locate the xla_extension shared libraries
//! // in this offline image; the example compiles and runs as
//! // `cargo run --example quickstart`.)
//! use rac_hac::graph::Graph;
//! use rac_hac::linkage::Linkage;
//! use rac_hac::rac::RacEngine;
//!
//! // A tiny weighted dissimilarity graph: 0-1 close, 2-3 close, far apart.
//! let edges = [(0, 1, 1.0), (2, 3, 1.5), (1, 2, 10.0), (0, 3, 12.0)];
//! let g = Graph::from_edges(4, edges.iter().copied());
//! let result = RacEngine::new(&g, Linkage::Average).run();
//! assert_eq!(result.dendrogram.merges().len(), 3);
//! ```

pub mod config;
pub mod data;
pub mod dendrogram;
pub mod dist;
pub mod graph;
pub mod hac;
pub mod knn;
pub mod linkage;
pub mod metrics;
pub mod pipeline;
pub mod rac;
pub mod runtime;
pub mod util;
