//! # rac-hac
//!
//! A distributed implementation of **Reciprocal Agglomerative Clustering
//! (RAC)** — exact Hierarchical Agglomerative Clustering that merges all
//! reciprocal-nearest-neighbor cluster pairs in parallel rounds — as
//! described in *"Scaling Hierarchical Agglomerative Clustering to
//! Billion-sized Datasets"* (Sumengen et al., 2021).
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//!
//! * [`runtime`] loads AOT-compiled XLA artifacts (JAX + Pallas pairwise
//!   dissimilarity kernels, lowered to HLO text at build time) and executes
//!   them on the PJRT CPU client; Python never runs at clustering time.
//! * [`knn`] streams dataset tiles through those kernels to build the
//!   kNN / ε-ball dissimilarity graphs the paper clusters.
//! * [`rac`] is the paper's contribution: the round-based
//!   reciprocal-nearest-neighbor merge engine; [`dist`] runs the same
//!   phases sharded across simulated machines with batched cross-shard
//!   messaging (exact `dist_rac` and ε-good `dist_approx`); [`approx`]
//!   relaxes the merge rule to TeraHAC-style (1+ε)-good merges for graphs
//!   where reciprocal pairs are scarce; [`hac`] holds the exact
//!   sequential baselines the engines are verified against. The
//!   shared-memory engines are all one loop: [`engine`]'s `RoundDriver`
//!   owns the init-scan + phase-2/3 machinery, parameterized by an
//!   [`engine::EngineStore`] backend and an [`engine::PairSelector`]
//!   (reciprocal-NN or ε-good) — so the ε = 0 bitwise anchor is shared
//!   code, not mirrored code. All engines keep cluster adjacency in
//!   [`store`], a flat arena-backed neighbor store with tombstone
//!   deletion, owner-sharded lock-free merge application, and periodic
//!   compaction.
//!
//! Quick start (see `examples/quickstart.rs` for the larger runnable
//! version):
//!
//! ```
//! use rac_hac::dist::{DistConfig, DistRacEngine};
//! use rac_hac::graph::Graph;
//! use rac_hac::linkage::Linkage;
//! use rac_hac::rac::RacEngine;
//!
//! // A tiny weighted dissimilarity graph: 0-1 close, 2-3 close, far apart.
//! let edges = [(0, 1, 1.0), (2, 3, 1.5), (1, 2, 10.0), (0, 3, 12.0)];
//! let g = Graph::from_edges(4, edges.iter().copied());
//! let result = RacEngine::new(&g, Linkage::Average).run();
//! assert_eq!(result.dendrogram.merges().len(), 3);
//!
//! // The distributed engine is exact: any (machines, cores) topology
//! // produces the identical dendrogram, and reports the cross-shard
//! // traffic it would cost (zero on a single machine).
//! let dist = DistRacEngine::new(&g, Linkage::Average, DistConfig::new(4, 2)).run();
//! assert!(result.dendrogram.same_clustering(&dist.dendrogram, 1e-12));
//! assert!(dist.metrics.total_net_messages() > 0);
//! let solo = DistRacEngine::new(&g, Linkage::Average, DistConfig::new(1, 2)).run();
//! assert_eq!(solo.metrics.total_net_bytes(), 0);
//! ```
//!
//! ## Distributed engine
//!
//! [`dist`] shards clusters over simulated machines by id
//! (`dist::shard_of`), runs the same three phases as bulk-synchronous
//! barriers, and batches all cross-shard state access — NN-pointer
//! exchange, partner-state fetches, pair-view lookups, edge patches —
//! into one encoded RPC per machine pair per communication step. Each
//! round reports
//! `net_messages` / `net_bytes` (exact wire lengths through the binary
//! codec in `dist::network`) and `t_sim`, a critical-path time model
//! (max per-machine work per phase ÷ cores, plus latency + bandwidth
//! terms) — the resource columns of the paper's Table 2. Exactness is by
//! construction: the merge arithmetic is the shared-memory engine's,
//! bit for bit, so Theorem 1 applies to every topology.
//! [`dist::DistApproxEngine`] (`dist_approx`) runs the ε-good selection
//! over the same sharded state — per topology it is bitwise identical to
//! [`approx::ApproxEngine`], and at ε = 0 to [`dist::DistRacEngine`] —
//! with the find phase additionally exchanging remote NN caches and
//! routing candidate edges through a matching coordinator. Its
//! [`dist::SyncMode::Batched`] mode adds TeraHAC-style subgraph
//! batching: clusters partition into `vshards` contiguous-id blocks
//! (machine-local by construction), good merges drain *inside* blocks
//! with zero traffic — cross-machine patches deferred to the next sync
//! boundary — and the global exchange runs only when the local rounds
//! dry up, so coordination scales with [`metrics::RoundMetrics::sync_points`]
//! instead of rounds (`benches/dist_sync.rs` →
//! `BENCH_dist_sync.json`). The block scope is the same
//! [`engine::EdgeScope`] mask the shared driver takes, so one block's
//! local engine *is* a scoped [`engine::GoodSelector`] driver instance
//! (pinned in `rust/tests/dist_batching.rs`).
//!
//! Both distributed engines also run **executed** ([`dist::exec`],
//! `exec_mode = "executed"`): one OS thread per machine owning its shard
//! of the rows, exchanging the same encoded batches over real channels
//! with injected per-link latency/jitter, so the modeled `t_sim` gains a
//! measured sibling [`metrics::RoundMetrics::t_exec`]. Machines
//! checkpoint at sync points through a versioned binary format
//! ([`dist::checkpoint`]): every `checkpoint_full_every`-th cut is a
//! full blob, the cuts between are dirty-row **deltas** chained onto it,
//! and restore folds the chain back. Faults come as a campaign —
//! [`dist::FaultSpec`] lists (multi-machine, repeated, fault *during*
//! recovery) plus seeded random kills (`fault_rate`) — and a dead shard
//! surfaces on the wire as a named [`dist::MachineDown`] error, never a
//! hang. [`dist::RecoveryMode`] picks how to heal: `global` rolls the
//! whole fleet back to the last cut; `shard_replay` respawns only the
//! dead machine, restores it from its own chain, and replays its
//! journaled inbound traffic while survivors idle — the cost lands in
//! [`metrics::RunMetrics::t_recover`] /
//! [`metrics::RunMetrics::recovery_rounds_replayed`] next to `t_exec`
//! (`benches/recovery.rs` → `BENCH_recovery.json`). Execution changes
//! the clock, never the algorithm: dendrogram, (1+ε) bounds trace, and
//! sync schedule stay bitwise equal to the simulation, faulted or not,
//! under either recovery mode — pinned in `rust/tests/dist_executed.rs`,
//! with the codec paths real execution leans on (batches, full blobs,
//! delta chains) fuzzed in `rust/tests/codec_adversarial.rs`.
//!
//! ## Approximate engine
//!
//! Exact RAC merges only reciprocal-nearest-neighbor pairs, so on inputs
//! with few reciprocal pairs (the Theorem-4 adversarial instance needs
//! Ω(n) rounds) parallelism collapses. [`approx::ApproxEngine`] trades a
//! bounded amount of dendrogram fidelity for rounds: per round a cluster
//! may merge with any neighbor whose linkage is within a `(1+ε)` factor
//! of the minimum linkage visible to either endpoint (TeraHAC's
//! good-merge criterion, arXiv:2308.03578), and a maximal conflict-free
//! merge set is chosen with the crate-wide deterministic `(weight, id)`
//! tie-break. Reach for `ε > 0` when round count — not per-merge cost —
//! dominates wall time; every merge provably stays within the `(1+ε)`
//! band of the best visible merge (recorded per merge and audited by
//! [`approx::quality`]), which TeraHAC shows bounds global dendrogram
//! distortion to the same factor. At `ε = 0` the criterion degenerates to
//! reciprocal nearest neighbors and the engine is **bitwise identical**
//! to [`rac::RacEngine`] — the correctness anchor, property-tested in
//! `rust/tests/approx_quality.rs`. `benches/approx_tradeoff.rs` sweeps
//! the ε × linkage × threads matrix and reports rounds, wall time, and
//! adjusted-Rand agreement against the exact dendrogram.
//!
//! ## Observability
//!
//! Every engine can stream structured events into a [`trace::TraceSink`]
//! (TOML `[output] trace_path`/`trace_format`, CLI `--trace` /
//! `--trace-format`). The schema is small and stable — each event is
//! stamped with engine, machine id ([`trace::COORD`] for
//! coordinator-level events), an OS-thread tag, the round, and
//! nanoseconds on one shared monotonic clock:
//!
//! | kind             | span? | payload |
//! |------------------|-------|---------|
//! | `run`            | span  | — |
//! | `round`          | span  | — |
//! | `phase`          | span  | `phase` ∈ find / merge / update_nn |
//! | `barrier_wait`   | span  | `step` |
//! | `wire_send`      | inst. | `dst`, `step`, `msgs`, `bytes` |
//! | `wire_recv`      | inst. | `src`, `step`, `bytes` |
//! | `sync_point`     | inst. | — |
//! | `checkpoint_cut` | inst. | `full`, `bytes` |
//! | `fault`          | inst. | `target` |
//! | `recovery`       | mixed | `stage`, `target`, `rounds`, `bytes` |
//!
//! The executed fleet's machines buffer events locally and ship them on
//! the existing per-round report channel, merged at join — the hot path
//! takes no lock. The overhead contract: tracing is purely
//! observational (traced runs are bitwise identical to untraced —
//! `rust/tests/trace_invariance.rs`), the *disabled* sink costs one
//! branch per emission site (pinned in `benches/hot_paths.rs`), and
//! event totals equal the [`metrics::RunMetrics`] counters because they
//! are emitted at the same accounting sites — `rac trace-report`
//! ([`trace::analyze`]) folds a trace into per-machine phase time,
//! barrier stragglers, the wire matrix, the checkpoint/recovery
//! timeline and per-round critical-path attribution, and asserts that
//! equality. Perfetto how-to: run with `--trace run.json --trace-format
//! chrome`, open <https://ui.perfetto.dev>, and load the file — each
//! machine renders as a process, phases and barrier waits as slices.
//!
//! ## Performance
//!
//! The hot path of every round is two linear scans over [`store`]'s flat
//! arena rows: the exact `(weight, id)`-min NN scan and the ε-good
//! eligibility sweep. Both lower to explicit SIMD kernels in
//! [`store::scan`] — AVX2 on `x86_64`, NEON on `aarch64`, selected once
//! per process by runtime feature detection with an always-compiled
//! scalar fallback. Arena rows are lane-padded with vacant slots so the
//! kernels consume whole rows with no tail loop, and the `(weight, id)`
//! lex-min tie-break is evaluated as a packed compare, which keeps the
//! vector paths **bitwise identical** to the scalar one (the module docs
//! prove why; `rust/tests/simd_scan.rs` property-tests it per kernel and
//! end-to-end across all five engines). Set `RAC_FORCE_SCALAR=1` (or
//! `force_scalar = true` under `[engine]`, or `--force-scalar`) to pin
//! the fallback; `benches/hot_paths.rs` reports scalar-vs-SIMD
//! counterpart cells and the active dispatch in `BENCH_hot_paths.json`.
//!
//! ## Serving
//!
//! A dendrogram is computed once and queried many times; [`serve`] is the
//! read path. [`serve::ServeIndex`] compiles a validated [`dendrogram::Dendrogram`]
//! into flat arrays — merges sorted by the crate-wide `(weight, a, b)`
//! order, the merge forest laid out so every internal node covers a
//! contiguous interval of a fixed leaf order, plus a binary-lifting
//! ancestor table. Flat cuts ([`serve::ServeIndex::cut_threshold`] /
//! [`serve::ServeIndex::cut_k`]) become one binary search plus an O(n)
//! interval paint instead of a per-query union-find rebuild; single-point
//! membership is O(log n); membership diffs between two thresholds and
//! subtree extraction walk only the merges in the band between them.
//! Every answer is bitwise-pinned to the naive [`dendrogram::Dendrogram`]
//! cuts across all five engines (`rust/tests/serve_queries.rs`). The
//! pipeline persists dendrograms through a versioned little-endian binary
//! codec ([`serve::codec`], `[output] dendrogram_path` /
//! `--dendrogram-out`), and `rac query` serves `cut-k` / `cut-threshold` /
//! `member` / `diff` against the file. [`serve::ServeHandle`] gives a
//! re-clustering pipeline atomic snapshot publication over live readers
//! (`Arc` swap). Concurrency/throughput numbers: `benches/serve.rs` →
//! `BENCH_serve.json` (Zipfian query mix from all cores, per-class
//! latency, naive-vs-indexed speedup).

pub mod approx;
pub mod config;
pub mod data;
pub mod dendrogram;
pub mod dist;
pub mod engine;
pub mod graph;
pub mod hac;
pub mod knn;
pub mod linkage;
pub mod metrics;
pub mod pipeline;
pub mod rac;
pub mod runtime;
pub mod serve;
pub mod store;
pub mod trace;
pub mod util;
