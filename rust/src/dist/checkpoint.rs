//! Versioned binary snapshots of per-machine engine state, taken at sync
//! points by the executed distributed mode ([`super::exec`]).
//!
//! A sync point is the only cut where a consistent global snapshot exists
//! for free: every deferred patch has been flushed, every in-flight
//! exchange has been drained by its barrier, and the next round has not
//! started. The executed driver checkpoints there, and recovery from a
//! killed shard restores *every* machine from the same cut — a global
//! rollback, the standard BSP recovery discipline — then replays rounds.
//! Determinism of the round body makes the replay bitwise identical, which
//! `rust/tests/dist_executed.rs` pins.
//!
//! ## Wire format (version 1)
//!
//! Little-endian, one blob per machine:
//!
//! ```text
//! magic   u32   0x4B434152 ("RACK")
//! version u32   1
//! machine u32   owner of this blob
//! machines u32  fleet width the blob was cut for
//! round   u64   next round to execute after restore
//! n       u64   total cluster-id space
//! owned   u32   number of owned-row records
//! owned × record:
//!   id        u32
//!   nn        u32   cached nearest-neighbor pointer
//!   nn_weight f64   cached NN edge weight (bit-exact)
//!   live_len  u32   entry count
//!   live_len × (target u32, weight f64, count u64)
//! size    u64 × n   replicated cluster sizes
//! active  u8  × n   replicated liveness flags
//! ```
//!
//! Rows are recorded for every owned id in ascending order (retired rows
//! as zero entries), preserving live-entry *order*: the union-map fold
//! emits its output in first-encounter order of the input rows, so
//! restoring rows in a different entry order would change later map
//! orders — layout may differ after restore (arena offsets, tombstones),
//! but the per-row live sequence is what the bitwise contract needs.
//!
//! Decoding reuses the hardened wire [`Reader`]: length prefixes are
//! validated against the remaining buffer *before* any element loop, so a
//! corrupt or truncated blob is rejected with an error instead of a panic
//! or an unbounded allocation.

use super::network::{len_u32, put_f64, put_u32, put_u64, Reader};
use crate::linkage::Weight;

const MAGIC: u32 = 0x4B43_4152; // "RACK" in little-endian byte order
const VERSION: u32 = 1;

/// One owned-row record: `(id, nn, nn_weight, entries)`.
pub type RowRecord = (u32, u32, Weight, Vec<(u32, Weight, u64)>);

/// The complete serializable state of one executed-mode machine.
#[derive(Debug, Clone, PartialEq)]
pub struct MachineCheckpoint {
    /// Machine this blob belongs to.
    pub machine: u32,
    /// Fleet width the blob was cut for (restore validates it).
    pub machines: u32,
    /// Next round to execute after restore.
    pub round: u64,
    /// Total cluster-id space.
    pub n: usize,
    /// Owned rows in ascending id order, with the owned slice of the NN
    /// cache riding along per row.
    pub rows: Vec<RowRecord>,
    /// Replicated size vector (all `n` entries).
    pub size: Vec<u64>,
    /// Replicated liveness flags (all `n` entries).
    pub active: Vec<bool>,
}

/// Serialize a machine snapshot to the version-1 binary format.
pub fn encode(cp: &MachineCheckpoint) -> Vec<u8> {
    assert_eq!(cp.size.len(), cp.n, "size vector must cover the id space");
    assert_eq!(cp.active.len(), cp.n, "active vector must cover the id space");
    let mut buf = Vec::new();
    put_u32(&mut buf, MAGIC);
    put_u32(&mut buf, VERSION);
    put_u32(&mut buf, cp.machine);
    put_u32(&mut buf, cp.machines);
    put_u64(&mut buf, cp.round);
    put_u64(&mut buf, cp.n as u64);
    put_u32(&mut buf, len_u32(cp.rows.len(), "checkpoint row"));
    for (id, nn, nn_weight, entries) in &cp.rows {
        put_u32(&mut buf, *id);
        put_u32(&mut buf, *nn);
        put_f64(&mut buf, *nn_weight);
        put_u32(&mut buf, len_u32(entries.len(), "checkpoint row entry"));
        for &(t, w, c) in entries {
            put_u32(&mut buf, t);
            put_f64(&mut buf, w);
            put_u64(&mut buf, c);
        }
    }
    for &s in &cp.size {
        put_u64(&mut buf, s);
    }
    for &a in &cp.active {
        buf.push(u8::from(a));
    }
    buf
}

/// Decode a version-1 blob, rejecting wrong magic/version, truncation,
/// corrupt length prefixes, and trailing bytes.
pub fn decode(bytes: &[u8]) -> Result<MachineCheckpoint, String> {
    let mut r = Reader::new(bytes);
    let magic = r.u32()?;
    if magic != MAGIC {
        return Err(format!("bad checkpoint magic {magic:#010x}"));
    }
    let version = r.u32()?;
    if version != VERSION {
        return Err(format!(
            "unsupported checkpoint version {version} (this build reads {VERSION})"
        ));
    }
    let machine = r.u32()?;
    let machines = r.u32()?;
    let round = r.u64()?;
    let n64 = r.u64()?;
    // The trailing size+active sections alone need 9 bytes per id; a
    // blob claiming more ids than its length supports is corrupt.
    if n64 > (r.remaining() / 9) as u64 {
        return Err(format!(
            "corrupt checkpoint id-space {n64}: only {} bytes remain",
            r.remaining()
        ));
    }
    let n = n64 as usize;
    let owned = r.u32()? as usize;
    // id + nn + nn_weight + live_len = 20 bytes minimum per record.
    r.check_count(owned, 20, "checkpoint row")?;
    let mut rows = Vec::with_capacity(owned);
    for _ in 0..owned {
        let id = r.u32()?;
        let nn = r.u32()?;
        let nn_weight = r.f64()?;
        let len = r.u32()? as usize;
        // (target u32, weight f64, count u64) = 20 bytes per entry.
        r.check_count(len, 20, "checkpoint row entry")?;
        let mut entries = Vec::with_capacity(len);
        for _ in 0..len {
            entries.push((r.u32()?, r.f64()?, r.u64()?));
        }
        rows.push((id, nn, nn_weight, entries));
    }
    r.check_count(n, 8, "checkpoint size entry")?;
    let mut size = Vec::with_capacity(n);
    for _ in 0..n {
        size.push(r.u64()?);
    }
    r.check_count(n, 1, "checkpoint active flag")?;
    let mut active = Vec::with_capacity(n);
    for _ in 0..n {
        active.push(r.u8()? != 0);
    }
    if r.remaining() != 0 {
        return Err(format!(
            "{} trailing bytes after checkpoint payload",
            r.remaining()
        ));
    }
    Ok(MachineCheckpoint {
        machine,
        machines,
        round,
        n,
        rows,
        size,
        active,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> MachineCheckpoint {
        MachineCheckpoint {
            machine: 1,
            machines: 3,
            round: 7,
            n: 5,
            rows: vec![
                (1, 4, 0.25, vec![(4, 0.25, 1), (2, f64::INFINITY, 3)]),
                (4, u32::MAX, Weight::INFINITY, vec![]),
            ],
            size: vec![1, 2, 1, 0, 3],
            active: vec![true, true, false, false, true],
        }
    }

    #[test]
    fn round_trips_bitwise() {
        let cp = sample();
        let blob = encode(&cp);
        let back = decode(&blob).unwrap();
        assert_eq!(back, cp);
        // Weight bits survive exactly (PartialEq on f64 misses -0.0/NaN
        // subtleties; pin the raw bits too).
        assert_eq!(
            back.rows[0].2.to_bits(),
            cp.rows[0].2.to_bits(),
            "nn_weight must round-trip bit-exactly"
        );
    }

    #[test]
    fn rejects_wrong_magic_and_version() {
        let mut blob = encode(&sample());
        blob[0] ^= 0xFF;
        assert!(decode(&blob).unwrap_err().contains("magic"));
        let mut blob = encode(&sample());
        blob[4] = 99;
        assert!(decode(&blob).unwrap_err().contains("version"));
    }

    #[test]
    fn rejects_truncation_at_every_cut() {
        let blob = encode(&sample());
        for cut in 0..blob.len() {
            assert!(decode(&blob[..cut]).is_err(), "cut={cut} accepted");
        }
        let mut extended = blob.clone();
        extended.push(0);
        assert!(decode(&extended).unwrap_err().contains("trailing"));
    }

    #[test]
    fn rejects_corrupt_counts_without_allocation() {
        // Blow up the owned-row count: the pre-loop guard must catch it.
        let mut blob = encode(&sample());
        // magic(4)+version(4)+machine(4)+machines(4)+round(8)+n(8) = 32.
        blob[32..36].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = decode(&blob).unwrap_err();
        assert!(err.contains("corrupt"), "want count rejection, got: {err}");
        // Blow up the id space: the size/active sections cannot fit.
        let mut blob = encode(&sample());
        blob[24..32].copy_from_slice(&u64::MAX.to_le_bytes());
        let err = decode(&blob).unwrap_err();
        assert!(err.contains("corrupt"), "want id-space rejection, got: {err}");
    }

    #[test]
    fn empty_machine_round_trips() {
        let cp = MachineCheckpoint {
            machine: 0,
            machines: 1,
            round: 0,
            n: 0,
            rows: vec![],
            size: vec![],
            active: vec![],
        };
        assert_eq!(decode(&encode(&cp)).unwrap(), cp);
    }
}
