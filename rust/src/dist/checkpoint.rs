//! Versioned binary snapshots of per-machine engine state, taken at sync
//! points by the executed distributed mode ([`super::exec`]).
//!
//! A sync point is the only cut where a consistent global snapshot exists
//! for free: every deferred patch has been flushed, every in-flight
//! exchange has been drained by its barrier, and the next round has not
//! started. The executed driver checkpoints there; recovery restores a
//! machine from its last cut (a full blob, or a full blob plus the delta
//! chain hanging off it) and replays — the whole fleet under `global`
//! recovery, a single shard under `shard_replay`. Determinism of the
//! round body makes the replay bitwise identical, which
//! `rust/tests/dist_executed.rs` pins.
//!
//! Two blob kinds share the `RACK` magic and are told apart by the
//! version word: version 1 is a **full** snapshot (every owned row),
//! version 2 is a **delta** (only rows and replicated scalars dirtied
//! since the previous cut, chained to that cut by `base_round`). The
//! driver cuts a full blob every `checkpoint_full_every`-th sync point
//! and deltas in between; [`restore_chain`] folds `[full, delta...]`
//! back into one [`MachineCheckpoint`].
//!
//! ## Wire format (version 1)
//!
//! Little-endian, one blob per machine:
//!
//! ```text
//! magic   u32   0x4B434152 ("RACK")
//! version u32   1
//! machine u32   owner of this blob
//! machines u32  fleet width the blob was cut for
//! round   u64   next round to execute after restore
//! n       u64   total cluster-id space
//! owned   u32   number of owned-row records
//! owned × record:
//!   id        u32
//!   nn        u32   cached nearest-neighbor pointer
//!   nn_weight f64   cached NN edge weight (bit-exact)
//!   live_len  u32   entry count
//!   live_len × (target u32, weight f64, count u64)
//! size    u64 × n   replicated cluster sizes
//! active  u8  × n   replicated liveness flags
//! ```
//!
//! Rows are recorded for every owned id in ascending order (retired rows
//! as zero entries), preserving live-entry *order*: the union-map fold
//! emits its output in first-encounter order of the input rows, so
//! restoring rows in a different entry order would change later map
//! orders — layout may differ after restore (arena offsets, tombstones),
//! but the per-row live sequence is what the bitwise contract needs.
//!
//! ## Wire format (version 2, delta)
//!
//! ```text
//! magic      u32   0x4B434152 ("RACK")
//! version    u32   2
//! machine    u32   owner of this blob
//! machines   u32   fleet width the blob was cut for
//! round      u64   next round to execute after this delta is applied
//! base_round u64   `round` field of the cut this delta chains onto
//! n          u64   total cluster-id space (must match the base)
//! dirty      u32   number of dirty-row records (same record layout as v1)
//! dirty × record
//! size_changes   u32, × (id u32, size u64)
//! active_changes u32, × (id u32, active u8)
//! ```
//!
//! A delta row record *replaces* the base's record for that id (a retired
//! row is recorded with zero entries, exactly as v1 does); scalar changes
//! overwrite single entries of the replicated `size`/`active` vectors.
//! [`apply_delta`] rejects a delta whose `base_round`, machine, fleet
//! width, or id space disagree with the checkpoint it is applied to — a
//! delta referencing a missing base is an error, never a partial apply.
//!
//! Decoding reuses the hardened wire [`Reader`]: length prefixes are
//! validated against the remaining buffer *before* any element loop, so a
//! corrupt or truncated blob is rejected with an error instead of a panic
//! or an unbounded allocation.

use super::network::{len_u32, put_f64, put_u32, put_u64, Reader};
use crate::linkage::Weight;

const MAGIC: u32 = 0x4B43_4152; // "RACK" in little-endian byte order
const VERSION: u32 = 1;
const VERSION_DELTA: u32 = 2;

/// One owned-row record: `(id, nn, nn_weight, entries)`.
pub type RowRecord = (u32, u32, Weight, Vec<(u32, Weight, u64)>);

/// The complete serializable state of one executed-mode machine.
#[derive(Debug, Clone, PartialEq)]
pub struct MachineCheckpoint {
    /// Machine this blob belongs to.
    pub machine: u32,
    /// Fleet width the blob was cut for (restore validates it).
    pub machines: u32,
    /// Next round to execute after restore.
    pub round: u64,
    /// Total cluster-id space.
    pub n: usize,
    /// Owned rows in ascending id order, with the owned slice of the NN
    /// cache riding along per row.
    pub rows: Vec<RowRecord>,
    /// Replicated size vector (all `n` entries).
    pub size: Vec<u64>,
    /// Replicated liveness flags (all `n` entries).
    pub active: Vec<bool>,
}

/// Serialize a machine snapshot to the version-1 binary format.
pub fn encode(cp: &MachineCheckpoint) -> Vec<u8> {
    assert_eq!(cp.size.len(), cp.n, "size vector must cover the id space");
    assert_eq!(cp.active.len(), cp.n, "active vector must cover the id space");
    let mut buf = Vec::new();
    put_u32(&mut buf, MAGIC);
    put_u32(&mut buf, VERSION);
    put_u32(&mut buf, cp.machine);
    put_u32(&mut buf, cp.machines);
    put_u64(&mut buf, cp.round);
    put_u64(&mut buf, cp.n as u64);
    put_u32(&mut buf, len_u32(cp.rows.len(), "checkpoint row"));
    for (id, nn, nn_weight, entries) in &cp.rows {
        put_u32(&mut buf, *id);
        put_u32(&mut buf, *nn);
        put_f64(&mut buf, *nn_weight);
        put_u32(&mut buf, len_u32(entries.len(), "checkpoint row entry"));
        for &(t, w, c) in entries {
            put_u32(&mut buf, t);
            put_f64(&mut buf, w);
            put_u64(&mut buf, c);
        }
    }
    for &s in &cp.size {
        put_u64(&mut buf, s);
    }
    for &a in &cp.active {
        buf.push(u8::from(a));
    }
    buf
}

/// Decode a version-1 blob, rejecting wrong magic/version, truncation,
/// corrupt length prefixes, and trailing bytes.
pub fn decode(bytes: &[u8]) -> Result<MachineCheckpoint, String> {
    let mut r = Reader::new(bytes);
    let magic = r.u32()?;
    if magic != MAGIC {
        return Err(format!("bad checkpoint magic {magic:#010x}"));
    }
    let version = r.u32()?;
    if version != VERSION {
        return Err(format!(
            "unsupported full-checkpoint version {version} (full blobs are version {VERSION}; \
             deltas are version {VERSION_DELTA} and decode via decode_delta)"
        ));
    }
    let machine = r.u32()?;
    let machines = r.u32()?;
    let round = r.u64()?;
    let n64 = r.u64()?;
    // The trailing size+active sections alone need 9 bytes per id; a
    // blob claiming more ids than its length supports is corrupt.
    if n64 > (r.remaining() / 9) as u64 {
        return Err(format!(
            "corrupt checkpoint id-space {n64}: only {} bytes remain",
            r.remaining()
        ));
    }
    let n = n64 as usize;
    let owned = r.u32()? as usize;
    // id + nn + nn_weight + live_len = 20 bytes minimum per record.
    r.check_count(owned, 20, "checkpoint row")?;
    let mut rows = Vec::with_capacity(owned);
    for _ in 0..owned {
        let id = r.u32()?;
        let nn = r.u32()?;
        let nn_weight = r.f64()?;
        let len = r.u32()? as usize;
        // (target u32, weight f64, count u64) = 20 bytes per entry.
        r.check_count(len, 20, "checkpoint row entry")?;
        let mut entries = Vec::with_capacity(len);
        for _ in 0..len {
            entries.push((r.u32()?, r.f64()?, r.u64()?));
        }
        rows.push((id, nn, nn_weight, entries));
    }
    r.check_count(n, 8, "checkpoint size entry")?;
    let mut size = Vec::with_capacity(n);
    for _ in 0..n {
        size.push(r.u64()?);
    }
    r.check_count(n, 1, "checkpoint active flag")?;
    let mut active = Vec::with_capacity(n);
    for _ in 0..n {
        active.push(r.u8()? != 0);
    }
    if r.remaining() != 0 {
        return Err(format!(
            "{} trailing bytes after checkpoint payload",
            r.remaining()
        ));
    }
    Ok(MachineCheckpoint {
        machine,
        machines,
        round,
        n,
        rows,
        size,
        active,
    })
}

/// The state a machine dirtied since its previous checkpoint cut: changed
/// owned rows (full replacement records) plus changed entries of the
/// replicated `size`/`active` vectors. Applying it to the checkpoint of
/// the previous cut reproduces the full snapshot of this cut.
#[derive(Debug, Clone, PartialEq)]
pub struct DeltaCheckpoint {
    /// Machine this blob belongs to.
    pub machine: u32,
    /// Fleet width the blob was cut for.
    pub machines: u32,
    /// Next round to execute once this delta is applied.
    pub round: u64,
    /// `round` of the cut this delta chains onto ([`apply_delta`] checks).
    pub base_round: u64,
    /// Total cluster-id space (must match the base).
    pub n: usize,
    /// Dirty owned rows in ascending id order, replacing the base's
    /// records wholesale (retired rows as zero entries, like v1).
    pub rows: Vec<RowRecord>,
    /// Changed replicated sizes, ascending id order.
    pub size: Vec<(u32, u64)>,
    /// Changed replicated liveness flags, ascending id order.
    pub active: Vec<(u32, bool)>,
}

/// Serialize a delta to the version-2 binary format.
pub fn encode_delta(d: &DeltaCheckpoint) -> Vec<u8> {
    let mut buf = Vec::new();
    put_u32(&mut buf, MAGIC);
    put_u32(&mut buf, VERSION_DELTA);
    put_u32(&mut buf, d.machine);
    put_u32(&mut buf, d.machines);
    put_u64(&mut buf, d.round);
    put_u64(&mut buf, d.base_round);
    put_u64(&mut buf, d.n as u64);
    put_u32(&mut buf, len_u32(d.rows.len(), "delta row"));
    for (id, nn, nn_weight, entries) in &d.rows {
        put_u32(&mut buf, *id);
        put_u32(&mut buf, *nn);
        put_f64(&mut buf, *nn_weight);
        put_u32(&mut buf, len_u32(entries.len(), "delta row entry"));
        for &(t, w, c) in entries {
            put_u32(&mut buf, t);
            put_f64(&mut buf, w);
            put_u64(&mut buf, c);
        }
    }
    put_u32(&mut buf, len_u32(d.size.len(), "delta size change"));
    for &(id, s) in &d.size {
        put_u32(&mut buf, id);
        put_u64(&mut buf, s);
    }
    put_u32(&mut buf, len_u32(d.active.len(), "delta active change"));
    for &(id, a) in &d.active {
        put_u32(&mut buf, id);
        buf.push(u8::from(a));
    }
    buf
}

/// Decode a version-2 delta blob, rejecting wrong magic/version,
/// truncation, corrupt length prefixes, and trailing bytes.
pub fn decode_delta(bytes: &[u8]) -> Result<DeltaCheckpoint, String> {
    let mut r = Reader::new(bytes);
    let magic = r.u32()?;
    if magic != MAGIC {
        return Err(format!("bad checkpoint magic {magic:#010x}"));
    }
    let version = r.u32()?;
    if version != VERSION_DELTA {
        return Err(format!(
            "unsupported delta-checkpoint version {version} (deltas are version {VERSION_DELTA})"
        ));
    }
    let machine = r.u32()?;
    let machines = r.u32()?;
    let round = r.u64()?;
    let base_round = r.u64()?;
    let n64 = r.u64()?;
    if n64 > usize::MAX as u64 {
        return Err(format!("corrupt delta id-space {n64}"));
    }
    let n = n64 as usize;
    let dirty = r.u32()? as usize;
    // id + nn + nn_weight + live_len = 20 bytes minimum per record.
    r.check_count(dirty, 20, "delta row")?;
    let mut rows = Vec::with_capacity(dirty);
    for _ in 0..dirty {
        let id = r.u32()?;
        let nn = r.u32()?;
        let nn_weight = r.f64()?;
        let len = r.u32()? as usize;
        r.check_count(len, 20, "delta row entry")?;
        let mut entries = Vec::with_capacity(len);
        for _ in 0..len {
            entries.push((r.u32()?, r.f64()?, r.u64()?));
        }
        rows.push((id, nn, nn_weight, entries));
    }
    let size_changes = r.u32()? as usize;
    r.check_count(size_changes, 12, "delta size change")?;
    let mut size = Vec::with_capacity(size_changes);
    for _ in 0..size_changes {
        size.push((r.u32()?, r.u64()?));
    }
    let active_changes = r.u32()? as usize;
    r.check_count(active_changes, 5, "delta active change")?;
    let mut active = Vec::with_capacity(active_changes);
    for _ in 0..active_changes {
        active.push((r.u32()?, r.u8()? != 0));
    }
    if r.remaining() != 0 {
        return Err(format!("{} trailing bytes after delta payload", r.remaining()));
    }
    Ok(DeltaCheckpoint {
        machine,
        machines,
        round,
        base_round,
        n,
        rows,
        size,
        active,
    })
}

/// Either blob kind, told apart by the version word.
#[derive(Debug, Clone, PartialEq)]
pub enum AnyCheckpoint {
    Full(MachineCheckpoint),
    Delta(DeltaCheckpoint),
}

/// Decode a blob of either version (full v1 or delta v2).
pub fn decode_any(bytes: &[u8]) -> Result<AnyCheckpoint, String> {
    let mut r = Reader::new(bytes);
    let magic = r.u32()?;
    if magic != MAGIC {
        return Err(format!("bad checkpoint magic {magic:#010x}"));
    }
    match r.u32()? {
        VERSION => decode(bytes).map(AnyCheckpoint::Full),
        VERSION_DELTA => decode_delta(bytes).map(AnyCheckpoint::Delta),
        v => Err(format!(
            "unsupported checkpoint version {v} (this build reads {VERSION} and {VERSION_DELTA})"
        )),
    }
}

/// Apply one delta in place. Rejects a delta cut for a different machine,
/// fleet width, or id space, a delta whose `base_round` does not match
/// the base's `round` (a chain with a missing link), and out-of-range or
/// un-owned ids — the base is left untouched on any error path that can
/// be checked up front, and id errors abort before later sections apply.
pub fn apply_delta(base: &mut MachineCheckpoint, d: &DeltaCheckpoint) -> Result<(), String> {
    if d.machine != base.machine {
        return Err(format!(
            "delta for machine {} applied to machine {}",
            d.machine, base.machine
        ));
    }
    if d.machines != base.machines {
        return Err(format!(
            "delta cut for {} machines applied to a {}-machine checkpoint",
            d.machines, base.machines
        ));
    }
    if d.n != base.n {
        return Err(format!(
            "delta id-space {} does not match base id-space {}",
            d.n, base.n
        ));
    }
    if d.base_round != base.round {
        return Err(format!(
            "delta chains onto round {} but the base is at round {} (missing link)",
            d.base_round, base.round
        ));
    }
    for rec in &d.rows {
        let id = rec.0;
        let slot = base
            .rows
            .binary_search_by_key(&id, |r| r.0)
            .map_err(|_| format!("delta row {id} is not an owned row of the base"))?;
        base.rows[slot] = rec.clone();
    }
    for &(id, s) in &d.size {
        let slot = base
            .size
            .get_mut(id as usize)
            .ok_or_else(|| format!("delta size change for out-of-range id {id}"))?;
        *slot = s;
    }
    for &(id, a) in &d.active {
        let slot = base
            .active
            .get_mut(id as usize)
            .ok_or_else(|| format!("delta active change for out-of-range id {id}"))?;
        *slot = a;
    }
    base.round = d.round;
    Ok(())
}

/// Fold a checkpoint chain — one full blob followed by zero or more
/// deltas in cut order — back into the full snapshot of the last cut.
pub fn restore_chain(blobs: &[Vec<u8>]) -> Result<MachineCheckpoint, String> {
    let (first, rest) = blobs
        .split_first()
        .ok_or_else(|| "empty checkpoint chain".to_string())?;
    let mut cp = match decode_any(first)? {
        AnyCheckpoint::Full(cp) => cp,
        AnyCheckpoint::Delta(d) => {
            return Err(format!(
                "checkpoint chain starts with a delta (base round {} is missing)",
                d.base_round
            ));
        }
    };
    for blob in rest {
        match decode_any(blob)? {
            AnyCheckpoint::Delta(d) => apply_delta(&mut cp, &d)?,
            AnyCheckpoint::Full(_) => {
                return Err("full checkpoint in the middle of a delta chain".to_string());
            }
        }
    }
    Ok(cp)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> MachineCheckpoint {
        MachineCheckpoint {
            machine: 1,
            machines: 3,
            round: 7,
            n: 5,
            rows: vec![
                (1, 4, 0.25, vec![(4, 0.25, 1), (2, f64::INFINITY, 3)]),
                (4, u32::MAX, Weight::INFINITY, vec![]),
            ],
            size: vec![1, 2, 1, 0, 3],
            active: vec![true, true, false, false, true],
        }
    }

    #[test]
    fn round_trips_bitwise() {
        let cp = sample();
        let blob = encode(&cp);
        let back = decode(&blob).unwrap();
        assert_eq!(back, cp);
        // Weight bits survive exactly (PartialEq on f64 misses -0.0/NaN
        // subtleties; pin the raw bits too).
        assert_eq!(
            back.rows[0].2.to_bits(),
            cp.rows[0].2.to_bits(),
            "nn_weight must round-trip bit-exactly"
        );
    }

    #[test]
    fn rejects_wrong_magic_and_version() {
        let mut blob = encode(&sample());
        blob[0] ^= 0xFF;
        assert!(decode(&blob).unwrap_err().contains("magic"));
        let mut blob = encode(&sample());
        blob[4] = 99;
        assert!(decode(&blob).unwrap_err().contains("version"));
    }

    #[test]
    fn rejects_truncation_at_every_cut() {
        let blob = encode(&sample());
        for cut in 0..blob.len() {
            assert!(decode(&blob[..cut]).is_err(), "cut={cut} accepted");
        }
        let mut extended = blob.clone();
        extended.push(0);
        assert!(decode(&extended).unwrap_err().contains("trailing"));
    }

    #[test]
    fn rejects_corrupt_counts_without_allocation() {
        // Blow up the owned-row count: the pre-loop guard must catch it.
        let mut blob = encode(&sample());
        // magic(4)+version(4)+machine(4)+machines(4)+round(8)+n(8) = 32.
        blob[32..36].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = decode(&blob).unwrap_err();
        assert!(err.contains("corrupt"), "want count rejection, got: {err}");
        // Blow up the id space: the size/active sections cannot fit.
        let mut blob = encode(&sample());
        blob[24..32].copy_from_slice(&u64::MAX.to_le_bytes());
        let err = decode(&blob).unwrap_err();
        assert!(err.contains("corrupt"), "want id-space rejection, got: {err}");
    }

    #[test]
    fn empty_machine_round_trips() {
        let cp = MachineCheckpoint {
            machine: 0,
            machines: 1,
            round: 0,
            n: 0,
            rows: vec![],
            size: vec![],
            active: vec![],
        };
        assert_eq!(decode(&encode(&cp)).unwrap(), cp);
    }

    fn sample_delta() -> DeltaCheckpoint {
        DeltaCheckpoint {
            machine: 1,
            machines: 3,
            round: 9,
            base_round: 7,
            n: 5,
            rows: vec![
                (1, 2, 0.5, vec![(2, 0.5, 4)]),
                (4, u32::MAX, Weight::INFINITY, vec![]),
            ],
            size: vec![(1, 3), (2, 0)],
            active: vec![(2, false)],
        }
    }

    #[test]
    fn delta_round_trips_bitwise() {
        let d = sample_delta();
        let blob = encode_delta(&d);
        let back = decode_delta(&blob).unwrap();
        assert_eq!(back, d);
        assert_eq!(back.rows[0].2.to_bits(), d.rows[0].2.to_bits());
        // decode_any tells the kinds apart by the version word.
        assert_eq!(decode_any(&blob).unwrap(), AnyCheckpoint::Delta(d));
        let full = sample();
        assert_eq!(
            decode_any(&encode(&full)).unwrap(),
            AnyCheckpoint::Full(full)
        );
    }

    #[test]
    fn delta_rejects_truncation_at_every_cut() {
        let blob = encode_delta(&sample_delta());
        for cut in 0..blob.len() {
            assert!(decode_delta(&blob[..cut]).is_err(), "cut={cut} accepted");
            assert!(decode_any(&blob[..cut]).is_err(), "any: cut={cut} accepted");
        }
        let mut extended = blob.clone();
        extended.push(0);
        assert!(decode_delta(&extended).unwrap_err().contains("trailing"));
    }

    #[test]
    fn delta_rejects_corrupt_counts_without_allocation() {
        // magic(4)+version(4)+machine(4)+machines(4)+round(8)+base(8)+n(8)
        // = 40; the dirty-row count sits at [40..44].
        let mut blob = encode_delta(&sample_delta());
        blob[40..44].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = decode_delta(&blob).unwrap_err();
        assert!(err.contains("corrupt"), "want count rejection, got: {err}");
        // Wrong-version blobs are named, not panicked on.
        let mut blob = encode_delta(&sample_delta());
        blob[4] = 99;
        assert!(decode_delta(&blob).unwrap_err().contains("version"));
        assert!(decode_any(&blob).unwrap_err().contains("version"));
    }

    #[test]
    fn chain_replay_reproduces_the_full_snapshot() {
        let base = sample();
        let d = sample_delta();
        let mut folded = base.clone();
        apply_delta(&mut folded, &d).unwrap();
        assert_eq!(folded.round, 9);
        assert_eq!(folded.rows[0], d.rows[0]);
        assert_eq!(folded.rows[1], d.rows[1]);
        assert_eq!(folded.size, vec![1, 3, 0, 0, 3]);
        assert_eq!(
            folded.active,
            vec![true, true, false, false, true],
            "active flag change applies"
        );
        let chained = restore_chain(&[encode(&base), encode_delta(&d)]).unwrap();
        assert_eq!(chained, folded, "chain replay == in-place apply");
        assert_eq!(restore_chain(&[encode(&base)]).unwrap(), base);
    }

    #[test]
    fn chain_rejects_missing_or_misordered_links() {
        let base = sample();
        let mut d = sample_delta();
        d.base_round = 99; // references a cut that never happened
        let err = restore_chain(&[encode(&base), encode_delta(&d)]).unwrap_err();
        assert!(err.contains("missing link"), "got: {err}");
        // A chain cannot start with a delta.
        let err = restore_chain(&[encode_delta(&sample_delta())]).unwrap_err();
        assert!(err.contains("starts with a delta"), "got: {err}");
        // Or contain a second full blob mid-chain.
        let err =
            restore_chain(&[encode(&base), encode(&base)]).unwrap_err();
        assert!(err.contains("middle"), "got: {err}");
        assert!(restore_chain(&[]).is_err());
    }

    #[test]
    fn apply_rejects_mismatched_and_out_of_range_targets() {
        let mut base = sample();
        let ok = base.clone();
        let mut d = sample_delta();
        d.machine = 2;
        assert!(apply_delta(&mut base, &d).is_err());
        assert_eq!(base, ok, "failed apply leaves the base untouched");
        let mut d = sample_delta();
        d.n = 4;
        assert!(apply_delta(&mut base, &d).unwrap_err().contains("id-space"));
        let mut d = sample_delta();
        d.rows[0].0 = 3; // not an owned row of the base
        assert!(apply_delta(&mut base, &d).unwrap_err().contains("owned"));
        let mut d = sample_delta();
        d.size[0].0 = 5; // out of range
        assert!(apply_delta(&mut base, &d)
            .unwrap_err()
            .contains("out-of-range"));
    }
}
