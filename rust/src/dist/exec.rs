//! Executed distribution: one OS thread per machine, each owning its
//! arena shard, exchanging the *same* [`Message`] batches the simulation
//! accounts for — over real `std::sync::mpsc` channels with injected
//! per-link latency and jitter.
//!
//! ## Why a second mode
//!
//! The simulated engine ([`super::DistCore`]) computes against the
//! authoritative global state and *stages* traffic through the wire codec;
//! `t_sim` is a model. That design makes the dendrogram provably
//! topology-invariant, but nothing ever actually crosses a thread
//! boundary, so the codec, the barrier structure, and the recovery story
//! are exercised only by construction, not by execution. This module runs
//! the identical round body truly sharded: every machine holds only its
//! owned rows plus replicated scalars, every remote read is a real
//! encode → channel → decode round trip, and the run reports a *measured*
//! wall clock ([`RoundMetrics::t_exec`]) as the empirical sibling of
//! `t_sim`. The contract, pinned by `rust/tests/dist_executed.rs`:
//!
//! > executed and simulated runs produce **bitwise identical** dendrogram,
//! > (1+ε) bounds trace, and sync-point schedule, for every topology,
//! > ε, and sync mode — and any shard (or several) killed mid-run
//! > recovers to the same bits under either recovery strategy.
//!
//! ## Why bitwise equality holds
//!
//! The only numeric folds are `scan_nn` and `compute_union_map`, and both
//! consume rows in storage order. The executed mode preserves exactly the
//! state the simulation reads at each decision point:
//!
//! * **Owned rows** — patched in per-(target, leader) sorted order, which
//!   matches the simulation's serial pair-loop order per row (patch
//!   targets of distinct pairs commute across rows; within a row, leaders
//!   apply ascending both here and there). Install/clear/compaction use
//!   the shared [`NeighborStore`] code, which preserves live-entry order.
//! * **Replicated scalars** (`active`, `size`, `matched`, `partner`,
//!   `pair_weight`) — rebuilt on every machine from the same broadcast
//!   pair list, in the same order the simulation writes them.
//! * **Remote NN caches** — refreshed each round by the same query sets
//!   the simulation stages ([`Message::NnQuery`]/[`Message::NnCacheQuery`]
//!   with identical batch content and order). A stale shadow is never
//!   decisive: the ε-good candidate test needs *both* halves to accept,
//!   and the half owned by the scanning machine is authoritative.
//!
//! ## Traffic accounting
//!
//! Batches are counted under the simulation's rule — one RPC per
//! non-empty (src, dst) pair per phase, at encoded wire length. Per-round
//! exact and ε-good executed traffic equals the simulation's minus its
//! `PairViewQuery`/`PairViewReply` batches (the executed mode replicates
//! pair state from the merge broadcast instead of querying it). The
//! batched mode diverges further by design: real execution must refresh
//! NN caches and reach the coordinator every round and must ship patches
//! eagerly, where the simulation's deferred-flush accounting charges the
//! wire only at sync points — the executed numbers are what a real
//! deployment pays for the same schedule, the simulated numbers are the
//! sync-boundary lower bound. The *schedule itself* (`sync_points`) is
//! bitwise shared.
//!
//! ## Checkpoint / recovery (v2)
//!
//! At every sync point the driver collects one versioned
//! [`super::checkpoint`] blob per machine. Cuts form a **chain**: a full
//! snapshot every [`ExecOptions::checkpoint_full_every`] cuts, deltas in
//! between. A delta carries only the rows and replicated scalars dirtied
//! since the previous cut (tracked through the merge/patch/rescan path;
//! compaction preserves row content so it never re-dirties). Restore
//! replays the chain ([`checkpoint::restore_chain`]); the codec also
//! serializes the initial state, so every executed run exercises a
//! restore. v1 full blobs still decode — the codec is versioned and
//! adversarially fuzzed in `rust/tests/codec_adversarial.rs`.
//!
//! Faults are a campaign, not a single event: [`ExecOptions::faults`]
//! schedules any number of `(machine, round)` kills (several machines in
//! one round, the same machine twice, a fault during recovery), and
//! [`ExecOptions::fault_rate`] adds seeded random kills on top. A dead
//! shard is *detected*, not assumed: every channel send funnels through
//! one helper that converts disconnection into a named [`MachineDown`]
//! error, which machines report instead of panicking and the driver
//! answers with recovery instead of a hang.
//!
//! Two recovery strategies, selected by [`ExecOptions::recovery_mode`]
//! and pinned bitwise-identical to each other and to the unfaulted run:
//!
//! * [`RecoveryMode::Global`] — BSP rollback. Tear the fleet down,
//!   restore every machine from the last cut, replay every round since.
//!   Cost: `(rounds since cut) × machines` machine-rounds.
//! * [`RecoveryMode::ShardReplay`] — respawn only the dead machine,
//!   restore it from its own chain, and re-feed it the journaled inbound
//!   traffic ([`JournalRecord`]: payload bytes keyed `(src, dst, round,
//!   step)`) while the survivors idle at the barrier. The respawn's
//!   outbound goes to a sink (survivors already consumed those bytes);
//!   after replay the fabric is rewired. Cost: `rounds since cut`
//!   machine-rounds — a fleet-width factor cheaper.
//!
//! Recovery cost is reported next to the round clocks:
//! `recovery_rounds_replayed`, `recovery_bytes_replayed`, `t_recover`.

use std::sync::mpsc::{self, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use rustc_hash::{FxHashMap, FxHashSet};

use super::checkpoint::{self, MachineCheckpoint};
use super::network::{decode_batch, encode_batch, BatchRecord, JournalRecord, Message, NetReport};
use super::{engine_name, vshard_of, DistCore, DistSelector, Placement};
use crate::approx::good::{self, Candidate, MergePair};
use crate::approx::quality::MergeBound;
use crate::dendrogram::{Dendrogram, Merge};
use crate::linkage::{EdgeState, Linkage, Weight};
use crate::metrics::{RoundMetrics, RunMetrics};
use crate::rac::logic::{compute_union_map, scan_nn, PairView};
use crate::rac::{RacResult, NO_NN};
use crate::store::{NeighborStore, NeighborsRef, RowRef};
use crate::trace::{
    EventKind, Phase as TracePhase, RecoveryStage, TraceBuf, TraceEvent, TraceSink, COORD,
};

/// A named shard failure: the machine whose channel went dead and the
/// round the death was observed in. This is the *only* way a dead shard
/// surfaces — every channel send and collect converts disconnection into
/// this error instead of panicking or hanging, so the driver can answer
/// with recovery.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MachineDown {
    /// Machine whose channel disconnected or timed out.
    pub machine: usize,
    /// Round in which the death was observed.
    pub round: usize,
}

impl std::fmt::Display for MachineDown {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "machine {} down in round {}", self.machine, self.round)
    }
}

/// How the driver recovers a dead shard. Both strategies land on bits
/// identical to the unfaulted run; they differ in replay cost. See the
/// module docs for guidance.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RecoveryMode {
    /// BSP global rollback: tear the whole fleet down and restore every
    /// machine from the last sync cut. Simple, journal-free, and the
    /// right call when faults are rare or the fleet is small.
    #[default]
    Global,
    /// Respawn only the dead machine: restore it from its own chain and
    /// replay its journaled inbound batches while survivors idle at the
    /// barrier. A fleet-width factor cheaper per fault, at the cost of
    /// journaling every packet between cuts.
    ShardReplay,
}

/// Kill `machine` at the top of `round` (0-based). A round the run never
/// reaches simply never faults.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultSpec {
    /// Machine to kill (must be `< machines`).
    pub machine: usize,
    /// Round at whose start the fault fires.
    pub round: usize,
}

/// Knobs for the executed distributed mode.
#[derive(Debug, Clone, PartialEq)]
pub struct ExecOptions {
    /// Fixed one-way link latency added to every cross-machine packet.
    pub latency: Duration,
    /// Upper bound on deterministic per-packet jitter (hashed from the
    /// link and round, so reruns see identical delays).
    pub jitter: Duration,
    /// Scheduled fault campaign: every entry kills its machine at the top
    /// of its round. Duplicate entries fire on consecutive passes over
    /// the round boundary — a duplicate `(machine, round)` is a fault
    /// *during* the recovery the first one triggered.
    pub faults: Vec<FaultSpec>,
    /// Per-(machine, round) probability of a seeded random kill, on top
    /// of the scheduled campaign. `0.0` disables.
    pub fault_rate: f64,
    /// Seed for the random-fault hash (reruns fault identically).
    pub fault_seed: u64,
    /// Recovery strategy for every fault in the run.
    pub recovery_mode: RecoveryMode,
    /// Cut a full checkpoint every this-many sync cuts; the cuts between
    /// are deltas chained onto it. `1` means every cut is full (the v1
    /// behavior); clamped to at least 1.
    pub checkpoint_full_every: usize,
}

impl Default for ExecOptions {
    fn default() -> Self {
        ExecOptions {
            latency: Duration::ZERO,
            jitter: Duration::ZERO,
            faults: Vec::new(),
            fault_rate: 0.0,
            fault_seed: 0,
            recovery_mode: RecoveryMode::Global,
            checkpoint_full_every: 4,
        }
    }
}

/// How long the driver waits for any single machine report before
/// scanning for a dead thread. Generous: test topologies finish rounds in
/// microseconds; only a genuine death or deadlock gets near this.
const REPORT_TIMEOUT: Duration = Duration::from_secs(120);

/// How long a machine waits for one peer packet before naming the first
/// silent peer in a [`MachineDown`]. The common death is *detected
/// instantly* (a dropped inbox makes the send fail); the timeout only
/// catches a peer that is alive but wedged.
const PEER_TIMEOUT: Duration = Duration::from_secs(10);

/// Cap on driver-*detected* recoveries (channel deaths we did not
/// inject) before declaring the run structurally broken.
const MAX_DETECTED_RECOVERIES: usize = 8;

// Per-round exchange step ids (unique per (round, step) because a round
// runs exactly one selector). Exact rounds:
const STEP_NN_QUERY: u8 = 0;
const STEP_NN_REPLY: u8 = 1;
// ε-good rounds:
const STEP_CACHE_QUERY: u8 = 0;
const STEP_CACHE_REPLY: u8 = 1;
const STEP_CANDIDATES: u8 = 2;
const STEP_MATCHING: u8 = 3;
// Merge phase (offset past the selector's find steps):
const EXACT_MERGE_BASE: u8 = 2;
const GOOD_MERGE_BASE: u8 = 4;

/// Convert a disconnected-channel send into the named shard failure.
/// Every send in this module — wire packets, driver commands, journal
/// injection — funnels through here, so a dead machine is always a
/// [`MachineDown`] error, never a panic or an ignored loss.
fn send_or_down<T>(
    tx: &Sender<T>,
    machine: usize,
    round: usize,
    value: T,
) -> Result<(), MachineDown> {
    tx.send(value).map_err(|_| MachineDown { machine, round })
}

/// Deterministic seeded fault coin: splitmix64-style hash of
/// `(seed, machine, round)` compared against `rate`. Rerunning with the
/// same seed faults the same (machine, round) cells.
fn random_fault(seed: u64, machine: usize, round: usize, rate: f64) -> bool {
    if rate <= 0.0 {
        return false;
    }
    let mut x = seed
        ^ (machine as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (round as u64).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    ((x >> 11) as f64) / ((1u64 << 53) as f64) < rate
}

/// One wire packet: an encoded [`Message`] batch plus its delivery time.
/// Empty batches still flow (they are the barrier) but are never counted.
struct Packet {
    src: usize,
    round: usize,
    step: u8,
    bytes: Vec<u8>,
    deliver_at: Instant,
}

/// Driver → machine commands.
#[derive(Clone)]
enum Cmd {
    /// Adopt the given checkpoint chain (full blob + deltas) as the
    /// complete machine state.
    Restore(Vec<Vec<u8>>),
    /// Run the find phase of `round` and report `Phase1`.
    Round { round: usize },
    /// Apply the globally selected pairs and report `RoundDone`.
    Merge { pairs: Vec<MergePair> },
    /// Serialize state (full snapshot or dirty delta) and report
    /// `CheckpointBlob`.
    Checkpoint { round: usize, full: bool },
    /// Swap the peer fabric (after a shard respawn replaced an inbox).
    Rewire { peers: Vec<Sender<Packet>> },
    /// No pairs anywhere: report `FinishAck` and exit.
    Finish,
    /// Tear down immediately (normal completion or fault injection).
    Exit,
}

/// Per-round wire counters a machine hands back with each report.
#[derive(Default)]
struct NetStats {
    messages: usize,
    bytes: usize,
    log: Vec<BatchRecord>,
    /// Every packet posted this round — barriers included — when the
    /// run journals for shard replay. Empty otherwise.
    journal: Vec<JournalRecord>,
    /// Trace events buffered on the machine since the last report —
    /// shipped on the existing report channel, so the hot path never
    /// takes a lock. Empty when tracing is disabled.
    events: Vec<TraceEvent>,
}

/// Machine → driver reports.
enum Report {
    /// Find-phase result. Exact rounds: one per machine (pairs from owned
    /// leaders). ε-good rounds: from the coordinator only.
    Phase1 { pairs: Vec<MergePair>, synced: bool },
    /// Merge phase done. `nn_weights` carries the pre-merge NN weight
    /// bits of owned pair members — the driver's (1+ε) bounds inputs.
    RoundDone {
        nn_weights: Vec<(u32, u64)>,
        nn_updates: usize,
        nn_scan_entries: usize,
        eligibility_scan_entries: usize,
        net: NetStats,
    },
    CheckpointBlob { machine: usize, blob: Vec<u8> },
    FinishAck {
        eligibility_scan_entries: usize,
        net: NetStats,
    },
    /// A peer's channel died mid-phase: the reporting machine is healthy
    /// and idles for instructions; the *named* machine is down.
    Down(MachineDown),
}

/// A neighbor row that is either borrowed from the local arena or was
/// fetched over the wire. [`compute_union_map`] takes one row type for
/// both inputs; this adapter lets a local leader row fold against a
/// remote partner's fetched entries without copying the local side.
#[derive(Clone, Copy)]
enum RowView<'a> {
    Store(RowRef<'a>),
    Fetched(&'a [(u32, EdgeState)]),
}

impl NeighborsRef for RowView<'_> {
    fn for_each_edge(self, mut f: impl FnMut(u32, EdgeState)) {
        match self {
            RowView::Store(r) => r.for_each_edge(f),
            RowView::Fetched(entries) => {
                for &(t, e) in entries {
                    f(t, e);
                }
            }
        }
    }

    fn live_len(self) -> usize {
        match self {
            RowView::Store(r) => r.live_len(),
            RowView::Fetched(entries) => entries.len(),
        }
    }
}

/// Deterministic per-packet jitter: splitmix64 over the link identity,
/// so a replayed round sees identical delays (recovery determinism).
fn jitter_ns(src: usize, dst: usize, round: usize, step: u8, bound: Duration) -> u64 {
    let bound = bound.as_nanos() as u64;
    if bound == 0 {
        return 0;
    }
    let mut x = (src as u64)
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add((dst as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9))
        .wrapping_add((round as u64).wrapping_mul(0x94D0_49BB_1331_11EB))
        .wrapping_add(step as u64 + 1);
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    x % (bound + 1)
}

/// The channel fabric of one machine: senders to every peer, its own
/// inbox, and the per-round traffic counters.
struct Wire {
    me: usize,
    machines: usize,
    peers: Vec<Sender<Packet>>,
    inbox: Receiver<Packet>,
    /// Packets that arrived ahead of the step we are collecting.
    stash: Vec<Packet>,
    latency: Duration,
    jitter: Duration,
    /// Record every posted packet (barriers included) for shard replay.
    journal: bool,
    /// How long to wait on a silent peer before naming it down.
    peer_timeout: Duration,
    round: usize,
    stats: NetStats,
    /// Per-machine trace buffer; drained into [`NetStats::events`] by
    /// [`Wire::take_stats`]. Disabled → every emission is one branch.
    tbuf: TraceBuf,
}

impl Wire {
    /// Ship one physical packet. Empty batches flow (barrier) but only
    /// non-empty ones are accounted — the simulation's counting rule.
    /// A disconnected peer is a named [`MachineDown`], never a panic.
    fn post(&mut self, dst: usize, step: u8, msgs: &[Message]) -> Result<(), MachineDown> {
        debug_assert_ne!(dst, self.me, "machines never post to themselves");
        let bytes = encode_batch(msgs);
        if !msgs.is_empty() {
            self.stats.messages += 1;
            self.stats.bytes += bytes.len();
            self.stats.log.push(BatchRecord {
                src: self.me,
                dst,
                messages: msgs.len(),
                bytes: bytes.len(),
                round: self.round,
            });
            // Same accounting site as the counters above, so trace totals
            // equal the RunMetrics columns by construction (`msgs: 1` —
            // one batched RPC, the simulation's counting unit).
            self.tbuf.instant(EventKind::WireSend {
                dst: dst as u32,
                step,
                msgs: 1,
                bytes: bytes.len(),
            });
        }
        if self.journal {
            // Barriers are journaled too: the replayed shard blocks on
            // them exactly like the original incarnation did.
            self.stats.journal.push(JournalRecord {
                src: self.me,
                dst,
                round: self.round,
                step,
                bytes: bytes.clone(),
            });
        }
        let delay = self.latency
            + Duration::from_nanos(jitter_ns(self.me, dst, self.round, step, self.jitter));
        let packet = Packet {
            src: self.me,
            round: self.round,
            step,
            bytes,
            deliver_at: Instant::now() + delay,
        };
        send_or_down(&self.peers[dst], dst, self.round, packet)
    }

    /// Wait for one packet from each of `from`, honoring delivery times,
    /// and decode them in ascending src order. A peer that disconnects or
    /// stays silent past [`Wire::peer_timeout`] is named in the error.
    fn collect(
        &mut self,
        step: u8,
        from: impl Iterator<Item = usize>,
    ) -> Result<Vec<(usize, Vec<Message>)>, MachineDown> {
        let expected: Vec<usize> = from.collect();
        let wait_start = self.tbuf.now();
        let mut packets: Vec<Packet> = Vec::with_capacity(expected.len());
        let mut i = 0;
        while i < self.stash.len() {
            if self.stash[i].round == self.round && self.stash[i].step == step {
                packets.push(self.stash.swap_remove(i));
            } else {
                i += 1;
            }
        }
        while packets.len() < expected.len() {
            let p = match self.inbox.recv_timeout(self.peer_timeout) {
                Ok(p) => p,
                Err(_) => {
                    // Disconnected or silent: name the first peer whose
                    // packet never arrived.
                    let have: FxHashSet<usize> = packets.iter().map(|p| p.src).collect();
                    let missing = expected
                        .iter()
                        .copied()
                        .find(|s| !have.contains(s))
                        .expect("collect short yet no peer missing");
                    return Err(MachineDown {
                        machine: missing,
                        round: self.round,
                    });
                }
            };
            if p.round == self.round && p.step == step {
                packets.push(p);
            } else {
                self.stash.push(p);
            }
        }
        // The link delay is modeled at the receiver: nothing is readable
        // before its delivery time.
        if let Some(latest) = packets.iter().map(|p| p.deliver_at).max() {
            let now = Instant::now();
            if latest > now {
                std::thread::sleep(latest - now);
            }
        }
        // The span covers arrival wait + modeled link delay: how long this
        // machine idled at the barrier before every peer was readable.
        self.tbuf.span(wait_start, EventKind::BarrierWait { step });
        packets.sort_by_key(|p| p.src);
        for p in &packets {
            self.tbuf.instant(EventKind::WireRecv {
                src: p.src as u32,
                step,
                bytes: p.bytes.len(),
            });
        }
        packets
            .into_iter()
            .map(|p| match decode_batch(&p.bytes) {
                Ok(msgs) => Ok((p.src, msgs)),
                // A corrupt batch means the sender's state is gone —
                // treat the link as dead and let the driver recover.
                Err(_) => Err(MachineDown {
                    machine: p.src,
                    round: self.round,
                }),
            })
            .collect()
    }

    /// Symmetric exchange: post `out[dst]` to every peer, collect one
    /// packet from every peer.
    fn all_to_all(
        &mut self,
        step: u8,
        out: Vec<Vec<Message>>,
    ) -> Result<Vec<(usize, Vec<Message>)>, MachineDown> {
        debug_assert_eq!(out.len(), self.machines);
        for (dst, msgs) in out.iter().enumerate() {
            if dst != self.me {
                self.post(dst, step, msgs)?;
            }
        }
        let me = self.me;
        self.collect(step, (0..self.machines).filter(move |&s| s != me))
    }

    /// Gather: non-root machines post `msgs` to `root`; root collects.
    fn gather_to(
        &mut self,
        root: usize,
        step: u8,
        msgs: &[Message],
    ) -> Result<Vec<(usize, Vec<Message>)>, MachineDown> {
        if self.me == root {
            let machines = self.machines;
            self.collect(step, (0..machines).filter(move |&s| s != root))
        } else {
            self.post(root, step, msgs)?;
            Ok(Vec::new())
        }
    }

    /// Broadcast: root posts `out[dst]` to every peer; peers receive one
    /// batch from root.
    fn broadcast_from(
        &mut self,
        root: usize,
        step: u8,
        out: &[Vec<Message>],
    ) -> Result<Vec<Message>, MachineDown> {
        if self.me == root {
            for (dst, msgs) in out.iter().enumerate() {
                if dst != root {
                    self.post(dst, step, msgs)?;
                }
            }
            Ok(Vec::new())
        } else {
            let mut got = self.collect(step, std::iter::once(root))?;
            Ok(got.pop().map(|(_, msgs)| msgs).unwrap_or_default())
        }
    }

    fn take_stats(&mut self) -> NetStats {
        let mut stats = std::mem::take(&mut self.stats);
        stats.events = self.tbuf.drain();
        stats
    }
}

/// One executed machine: the owned shard of the arena plus the replicated
/// scalars, mirroring [`super::DistCore`]'s fields sliced by ownership.
struct Machine {
    me: usize,
    n: usize,
    linkage: Linkage,
    place: Placement,
    selector: DistSelector,
    store: NeighborStore,
    /// Owned ids still active, ascending (the machine's `active_ids`).
    owned_active: Vec<u32>,
    /// Replicated liveness (maintained from broadcast pair lists).
    active: Vec<bool>,
    /// Replicated sizes (same maintenance).
    size: Vec<u64>,
    /// NN cache: authoritative for owned ids, per-round-refreshed shadow
    /// for remote ids (defaults harmless — see module docs).
    nn: Vec<u32>,
    nn_weight: Vec<Weight>,
    /// Per-round pair state, replicated from the merge broadcast.
    matched: Vec<bool>,
    partner: Vec<u32>,
    pair_weight: Vec<Weight>,
    /// Per-round ε-good sweep cost (reported, then reset).
    eligibility_scan_entries: usize,
    /// Owned rows touched since the last cut (patch, install, clear,
    /// phase-3 NN rescan) — the delta checkpoint's row set. Remote NN
    /// shadows are deliberately not tracked: checkpoints only carry
    /// owned state, and shadows are refreshed every round.
    dirty_rows: FxHashSet<u32>,
    /// Replicated sizes changed since the last cut.
    dirty_size: FxHashSet<u32>,
    /// Replicated liveness flags changed since the last cut.
    dirty_active: FxHashSet<u32>,
    /// `round` of the last cut — the delta's `base_round` chain link.
    last_cut_round: u64,
    wire: Wire,
}

impl Machine {
    fn owns(&self, c: u32) -> bool {
        self.place.machine_of(c) == self.me
    }

    /// Adopt a checkpoint chain (full blob + deltas) as the complete
    /// machine state.
    fn restore(&mut self, chain: &[Vec<u8>]) {
        let cp = checkpoint::restore_chain(chain)
            .expect("driver handed a corrupt checkpoint chain");
        assert_eq!(cp.machine as usize, self.me, "chain for the wrong machine");
        assert_eq!(
            cp.machines as usize, self.wire.machines,
            "chain for the wrong fleet width"
        );
        self.n = cp.n;
        self.store = NeighborStore::new(cp.n);
        self.owned_active.clear();
        self.nn = vec![NO_NN; cp.n];
        self.nn_weight = vec![Weight::INFINITY; cp.n];
        for (id, nn, nn_weight, entries) in &cp.rows {
            let row: Vec<(u32, EdgeState)> = entries
                .iter()
                .map(|&(t, w, c)| (t, EdgeState { weight: w, count: c }))
                .collect();
            if !row.is_empty() {
                self.store.install_row(*id, &row);
            }
            self.nn[*id as usize] = *nn;
            self.nn_weight[*id as usize] = *nn_weight;
        }
        self.size = cp.size;
        self.active = cp.active;
        self.owned_active = (0..cp.n as u32)
            .filter(|&c| self.owns(c) && self.active[c as usize])
            .collect();
        self.matched = vec![false; cp.n];
        self.partner = vec![NO_NN; cp.n];
        self.pair_weight = vec![0.0; cp.n];
        // A restore *is* the cut it loaded: nothing is dirty against it.
        self.dirty_rows.clear();
        self.dirty_size.clear();
        self.dirty_active.clear();
        self.last_cut_round = cp.round;
    }

    /// Serialize machine state for the given next round: the complete
    /// owned shard (`full`) or only what changed since the last cut.
    /// Either way the cut becomes the new dirty-tracking baseline.
    fn checkpoint(&mut self, round: usize, full: bool) -> Vec<u8> {
        let row_record = |c: u32| {
            let entries = self
                .store
                .row(c)
                .iter()
                .map(|(t, e)| (t, e.weight, e.count))
                .collect();
            (c, self.nn[c as usize], self.nn_weight[c as usize], entries)
        };
        let blob = if full {
            let rows = (0..self.n as u32).filter(|&c| self.owns(c)).map(row_record).collect();
            checkpoint::encode(&MachineCheckpoint {
                machine: self.me as u32,
                machines: self.wire.machines as u32,
                round: round as u64,
                n: self.n,
                rows,
                size: self.size.clone(),
                active: self.active.clone(),
            })
        } else {
            let mut row_ids: Vec<u32> = self.dirty_rows.iter().copied().collect();
            row_ids.sort_unstable();
            let mut size_ids: Vec<u32> = self.dirty_size.iter().copied().collect();
            size_ids.sort_unstable();
            let mut active_ids: Vec<u32> = self.dirty_active.iter().copied().collect();
            active_ids.sort_unstable();
            checkpoint::encode_delta(&checkpoint::DeltaCheckpoint {
                machine: self.me as u32,
                machines: self.wire.machines as u32,
                round: round as u64,
                base_round: self.last_cut_round,
                n: self.n,
                rows: row_ids.into_iter().map(row_record).collect(),
                size: size_ids.into_iter().map(|c| (c, self.size[c as usize])).collect(),
                active: active_ids
                    .into_iter()
                    .map(|c| (c, self.active[c as usize]))
                    .collect(),
            })
        };
        self.dirty_rows.clear();
        self.dirty_size.clear();
        self.dirty_active.clear();
        self.last_cut_round = round as u64;
        blob
    }

    fn begin_round(&mut self, round: usize) {
        self.wire.round = round;
        self.wire.stats = NetStats::default();
        self.wire.tbuf.set_round(round);
        self.eligibility_scan_entries = 0;
    }

    /// Exact find phase: refresh remote NN shadows, then test reciprocity
    /// over owned active ids. Query staging matches the simulation's
    /// `exchange_nn_pointers` (ascending scan, per-destination dedup).
    fn find_reciprocal(&mut self) -> Result<Vec<MergePair>, MachineDown> {
        let m = self.wire.machines;
        let mut queries: Vec<Vec<Message>> = vec![Vec::new(); m];
        let mut seen: FxHashSet<u32> = FxHashSet::default();
        for &c in &self.owned_active {
            let v = self.nn[c as usize];
            if v == NO_NN {
                continue;
            }
            let sv = self.place.machine_of(v);
            if sv != self.me && seen.insert(v) {
                queries[sv].push(Message::NnQuery { cluster: v });
            }
        }
        let incoming = self.wire.all_to_all(STEP_NN_QUERY, queries)?;
        let mut replies: Vec<Vec<Message>> = vec![Vec::new(); m];
        for (src, batch) in incoming {
            replies[src] = batch
                .iter()
                .map(|q| match q {
                    Message::NnQuery { cluster } => Message::NnReply {
                        cluster: *cluster,
                        nn: self.nn[*cluster as usize],
                    },
                    other => panic!("unexpected message in NN-query step: {other:?}"),
                })
                .collect();
        }
        for (_, batch) in self.wire.all_to_all(STEP_NN_REPLY, replies)? {
            for msg in batch {
                match msg {
                    Message::NnReply { cluster, nn } => self.nn[cluster as usize] = nn,
                    other => panic!("unexpected message in NN-reply step: {other:?}"),
                }
            }
        }
        let mut pairs = Vec::new();
        for &c in &self.owned_active {
            let v = self.nn[c as usize];
            if v != NO_NN && self.nn[v as usize] == c && c < v {
                pairs.push(MergePair {
                    leader: c,
                    partner: v,
                    weight: self.nn_weight[c as usize],
                });
            }
        }
        Ok(pairs)
    }

    /// ε-good find phase (per-round and batched). Refreshes the remote NN
    /// shadows needed by the sweep's partner-half test, sweeps owned rows,
    /// gathers candidates to the coordinator (machine 0), which selects
    /// the matching — globally for per-round mode, or with the batched
    /// local-first rule — and broadcasts it. Returns the selection on the
    /// coordinator, `None` elsewhere.
    fn find_good(
        &mut self,
        epsilon: f64,
        vshards: Option<u32>,
    ) -> Result<Option<(Vec<MergePair>, bool)>, MachineDown> {
        let m = self.wire.machines;
        // Steps 0/1: refresh the shadow NN cache for remote upper
        // endpoints that pass our half of the acceptance test — the same
        // query set the simulation stages in `stage_nn_cache_queries`.
        let mut queries: Vec<Vec<Message>> = vec![Vec::new(); m];
        let mut seen: FxHashSet<u32> = FxHashSet::default();
        for &a in &self.owned_active {
            let ai = a as usize;
            let (nn_a, w_a) = (self.nn[ai], self.nn_weight[ai]);
            for (b, e) in self.store.row(a).iter() {
                if b > a && good::accepts(e.weight, b, epsilon, w_a, nn_a) {
                    let sb = self.place.machine_of(b);
                    if sb != self.me && seen.insert(b) {
                        queries[sb].push(Message::NnCacheQuery { cluster: b });
                    }
                }
            }
        }
        let incoming = self.wire.all_to_all(STEP_CACHE_QUERY, queries)?;
        let mut replies: Vec<Vec<Message>> = vec![Vec::new(); m];
        for (src, batch) in incoming {
            replies[src] = batch
                .iter()
                .map(|q| match q {
                    Message::NnCacheQuery { cluster } => Message::NnCacheReply {
                        cluster: *cluster,
                        nn: self.nn[*cluster as usize],
                        weight: self.nn_weight[*cluster as usize],
                    },
                    other => panic!("unexpected message in cache-query step: {other:?}"),
                })
                .collect();
        }
        for (_, batch) in self.wire.all_to_all(STEP_CACHE_REPLY, replies)? {
            for msg in batch {
                match msg {
                    Message::NnCacheReply { cluster, nn, weight } => {
                        self.nn[cluster as usize] = nn;
                        self.nn_weight[cluster as usize] = weight;
                    }
                    other => panic!("unexpected message in cache-reply step: {other:?}"),
                }
            }
        }
        // Sweep owned rows in ascending order — concatenated across
        // machines by the gather below, this reproduces the simulation's
        // global ascending candidate order.
        let mut cands: Vec<Candidate> = Vec::new();
        for &a in &self.owned_active {
            let (row_cands, scanned) =
                good::scan_row_candidates(self.store.row(a), a, epsilon, &self.nn_weight, &self.nn);
            self.eligibility_scan_entries += scanned;
            cands.extend(row_cands.into_iter().map(|(w, b)| (w, a, b)));
        }
        // Step 2: gather to the coordinator.
        let gathered = if self.me != 0 && !cands.is_empty() {
            vec![Message::CandidateBatch { edges: std::mem::take(&mut cands) }]
        } else {
            Vec::new()
        };
        let incoming = self.wire.gather_to(0, STEP_CANDIDATES, &gathered)?;
        let selection = (self.me == 0).then(|| {
            let mut all = cands;
            for (_, batch) in incoming {
                for msg in batch {
                    match msg {
                        Message::CandidateBatch { edges } => all.extend(edges),
                        other => panic!("unexpected message in candidate step: {other:?}"),
                    }
                }
            }
            let mut scratch = vec![false; self.n];
            match vshards {
                None => (good::select_matching(all, &mut scratch), true),
                Some(v) => {
                    // The batched local-first rule, decided globally: any
                    // co-block candidate anywhere makes this a local
                    // round; only a dry sweep forces the sync round.
                    let (local, frontier): (Vec<Candidate>, Vec<Candidate>) = all
                        .into_iter()
                        .partition(|&(_, a, b)| vshard_of(a, self.n, v) == vshard_of(b, self.n, v));
                    if !local.is_empty() {
                        (good::select_matching(local, &mut scratch), false)
                    } else {
                        (good::select_matching(frontier, &mut scratch), true)
                    }
                }
            }
        });
        // Step 3: broadcast the matching. The physical packet is the
        // barrier; the simulation's accounting rule (non-empty matching,
        // destination owns an active cluster) decides what is counted.
        let mut out: Vec<Vec<Message>> = vec![Vec::new(); m];
        if let Some((pairs, _)) = &selection {
            if !pairs.is_empty() {
                let mut has_active = vec![false; m];
                for c in 0..self.n as u32 {
                    if self.active[c as usize] {
                        has_active[self.place.machine_of(c)] = true;
                    }
                }
                let wire_pairs: Vec<(u32, u32, Weight)> =
                    pairs.iter().map(|p| (p.leader, p.partner, p.weight)).collect();
                for (dst, slot) in out.iter_mut().enumerate() {
                    if dst != 0 && has_active[dst] {
                        *slot = vec![Message::MatchingBroadcast { pairs: wire_pairs.clone() }];
                    }
                }
            }
        }
        let _echo = self.wire.broadcast_from(0, STEP_MATCHING, &out)?;
        // Non-coordinators apply the authoritative pair list from the
        // driver's `Cmd::Merge`; the broadcast they just received carries
        // the same pairs (wire-accounting fidelity).
        Ok(selection)
    }

    /// Merge phase: replicate pair state, fetch remote partner rows, fold
    /// union maps for owned leaders, route and apply patches, update
    /// replicated scalars, rescan stale NN caches. Ordering mirrors the
    /// simulation's `compute_unions` + `apply_unions` + phase 3. Every
    /// owned-state write also lands in the dirty sets — the delta
    /// checkpoint's change tracking.
    fn merge_and_rescan(&mut self, pairs: &[MergePair]) -> Result<Report, MachineDown> {
        let m = self.wire.machines;
        let merge_start = self.wire.tbuf.now();
        let base = match self.selector {
            DistSelector::Rnn => EXACT_MERGE_BASE,
            _ => GOOD_MERGE_BASE,
        };
        // Pre-merge NN weights of owned pair members: the driver's
        // (1+ε) bounds inputs (the simulation reads these before its
        // phase 3 overwrites them).
        let mut nn_weights: Vec<(u32, u64)> = Vec::new();
        for p in pairs {
            for c in [p.leader, p.partner] {
                if self.owns(c) {
                    nn_weights.push((c, self.nn_weight[c as usize].to_bits()));
                }
            }
        }
        // Replicate pair state — every machine sees the same list in the
        // same order, so `PairView` reads are bitwise shared.
        for p in pairs {
            let (l, pr) = (p.leader as usize, p.partner as usize);
            self.matched[l] = true;
            self.matched[pr] = true;
            self.partner[l] = p.partner;
            self.partner[pr] = p.leader;
            self.pair_weight[l] = p.weight;
            self.pair_weight[pr] = p.weight;
        }
        // Fetch remote partner rows for owned leaders (ascending pair
        // order — the simulation's staging order).
        let mut fetch: Vec<Vec<Message>> = vec![Vec::new(); m];
        for p in pairs {
            if self.owns(p.leader) {
                let sp = self.place.machine_of(p.partner);
                if sp != self.me {
                    fetch[sp].push(Message::PartnerFetch { partner: p.partner });
                }
            }
        }
        let incoming = self.wire.all_to_all(base, fetch)?;
        let mut replies: Vec<Vec<Message>> = vec![Vec::new(); m];
        for (src, batch) in incoming {
            replies[src] = batch
                .iter()
                .map(|q| match q {
                    Message::PartnerFetch { partner } => Message::PartnerState {
                        partner: *partner,
                        size: self.size[*partner as usize],
                        entries: self
                            .store
                            .row(*partner)
                            .iter()
                            .map(|(t, e)| (t, e.weight, e.count))
                            .collect(),
                    },
                    other => panic!("unexpected message in partner-fetch step: {other:?}"),
                })
                .collect();
        }
        let mut fetched: FxHashMap<u32, Vec<(u32, EdgeState)>> = FxHashMap::default();
        for (_, batch) in self.wire.all_to_all(base + 1, replies)? {
            for msg in batch {
                match msg {
                    Message::PartnerState { partner, entries, .. } => {
                        fetched.insert(
                            partner,
                            entries
                                .into_iter()
                                .map(|(t, w, c)| (t, EdgeState { weight: w, count: c }))
                                .collect(),
                        );
                    }
                    other => panic!("unexpected message in partner-state step: {other:?}"),
                }
            }
        }
        // Union maps for owned leaders, in pair-list order — the same
        // order the simulation's `compute_unions` walks (ascending leader
        // for exact rounds, matching order for ε-good rounds). Sizes are
        // still pre-merge here, as in the simulation.
        let mut unions: Vec<(u32, Vec<(u32, EdgeState)>)> = Vec::new();
        for p in pairs {
            if !self.owns(p.leader) {
                continue;
            }
            let row_l = RowView::Store(self.store.row(p.leader));
            let fetched_row;
            let row_p = if self.owns(p.partner) {
                RowView::Store(self.store.row(p.partner))
            } else {
                fetched_row = &fetched[&p.partner];
                RowView::Fetched(fetched_row)
            };
            let map = compute_union_map(
                self.linkage,
                p.leader,
                p.partner,
                self.pair_weight[p.leader as usize],
                self.size[p.leader as usize],
                self.size[p.partner as usize],
                row_l,
                row_p,
                |x| PairView {
                    merging: self.matched[x as usize],
                    partner: self.partner[x as usize],
                    size: self.size[x as usize],
                    pair_weight: self.pair_weight[x as usize],
                },
            );
            unions.push((p.leader, map));
        }
        // Route patches: local ones applied below, remote ones shipped
        // now (the executed mode has no deferred-flush option — state is
        // truly sharded, so correctness needs the bytes this round).
        let mut patches: Vec<(u32, u32, u32, EdgeState)> = Vec::new();
        let mut out: Vec<Vec<Message>> = vec![Vec::new(); m];
        for (l, map) in &unions {
            let pr = self.partner[*l as usize];
            for &(t, e) in map {
                if !self.matched[t as usize] {
                    let st = self.place.machine_of(t);
                    if st == self.me {
                        patches.push((t, *l, pr, e));
                    } else {
                        out[st].push(Message::EdgePatch {
                            target: t,
                            leader: *l,
                            retired: pr,
                            weight: e.weight,
                            count: e.count,
                        });
                    }
                }
            }
        }
        for (_, batch) in self.wire.all_to_all(base + 2, out)? {
            for msg in batch {
                match msg {
                    Message::EdgePatch { target, leader, retired, weight, count } => {
                        patches.push((target, leader, retired, EdgeState { weight, count }));
                    }
                    other => panic!("unexpected message in patch step: {other:?}"),
                }
            }
        }
        // Apply in (target, leader) order: per-row ascending leaders is
        // the simulation's serial order, and distinct rows commute.
        patches.sort_unstable_by_key(|&(t, l, _, _)| (t, l));
        for (t, l, pr, e) in patches {
            self.store.patch(t, l, pr, e);
            self.dirty_rows.insert(t);
        }
        // Commit the merges to the replicated scalars and owned rows.
        for p in pairs {
            let (l, pr) = (p.leader as usize, p.partner as usize);
            self.size[l] += self.size[pr];
            self.active[pr] = false;
            self.dirty_size.insert(p.leader);
            self.dirty_active.insert(p.partner);
        }
        for (l, map) in &unions {
            self.store.install_row(*l, map);
            self.dirty_rows.insert(*l);
        }
        for p in pairs {
            if self.owns(p.partner) {
                self.store.clear_row(p.partner);
                self.dirty_rows.insert(p.partner);
            }
        }
        // Compaction preserves live-entry content and order, so it never
        // re-dirties rows the cut already has the latest bytes for.
        self.store.maybe_compact();
        self.owned_active.retain(|&c| self.active[c as usize]);
        self.wire
            .tbuf
            .span(merge_start, EventKind::Phase(TracePhase::Merge));
        let update_start = self.wire.tbuf.now();
        // Phase 3: rescan owned NN caches invalidated by the merges —
        // the same filter and scan as the simulation's round tail.
        let mut nn_updates = 0;
        let mut nn_scan_entries = 0;
        let updates: Vec<(u32, u32, Weight, usize)> = self
            .owned_active
            .iter()
            .filter_map(|&c| {
                let ci = c as usize;
                let v = self.nn[ci];
                let stale = self.matched[ci] || (v != NO_NN && self.matched[v as usize]);
                stale.then(|| {
                    let row = self.store.row(c);
                    let (nn, w) = scan_nn(row);
                    (c, nn, w, row.live_len())
                })
            })
            .collect();
        for (c, nn, w, scanned) in updates {
            self.nn[c as usize] = nn;
            self.nn_weight[c as usize] = w;
            self.dirty_rows.insert(c);
            nn_updates += 1;
            nn_scan_entries += scanned;
        }
        for p in pairs {
            self.matched[p.leader as usize] = false;
            self.matched[p.partner as usize] = false;
        }
        self.wire
            .tbuf
            .span(update_start, EventKind::Phase(TracePhase::UpdateNn));
        Ok(Report::RoundDone {
            nn_weights,
            nn_updates,
            nn_scan_entries,
            eligibility_scan_entries: std::mem::take(&mut self.eligibility_scan_entries),
            net: self.wire.take_stats(),
        })
    }

    /// Execute one non-terminal driver command. A wire failure bubbles up
    /// as the named dead machine.
    fn handle(&mut self, cmd: Cmd, reports: &Sender<Report>) -> Result<(), MachineDown> {
        match cmd {
            Cmd::Restore(chain) => self.restore(&chain),
            Cmd::Rewire { peers } => {
                debug_assert!(
                    self.wire.stash.is_empty(),
                    "rewire with stashed packets would strand them"
                );
                self.wire.peers = peers;
            }
            Cmd::Round { round } => {
                self.begin_round(round);
                let find_start = self.wire.tbuf.now();
                let phase1 = match self.selector {
                    DistSelector::Rnn => Some((self.find_reciprocal()?, true)),
                    DistSelector::Good { epsilon } => self.find_good(epsilon, None)?,
                    DistSelector::GoodBatched { epsilon, vshards } => {
                        self.find_good(epsilon, Some(vshards))?
                    }
                };
                self.wire
                    .tbuf
                    .span(find_start, EventKind::Phase(TracePhase::Find));
                if let Some((pairs, synced)) = phase1 {
                    let _ = reports.send(Report::Phase1 { pairs, synced });
                }
            }
            Cmd::Merge { pairs } => {
                let report = self.merge_and_rescan(&pairs)?;
                let _ = reports.send(report);
            }
            Cmd::Checkpoint { round, full } => {
                let blob = self.checkpoint(round, full);
                let _ = reports.send(Report::CheckpointBlob { machine: self.me, blob });
            }
            Cmd::Finish | Cmd::Exit => {
                unreachable!("terminal commands are handled by machine_main")
            }
        }
        Ok(())
    }
}

/// Machine thread body: obey driver commands until told to exit. A dead
/// peer mid-command is *reported*, not fatal — the machine stays up and
/// idles for the driver's recovery instructions.
fn machine_main(mut mc: Machine, cmds: Receiver<Cmd>, reports: Sender<Report>) {
    loop {
        let cmd = match cmds.recv() {
            Ok(cmd) => cmd,
            // Driver gone (fault teardown or panic): die quietly.
            Err(_) => return,
        };
        match cmd {
            Cmd::Finish => {
                let _ = reports.send(Report::FinishAck {
                    eligibility_scan_entries: std::mem::take(&mut mc.eligibility_scan_entries),
                    net: mc.wire.take_stats(),
                });
                return;
            }
            Cmd::Exit => return,
            cmd => {
                if let Err(down) = mc.handle(cmd, &reports) {
                    let _ = reports.send(Report::Down(down));
                }
            }
        }
    }
}

/// The driver's handle on a running fleet.
struct Fleet {
    cmds: Vec<Sender<Cmd>>,
    reports: Receiver<Report>,
    /// Kept so the report channel never disconnects even with every
    /// machine dead — `recv` must time out and *diagnose*, not error.
    report_tx: Sender<Report>,
    /// Current packet fabric (a respawn replaces one sender, then the
    /// whole vector is rebroadcast via `Cmd::Rewire`).
    peer_senders: Vec<Sender<Packet>>,
    handles: Vec<JoinHandle<()>>,
}

impl Fleet {
    fn send_to(&self, machine: usize, round: usize, cmd: &Cmd) -> Result<(), MachineDown> {
        send_or_down(&self.cmds[machine], machine, round, cmd.clone())
    }

    fn send_all(&self, round: usize, cmd: &Cmd) -> Result<(), MachineDown> {
        for machine in 0..self.cmds.len() {
            self.send_to(machine, round, cmd)?;
        }
        Ok(())
    }

    /// Receive one report. A `Down` report or a timeout with a finished
    /// thread is the named dead machine; a timeout with every thread
    /// alive is a wedge bug and panics loudly.
    fn recv(&self, round: usize) -> Result<Report, MachineDown> {
        match self.reports.recv_timeout(REPORT_TIMEOUT) {
            Ok(Report::Down(down)) => Err(down),
            Ok(report) => Ok(report),
            Err(_) => {
                let machine = self
                    .handles
                    .iter()
                    .position(|h| h.is_finished())
                    .expect("machine unresponsive yet all threads alive: fleet wedged");
                Err(MachineDown { machine, round })
            }
        }
    }

    /// Tear the fleet down and reap the threads, surfacing any panic.
    fn shutdown(self) {
        for c in &self.cmds {
            let _ = c.send(Cmd::Exit);
        }
        for h in self.handles {
            if h.join().is_err() {
                panic!("executed machine thread panicked");
            }
        }
    }

    /// Teardown on the recovery path: a machine that died abnormally is
    /// exactly what we are recovering from, so join errors are expected
    /// and swallowed.
    fn teardown_lossy(self) {
        for c in &self.cmds {
            let _ = c.send(Cmd::Exit);
        }
        for h in self.handles {
            let _ = h.join();
        }
    }
}

/// Immutable per-run parameters shared by spawns and respawns.
struct FleetSpec {
    machines: usize,
    linkage: Linkage,
    place: Placement,
    selector: DistSelector,
    latency: Duration,
    jitter: Duration,
    /// Journal posted packets for shard replay (`RecoveryMode::ShardReplay`).
    journal: bool,
    /// Trace sink every spawned (or respawned) machine buffers into.
    sink: TraceSink,
}

/// Spawn one machine thread on the given fabric and feed it its
/// checkpoint chain.
fn spawn_machine(
    spec: &FleetSpec,
    me: usize,
    peers: Vec<Sender<Packet>>,
    inbox: Receiver<Packet>,
    report_tx: Sender<Report>,
    chain: &[Vec<u8>],
) -> (Sender<Cmd>, JoinHandle<()>) {
    let (cmd_tx, cmd_rx) = mpsc::channel::<Cmd>();
    let machine = Machine {
        me,
        n: 0,
        linkage: spec.linkage,
        place: spec.place,
        selector: spec.selector,
        store: NeighborStore::new(0),
        owned_active: Vec::new(),
        active: Vec::new(),
        size: Vec::new(),
        nn: Vec::new(),
        nn_weight: Vec::new(),
        matched: Vec::new(),
        partner: Vec::new(),
        pair_weight: Vec::new(),
        eligibility_scan_entries: 0,
        dirty_rows: FxHashSet::default(),
        dirty_size: FxHashSet::default(),
        dirty_active: FxHashSet::default(),
        last_cut_round: 0,
        wire: Wire {
            me,
            machines: spec.machines,
            peers,
            inbox,
            stash: Vec::new(),
            latency: spec.latency,
            jitter: spec.jitter,
            journal: spec.journal,
            peer_timeout: PEER_TIMEOUT,
            round: 0,
            stats: NetStats::default(),
            // Thread tag convention: coordinator is 0, machine m is m+1.
            tbuf: spec
                .sink
                .buf(engine_name(spec.selector), me as u32, me as u32 + 1),
        },
    };
    let handle = std::thread::spawn(move || machine_main(machine, cmd_rx, report_tx));
    let _ = cmd_tx.send(Cmd::Restore(chain.to_vec()));
    (cmd_tx, handle)
}

/// Spawn the fleet and feed every machine its checkpoint chain — recovery
/// and cold start are the same code path, so the checkpoint codec is
/// exercised by every executed run.
fn spawn_fleet(spec: &FleetSpec, chains: &[Vec<Vec<u8>>]) -> Fleet {
    let m = spec.machines;
    let (report_tx, report_rx) = mpsc::channel::<Report>();
    let data: Vec<(Sender<Packet>, Receiver<Packet>)> = (0..m).map(|_| mpsc::channel()).collect();
    let peer_senders: Vec<Sender<Packet>> = data.iter().map(|(tx, _)| tx.clone()).collect();
    let mut data_rx: Vec<Option<Receiver<Packet>>> =
        data.into_iter().map(|(_, rx)| Some(rx)).collect();
    let mut cmds = Vec::with_capacity(m);
    let mut handles = Vec::with_capacity(m);
    for me in 0..m {
        let (cmd_tx, handle) = spawn_machine(
            spec,
            me,
            peer_senders.clone(),
            data_rx[me].take().expect("inbox taken once"),
            report_tx.clone(),
            &chains[me],
        );
        cmds.push(cmd_tx);
        handles.push(handle);
    }
    Fleet {
        cmds,
        reports: report_rx,
        report_tx,
        peer_senders,
        handles,
    }
}

/// The driver's recovery image: everything needed to roll the run back
/// to a sync point — the machines' checkpoint chains plus the
/// driver-side outputs accumulated up to that cut.
struct Snapshot {
    round: usize,
    n_active: usize,
    merges: Vec<Merge>,
    bounds: Vec<MergeBound>,
    rounds: Vec<RoundMetrics>,
    log: Vec<BatchRecord>,
    /// Round-scoped trace events accumulated up to this cut — rewound on
    /// rollback exactly like `log`, so re-executed rounds never
    /// double-emit (the analyzer's totals == RunMetrics contract).
    tevents: Vec<TraceEvent>,
    /// Per-machine checkpoint chain: one full blob, then deltas.
    chains: Vec<Vec<Vec<u8>>>,
}

/// Respawn one dead machine and bring it back to the current round:
/// restore from its own chain, replay its journaled inbound traffic
/// (outbound goes to a sink — survivors already consumed those bytes),
/// then rewire the fabric. Survivors idle at their command channels the
/// whole time. Returns `(machine_rounds_replayed, journal_bytes_replayed)`.
fn shard_recover(
    fl: &mut Fleet,
    spec: &FleetSpec,
    x: usize,
    snapshot: &Snapshot,
    trace: &[(usize, Vec<MergePair>)],
    journal: &[JournalRecord],
) -> Result<(usize, usize), MachineDown> {
    // Kill the shard (simulated preemption) and reap the old thread. The
    // old inbox receiver dies with it; survivors still hold its sender,
    // which is why the recovery ends in a fleet-wide rewire.
    let _ = fl.cmds[x].send(Cmd::Exit);
    let (new_tx, new_rx) = mpsc::channel::<Packet>();
    let (sink_tx, _sink_rx) = mpsc::channel::<Packet>();
    let replay_peers: Vec<Sender<Packet>> = (0..spec.machines).map(|_| sink_tx.clone()).collect();
    let (cmd_tx, handle) = spawn_machine(
        spec,
        x,
        replay_peers,
        new_rx,
        fl.report_tx.clone(),
        &snapshot.chains[x],
    );
    let old = std::mem::replace(&mut fl.handles[x], handle);
    // The dead incarnation exits cleanly on Exit (or already returned);
    // a panic here is a real bug, not the injected fault.
    if old.join().is_err() {
        panic!("executed machine thread panicked");
    }
    fl.cmds[x] = cmd_tx;
    fl.peer_senders[x] = new_tx.clone();
    // Inject the journaled inbound traffic, barriers included, stamped
    // deliverable now: replay runs at channel speed, not modeled-latency
    // speed (the original delays already shaped the bytes).
    let mut bytes_replayed = 0usize;
    for rec in journal.iter().filter(|r| r.dst == x) {
        bytes_replayed += rec.bytes.len();
        let packet = Packet {
            src: rec.src,
            round: rec.round,
            step: rec.step,
            bytes: rec.bytes.clone(),
            deliver_at: Instant::now(),
        };
        send_or_down(&new_tx, x, rec.round, packet)?;
    }
    // Re-drive the respawn through every round since the cut. Its
    // reports are drained and discarded — the driver's copies from the
    // original execution stay authoritative, so metrics and the traffic
    // log stay identical to the unfaulted run.
    let expects_phase1 = matches!(spec.selector, DistSelector::Rnn) || x == 0;
    for (round, pairs) in trace {
        fl.send_to(x, *round, &Cmd::Round { round: *round })?;
        if expects_phase1 {
            match fl.recv(*round)? {
                Report::Phase1 { .. } => {}
                _ => panic!("expected Phase1 report during shard replay"),
            }
        }
        fl.send_to(x, *round, &Cmd::Merge { pairs: pairs.clone() })?;
        match fl.recv(*round)? {
            Report::RoundDone { .. } => {}
            _ => panic!("expected RoundDone report during shard replay"),
        }
    }
    // Rewire everyone onto the new fabric. Command channels are FIFO, so
    // the rewire is processed before any post-recovery round work; the
    // sink drops with this frame only after the respawn has no further
    // replay posts to make.
    let peers = fl.peer_senders.clone();
    for me in 0..spec.machines {
        fl.send_to(me, snapshot.round, &Cmd::Rewire { peers: peers.clone() })?;
    }
    Ok((trace.len(), bytes_replayed))
}

/// What a completed round means for the run loop.
enum Flow {
    Continue,
    Finished,
}

/// The executed-run driver: owns the fleet, the recovery image, and the
/// accumulated outputs, and turns fault hits into recoveries.
struct Driver {
    spec: FleetSpec,
    m: usize,
    n: usize,
    max_rounds: usize,
    full_every: usize,
    recovery_mode: RecoveryMode,
    fault_rate: f64,
    fault_seed: u64,
    /// Scheduled faults not yet fired (one instance consumed per hit, so
    /// duplicates fire on consecutive passes — fault during recovery).
    pending_faults: Vec<FaultSpec>,
    /// Random-fault cells already fired: a rollback re-crosses the same
    /// round boundaries, and the same seeded coin must not refire forever.
    fired_random: FxHashSet<(usize, usize)>,
    snapshot: Snapshot,
    /// Pair lists of every round since the last cut — the shard-replay
    /// command script.
    trace: Vec<(usize, Vec<MergePair>)>,
    /// Every packet posted since the last cut (shard-replay mode only).
    journal: Vec<JournalRecord>,
    /// Round-scoped trace events (machine events shipped in reports,
    /// driver round spans, sync points) — rewound with the snapshot.
    tevents: Vec<TraceEvent>,
    /// Durable trace buffer for events whose metrics counterparts
    /// accumulate across rollbacks (run span, checkpoint cuts, faults,
    /// recovery) — never rewound.
    tbuf: TraceBuf,
    sink: TraceSink,
    merges: Vec<Merge>,
    bounds: Vec<MergeBound>,
    metrics: RunMetrics,
    log: Vec<BatchRecord>,
    n_active: usize,
    round: usize,
    fleet: Option<Fleet>,
}

impl Driver {
    fn fleet(&self) -> &Fleet {
        self.fleet.as_ref().expect("fleet alive")
    }

    /// Machines to kill at the top of the current round: one scheduled
    /// instance per machine per pass, plus unfired random cells.
    fn fault_hits(&mut self) -> Vec<usize> {
        let round = self.round;
        let mut hits = Vec::new();
        for x in 0..self.m {
            if let Some(i) = self
                .pending_faults
                .iter()
                .position(|f| f.machine == x && f.round == round)
            {
                self.pending_faults.swap_remove(i);
                hits.push(x);
                continue;
            }
            if random_fault(self.fault_seed, x, round, self.fault_rate)
                && self.fired_random.insert((x, round))
            {
                hits.push(x);
            }
        }
        hits
    }

    /// Global rollback: tear the fleet down, restore everyone from the
    /// last cut, rewind the driver-side outputs, replay. The rounds and
    /// bytes being re-executed are charged to the recovery metrics.
    fn rollback_global(&mut self) {
        let teardown_start = self.tbuf.now();
        self.fleet.take().expect("fleet alive").teardown_lossy();
        self.tbuf.span(
            teardown_start,
            EventKind::Recovery {
                stage: RecoveryStage::Teardown,
                target: COORD,
                rounds: 0,
                bytes: 0,
            },
        );
        let rounds_replayed = (self.round - self.snapshot.round) * self.m;
        let bytes_replayed = self.metrics.rounds[self.snapshot.rounds.len()..]
            .iter()
            .map(|r| r.net_bytes)
            .sum::<usize>();
        self.metrics.recovery_rounds_replayed += rounds_replayed;
        self.metrics.recovery_bytes_replayed += bytes_replayed;
        self.merges = self.snapshot.merges.clone();
        self.bounds = self.snapshot.bounds.clone();
        self.metrics.rounds = self.snapshot.rounds.clone();
        self.log = self.snapshot.log.clone();
        self.tevents = self.snapshot.tevents.clone();
        self.n_active = self.snapshot.n_active;
        self.round = self.snapshot.round;
        self.trace.clear();
        self.journal.clear();
        let restore_start = self.tbuf.now();
        self.fleet = Some(spawn_fleet(&self.spec, &self.snapshot.chains));
        self.tbuf.span(
            restore_start,
            EventKind::Recovery {
                stage: RecoveryStage::Restore,
                target: COORD,
                rounds: 0,
                bytes: 0,
            },
        );
        // Emitted where the recovery counters accumulate, with the same
        // numbers — the analyzer folds these back into the totals.
        self.tbuf.instant(EventKind::Recovery {
            stage: RecoveryStage::Replay,
            target: COORD,
            rounds: rounds_replayed,
            bytes: bytes_replayed,
        });
    }

    /// Recover the given dead machines under the configured strategy.
    fn recover(&mut self, hits: &[usize]) -> Result<(), MachineDown> {
        match self.recovery_mode {
            // One rollback covers every machine lost this round.
            RecoveryMode::Global => {
                self.rollback_global();
                Ok(())
            }
            RecoveryMode::ShardReplay => {
                for &x in hits {
                    let mut fl = self.fleet.take().expect("fleet alive");
                    let res =
                        shard_recover(&mut fl, &self.spec, x, &self.snapshot, &self.trace, &self.journal);
                    self.fleet = Some(fl);
                    let (rounds_replayed, bytes_replayed) = res?;
                    self.metrics.recovery_rounds_replayed += rounds_replayed;
                    self.metrics.recovery_bytes_replayed += bytes_replayed;
                    self.tbuf.instant(EventKind::Recovery {
                        stage: RecoveryStage::Replay,
                        target: x as u32,
                        rounds: rounds_replayed,
                        bytes: bytes_replayed,
                    });
                }
                Ok(())
            }
        }
    }

    /// Sync point: cut a recovery image. Checkpoint time is deliberately
    /// outside `t_exec` — it is recovery machinery, not round work. Cuts
    /// chain: a full blob every `full_every` cuts, deltas between.
    fn cut_checkpoint(&mut self, next_round: usize) -> Result<(), MachineDown> {
        let full = self.snapshot.chains[0].len() >= self.full_every;
        self.fleet()
            .send_all(next_round, &Cmd::Checkpoint { round: next_round, full })?;
        let mut blobs: Vec<Vec<u8>> = vec![Vec::new(); self.m];
        for _ in 0..self.m {
            let report = self.fleet().recv(next_round)?;
            match report {
                Report::CheckpointBlob { machine, blob } => blobs[machine] = blob,
                _ => panic!("expected CheckpointBlob report"),
            }
        }
        let cut_bytes = blobs.iter().map(|b| b.len()).sum::<usize>();
        self.metrics.checkpoint_bytes += cut_bytes;
        self.tbuf.instant(EventKind::CheckpointCut {
            full,
            bytes: cut_bytes,
        });
        let chains: Vec<Vec<Vec<u8>>> = if full {
            blobs.into_iter().map(|b| vec![b]).collect()
        } else {
            let mut chains = self.snapshot.chains.clone();
            for (chain, blob) in chains.iter_mut().zip(blobs) {
                chain.push(blob);
            }
            chains
        };
        self.snapshot = Snapshot {
            round: next_round,
            n_active: self.n_active,
            merges: self.merges.clone(),
            bounds: self.bounds.clone(),
            rounds: self.metrics.rounds.clone(),
            log: self.log.clone(),
            tevents: self.tevents.clone(),
            chains,
        };
        self.trace.clear();
        self.journal.clear();
        Ok(())
    }

    /// Drive one full round: find phase, pair selection, merge phase,
    /// bookkeeping, and (at sync points) a checkpoint cut.
    fn execute_round(&mut self) -> Result<Flow, MachineDown> {
        let round = self.round;
        let m = self.m;
        self.tbuf.set_round(round);
        let round_start = self.tbuf.now();
        let t_round = Instant::now();
        self.fleet().send_all(round, &Cmd::Round { round })?;
        // Exact rounds: every machine reports its owned pairs and the
        // driver merges them into the global ascending-leader list.
        // ε-good rounds: the coordinator reports the global matching.
        let (pairs, synced) = match self.spec.selector {
            DistSelector::Rnn => {
                let mut all: Vec<MergePair> = Vec::new();
                for _ in 0..m {
                    let report = self.fleet().recv(round)?;
                    match report {
                        Report::Phase1 { pairs, .. } => all.extend(pairs),
                        _ => panic!("expected Phase1 report"),
                    }
                }
                all.sort_unstable_by_key(|p| p.leader);
                (all, true)
            }
            _ => {
                let report = self.fleet().recv(round)?;
                match report {
                    Report::Phase1 { pairs, synced } => (pairs, synced),
                    _ => panic!("expected Phase1 report"),
                }
            }
        };
        let t_find = t_round.elapsed();
        let mut rm = RoundMetrics {
            round,
            clusters: self.n_active,
            merges: pairs.len(),
            sync_points: usize::from(synced),
            t_find,
            ..Default::default()
        };
        // Round-scoped events route through `tevents` (not `tbuf`) so a
        // rollback rewinds them together with the metrics they mirror.
        if synced {
            if let Some(e) = self.tbuf.make_instant(EventKind::SyncPoint) {
                self.tevents.push(e);
            }
        }
        if pairs.is_empty() {
            self.fleet().send_all(round, &Cmd::Finish)?;
            for _ in 0..m {
                let report = self.fleet().recv(round)?;
                match report {
                    Report::FinishAck { eligibility_scan_entries, net } => {
                        rm.eligibility_scan_entries += eligibility_scan_entries;
                        rm.net_messages += net.messages;
                        rm.net_bytes += net.bytes;
                        self.log.extend(net.log);
                        self.tevents.extend(net.events);
                    }
                    _ => panic!("expected FinishAck report"),
                }
            }
            rm.t_exec = t_round.elapsed();
            if let Some(e) = self.tbuf.make_span(round_start, EventKind::Round) {
                self.tevents.push(e);
            }
            self.metrics.rounds.push(rm);
            // Finish is a terminal command: machines have already exited.
            for h in self.fleet.take().expect("fleet alive").handles {
                if h.join().is_err() {
                    panic!("executed machine thread panicked");
                }
            }
            return Ok(Flow::Finished);
        }
        let t_merge = Instant::now();
        self.fleet().send_all(round, &Cmd::Merge { pairs: pairs.clone() })?;
        let mut pre_nn: FxHashMap<u32, u64> = FxHashMap::default();
        for _ in 0..m {
            let report = self.fleet().recv(round)?;
            match report {
                Report::RoundDone {
                    nn_weights,
                    nn_updates,
                    nn_scan_entries,
                    eligibility_scan_entries,
                    net,
                } => {
                    pre_nn.extend(nn_weights);
                    rm.nn_updates += nn_updates;
                    rm.nn_scan_entries += nn_scan_entries;
                    rm.eligibility_scan_entries += eligibility_scan_entries;
                    rm.net_messages += net.messages;
                    rm.net_bytes += net.bytes;
                    self.log.extend(net.log);
                    self.journal.extend(net.journal);
                    self.tevents.extend(net.events);
                }
                _ => panic!("expected RoundDone report"),
            }
        }
        self.trace.push((round, pairs.clone()));
        for p in &pairs {
            self.merges.push(Merge {
                a: p.leader,
                b: p.partner,
                weight: p.weight,
            });
            let wl = f64::from_bits(pre_nn[&p.leader]);
            let wp = f64::from_bits(pre_nn[&p.partner]);
            self.bounds.push(MergeBound {
                weight: p.weight,
                visible_min: wl.min(wp),
            });
        }
        self.n_active -= pairs.len();
        rm.t_merge = t_merge.elapsed();
        rm.t_exec = t_round.elapsed();
        if let Some(e) = self.tbuf.make_span(round_start, EventKind::Round) {
            self.tevents.push(e);
        }
        self.metrics.rounds.push(rm);
        if self.n_active <= 1 {
            self.fleet.take().expect("fleet alive").shutdown();
            return Ok(Flow::Finished);
        }
        if synced {
            self.cut_checkpoint(round + 1)?;
        }
        Ok(Flow::Continue)
    }

    /// The run loop: fire the fault campaign at round boundaries, recover
    /// (charged to `t_recover`), and treat *detected* deaths — channel
    /// failures we did not inject — as global rollbacks, bounded by
    /// [`MAX_DETECTED_RECOVERIES`].
    fn run(mut self, t0: Instant) -> (RacResult, NetReport, Vec<MergeBound>) {
        let run_start = self.tbuf.now();
        self.fleet = Some(spawn_fleet(&self.spec, &self.snapshot.chains));
        let mut detected = 0usize;
        while self.round < self.max_rounds {
            let hits = self.fault_hits();
            if !hits.is_empty() {
                for &x in &hits {
                    self.tbuf.instant(EventKind::Fault { target: x as u32 });
                }
                let t = Instant::now();
                let res = self.recover(&hits);
                self.metrics.t_recover += t.elapsed();
                if let Err(down) = res {
                    detected += 1;
                    assert!(
                        detected <= MAX_DETECTED_RECOVERIES,
                        "recovery kept dying ({down}); fleet structurally broken"
                    );
                    let t = Instant::now();
                    self.rollback_global();
                    self.metrics.t_recover += t.elapsed();
                }
                continue;
            }
            match self.execute_round() {
                Ok(Flow::Finished) => break,
                Ok(Flow::Continue) => {
                    detected = 0;
                    self.round += 1;
                }
                Err(down) => {
                    detected += 1;
                    assert!(
                        detected <= MAX_DETECTED_RECOVERIES,
                        "round kept dying ({down}); fleet structurally broken"
                    );
                    let t = Instant::now();
                    self.rollback_global();
                    self.metrics.t_recover += t.elapsed();
                }
            }
        }
        if let Some(fl) = self.fleet.take() {
            // Round cap exhausted with the fleet still up (safety valve).
            fl.shutdown();
        }
        self.metrics.total_time = t0.elapsed();
        self.log.sort_by_key(|b| (b.round, b.src, b.dst));
        self.tbuf.span(run_start, EventKind::Run);
        self.sink.absorb_events(std::mem::take(&mut self.tevents));
        let Driver {
            sink,
            tbuf,
            n,
            merges,
            metrics,
            log,
            bounds,
            ..
        } = self;
        sink.absorb(tbuf);
        (
            RacResult {
                dendrogram: Dendrogram::new(n, merges),
                metrics,
            },
            NetReport { batches: log },
            bounds,
        )
    }
}

/// Run the distributed round schedule for real: thread-per-machine,
/// channel-backed wire, measured `t_exec`, chained sync-point
/// checkpoints, and the fault campaign + recovery. Consumes the prepared
/// core; the returned results are bitwise identical to
/// `core.run_rounds(selector)` on the dendrogram, bounds trace, and
/// sync-point schedule — faulted or not, under either recovery mode.
pub(super) fn run_executed(
    core: DistCore,
    selector: DistSelector,
    opts: &ExecOptions,
) -> (RacResult, NetReport, Vec<MergeBound>) {
    let t0 = Instant::now();
    let m = core.cfg.machines;
    let n = core.n;
    for f in &opts.faults {
        assert!(
            f.machine < m,
            "fault machine {} out of range for {m} machines",
            f.machine
        );
    }
    assert!(
        (0.0..=1.0).contains(&opts.fault_rate),
        "fault_rate {} outside [0, 1]",
        opts.fault_rate
    );
    // Checkpoint-cut invariant: a cut must never race staged deferred
    // batches, or batched-mode recovery would silently drop them. The
    // boot cut holds it by construction; later cuts hold it because the
    // executed mode ships patches eagerly (nothing is ever deferred).
    debug_assert!(
        core.pending_is_empty(),
        "checkpoint cut with staged deferred batches"
    );
    // Initial NN scan over the full graph — identical to the simulated
    // engine's init — then cut the round-0 full checkpoint every machine
    // boots from (every chain starts with a full blob).
    let mut nn = vec![NO_NN; n];
    let mut nn_weight = vec![Weight::INFINITY; n];
    for c in 0..n {
        let (v, w) = scan_nn(core.store.row(c as u32));
        nn[c] = v;
        nn_weight[c] = w;
    }
    let chains: Vec<Vec<Vec<u8>>> = (0..m)
        .map(|mid| {
            let rows = (0..n as u32)
                .filter(|&c| core.place.machine_of(c) == mid)
                .map(|c| {
                    let entries =
                        core.store.row(c).iter().map(|(t, e)| (t, e.weight, e.count)).collect();
                    (c, nn[c as usize], nn_weight[c as usize], entries)
                })
                .collect();
            vec![checkpoint::encode(&MachineCheckpoint {
                machine: mid as u32,
                machines: m as u32,
                round: 0,
                n,
                rows,
                size: core.size.clone(),
                active: core.active.clone(),
            })]
        })
        .collect();
    let sink = core.sink.clone();
    let mut tbuf = sink.buf(engine_name(selector), COORD, 0);
    let mut metrics = RunMetrics::default();
    let boot_bytes = chains.iter().map(|c| c[0].len()).sum::<usize>();
    metrics.checkpoint_bytes += boot_bytes;
    // The boot cut is a checkpoint like any other: trace it where its
    // bytes are charged.
    tbuf.instant(EventKind::CheckpointCut {
        full: true,
        bytes: boot_bytes,
    });
    let spec = FleetSpec {
        machines: m,
        linkage: core.linkage,
        place: core.place,
        selector,
        latency: opts.latency,
        jitter: opts.jitter,
        journal: opts.recovery_mode == RecoveryMode::ShardReplay,
        sink: sink.clone(),
    };
    let driver = Driver {
        spec,
        m,
        n,
        max_rounds: core.max_rounds,
        full_every: opts.checkpoint_full_every.max(1),
        recovery_mode: opts.recovery_mode,
        fault_rate: opts.fault_rate,
        fault_seed: opts.fault_seed,
        pending_faults: opts.faults.clone(),
        fired_random: FxHashSet::default(),
        snapshot: Snapshot {
            round: 0,
            n_active: n,
            merges: Vec::new(),
            bounds: Vec::new(),
            rounds: Vec::new(),
            log: Vec::new(),
            tevents: Vec::new(),
            chains,
        },
        trace: Vec::new(),
        journal: Vec::new(),
        tevents: Vec::new(),
        tbuf,
        sink,
        merges: Vec::new(),
        bounds: Vec::new(),
        metrics,
        log: Vec::new(),
        n_active: n,
        round: 0,
        fleet: None,
    };
    driver.run(t0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jitter_is_deterministic_and_bounded() {
        let bound = Duration::from_micros(50);
        for (src, dst, round, step) in [(0, 1, 0, 0u8), (1, 0, 0, 0), (2, 5, 31, 4)] {
            let a = jitter_ns(src, dst, round, step, bound);
            let b = jitter_ns(src, dst, round, step, bound);
            assert_eq!(a, b, "same link+round must hash identically");
            assert!(a <= bound.as_nanos() as u64);
        }
        assert_eq!(jitter_ns(0, 1, 0, 0, Duration::ZERO), 0);
        // Direction matters: the hash must separate (src, dst) from
        // (dst, src) on at least some links.
        let diff = (0..16).any(|r| {
            jitter_ns(0, 1, r, 0, bound) != jitter_ns(1, 0, r, 0, bound)
        });
        assert!(diff, "jitter hash ignores link direction");
    }

    #[test]
    fn row_view_adapters_agree() {
        let mut store = NeighborStore::new(4);
        let row: Vec<(u32, EdgeState)> = vec![
            (2, EdgeState { weight: 0.5, count: 1 }),
            (1, EdgeState { weight: 0.25, count: 2 }),
        ];
        store.install_row(0, &row);
        let from_store = {
            let mut v = Vec::new();
            RowView::Store(store.row(0)).for_each_edge(|t, e| v.push((t, e.weight, e.count)));
            v
        };
        let from_fetched = {
            let mut v = Vec::new();
            RowView::Fetched(&row).for_each_edge(|t, e| v.push((t, e.weight, e.count)));
            v
        };
        assert_eq!(from_store, from_fetched, "adapters must iterate identically");
        assert_eq!(RowView::Store(store.row(0)).live_len(), 2);
        assert_eq!(RowView::Fetched(&row).live_len(), 2);
    }

    fn test_wire(me: usize, machines: usize, peers: Vec<Sender<Packet>>, inbox: Receiver<Packet>) -> Wire {
        Wire {
            me,
            machines,
            peers,
            inbox,
            stash: Vec::new(),
            latency: Duration::ZERO,
            jitter: Duration::ZERO,
            journal: true,
            peer_timeout: Duration::from_millis(25),
            round: 3,
            stats: NetStats::default(),
            tbuf: TraceSink::disabled().buf("dist_rac", me as u32, me as u32 + 1),
        }
    }

    #[test]
    fn post_to_dead_peer_is_a_named_error_not_a_panic() {
        let (tx_self, _rx_self) = mpsc::channel::<Packet>();
        let (tx_dead, rx_dead) = mpsc::channel::<Packet>();
        drop(rx_dead);
        let (_inbox_tx, inbox_rx) = mpsc::channel::<Packet>();
        let mut wire = test_wire(0, 2, vec![tx_self, tx_dead], inbox_rx);
        let err = wire.post(1, 0, &[]).unwrap_err();
        assert_eq!(err, MachineDown { machine: 1, round: 3 });
        assert_eq!(format!("{err}"), "machine 1 down in round 3");
        // The doomed barrier was still journaled: replay must see every
        // packet the original incarnation would have.
        assert_eq!(wire.stats.journal.len(), 1);
        assert!(wire.stats.journal[0].bytes.len() >= 4, "journal keeps payload bytes");
    }

    #[test]
    fn silent_or_disconnected_peer_is_named_in_collect() {
        // Silent peer: machine 1 delivers, machine 2 never does.
        let (inbox_tx, inbox_rx) = mpsc::channel::<Packet>();
        let mut wire = test_wire(0, 3, Vec::new(), inbox_rx);
        inbox_tx
            .send(Packet {
                src: 1,
                round: 3,
                step: 0,
                bytes: encode_batch(&[]),
                deliver_at: Instant::now(),
            })
            .unwrap();
        let err = wire.collect(0, 1..3).unwrap_err();
        assert_eq!(err, MachineDown { machine: 2, round: 3 });
        // Disconnected inbox: the error is immediate, no timeout wait.
        drop(inbox_tx);
        let t = Instant::now();
        let err = wire.collect(0, 1..3).unwrap_err();
        assert_eq!(err.round, 3);
        assert!(t.elapsed() < Duration::from_secs(1), "disconnect must not wait out the timeout");
    }

    #[test]
    fn random_faults_are_deterministic_and_rate_shaped() {
        assert_eq!(
            random_fault(7, 1, 3, 0.5),
            random_fault(7, 1, 3, 0.5),
            "same seed and cell must agree"
        );
        assert!(!random_fault(7, 1, 3, 0.0), "rate 0 never fires");
        assert!(random_fault(7, 1, 3, 1.0), "rate 1 always fires");
        let hits = (0..1000).filter(|&r| random_fault(42, 0, r, 0.1)).count();
        assert!(
            (20..=250).contains(&hits),
            "rate 0.1 produced {hits}/1000 hits — hash badly shaped"
        );
        // Different seeds decorrelate the campaign.
        let a: Vec<bool> = (0..64).map(|r| random_fault(1, 0, r, 0.3)).collect();
        let b: Vec<bool> = (0..64).map(|r| random_fault(2, 0, r, 0.3)).collect();
        assert_ne!(a, b, "seeds must produce distinct fault patterns");
    }
}
