//! Executed distribution: one OS thread per machine, each owning its
//! arena shard, exchanging the *same* [`Message`] batches the simulation
//! accounts for — over real `std::sync::mpsc` channels with injected
//! per-link latency and jitter.
//!
//! ## Why a second mode
//!
//! The simulated engine ([`super::DistCore`]) computes against the
//! authoritative global state and *stages* traffic through the wire codec;
//! `t_sim` is a model. That design makes the dendrogram provably
//! topology-invariant, but nothing ever actually crosses a thread
//! boundary, so the codec, the barrier structure, and the recovery story
//! are exercised only by construction, not by execution. This module runs
//! the identical round body truly sharded: every machine holds only its
//! owned rows plus replicated scalars, every remote read is a real
//! encode → channel → decode round trip, and the run reports a *measured*
//! wall clock ([`RoundMetrics::t_exec`]) as the empirical sibling of
//! `t_sim`. The contract, pinned by `rust/tests/dist_executed.rs`:
//!
//! > executed and simulated runs produce **bitwise identical** dendrogram,
//! > (1+ε) bounds trace, and sync-point schedule, for every topology,
//! > ε, and sync mode — and a shard killed mid-run recovers from the last
//! > sync-point checkpoint to the same bits.
//!
//! ## Why bitwise equality holds
//!
//! The only numeric folds are `scan_nn` and `compute_union_map`, and both
//! consume rows in storage order. The executed mode preserves exactly the
//! state the simulation reads at each decision point:
//!
//! * **Owned rows** — patched in per-(target, leader) sorted order, which
//!   matches the simulation's serial pair-loop order per row (patch
//!   targets of distinct pairs commute across rows; within a row, leaders
//!   apply ascending both here and there). Install/clear/compaction use
//!   the shared [`NeighborStore`] code, which preserves live-entry order.
//! * **Replicated scalars** (`active`, `size`, `matched`, `partner`,
//!   `pair_weight`) — rebuilt on every machine from the same broadcast
//!   pair list, in the same order the simulation writes them.
//! * **Remote NN caches** — refreshed each round by the same query sets
//!   the simulation stages ([`Message::NnQuery`]/[`Message::NnCacheQuery`]
//!   with identical batch content and order). A stale shadow is never
//!   decisive: the ε-good candidate test needs *both* halves to accept,
//!   and the half owned by the scanning machine is authoritative.
//!
//! ## Traffic accounting
//!
//! Batches are counted under the simulation's rule — one RPC per
//! non-empty (src, dst) pair per phase, at encoded wire length. Per-round
//! exact and ε-good executed traffic equals the simulation's minus its
//! `PairViewQuery`/`PairViewReply` batches (the executed mode replicates
//! pair state from the merge broadcast instead of querying it). The
//! batched mode diverges further by design: real execution must refresh
//! NN caches and reach the coordinator every round and must ship patches
//! eagerly, where the simulation's deferred-flush accounting charges the
//! wire only at sync points — the executed numbers are what a real
//! deployment pays for the same schedule, the simulated numbers are the
//! sync-boundary lower bound. The *schedule itself* (`sync_points`) is
//! bitwise shared.
//!
//! ## Checkpoint / recovery
//!
//! At every sync point the driver collects one versioned
//! [`super::checkpoint`] blob per machine (the codec also serializes the
//! initial state, so every executed run exercises a restore). A
//! round-indexed [`FaultSpec`] kills the whole fleet at the top of the
//! chosen round — the shard's death tears down the bulk-synchronous round
//! for everyone, which is exactly why recovery is a *global* rollback:
//! the driver respawns the fleet, feeds each machine its last blob, and
//! replays from the checkpointed round. Determinism makes the replay
//! bitwise identical to the unfaulted run.

use std::sync::mpsc::{self, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use rustc_hash::{FxHashMap, FxHashSet};

use super::checkpoint::{self, MachineCheckpoint};
use super::network::{decode_batch, encode_batch, BatchRecord, Message, NetReport};
use super::{vshard_of, DistCore, DistSelector, Placement};
use crate::approx::good::{self, Candidate, MergePair};
use crate::approx::quality::MergeBound;
use crate::dendrogram::{Dendrogram, Merge};
use crate::linkage::{EdgeState, Linkage, Weight};
use crate::metrics::{RoundMetrics, RunMetrics};
use crate::rac::logic::{compute_union_map, scan_nn, PairView};
use crate::rac::{RacResult, NO_NN};
use crate::store::{NeighborStore, NeighborsRef, RowRef};

/// Kill the fleet at the top of `round` (0-based), then recover every
/// machine from its last sync-point checkpoint and replay.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultSpec {
    /// Machine reported as failed (must be `< machines`; with one fleet
    /// per process the whole fleet restarts either way — BSP recovery is
    /// a global rollback).
    pub machine: usize,
    /// Round at whose start the fault fires. A round the run never
    /// reaches simply never faults.
    pub round: usize,
}

/// Knobs for the executed distributed mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ExecOptions {
    /// Fixed one-way link latency added to every cross-machine packet.
    pub latency: Duration,
    /// Upper bound on deterministic per-packet jitter (hashed from the
    /// link and round, so reruns see identical delays).
    pub jitter: Duration,
    /// Optional fault injection; `None` runs clean.
    pub fault: Option<FaultSpec>,
}

/// How long the driver waits for any single machine report before
/// declaring the fleet wedged. Generous: test topologies finish rounds in
/// microseconds; only a deadlock bug ever gets near this.
const REPORT_TIMEOUT: Duration = Duration::from_secs(120);

// Per-round exchange step ids (unique per (round, step) because a round
// runs exactly one selector). Exact rounds:
const STEP_NN_QUERY: u8 = 0;
const STEP_NN_REPLY: u8 = 1;
// ε-good rounds:
const STEP_CACHE_QUERY: u8 = 0;
const STEP_CACHE_REPLY: u8 = 1;
const STEP_CANDIDATES: u8 = 2;
const STEP_MATCHING: u8 = 3;
// Merge phase (offset past the selector's find steps):
const EXACT_MERGE_BASE: u8 = 2;
const GOOD_MERGE_BASE: u8 = 4;

/// One wire packet: an encoded [`Message`] batch plus its delivery time.
/// Empty batches still flow (they are the barrier) but are never counted.
struct Packet {
    src: usize,
    round: usize,
    step: u8,
    bytes: Vec<u8>,
    deliver_at: Instant,
}

/// Driver → machine commands.
#[derive(Clone)]
enum Cmd {
    /// Adopt the given checkpoint blob as the complete machine state.
    Restore(Vec<u8>),
    /// Run the find phase of `round` and report `Phase1`.
    Round { round: usize },
    /// Apply the globally selected pairs and report `RoundDone`.
    Merge { pairs: Vec<MergePair> },
    /// Serialize state and report `CheckpointBlob`.
    Checkpoint { round: usize },
    /// No pairs anywhere: report `FinishAck` and exit.
    Finish,
    /// Tear down immediately (normal completion or fault injection).
    Exit,
}

/// Per-round wire counters a machine hands back with each report.
#[derive(Default)]
struct NetStats {
    messages: usize,
    bytes: usize,
    log: Vec<BatchRecord>,
}

/// Machine → driver reports.
enum Report {
    /// Find-phase result. Exact rounds: one per machine (pairs from owned
    /// leaders). ε-good rounds: from the coordinator only.
    Phase1 { pairs: Vec<MergePair>, synced: bool },
    /// Merge phase done. `nn_weights` carries the pre-merge NN weight
    /// bits of owned pair members — the driver's (1+ε) bounds inputs.
    RoundDone {
        nn_weights: Vec<(u32, u64)>,
        nn_updates: usize,
        nn_scan_entries: usize,
        eligibility_scan_entries: usize,
        net: NetStats,
    },
    CheckpointBlob { machine: usize, blob: Vec<u8> },
    FinishAck {
        eligibility_scan_entries: usize,
        net: NetStats,
    },
}

/// A neighbor row that is either borrowed from the local arena or was
/// fetched over the wire. [`compute_union_map`] takes one row type for
/// both inputs; this adapter lets a local leader row fold against a
/// remote partner's fetched entries without copying the local side.
#[derive(Clone, Copy)]
enum RowView<'a> {
    Store(RowRef<'a>),
    Fetched(&'a [(u32, EdgeState)]),
}

impl NeighborsRef for RowView<'_> {
    fn for_each_edge(self, mut f: impl FnMut(u32, EdgeState)) {
        match self {
            RowView::Store(r) => r.for_each_edge(f),
            RowView::Fetched(entries) => {
                for &(t, e) in entries {
                    f(t, e);
                }
            }
        }
    }

    fn live_len(self) -> usize {
        match self {
            RowView::Store(r) => r.live_len(),
            RowView::Fetched(entries) => entries.len(),
        }
    }
}

/// Deterministic per-packet jitter: splitmix64 over the link identity,
/// so a replayed round sees identical delays (recovery determinism).
fn jitter_ns(src: usize, dst: usize, round: usize, step: u8, bound: Duration) -> u64 {
    let bound = bound.as_nanos() as u64;
    if bound == 0 {
        return 0;
    }
    let mut x = (src as u64)
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add((dst as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9))
        .wrapping_add((round as u64).wrapping_mul(0x94D0_49BB_1331_11EB))
        .wrapping_add(step as u64 + 1);
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    x % (bound + 1)
}

/// The channel fabric of one machine: senders to every peer, its own
/// inbox, and the per-round traffic counters.
struct Wire {
    me: usize,
    machines: usize,
    peers: Vec<Sender<Packet>>,
    inbox: Receiver<Packet>,
    /// Packets that arrived ahead of the step we are collecting.
    stash: Vec<Packet>,
    latency: Duration,
    jitter: Duration,
    round: usize,
    stats: NetStats,
}

impl Wire {
    /// Ship one physical packet. Empty batches flow (barrier) but only
    /// non-empty ones are accounted — the simulation's counting rule.
    fn post(&mut self, dst: usize, step: u8, msgs: &[Message]) {
        debug_assert_ne!(dst, self.me, "machines never post to themselves");
        let bytes = encode_batch(msgs);
        if !msgs.is_empty() {
            self.stats.messages += 1;
            self.stats.bytes += bytes.len();
            self.stats.log.push(BatchRecord {
                src: self.me,
                dst,
                messages: msgs.len(),
                bytes: bytes.len(),
                round: self.round,
            });
        }
        let delay = self.latency
            + Duration::from_nanos(jitter_ns(self.me, dst, self.round, step, self.jitter));
        let packet = Packet {
            src: self.me,
            round: self.round,
            step,
            bytes,
            deliver_at: Instant::now() + delay,
        };
        // A dead peer (fault teardown) makes sends fail; the machine will
        // be told to exit via its command channel, so just drop.
        let _ = self.peers[dst].send(packet);
    }

    /// Wait for one packet from each of `from`, honoring delivery times,
    /// and decode them in ascending src order.
    fn collect(
        &mut self,
        step: u8,
        from: impl Iterator<Item = usize>,
    ) -> Vec<(usize, Vec<Message>)> {
        let expected = from.count();
        let mut packets: Vec<Packet> = Vec::with_capacity(expected);
        let mut i = 0;
        while i < self.stash.len() {
            if self.stash[i].round == self.round && self.stash[i].step == step {
                packets.push(self.stash.swap_remove(i));
            } else {
                i += 1;
            }
        }
        while packets.len() < expected {
            let p = self
                .inbox
                .recv_timeout(REPORT_TIMEOUT)
                .expect("peer silent mid-step: executed fleet wedged");
            if p.round == self.round && p.step == step {
                packets.push(p);
            } else {
                self.stash.push(p);
            }
        }
        // The link delay is modeled at the receiver: nothing is readable
        // before its delivery time.
        if let Some(latest) = packets.iter().map(|p| p.deliver_at).max() {
            let now = Instant::now();
            if latest > now {
                std::thread::sleep(latest - now);
            }
        }
        packets.sort_by_key(|p| p.src);
        packets
            .into_iter()
            .map(|p| {
                let msgs = decode_batch(&p.bytes).expect("peer sent a corrupt batch");
                (p.src, msgs)
            })
            .collect()
    }

    /// Symmetric exchange: post `out[dst]` to every peer, collect one
    /// packet from every peer.
    fn all_to_all(&mut self, step: u8, out: Vec<Vec<Message>>) -> Vec<(usize, Vec<Message>)> {
        debug_assert_eq!(out.len(), self.machines);
        for (dst, msgs) in out.iter().enumerate() {
            if dst != self.me {
                self.post(dst, step, msgs);
            }
        }
        let me = self.me;
        self.collect(step, (0..self.machines).filter(move |&s| s != me))
    }

    /// Gather: non-root machines post `msgs` to `root`; root collects.
    fn gather_to(&mut self, root: usize, step: u8, msgs: &[Message]) -> Vec<(usize, Vec<Message>)> {
        if self.me == root {
            let machines = self.machines;
            self.collect(step, (0..machines).filter(move |&s| s != root))
        } else {
            self.post(root, step, msgs);
            Vec::new()
        }
    }

    /// Broadcast: root posts `out[dst]` to every peer; peers receive one
    /// batch from root.
    fn broadcast_from(&mut self, root: usize, step: u8, out: &[Vec<Message>]) -> Vec<Message> {
        if self.me == root {
            for (dst, msgs) in out.iter().enumerate() {
                if dst != root {
                    self.post(dst, step, msgs);
                }
            }
            Vec::new()
        } else {
            let mut got = self.collect(step, std::iter::once(root));
            got.pop().map(|(_, msgs)| msgs).unwrap_or_default()
        }
    }

    fn take_stats(&mut self) -> NetStats {
        std::mem::take(&mut self.stats)
    }
}

/// One executed machine: the owned shard of the arena plus the replicated
/// scalars, mirroring [`super::DistCore`]'s fields sliced by ownership.
struct Machine {
    me: usize,
    n: usize,
    linkage: Linkage,
    place: Placement,
    selector: DistSelector,
    store: NeighborStore,
    /// Owned ids still active, ascending (the machine's `active_ids`).
    owned_active: Vec<u32>,
    /// Replicated liveness (maintained from broadcast pair lists).
    active: Vec<bool>,
    /// Replicated sizes (same maintenance).
    size: Vec<u64>,
    /// NN cache: authoritative for owned ids, per-round-refreshed shadow
    /// for remote ids (defaults harmless — see module docs).
    nn: Vec<u32>,
    nn_weight: Vec<Weight>,
    /// Per-round pair state, replicated from the merge broadcast.
    matched: Vec<bool>,
    partner: Vec<u32>,
    pair_weight: Vec<Weight>,
    /// Per-round ε-good sweep cost (reported, then reset).
    eligibility_scan_entries: usize,
    wire: Wire,
}

impl Machine {
    fn owns(&self, c: u32) -> bool {
        self.place.machine_of(c) == self.me
    }

    /// Adopt a checkpoint blob as the complete machine state.
    fn restore(&mut self, blob: &[u8]) {
        let cp = checkpoint::decode(blob).expect("driver handed a corrupt checkpoint");
        assert_eq!(cp.machine as usize, self.me, "blob for the wrong machine");
        assert_eq!(
            cp.machines as usize, self.wire.machines,
            "blob for the wrong fleet width"
        );
        self.n = cp.n;
        self.store = NeighborStore::new(cp.n);
        self.owned_active.clear();
        self.nn = vec![NO_NN; cp.n];
        self.nn_weight = vec![Weight::INFINITY; cp.n];
        for (id, nn, nn_weight, entries) in &cp.rows {
            let row: Vec<(u32, EdgeState)> = entries
                .iter()
                .map(|&(t, w, c)| (t, EdgeState { weight: w, count: c }))
                .collect();
            if !row.is_empty() {
                self.store.install_row(*id, &row);
            }
            self.nn[*id as usize] = *nn;
            self.nn_weight[*id as usize] = *nn_weight;
        }
        self.size = cp.size;
        self.active = cp.active;
        self.owned_active = (0..cp.n as u32)
            .filter(|&c| self.owns(c) && self.active[c as usize])
            .collect();
        self.matched = vec![false; cp.n];
        self.partner = vec![NO_NN; cp.n];
        self.pair_weight = vec![0.0; cp.n];
    }

    /// Serialize the complete machine state for the given next round.
    fn checkpoint(&self, round: usize) -> Vec<u8> {
        let rows = (0..self.n as u32)
            .filter(|&c| self.owns(c))
            .map(|c| {
                let entries =
                    self.store.row(c).iter().map(|(t, e)| (t, e.weight, e.count)).collect();
                (c, self.nn[c as usize], self.nn_weight[c as usize], entries)
            })
            .collect();
        checkpoint::encode(&MachineCheckpoint {
            machine: self.me as u32,
            machines: self.wire.machines as u32,
            round: round as u64,
            n: self.n,
            rows,
            size: self.size.clone(),
            active: self.active.clone(),
        })
    }

    fn begin_round(&mut self, round: usize) {
        self.wire.round = round;
        self.wire.stats = NetStats::default();
        self.eligibility_scan_entries = 0;
    }

    /// Exact find phase: refresh remote NN shadows, then test reciprocity
    /// over owned active ids. Query staging matches the simulation's
    /// `exchange_nn_pointers` (ascending scan, per-destination dedup).
    fn find_reciprocal(&mut self) -> Vec<MergePair> {
        let m = self.wire.machines;
        let mut queries: Vec<Vec<Message>> = vec![Vec::new(); m];
        let mut seen: FxHashSet<u32> = FxHashSet::default();
        for &c in &self.owned_active {
            let v = self.nn[c as usize];
            if v == NO_NN {
                continue;
            }
            let sv = self.place.machine_of(v);
            if sv != self.me && seen.insert(v) {
                queries[sv].push(Message::NnQuery { cluster: v });
            }
        }
        let incoming = self.wire.all_to_all(STEP_NN_QUERY, queries);
        let mut replies: Vec<Vec<Message>> = vec![Vec::new(); m];
        for (src, batch) in incoming {
            replies[src] = batch
                .iter()
                .map(|q| match q {
                    Message::NnQuery { cluster } => Message::NnReply {
                        cluster: *cluster,
                        nn: self.nn[*cluster as usize],
                    },
                    other => panic!("unexpected message in NN-query step: {other:?}"),
                })
                .collect();
        }
        for (_, batch) in self.wire.all_to_all(STEP_NN_REPLY, replies) {
            for msg in batch {
                match msg {
                    Message::NnReply { cluster, nn } => self.nn[cluster as usize] = nn,
                    other => panic!("unexpected message in NN-reply step: {other:?}"),
                }
            }
        }
        let mut pairs = Vec::new();
        for &c in &self.owned_active {
            let v = self.nn[c as usize];
            if v != NO_NN && self.nn[v as usize] == c && c < v {
                pairs.push(MergePair {
                    leader: c,
                    partner: v,
                    weight: self.nn_weight[c as usize],
                });
            }
        }
        pairs
    }

    /// ε-good find phase (per-round and batched). Refreshes the remote NN
    /// shadows needed by the sweep's partner-half test, sweeps owned rows,
    /// gathers candidates to the coordinator (machine 0), which selects
    /// the matching — globally for per-round mode, or with the batched
    /// local-first rule — and broadcasts it. Returns the selection on the
    /// coordinator, `None` elsewhere.
    fn find_good(&mut self, epsilon: f64, vshards: Option<u32>) -> Option<(Vec<MergePair>, bool)> {
        let m = self.wire.machines;
        // Steps 0/1: refresh the shadow NN cache for remote upper
        // endpoints that pass our half of the acceptance test — the same
        // query set the simulation stages in `stage_nn_cache_queries`.
        let mut queries: Vec<Vec<Message>> = vec![Vec::new(); m];
        let mut seen: FxHashSet<u32> = FxHashSet::default();
        for &a in &self.owned_active {
            let ai = a as usize;
            let (nn_a, w_a) = (self.nn[ai], self.nn_weight[ai]);
            for (b, e) in self.store.row(a).iter() {
                if b > a && good::accepts(e.weight, b, epsilon, w_a, nn_a) {
                    let sb = self.place.machine_of(b);
                    if sb != self.me && seen.insert(b) {
                        queries[sb].push(Message::NnCacheQuery { cluster: b });
                    }
                }
            }
        }
        let incoming = self.wire.all_to_all(STEP_CACHE_QUERY, queries);
        let mut replies: Vec<Vec<Message>> = vec![Vec::new(); m];
        for (src, batch) in incoming {
            replies[src] = batch
                .iter()
                .map(|q| match q {
                    Message::NnCacheQuery { cluster } => Message::NnCacheReply {
                        cluster: *cluster,
                        nn: self.nn[*cluster as usize],
                        weight: self.nn_weight[*cluster as usize],
                    },
                    other => panic!("unexpected message in cache-query step: {other:?}"),
                })
                .collect();
        }
        for (_, batch) in self.wire.all_to_all(STEP_CACHE_REPLY, replies) {
            for msg in batch {
                match msg {
                    Message::NnCacheReply { cluster, nn, weight } => {
                        self.nn[cluster as usize] = nn;
                        self.nn_weight[cluster as usize] = weight;
                    }
                    other => panic!("unexpected message in cache-reply step: {other:?}"),
                }
            }
        }
        // Sweep owned rows in ascending order — concatenated across
        // machines by the gather below, this reproduces the simulation's
        // global ascending candidate order.
        let mut cands: Vec<Candidate> = Vec::new();
        for &a in &self.owned_active {
            let (row_cands, scanned) =
                good::scan_row_candidates(self.store.row(a), a, epsilon, &self.nn_weight, &self.nn);
            self.eligibility_scan_entries += scanned;
            cands.extend(row_cands.into_iter().map(|(w, b)| (w, a, b)));
        }
        // Step 2: gather to the coordinator.
        let gathered = if self.me != 0 && !cands.is_empty() {
            vec![Message::CandidateBatch { edges: std::mem::take(&mut cands) }]
        } else {
            Vec::new()
        };
        let incoming = self.wire.gather_to(0, STEP_CANDIDATES, &gathered);
        let selection = (self.me == 0).then(|| {
            let mut all = cands;
            for (_, batch) in incoming {
                for msg in batch {
                    match msg {
                        Message::CandidateBatch { edges } => all.extend(edges),
                        other => panic!("unexpected message in candidate step: {other:?}"),
                    }
                }
            }
            let mut scratch = vec![false; self.n];
            match vshards {
                None => (good::select_matching(all, &mut scratch), true),
                Some(v) => {
                    // The batched local-first rule, decided globally: any
                    // co-block candidate anywhere makes this a local
                    // round; only a dry sweep forces the sync round.
                    let (local, frontier): (Vec<Candidate>, Vec<Candidate>) = all
                        .into_iter()
                        .partition(|&(_, a, b)| vshard_of(a, self.n, v) == vshard_of(b, self.n, v));
                    if !local.is_empty() {
                        (good::select_matching(local, &mut scratch), false)
                    } else {
                        (good::select_matching(frontier, &mut scratch), true)
                    }
                }
            }
        });
        // Step 3: broadcast the matching. The physical packet is the
        // barrier; the simulation's accounting rule (non-empty matching,
        // destination owns an active cluster) decides what is counted.
        let mut out: Vec<Vec<Message>> = vec![Vec::new(); m];
        if let Some((pairs, _)) = &selection {
            if !pairs.is_empty() {
                let mut has_active = vec![false; m];
                for c in 0..self.n as u32 {
                    if self.active[c as usize] {
                        has_active[self.place.machine_of(c)] = true;
                    }
                }
                let wire_pairs: Vec<(u32, u32, Weight)> =
                    pairs.iter().map(|p| (p.leader, p.partner, p.weight)).collect();
                for (dst, slot) in out.iter_mut().enumerate() {
                    if dst != 0 && has_active[dst] {
                        *slot = vec![Message::MatchingBroadcast { pairs: wire_pairs.clone() }];
                    }
                }
            }
        }
        let _echo = self.wire.broadcast_from(0, STEP_MATCHING, &out);
        // Non-coordinators apply the authoritative pair list from the
        // driver's `Cmd::Merge`; the broadcast they just received carries
        // the same pairs (wire-accounting fidelity).
        selection
    }

    /// Merge phase: replicate pair state, fetch remote partner rows, fold
    /// union maps for owned leaders, route and apply patches, update
    /// replicated scalars, rescan stale NN caches. Ordering mirrors the
    /// simulation's `compute_unions` + `apply_unions` + phase 3.
    fn merge_and_rescan(&mut self, pairs: &[MergePair]) -> Report {
        let m = self.wire.machines;
        let base = match self.selector {
            DistSelector::Rnn => EXACT_MERGE_BASE,
            _ => GOOD_MERGE_BASE,
        };
        // Pre-merge NN weights of owned pair members: the driver's
        // (1+ε) bounds inputs (the simulation reads these before its
        // phase 3 overwrites them).
        let mut nn_weights: Vec<(u32, u64)> = Vec::new();
        for p in pairs {
            for c in [p.leader, p.partner] {
                if self.owns(c) {
                    nn_weights.push((c, self.nn_weight[c as usize].to_bits()));
                }
            }
        }
        // Replicate pair state — every machine sees the same list in the
        // same order, so `PairView` reads are bitwise shared.
        for p in pairs {
            let (l, pr) = (p.leader as usize, p.partner as usize);
            self.matched[l] = true;
            self.matched[pr] = true;
            self.partner[l] = p.partner;
            self.partner[pr] = p.leader;
            self.pair_weight[l] = p.weight;
            self.pair_weight[pr] = p.weight;
        }
        // Fetch remote partner rows for owned leaders (ascending pair
        // order — the simulation's staging order).
        let mut fetch: Vec<Vec<Message>> = vec![Vec::new(); m];
        for p in pairs {
            if self.owns(p.leader) {
                let sp = self.place.machine_of(p.partner);
                if sp != self.me {
                    fetch[sp].push(Message::PartnerFetch { partner: p.partner });
                }
            }
        }
        let incoming = self.wire.all_to_all(base, fetch);
        let mut replies: Vec<Vec<Message>> = vec![Vec::new(); m];
        for (src, batch) in incoming {
            replies[src] = batch
                .iter()
                .map(|q| match q {
                    Message::PartnerFetch { partner } => Message::PartnerState {
                        partner: *partner,
                        size: self.size[*partner as usize],
                        entries: self
                            .store
                            .row(*partner)
                            .iter()
                            .map(|(t, e)| (t, e.weight, e.count))
                            .collect(),
                    },
                    other => panic!("unexpected message in partner-fetch step: {other:?}"),
                })
                .collect();
        }
        let mut fetched: FxHashMap<u32, Vec<(u32, EdgeState)>> = FxHashMap::default();
        for (_, batch) in self.wire.all_to_all(base + 1, replies) {
            for msg in batch {
                match msg {
                    Message::PartnerState { partner, entries, .. } => {
                        fetched.insert(
                            partner,
                            entries
                                .into_iter()
                                .map(|(t, w, c)| (t, EdgeState { weight: w, count: c }))
                                .collect(),
                        );
                    }
                    other => panic!("unexpected message in partner-state step: {other:?}"),
                }
            }
        }
        // Union maps for owned leaders, in pair-list order — the same
        // order the simulation's `compute_unions` walks (ascending leader
        // for exact rounds, matching order for ε-good rounds). Sizes are
        // still pre-merge here, as in the simulation.
        let mut unions: Vec<(u32, Vec<(u32, EdgeState)>)> = Vec::new();
        for p in pairs {
            if !self.owns(p.leader) {
                continue;
            }
            let row_l = RowView::Store(self.store.row(p.leader));
            let fetched_row;
            let row_p = if self.owns(p.partner) {
                RowView::Store(self.store.row(p.partner))
            } else {
                fetched_row = &fetched[&p.partner];
                RowView::Fetched(fetched_row)
            };
            let map = compute_union_map(
                self.linkage,
                p.leader,
                p.partner,
                self.pair_weight[p.leader as usize],
                self.size[p.leader as usize],
                self.size[p.partner as usize],
                row_l,
                row_p,
                |x| PairView {
                    merging: self.matched[x as usize],
                    partner: self.partner[x as usize],
                    size: self.size[x as usize],
                    pair_weight: self.pair_weight[x as usize],
                },
            );
            unions.push((p.leader, map));
        }
        // Route patches: local ones applied below, remote ones shipped
        // now (the executed mode has no deferred-flush option — state is
        // truly sharded, so correctness needs the bytes this round).
        let mut patches: Vec<(u32, u32, u32, EdgeState)> = Vec::new();
        let mut out: Vec<Vec<Message>> = vec![Vec::new(); m];
        for (l, map) in &unions {
            let pr = self.partner[*l as usize];
            for &(t, e) in map {
                if !self.matched[t as usize] {
                    let st = self.place.machine_of(t);
                    if st == self.me {
                        patches.push((t, *l, pr, e));
                    } else {
                        out[st].push(Message::EdgePatch {
                            target: t,
                            leader: *l,
                            retired: pr,
                            weight: e.weight,
                            count: e.count,
                        });
                    }
                }
            }
        }
        for (_, batch) in self.wire.all_to_all(base + 2, out) {
            for msg in batch {
                match msg {
                    Message::EdgePatch { target, leader, retired, weight, count } => {
                        patches.push((target, leader, retired, EdgeState { weight, count }));
                    }
                    other => panic!("unexpected message in patch step: {other:?}"),
                }
            }
        }
        // Apply in (target, leader) order: per-row ascending leaders is
        // the simulation's serial order, and distinct rows commute.
        patches.sort_unstable_by_key(|&(t, l, _, _)| (t, l));
        for (t, l, pr, e) in patches {
            self.store.patch(t, l, pr, e);
        }
        // Commit the merges to the replicated scalars and owned rows.
        for p in pairs {
            let (l, pr) = (p.leader as usize, p.partner as usize);
            self.size[l] += self.size[pr];
            self.active[pr] = false;
        }
        for (l, map) in &unions {
            self.store.install_row(*l, map);
        }
        for p in pairs {
            if self.owns(p.partner) {
                self.store.clear_row(p.partner);
            }
        }
        self.store.maybe_compact();
        self.owned_active.retain(|&c| self.active[c as usize]);
        // Phase 3: rescan owned NN caches invalidated by the merges —
        // the same filter and scan as the simulation's round tail.
        let mut nn_updates = 0;
        let mut nn_scan_entries = 0;
        let updates: Vec<(u32, u32, Weight, usize)> = self
            .owned_active
            .iter()
            .filter_map(|&c| {
                let ci = c as usize;
                let v = self.nn[ci];
                let stale = self.matched[ci] || (v != NO_NN && self.matched[v as usize]);
                stale.then(|| {
                    let row = self.store.row(c);
                    let (nn, w) = scan_nn(row);
                    (c, nn, w, row.live_len())
                })
            })
            .collect();
        for (c, nn, w, scanned) in updates {
            self.nn[c as usize] = nn;
            self.nn_weight[c as usize] = w;
            nn_updates += 1;
            nn_scan_entries += scanned;
        }
        for p in pairs {
            self.matched[p.leader as usize] = false;
            self.matched[p.partner as usize] = false;
        }
        Report::RoundDone {
            nn_weights,
            nn_updates,
            nn_scan_entries,
            eligibility_scan_entries: std::mem::take(&mut self.eligibility_scan_entries),
            net: self.wire.take_stats(),
        }
    }
}

/// Machine thread body: obey driver commands until told to exit.
fn machine_main(mut mc: Machine, cmds: Receiver<Cmd>, reports: Sender<Report>) {
    loop {
        let cmd = match cmds.recv() {
            Ok(cmd) => cmd,
            // Driver gone (fault teardown or panic): die quietly.
            Err(_) => return,
        };
        match cmd {
            Cmd::Restore(blob) => mc.restore(&blob),
            Cmd::Round { round } => {
                mc.begin_round(round);
                match mc.selector {
                    DistSelector::Rnn => {
                        let pairs = mc.find_reciprocal();
                        let _ = reports.send(Report::Phase1 { pairs, synced: true });
                    }
                    DistSelector::Good { epsilon } => {
                        if let Some((pairs, synced)) = mc.find_good(epsilon, None) {
                            let _ = reports.send(Report::Phase1 { pairs, synced });
                        }
                    }
                    DistSelector::GoodBatched { epsilon, vshards } => {
                        if let Some((pairs, synced)) = mc.find_good(epsilon, Some(vshards)) {
                            let _ = reports.send(Report::Phase1 { pairs, synced });
                        }
                    }
                }
            }
            Cmd::Merge { pairs } => {
                let report = mc.merge_and_rescan(&pairs);
                let _ = reports.send(report);
            }
            Cmd::Checkpoint { round } => {
                let _ = reports.send(Report::CheckpointBlob {
                    machine: mc.me,
                    blob: mc.checkpoint(round),
                });
            }
            Cmd::Finish => {
                let _ = reports.send(Report::FinishAck {
                    eligibility_scan_entries: std::mem::take(&mut mc.eligibility_scan_entries),
                    net: mc.wire.take_stats(),
                });
                return;
            }
            Cmd::Exit => return,
        }
    }
}

/// The driver's handle on a running fleet.
struct Fleet {
    cmds: Vec<Sender<Cmd>>,
    reports: Receiver<Report>,
    handles: Vec<JoinHandle<()>>,
}

impl Fleet {
    fn send_all(&self, cmd: &Cmd) {
        for c in &self.cmds {
            let _ = c.send(cmd.clone());
        }
    }

    fn recv(&self) -> Report {
        self.reports
            .recv_timeout(REPORT_TIMEOUT)
            .expect("machine unresponsive: executed fleet wedged")
    }

    /// Tear the fleet down and reap the threads, surfacing any panic.
    fn shutdown(self) {
        for c in &self.cmds {
            let _ = c.send(Cmd::Exit);
        }
        for h in self.handles {
            if h.join().is_err() {
                panic!("executed machine thread panicked");
            }
        }
    }
}

/// Immutable per-run parameters shared by spawns and respawns.
struct FleetSpec {
    machines: usize,
    linkage: Linkage,
    place: Placement,
    selector: DistSelector,
    latency: Duration,
    jitter: Duration,
}

/// Spawn the fleet and feed every machine its state blob — recovery and
/// cold start are the same code path, so the checkpoint codec is
/// exercised by every executed run.
fn spawn_fleet(spec: &FleetSpec, blobs: &[Vec<u8>]) -> Fleet {
    let m = spec.machines;
    let (report_tx, report_rx) = mpsc::channel::<Report>();
    let data: Vec<(Sender<Packet>, Receiver<Packet>)> = (0..m).map(|_| mpsc::channel()).collect();
    let peer_senders: Vec<Sender<Packet>> = data.iter().map(|(tx, _)| tx.clone()).collect();
    let mut data_rx: Vec<Option<Receiver<Packet>>> =
        data.into_iter().map(|(_, rx)| Some(rx)).collect();
    let mut cmds = Vec::with_capacity(m);
    let mut handles = Vec::with_capacity(m);
    for me in 0..m {
        let (cmd_tx, cmd_rx) = mpsc::channel::<Cmd>();
        let machine = Machine {
            me,
            n: 0,
            linkage: spec.linkage,
            place: spec.place,
            selector: spec.selector,
            store: NeighborStore::new(0),
            owned_active: Vec::new(),
            active: Vec::new(),
            size: Vec::new(),
            nn: Vec::new(),
            nn_weight: Vec::new(),
            matched: Vec::new(),
            partner: Vec::new(),
            pair_weight: Vec::new(),
            eligibility_scan_entries: 0,
            wire: Wire {
                me,
                machines: m,
                peers: peer_senders.clone(),
                inbox: data_rx[me].take().expect("inbox taken once"),
                stash: Vec::new(),
                latency: spec.latency,
                jitter: spec.jitter,
                round: 0,
                stats: NetStats::default(),
            },
        };
        let reports = report_tx.clone();
        handles.push(std::thread::spawn(move || machine_main(machine, cmd_rx, reports)));
        let _ = cmd_tx.send(Cmd::Restore(blobs[me].clone()));
        cmds.push(cmd_tx);
    }
    Fleet {
        cmds,
        reports: report_rx,
        handles,
    }
}

/// The driver's recovery image: everything needed to roll the run back
/// to a sync point — the machines' blobs plus the driver-side outputs
/// accumulated up to that cut.
struct Snapshot {
    round: usize,
    n_active: usize,
    merges: Vec<Merge>,
    bounds: Vec<MergeBound>,
    rounds: Vec<RoundMetrics>,
    log: Vec<BatchRecord>,
    blobs: Vec<Vec<u8>>,
}

/// Run the distributed round schedule for real: thread-per-machine,
/// channel-backed wire, measured `t_exec`, sync-point checkpoints, and
/// optional fault injection + recovery. Consumes the prepared core; the
/// returned results are bitwise identical to `core.run_rounds(selector)`
/// on the dendrogram, bounds trace, and sync-point schedule.
pub(super) fn run_executed(
    core: DistCore,
    selector: DistSelector,
    opts: &ExecOptions,
) -> (RacResult, NetReport, Vec<MergeBound>) {
    let t0 = Instant::now();
    let m = core.cfg.machines;
    let n = core.n;
    if let Some(f) = opts.fault {
        assert!(
            f.machine < m,
            "fault machine {} out of range for {m} machines",
            f.machine
        );
    }
    // Initial NN scan over the full graph — identical to the simulated
    // engine's init — then cut the round-0 "checkpoint" every machine
    // boots from.
    let mut nn = vec![NO_NN; n];
    let mut nn_weight = vec![Weight::INFINITY; n];
    for c in 0..n {
        let (v, w) = scan_nn(core.store.row(c as u32));
        nn[c] = v;
        nn_weight[c] = w;
    }
    let blobs: Vec<Vec<u8>> = (0..m)
        .map(|mid| {
            let rows = (0..n as u32)
                .filter(|&c| core.place.machine_of(c) == mid)
                .map(|c| {
                    let entries =
                        core.store.row(c).iter().map(|(t, e)| (t, e.weight, e.count)).collect();
                    (c, nn[c as usize], nn_weight[c as usize], entries)
                })
                .collect();
            checkpoint::encode(&MachineCheckpoint {
                machine: mid as u32,
                machines: m as u32,
                round: 0,
                n,
                rows,
                size: core.size.clone(),
                active: core.active.clone(),
            })
        })
        .collect();
    let spec = FleetSpec {
        machines: m,
        linkage: core.linkage,
        place: core.place,
        selector,
        latency: opts.latency,
        jitter: opts.jitter,
    };
    let mut snapshot = Snapshot {
        round: 0,
        n_active: n,
        merges: Vec::new(),
        bounds: Vec::new(),
        rounds: Vec::new(),
        log: Vec::new(),
        blobs,
    };
    let mut merges: Vec<Merge> = Vec::new();
    let mut bounds: Vec<MergeBound> = Vec::new();
    let mut metrics = RunMetrics::default();
    let mut log: Vec<BatchRecord> = Vec::new();
    let mut n_active = n;
    let mut fault = opts.fault;
    let mut fleet = Some(spawn_fleet(&spec, &snapshot.blobs));
    let mut round = 0;
    while round < core.max_rounds {
        if let Some(f) = fault {
            if f.round == round {
                // Fault: machine f.machine dies at the round boundary. A
                // dead shard stalls the whole bulk-synchronous round, so
                // recovery is a global rollback — tear down, respawn,
                // restore everyone from the last sync-point cut, replay.
                fault = None;
                fleet.take().expect("fleet alive").shutdown();
                merges = snapshot.merges.clone();
                bounds = snapshot.bounds.clone();
                metrics.rounds = snapshot.rounds.clone();
                log = snapshot.log.clone();
                n_active = snapshot.n_active;
                round = snapshot.round;
                fleet = Some(spawn_fleet(&spec, &snapshot.blobs));
                continue;
            }
        }
        let fl = fleet.as_ref().expect("fleet alive");
        let t_round = Instant::now();
        fl.send_all(&Cmd::Round { round });
        // Exact rounds: every machine reports its owned pairs and the
        // driver merges them into the global ascending-leader list.
        // ε-good rounds: the coordinator reports the global matching.
        let (pairs, synced) = match selector {
            DistSelector::Rnn => {
                let mut all: Vec<MergePair> = Vec::new();
                for _ in 0..m {
                    match fl.recv() {
                        Report::Phase1 { pairs, .. } => all.extend(pairs),
                        _ => panic!("expected Phase1 report"),
                    }
                }
                all.sort_unstable_by_key(|p| p.leader);
                (all, true)
            }
            _ => match fl.recv() {
                Report::Phase1 { pairs, synced } => (pairs, synced),
                _ => panic!("expected Phase1 report"),
            },
        };
        let t_find = t_round.elapsed();
        let mut rm = RoundMetrics {
            round,
            clusters: n_active,
            merges: pairs.len(),
            sync_points: usize::from(synced),
            t_find,
            ..Default::default()
        };
        if pairs.is_empty() {
            fl.send_all(&Cmd::Finish);
            for _ in 0..m {
                match fl.recv() {
                    Report::FinishAck { eligibility_scan_entries, net } => {
                        rm.eligibility_scan_entries += eligibility_scan_entries;
                        rm.net_messages += net.messages;
                        rm.net_bytes += net.bytes;
                        log.extend(net.log);
                    }
                    _ => panic!("expected FinishAck report"),
                }
            }
            rm.t_exec = t_round.elapsed();
            metrics.rounds.push(rm);
            // Finish is a terminal command: machines have already exited.
            for h in fleet.take().expect("fleet alive").handles {
                if h.join().is_err() {
                    panic!("executed machine thread panicked");
                }
            }
            break;
        }
        let t_merge = Instant::now();
        fl.send_all(&Cmd::Merge { pairs: pairs.clone() });
        let mut pre_nn: FxHashMap<u32, u64> = FxHashMap::default();
        for _ in 0..m {
            match fl.recv() {
                Report::RoundDone {
                    nn_weights,
                    nn_updates,
                    nn_scan_entries,
                    eligibility_scan_entries,
                    net,
                } => {
                    pre_nn.extend(nn_weights);
                    rm.nn_updates += nn_updates;
                    rm.nn_scan_entries += nn_scan_entries;
                    rm.eligibility_scan_entries += eligibility_scan_entries;
                    rm.net_messages += net.messages;
                    rm.net_bytes += net.bytes;
                    log.extend(net.log);
                }
                _ => panic!("expected RoundDone report"),
            }
        }
        for p in &pairs {
            merges.push(Merge {
                a: p.leader,
                b: p.partner,
                weight: p.weight,
            });
            let wl = f64::from_bits(pre_nn[&p.leader]);
            let wp = f64::from_bits(pre_nn[&p.partner]);
            bounds.push(MergeBound {
                weight: p.weight,
                visible_min: wl.min(wp),
            });
        }
        n_active -= pairs.len();
        rm.t_merge = t_merge.elapsed();
        rm.t_exec = t_round.elapsed();
        metrics.rounds.push(rm);
        if n_active <= 1 {
            fleet.take().expect("fleet alive").shutdown();
            break;
        }
        if synced {
            // Sync point: cut a recovery image (checkpoint time is
            // deliberately outside `t_exec` — it is recovery machinery,
            // not round work).
            let fl = fleet.as_ref().expect("fleet alive");
            fl.send_all(&Cmd::Checkpoint { round: round + 1 });
            let mut cp_blobs: Vec<Vec<u8>> = vec![Vec::new(); m];
            for _ in 0..m {
                match fl.recv() {
                    Report::CheckpointBlob { machine, blob } => cp_blobs[machine] = blob,
                    _ => panic!("expected CheckpointBlob report"),
                }
            }
            snapshot = Snapshot {
                round: round + 1,
                n_active,
                merges: merges.clone(),
                bounds: bounds.clone(),
                rounds: metrics.rounds.clone(),
                log: log.clone(),
                blobs: cp_blobs,
            };
        }
        round += 1;
    }
    if let Some(fl) = fleet.take() {
        // Round cap exhausted with the fleet still up (safety valve).
        fl.shutdown();
    }
    metrics.total_time = t0.elapsed();
    log.sort_by_key(|b| (b.round, b.src, b.dst));
    (
        RacResult {
            dendrogram: Dendrogram::new(n, merges),
            metrics,
        },
        NetReport { batches: log },
        bounds,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jitter_is_deterministic_and_bounded() {
        let bound = Duration::from_micros(50);
        for (src, dst, round, step) in [(0, 1, 0, 0u8), (1, 0, 0, 0), (2, 5, 31, 4)] {
            let a = jitter_ns(src, dst, round, step, bound);
            let b = jitter_ns(src, dst, round, step, bound);
            assert_eq!(a, b, "same link+round must hash identically");
            assert!(a <= bound.as_nanos() as u64);
        }
        assert_eq!(jitter_ns(0, 1, 0, 0, Duration::ZERO), 0);
        // Direction matters: the hash must separate (src, dst) from
        // (dst, src) on at least some links.
        let diff = (0..16).any(|r| {
            jitter_ns(0, 1, r, 0, bound) != jitter_ns(1, 0, r, 0, bound)
        });
        assert!(diff, "jitter hash ignores link direction");
    }

    #[test]
    fn row_view_adapters_agree() {
        let mut store = NeighborStore::new(4);
        let row: Vec<(u32, EdgeState)> = vec![
            (2, EdgeState { weight: 0.5, count: 1 }),
            (1, EdgeState { weight: 0.25, count: 2 }),
        ];
        store.install_row(0, &row);
        let from_store = {
            let mut v = Vec::new();
            RowView::Store(store.row(0)).for_each_edge(|t, e| v.push((t, e.weight, e.count)));
            v
        };
        let from_fetched = {
            let mut v = Vec::new();
            RowView::Fetched(&row).for_each_edge(|t, e| v.push((t, e.weight, e.count)));
            v
        };
        assert_eq!(from_store, from_fetched, "adapters must iterate identically");
        assert_eq!(RowView::Store(store.row(0)).live_len(), 2);
        assert_eq!(RowView::Fetched(&row).live_len(), 2);
    }
}
